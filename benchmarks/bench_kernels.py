"""Kernel micro-bench: wall time of the Pallas kernels (interpret mode on
CPU — correctness-bearing only; the derived column reports achieved
GFLOP/s for context) vs their jnp oracles."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import autotune, ops, ref
from benchmarks.common import csv_line, emit


def _time(f, *args, reps=3):
    out = f(*args)
    jax.tree.map(lambda a: a.block_until_ready(), out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f(*args)
        jax.tree.map(lambda a: a.block_until_ready(), out)
    return (time.perf_counter() - t0) / reps


def run():
    key = jax.random.PRNGKey(0)
    rows, lines = [], []

    m = k = n = 512
    x = jax.random.normal(key, (m, k), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n), jnp.float32)
    t_kern = _time(lambda a, b: ops.matmul(a, b, block_m=128, block_n=128,
                                           block_k=128), x, w)
    t_ref = _time(lambda a, b: jax.jit(ref.matmul_ref)(a, b), x, w)
    gflops = 2 * m * k * n / t_kern / 1e9
    rows.append({"kernel": "streamed_matmul", "t_kernel_s": t_kern,
                 "t_ref_s": t_ref, "gflops": gflops})
    lines.append(csv_line("kernel[streamed_matmul_512]", t_kern * 1e6,
                          f"{gflops:.2f}GFLOP/s(interp)"))

    q = jax.random.normal(key, (4, 256, 64))
    kk = jax.random.normal(jax.random.fold_in(key, 2), (4, 256, 64))
    v = jax.random.normal(jax.random.fold_in(key, 3), (4, 256, 64))
    t_kern = _time(lambda a, b, c: ops.attention(a, b, c, block_q=128,
                                                 block_k=128), q, kk, v)
    rows.append({"kernel": "flash_attention", "t_kernel_s": t_kern})
    lines.append(csv_line("kernel[flash_attention_256]", t_kern * 1e6,
                          "interp"))

    qd = jax.random.normal(key, (8, 64))
    kc = jax.random.normal(jax.random.fold_in(key, 4), (8, 1024, 64))
    vc = jax.random.normal(jax.random.fold_in(key, 5), (8, 1024, 64))
    valid = jnp.ones((8, 1024), bool)
    t_kern = _time(lambda a, b, c, d: ops.decode(a, b, c, d, block_k=256),
                   qd, kc, vc, valid)
    rows.append({"kernel": "flash_decode", "t_kernel_s": t_kern})
    lines.append(csv_line("kernel[flash_decode_1k]", t_kern * 1e6, "interp"))

    # matmul again under the autotuned tiles (kernels/autotune.py; a
    # private cache so the bench never pollutes ~/.cache/repro) — the
    # derived column reports the winning tile so the tuned-vs-fixed
    # delta stays visible in the headline JSON
    sel = autotune.tune_matmul(m, k, n, cache=autotune.AutotuneCache(
        "/tmp/repro_bench_autotune.json"), reps=3)
    tiles = {kk2: sel[kk2] for kk2 in ("block_m", "block_n", "block_k")}
    t_tuned = _time(lambda a, b: ops.matmul(a, b, **tiles), x, w)
    rows.append({"kernel": "streamed_matmul_tuned", "t_kernel_s": t_tuned,
                 "tiles": tiles})
    lines.append(csv_line(
        "kernel[streamed_matmul_512_tuned]", t_tuned * 1e6,
        f"tiles={tiles['block_m']}x{tiles['block_n']}x{tiles['block_k']}"))

    emit(rows, "kernels")
    return lines
