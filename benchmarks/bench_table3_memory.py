"""Table III reproduction: peak memory footprints of Baseline / PipeSwitch /
PIPELOAD (2, 4, 6 agents); Ratio = M_other / M_baseline."""
from __future__ import annotations

import numpy as np

from repro.core import PipeloadEngine
from benchmarks.common import (PAPER_MODELS, csv_line, emit,
                               ensure_paper_ckpt, paper_cfg)

AGENT_COUNTS = (2, 4, 6)


def run():
    rows, lines = [], []
    rng = np.random.default_rng(0)
    for name, spec in PAPER_MODELS.items():
        cfg, full_layers = paper_cfg(name)
        ckpt = ensure_paper_ckpt(name)
        seq = 196 if name == "vit_large" else (4 if spec["gen"] else 64)
        toks = rng.integers(0, cfg.vocab_size, (1, seq))
        gen = spec["gen"]

        res = {"model": name, "depth_frac": cfg.num_layers / full_layers}

        def peak(mode, m=1):
            eng = PipeloadEngine(ckpt, cfg, mode=mode,
                                 num_agents=m).warmup(1, seq)
            if gen:
                _, st = eng.run_generate(toks, gen)
            else:
                _, st = eng.run_single(toks)
            return st.peak_bytes

        res["baseline_mb"] = peak("baseline") / 2**20
        res["pipeswitch_mb"] = peak("pipeswitch") / 2**20
        for m in AGENT_COUNTS:
            res[f"pipeload{m}_mb"] = peak("pipeload", m) / 2**20
        for k in ("pipeswitch_mb", *(f"pipeload{m}_mb"
                                     for m in AGENT_COUNTS)):
            res[k.replace("_mb", "_ratio")] = res[k] / res["baseline_mb"]
        rows.append(res)
        lines.append(csv_line(
            f"table3_memory[{name}]", 0.0,
            f"pipeload2_ratio={res['pipeload2_ratio']:.3f}"))
    emit(rows, "table3_memory")
    return lines
