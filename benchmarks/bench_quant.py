"""Beyond-paper: quantized weight streaming (int8/int4 PIPELOAD shards)
vs. full precision on the GPT-2 KV-decode workload.

Two memory regimes per dtype, everything else held fixed:

  * ``roomy``  — one shared budget sized so even fp32 can pin the whole
    stack.  The planner does exactly that for every dtype, so the
    measured ledger peak IS each dtype's full-resident envelope and the
    prefill round streams every shard once: the bytes-streamed and
    peak-bytes columns are the ~4x (int8) / ~8x (int4) shard shrinkage,
    measured end to end through the engine.
  * ``tight``  — one shared budget a few fp32 layers above the fp32
    decode floor.  fp32 must re-stream most of the stack every decode
    round; int8/int4 pin everything inside the same budget and decode
    from memory — the tokens/s column is the edge-regime win.

Accuracy rides along: per-dtype last-token logits (vs. fp32) and greedy
token agreement land in every row — the trade-off table in
docs/quantization.md is generated from this output.
"""
from __future__ import annotations

import numpy as np

from repro.core import Hermes, PipeloadEngine
from benchmarks.common import csv_line, emit, ensure_paper_ckpt, paper_cfg

MODEL = "gpt2_base"
PROMPT_LEN = 64
NEW_TOKENS = 8
AGENTS = 4
DTYPES = ("fp32", "int8", "int4")


def run():
    cfg, full_layers = paper_cfg(MODEL)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (1, PROMPT_LEN))
    total = PROMPT_LEN + NEW_TOKENS

    ckpts = {d: ensure_paper_ckpt(MODEL, None if d == "fp32" else d)
             for d in DTYPES}
    hermes = {d: Hermes(ckpts[d], cfg) for d in DTYPES}
    profiles = {d: hermes[d].profile(batch=1, seq=PROMPT_LEN)
                for d in DTYPES}

    # shared budgets, both sized off the fp32 profile (same budget for
    # every dtype — the quantized runs just need less of it)
    p32 = profiles["fp32"]
    n, lb32, other32 = p32["num_layers"], p32["layer_bytes"], \
        p32["other_bytes"]
    cache_total = n * cfg.cache_bytes(1, total)
    budgets = {
        "roomy": other32 + cache_total + (n + 2) * lb32,
        "tight": other32 + cache_total + 3 * lb32,
    }

    rows, lines = [], []
    fp32_logits = None
    fp32_tokens = {}

    for dtype in DTYPES:
        # one full forward for the accuracy columns (streams each shard
        # once; unbudgeted so it never interferes with the timed runs)
        eng = PipeloadEngine(ckpts[dtype], cfg, mode="pipeload",
                             num_agents=AGENTS)
        eng.warmup(1, PROMPT_LEN)
        logits, _ = eng.run_single(toks)
        logits = np.asarray(logits)
        if dtype == "fp32":
            fp32_logits = logits
        logit_err = float(np.abs(logits - fp32_logits).max())
        logit_rel = logit_err / float(np.abs(fp32_logits).max())
        del eng

        for regime, budget in budgets.items():
            g = hermes[dtype].plan_generate(
                [budget], batch=1, prompt_len=PROMPT_LEN,
                new_tokens=NEW_TOKENS, max_agents=AGENTS)[0]
            eng = PipeloadEngine(
                ckpts[dtype], cfg, mode="pipeload",
                num_agents=g.num_agents, pin_window=g.pin_window,
                budget_bytes=budget if g.feasible else None)
            eng.warmup(1, PROMPT_LEN, decode=True, total_len=total)
            out, stats = eng.run_generate(toks, NEW_TOKENS, kv_cache=True)
            out = np.asarray(out)[:, PROMPT_LEN:]
            if dtype == "fp32":
                fp32_tokens[regime] = out
            agree = float((out == fp32_tokens[regime]).mean())
            rows.append({
                "model": MODEL, "depth_frac": cfg.num_layers / full_layers,
                "prompt_len": PROMPT_LEN, "new_tokens": NEW_TOKENS,
                "dtype": dtype, "regime": regime,
                "budget_bytes": budget, "feasible": g.feasible,
                "num_agents": g.num_agents, "pin_window": g.pin_window,
                "latency_s": stats.latency_s,
                "per_token_s": stats.per_token_s,
                "prefill_s": stats.prefill_s, "decode_s": stats.decode_s,
                "peak_bytes": stats.peak_bytes,
                "streamed_bytes": stats.streamed_bytes,
                "cache_bytes": stats.cache_bytes, "loads": stats.loads,
                "within_budget": stats.peak_bytes <= budget,
                "planner_peak_bytes": g.predicted_peak_bytes,
                "logit_max_abs_err_vs_fp32": logit_err,
                "logit_max_rel_err_vs_fp32": logit_rel,
                "token_agreement_vs_fp32": agree,
            })
            del eng

    emit(rows, "quant")

    def row(dtype, regime):
        return next(r for r in rows
                    if r["dtype"] == dtype and r["regime"] == regime)

    base_roomy, base_tight = row("fp32", "roomy"), row("fp32", "tight")
    for dtype in DTYPES:
        roomy, tight = row(dtype, "roomy"), row(dtype, "tight")
        lines.append(csv_line(
            f"quant[{dtype}]", tight["per_token_s"] * 1e6,
            f"streamed_reduction_x="
            f"{base_roomy['streamed_bytes'] / roomy['streamed_bytes']:.2f},"
            f"peak_reduction_x="
            f"{base_roomy['peak_bytes'] / roomy['peak_bytes']:.2f},"
            f"tight_tok_s={1.0 / tight['per_token_s']:.1f}"
            f"_vs_{1.0 / base_tight['per_token_s']:.1f}_fp32,"
            f"within_budget={tight['within_budget']},"
            f"logit_rel_err={tight['logit_max_rel_err_vs_fp32']:.3f},"
            f"tok_agree={tight['token_agreement_vs_fp32']:.2f}"))
    return lines
