"""Beyond-paper: KV-cache incremental decode vs. the paper's per-token
re-prefill engine (§V-B2) on a GPT-style workload.

Four generation paths over the same checkpoint and prompt:

  * ``baseline``     — whole model resident, per-token re-prefill.
  * ``pipeswitch``   — pipelined load, no destruction, re-prefill.
  * ``pipeload``     — the paper's engine: full load+prefix pipeline
                       re-runs for EVERY token.
  * ``pipeload+kv``  — ONE cache-capturing prefill, then single-token
                       decode rounds; (num_agents, pin_window) come from
                       the generation-aware planner and cache bytes are
                       charged against the same budget as weights.

Reports per-token latency and peak resident bytes (weights + KV pages),
plus the planner's predicted peak so budget honesty is visible in the
emitted JSON (``experiments/bench/decode.json``).
"""
from __future__ import annotations

import numpy as np

from repro.core import Hermes, PipeloadEngine
from benchmarks.common import csv_line, emit, ensure_paper_ckpt, paper_cfg

MODEL = "gpt2_base"
PROMPT_LEN = 64
NEW_TOKENS = 8
AGENTS = 4


def run():
    cfg, full_layers = paper_cfg(MODEL)
    ckpt = ensure_paper_ckpt(MODEL)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (1, PROMPT_LEN))
    total = PROMPT_LEN + NEW_TOKENS

    hermes = Hermes(ckpt, cfg)
    hermes.profile(batch=1, seq=PROMPT_LEN)

    rows, lines = [], []

    def record(label, stats, budget=None, predicted_peak=None):
        row = {
            "model": MODEL, "depth_frac": cfg.num_layers / full_layers,
            "prompt_len": PROMPT_LEN, "new_tokens": NEW_TOKENS,
            "path": label, "latency_s": stats.latency_s,
            "per_token_s": stats.per_token_s,
            "prefill_s": stats.prefill_s, "decode_s": stats.decode_s,
            "peak_bytes": stats.peak_bytes, "cache_bytes": stats.cache_bytes,
            "loads": stats.loads,
        }
        if budget is not None:
            row["budget_bytes"] = budget
            row["within_budget"] = stats.peak_bytes <= budget
        if predicted_peak is not None:
            row["planner_peak_bytes"] = predicted_peak
        rows.append(row)
        return row

    for mode in ("baseline", "pipeswitch", "pipeload"):
        agents = AGENTS if mode == "pipeload" else 1
        eng = PipeloadEngine(ckpt, cfg, mode=mode, num_agents=agents)
        eng.warmup(1, PROMPT_LEN)
        _, stats = eng.run_generate(toks, NEW_TOKENS)
        record(mode, stats)
        del eng

    # budget the KV run to the re-prefill pipeload's measured peak: same
    # memory envelope, so any speedup is pure cache-aware decoding.  A
    # second, unbudgeted run shows the planner trading memory (pin the
    # whole stack) for per-token speed.
    reprefill = next(r for r in rows if r["path"] == "pipeload")
    kv = None
    for budget in (reprefill["peak_bytes"], None):
        gplan = hermes.plan_generate([budget], batch=1,
                                     prompt_len=PROMPT_LEN,
                                     new_tokens=NEW_TOKENS,
                                     max_agents=AGENTS)[0]
        eng = PipeloadEngine(
            ckpt, cfg, mode="pipeload", num_agents=gplan.num_agents,
            pin_window=gplan.pin_window,
            budget_bytes=budget if gplan.feasible else None)
        eng.warmup(1, PROMPT_LEN, decode=True, total_len=total)
        _, stats = eng.run_generate(toks, NEW_TOKENS, kv_cache=True)
        tag = "budgeted" if budget is not None else "unbudgeted"
        row = record(
            f"pipeload+kv[{tag},m={gplan.num_agents},"
            f"pin={gplan.pin_window}]",
            stats, budget=budget, predicted_peak=gplan.predicted_peak_bytes)
        if budget is not None:
            kv = row
        del eng

    emit(rows, "decode")
    lines.append(csv_line(
        "decode[pipeload_reprefill]", reprefill["per_token_s"] * 1e6,
        f"peak_mb={reprefill['peak_bytes'] / 2**20:.0f}"))
    lines.append(csv_line(
        "decode[pipeload_kv]", kv["per_token_s"] * 1e6,
        f"speedup_vs_reprefill="
        f"{reprefill['per_token_s'] / kv['per_token_s']:.2f},"
        f"peak_mb={kv['peak_bytes'] / 2**20:.0f},"
        f"within_budget={kv['within_budget']},"
        f"cache_mb={kv['cache_bytes'] / 2**20:.1f}"))
    return lines
