"""Expert-streaming PIPELOAD vs whole-layer MoE streaming (beyond-paper).

One 128-expert top-8 MoE stack (the qwen3-moe routing shape at smoke
dims), one shared memory budget, two checkpoint layouts of the SAME
weights:

  * ``whole`` — the paper's layer shards: every decode round re-streams
    each layer's full FFN, all 128 experts, even though top-8 routing
    touches ~6% of them.
  * ``split`` — expert-split shards (attention+router per layer + one
    shard per expert): attention+router stream eagerly, the round's
    activated expert union is demand-loaded after the router runs, and
    the LRU ExpertCache (sized from the same budget's headroom) turns
    repeat activations into disk-free hits.

Both engines run the identical KV-cache generation workload with
``pin_window=0`` so every round pays its layer stream — the measured
decode-phase bytes-per-round ratio is pure routing sparsity.  Outputs
are token-identical (the streamed combine is the oracle's math over the
activated experts), reported as ``tok_agree``.
"""
from __future__ import annotations

import numpy as np

from repro.configs import get_config
from repro.core import PipeloadEngine
from repro.checkpoint import load_manifest, partition_and_save
from repro.models.api import build_model
from benchmarks.common import CKPT_ROOT, csv_line, emit

import jax

PROMPT_LEN = 32
NEW_TOKENS = 8
AGENTS = 4
LAYERS = 4
N_EXPERTS, TOP_K = 128, 8


def _config():
    cfg = get_config("qwen3_moe_30b_a3b").reduced()
    return cfg.with_(name="qwen3-moe-smoke-128e", num_layers=LAYERS,
                     n_experts=N_EXPERTS, top_k=TOP_K, expert_d_ff=32)


def ensure_ckpts(cfg):
    paths = {"whole": CKPT_ROOT / "moe_stream_whole",
             "split": CKPT_ROOT / "moe_stream_split"}
    if not all((p / "manifest.json").exists() for p in paths.values()):
        params = build_model(cfg).init(jax.random.PRNGKey(0))
        partition_and_save(params, cfg, paths["whole"], expert_split=False)
        partition_and_save(params, cfg, paths["split"], expert_split=True)
        del params
    return paths


def _decode_bytes(stats, shards) -> int:
    """Disk bytes read during the decode phase (after the first sampled
    token; the prefill round's loads are excluded)."""
    token_ts = [e[0] for e in stats.events if e[1] == "token"]
    if not token_ts:
        return 0
    t_dec = min(token_ts)
    return sum(shards[e[2]]["bytes"] for e in stats.events
               if e[1] == "load_end" and e[0] >= t_dec)


def run():
    cfg = _config()
    paths = ensure_ckpts(cfg)
    manifests = {k: load_manifest(p) for k, p in paths.items()}
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (1, PROMPT_LEN))
    total = PROMPT_LEN + NEW_TOKENS

    # one shared budget, sized off the WHOLE-layer manifest so the dense
    # baseline can stream: other + KV pages + 2.5 full layers of headroom
    man_w = manifests["whole"]
    other = sum(s["bytes"] for s in man_w["shards"]
                if s["kind"] != "layer")
    lb = max(s["bytes"] for s in man_w["shards"] if s["kind"] == "layer")
    kv = cfg.num_layers * cfg.cache_bytes(1, total)
    budget = other + kv + int(2.5 * lb)

    rows, outs = [], {}
    dec_rounds = NEW_TOKENS - 1
    for layout, path in paths.items():
        eng = PipeloadEngine(path, cfg, mode="pipeload", num_agents=AGENTS,
                             pin_window=0, budget_bytes=budget)
        eng.warmup(1, PROMPT_LEN, decode=True, total_len=total)
        out, stats = eng.run_generate(toks, NEW_TOKENS, kv_cache=True)
        outs[layout] = np.asarray(out)[:, PROMPT_LEN:]
        rows.append({
            "model": cfg.name, "layout": layout,
            "n_experts": cfg.n_experts, "top_k": cfg.top_k,
            "num_layers": cfg.num_layers,
            "prompt_len": PROMPT_LEN, "new_tokens": NEW_TOKENS,
            "budget_bytes": budget, "num_agents": AGENTS,
            "latency_s": stats.latency_s, "per_token_s": stats.per_token_s,
            "peak_bytes": stats.peak_bytes,
            "within_budget": stats.peak_bytes <= budget,
            "streamed_bytes": stats.streamed_bytes,
            "decode_streamed_bytes": _decode_bytes(stats, eng.shards),
            "decode_bytes_per_round":
                _decode_bytes(stats, eng.shards) / dec_rounds,
            "loads": stats.loads,
            "expert_hits": stats.expert_hits,
            "expert_misses": stats.expert_misses,
            "expert_evictions": stats.expert_evictions,
            "expert_hit_rate": stats.expert_hit_rate,
            "expert_cache_bytes": stats.expert_cache_bytes,
            "unique_experts_per_round": stats.unique_experts_per_round,
        })
        del eng

    agree = float((outs["split"] == outs["whole"]).mean())
    for r in rows:
        r["token_agreement"] = agree
    emit(rows, "moe")

    whole = next(r for r in rows if r["layout"] == "whole")
    split = next(r for r in rows if r["layout"] == "split")
    reduction = (whole["decode_bytes_per_round"]
                 / max(split["decode_bytes_per_round"], 1))
    lines = [
        csv_line("moe[whole]", whole["per_token_s"] * 1e6,
                 f"decode_MB_per_round="
                 f"{whole['decode_bytes_per_round']/2**20:.2f},"
                 f"within_budget={whole['within_budget']}"),
        csv_line("moe[split]", split["per_token_s"] * 1e6,
                 f"decode_bytes_per_round_reduction_x={reduction:.2f},"
                 f"decode_MB_per_round="
                 f"{split['decode_bytes_per_round']/2**20:.2f},"
                 f"expert_hit_rate={split['expert_hit_rate']:.2f},"
                 f"unique_experts_per_round="
                 f"{split['unique_experts_per_round']:.1f},"
                 f"tok_agree={agree:.2f},"
                 f"within_budget={split['within_budget']}"),
    ]
    return lines
