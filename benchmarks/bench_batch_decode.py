"""Continuous batching: aggregate tokens/s and peak bytes vs. in-flight
count, against sequential single-request serving.

For each in-flight count R the same R requests are served two ways under
the SAME memory budget (sized by the planner for R concurrent caches):

  * ``sequential`` — R consecutive ``run_generate(kv_cache=True)`` calls,
    one weight stream per request per round (how the pre-scheduler engine
    would serve a queue).
  * ``batched``    — the continuous-batching scheduler: each PIPELOAD
    round streams every layer once and applies it to all R stacked
    requests (ragged positions), so the dominant weight-stream cost is
    amortised R ways.

Reports aggregate tokens/s, speedup, ledger peak (weights + all KV
pages) and shard-load counts per arm (``experiments/bench/
batch_decode.json``).  The acceptance check is ``speedup >= 2`` at R=4
with ``within_budget == true`` on the batched arm.
"""
from __future__ import annotations

import time

import numpy as np

from repro.checkpoint import load_manifest
from repro.core import BatchScheduler, PipeloadEngine
from benchmarks.common import csv_line, emit, ensure_paper_ckpt, paper_cfg

MODEL = "gpt2_base"
PROMPT_LEN = 32
NEW_TOKENS = 8
INFLIGHTS = (1, 2, 4)
AGENTS = 4


def run():
    cfg, full_layers = paper_cfg(MODEL)
    ckpt = ensure_paper_ckpt(MODEL)
    man = load_manifest(ckpt)
    layer_b = man["layer_bytes"] // cfg.num_layers
    other = man["total_bytes"] - man["layer_bytes"]
    total = PROMPT_LEN + NEW_TOKENS
    per_req_cache = cfg.num_layers * cfg.cache_bytes(1, total)

    rows, lines = [], []
    for r in INFLIGHTS:
        # one budget for both arms: R concurrent caches + streaming room
        budget = other + r * per_req_cache + (AGENTS + 2) * layer_b
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, cfg.vocab_size, (PROMPT_LEN,))
                   for _ in range(r)]

        # ---- sequential arm: R independent single-request runs
        eng = PipeloadEngine(ckpt, cfg, mode="pipeload", num_agents=AGENTS,
                             budget_bytes=budget)
        eng.warmup(1, PROMPT_LEN, decode=True, total_len=total)
        seq_s, seq_loads, seq_peak = 0.0, 0, 0
        outs_seq = []
        for p in prompts:
            t0 = time.perf_counter()
            out, st = eng.run_generate(p[None], NEW_TOKENS, kv_cache=True)
            seq_s += time.perf_counter() - t0
            seq_loads += st.loads
            seq_peak = max(seq_peak, st.peak_bytes)
            outs_seq.append(np.asarray(out)[0])
        del eng

        # ---- batched arm: one scheduler, everyone arrives at once
        eng = PipeloadEngine(ckpt, cfg, mode="pipeload", num_agents=AGENTS,
                             budget_bytes=budget)
        sched = BatchScheduler(eng, max_inflight=r, max_total_len=total)
        sched.warmup(prompt_lens=[PROMPT_LEN])
        rids = [sched.submit(p, NEW_TOKENS) for p in prompts]
        t0 = time.perf_counter()
        outs, st = sched.run()
        bat_s = time.perf_counter() - t0
        del eng, sched

        tokens = r * NEW_TOKENS
        identical = all(np.array_equal(outs[rid], ref)
                        for rid, ref in zip(rids, outs_seq))
        row = {
            "model": MODEL, "depth_frac": cfg.num_layers / full_layers,
            "prompt_len": PROMPT_LEN, "new_tokens": NEW_TOKENS,
            "inflight": r, "budget_bytes": budget,
            "seq_latency_s": seq_s, "seq_tokens_per_s": tokens / seq_s,
            "seq_loads": seq_loads, "seq_peak_bytes": seq_peak,
            "batch_latency_s": bat_s,
            "batch_tokens_per_s": tokens / bat_s,
            "batch_loads": st.loads, "batch_peak_bytes": st.peak_bytes,
            "batch_rounds": st.rounds,
            "speedup": seq_s / bat_s,
            "within_budget": st.peak_bytes <= budget,
            "tokens_identical": identical,
        }
        rows.append(row)
        lines.append(csv_line(
            f"batch_decode[inflight={r}]",
            bat_s / tokens * 1e6,
            f"speedup_vs_sequential={row['speedup']:.2f},"
            f"tok_s={row['batch_tokens_per_s']:.1f},"
            f"peak_mb={st.peak_bytes/2**20:.0f},"
            f"within_budget={row['within_budget']},"
            f"loads={st.loads}_vs_{seq_loads},"
            f"identical={identical}"))

    emit(rows, "batch_decode")
    return lines
