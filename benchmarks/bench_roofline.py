"""Roofline summary bench: aggregates the committed dry-run artifacts
(experiments/dryrun/*.json) into the per-(arch x shape) roofline table."""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import ROOT, csv_line, emit

DRYRUN = ROOT / "experiments" / "dryrun"


def run():
    rows, lines = [], []
    if not DRYRUN.exists():
        return [csv_line("roofline[missing]", 0.0,
                         "run repro.launch.dryrun first")]
    for f in sorted(DRYRUN.glob("*__pod.json")):
        d = json.loads(f.read_text())
        if d.get("status") != "ok":
            continue
        r = d["roofline"]
        rows.append({
            "arch": d["arch"], "shape": d["shape"],
            "dominant": r["dominant"],
            "compute_ms": r["compute_s"] * 1e3,
            "memory_ms": r["memory_s"] * 1e3,
            "collective_ms": r["collective_s"] * 1e3,
            "useful_flops_ratio": d["useful_flops_ratio"],
            "fits_hbm": d["memory"]["fits_hbm"],
            "gib_per_chip": d["memory"]["per_chip_bytes"] / 2**30,
        })
        lines.append(csv_line(
            f"roofline[{d['arch']}|{d['shape']}]", r["bound_s"] * 1e6,
            f"dominant={r['dominant']};useful={d['useful_flops_ratio']:.2f}"))
    emit(rows, "roofline_summary")
    return lines
