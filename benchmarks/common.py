"""Shared benchmark utilities: paper-model checkpoints + result emission."""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.checkpoint import (ensure_quantized, load_manifest,
                              partition_and_save)
from repro.configs import get_config, list_paper_models
from repro.models.api import build_model

ROOT = Path(__file__).resolve().parents[1]
BENCH_DIR = ROOT / "experiments" / "bench"
CKPT_ROOT = Path("/tmp/repro_bench_ckpts")

# Paper workloads (Table I), derived from the config registry: encoder
# models (BERT / ViT) run single-pass, causal decoders generate 8
# tokens.  Oversized decoders use a reduced-DEPTH clone (GPT-J: 6 of 28
# layers): per-layer bytes/latencies are exact, totals extrapolate by
# depth — recorded in every emitted row as depth_frac.
_DEPTH_CAP = {"gpt_j": 6}


def _paper_models():
    table = {}
    for name in list_paper_models():
        cfg = get_config(name)
        table[name] = {
            "layers": _DEPTH_CAP.get(name, cfg.num_layers),
            "gen": 8 if cfg.causal else 0,
        }
    return table


PAPER_MODELS = _paper_models()


def paper_cfg(name: str):
    spec = PAPER_MODELS[name]
    cfg = get_config(name)
    full_layers = cfg.num_layers
    if spec["layers"] != full_layers:
        cfg = cfg.with_(num_layers=spec["layers"])
    return cfg, full_layers


def ensure_paper_ckpt(name: str, quant: str | None = None) -> Path:
    cfg, _ = paper_cfg(name)
    path = CKPT_ROOT / name
    if not (path / "manifest.json").exists():
        api = build_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        partition_and_save(params, cfg, path)
        del params
    if quant:
        return ensure_quantized(path, CKPT_ROOT / f"{name}-{quant}", quant)
    return path


def emit(rows, name: str):
    BENCH_DIR.mkdir(parents=True, exist_ok=True)
    (BENCH_DIR / f"{name}.json").write_text(
        json.dumps(rows, indent=1, default=float))


def csv_line(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
