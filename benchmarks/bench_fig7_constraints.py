"""Fig. 7 reproduction: latency and optimal Loading-Agent count under
different memory constraints (planner sweep + engine validation runs)."""
from __future__ import annotations

import numpy as np

from repro.core import Hermes
from benchmarks.common import (PAPER_MODELS, csv_line, emit,
                               ensure_paper_ckpt, paper_cfg)


def run():
    rows, lines = [], []
    rng = np.random.default_rng(0)
    for name, spec in PAPER_MODELS.items():
        cfg, _ = paper_cfg(name)
        ckpt = ensure_paper_ckpt(name)
        h = Hermes(ckpt, cfg)
        prof = h.profile()
        lb, other = prof["layer_bytes"], prof["other_bytes"]
        budgets = [other + k * lb for k in (3, 5, 8, 12)]
        entries = h.plan(budgets, max_agents=8)
        seq = 196 if name == "vit_large" else (4 if spec["gen"] else 64)
        toks = rng.integers(0, cfg.vocab_size, (1, seq))
        for budget, e in zip(budgets, entries):
            eng = h.engine(mode="pipeload", budget_bytes=budget,
                           num_agents=e.num_agents).warmup(1, seq)
            if spec["gen"]:
                _, st = eng.run_generate(toks, spec["gen"])
            else:
                _, st = eng.run_single(toks)
            rows.append({"model": name, "budget_mb": budget / 2**20,
                         "agents": e.num_agents,
                         "predicted_s": e.predicted_latency_s,
                         "measured_s": st.latency_s,
                         "peak_mb": st.peak_bytes / 2**20,
                         "within_budget": bool(st.peak_bytes <= budget)})
        lines.append(csv_line(
            f"fig7_constraints[{name}]", rows[-1]["measured_s"] * 1e6,
            f"agents@largest_budget={rows[-1]['agents']}"))
    emit(rows, "fig7_constraints")
    return lines
