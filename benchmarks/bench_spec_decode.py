"""Speculative decoding vs plain paged decode, one budget (PR 6).

Single-request decode is PIPELOAD's worst regime: every generated token
pays a full weight stream (all non-pinned layers through the Loading
Agents) to compute ONE token.  Speculative decoding amortises exactly
that: a draft proposes ``DEPTH`` tokens, the target scores the whole
window ``[last committed, d_1..d_k]`` in ONE stacked verify round over
the paged KV block tables (kernels/paged_decode.py), and the accepted
prefix plus the target's bonus token commit together — up to
``DEPTH + 1`` tokens per weight stream.

Both arms run the SAME engine, checkpoint, page size and memory budget:

  * ``plain`` — non-speculative paged KV decode (PR 5 path): one token
    per pipeline round.
  * ``spec``  — ``run_generate(speculative=...)`` with the draft set to
    the TARGET ITSELF (self-speculation).  Acceptance is then exactly
    1.0 — the documented DEGENERATE CEILING: it isolates the round
    amortisation (what the verify machinery buys at a given acceptance
    rate) from draft quality, which is a model-selection question, not
    an engine one.  Real drafts land between this ceiling and the
    plain arm; the planner's acceptance-rate model interpolates.

The acceptance check is ``speedup >= 2.0`` (single-request decode
tokens/s) with BOTH arms inside the same budget and
``tok_agree == 1.0`` — speculative greedy output is bitwise identical
to plain paged decode (rejected suffixes roll back by refcount, never
by copy).  Results land in ``experiments/bench/spec.json``; run.py
writes the headline to repo-root ``BENCH_spec.json``.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.checkpoint import load_manifest, partition_and_save
from repro.configs import get_config
from repro.core import PipeloadEngine
from repro.core.engine import SpecConfig
from repro.models.api import build_model
from benchmarks.common import CKPT_ROOT, csv_line, emit

PROMPT_LEN = 32
NEW_TOKENS = 64
PAGE = 16
DEPTHS = (2, 4)             # headline = deepest window
AGENTS = 4


def _cfg():
    return get_config("gpt2_base").with_(
        name="gpt2-specbench", num_layers=8, d_model=256, n_heads=8,
        n_kv_heads=8, head_dim=32, d_ff=1024, vocab_size=2000,
        vocab_pad_to=8, dtype="float32", remat=False)


def _ckpt(cfg):
    path = CKPT_ROOT / "gpt2_specbench"
    if not (path / "manifest.json").exists():
        api = build_model(cfg)
        partition_and_save(api.init(jax.random.PRNGKey(0)), cfg, path)
    return path


def _gen(ckpt, cfg, prompt, budget, spec):
    eng = PipeloadEngine(ckpt, cfg, mode="pipeload", num_agents=AGENTS,
                         budget_bytes=budget, page_size=PAGE)
    # untimed short run compiles every executable the timed run needs
    # (prefill, decode/verify, draft chain) so the clock sees rounds,
    # not jit
    eng.run_generate(prompt, 2, kv_cache=True, speculative=spec)
    t0 = time.perf_counter()
    out, st = eng.run_generate(prompt, NEW_TOKENS, kv_cache=True,
                               speculative=spec)
    dt = time.perf_counter() - t0
    del eng
    return np.asarray(out), st, dt


def run():
    cfg = _cfg()
    ckpt = _ckpt(cfg)
    man = load_manifest(ckpt)
    # one budget for every arm, sized for the SPEC floor: the self-draft
    # pins the whole model next to the streamed layers, its dense cache
    # row, and the paged pool + verify-window overhang
    total = PROMPT_LEN + NEW_TOKENS
    cache = cfg.num_layers * cfg.cache_bytes(1, total + max(DEPTHS) + 1)
    budget = 2 * man["total_bytes"] + 3 * cache

    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, (1, PROMPT_LEN))

    base_out, base_st, base_s = _gen(ckpt, cfg, prompt, budget, None)
    rows, lines = [], []
    for depth in DEPTHS:
        spec = SpecConfig(ckpt, cfg, depth=depth)   # self-speculation
        out, st, dt = _gen(ckpt, cfg, prompt, budget, spec)
        agree = float(np.array_equal(out, base_out))
        speedup = base_s / dt
        within = (st.peak_bytes <= budget
                  and base_st.peak_bytes <= budget)
        rows.append({
            "model": cfg.name, "prompt_len": PROMPT_LEN,
            "new_tokens": NEW_TOKENS, "page_size": PAGE,
            "spec_depth": depth, "budget_bytes": budget,
            "plain_latency_s": base_s,
            "plain_tokens_per_s": NEW_TOKENS / base_s,
            "plain_peak_bytes": base_st.peak_bytes,
            "plain_loads": base_st.loads,
            "spec_latency_s": dt,
            "spec_tokens_per_s": NEW_TOKENS / dt,
            "spec_peak_bytes": st.peak_bytes,
            "spec_loads": st.loads,
            "spec_rounds": st.spec_rounds,
            "acceptance_rate": st.acceptance_rate,
            "speedup": speedup,
            "within_budget": within,
            "tok_agree": agree,
        })
        lines.append(csv_line(
            f"spec[depth={depth} page={PAGE}]",
            dt / NEW_TOKENS * 1e6,
            f"speedup_vs_plain={speedup:.2f},"
            f"tok_s={NEW_TOKENS / dt:.1f},"
            f"plain_tok_s={NEW_TOKENS / base_s:.1f},"
            f"rounds={st.spec_rounds}_vs_{NEW_TOKENS},"
            f"acceptance={st.acceptance_rate:.2f},"
            f"within_budget={within},"
            f"tok_agree={agree:.2f}"))
    emit(rows, "spec")
    return lines
