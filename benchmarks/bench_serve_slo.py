"""SLO serving tier vs FIFO baseline on one seeded multi-tenant trace.

Both arms replay the SAME ``repro.data.traces`` trace (heavy-tailed
prompts, Zipf tenant mix, priority classes 0..2) through the
continuous-batching scheduler at the SAME concurrency:

  * ``fifo``  — the pre-serving-tier policy: priorities flattened to 0,
    monolithic prefill, round-boundary FIFO admission.
  * ``slo``   — the serving tier: priority classes (a high-priority
    arrival may preempt the lowest-priority/youngest in-flight
    request), chunked prefill (long prompts join decode rounds in
    page-aligned chunks), per-tenant prefix namespaces.

The headline is **p99 TTFT of the SLO classes** (priority >= 1 — the
latency-sensitive traffic the tier exists for) on the deterministic
ROUND clock, plus goodput-under-SLO (tokens from requests meeting
``SLO_TTFT_ROUNDS``) for the whole fleet.  Priority admission moves
queueing delay from the SLO classes onto best-effort traffic, so the
class p99 must IMPROVE (``p99_ttft_improvement > 1``) while every
request's outputs stay token-identical across arms (preemption
re-prefill is bitwise stable; ``tok_agree == 1.0``).  Results land in
``experiments/bench/serve_slo.json``; run.py writes the headline to
repo-root ``BENCH_serve_slo.json``.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.checkpoint import partition_and_save
from repro.configs import get_config
from repro.core import BatchScheduler, PipeloadEngine
from repro.core.scheduler import SLO
from repro.data.traces import make_trace, submit_trace, trace_max_len
from repro.models.api import build_model
from benchmarks.common import CKPT_ROOT, csv_line, emit

REQUESTS = 16
TENANTS = 2
SEED = 5
PAGE = 8
CHUNK = 16                  # prompts beyond this prefill in chunks
MAX_INFLIGHT = 2            # slot pressure -> real queueing delay
SLO_TTFT_ROUNDS = 16        # goodput counts requests first-tokened by here
AGENTS = 2


def _cfg():
    return get_config("gpt2_base").with_(
        name="gpt2-slobench", num_layers=4, d_model=128, n_heads=4,
        n_kv_heads=4, head_dim=32, d_ff=512, vocab_size=1000,
        vocab_pad_to=8, dtype="float32", remat=False)


def _ckpt(cfg):
    path = CKPT_ROOT / "gpt2_slobench"
    if not (path / "manifest.json").exists():
        api = build_model(cfg)
        partition_and_save(api.init(jax.random.PRNGKey(0)), cfg, path)
    return path


def _trace(vocab):
    return make_trace(REQUESTS, tenants=TENANTS, seed=SEED, vocab=vocab,
                      arrival_rate=3.0, prompt_mean=16, max_prompt=40,
                      new_mean=4, max_new=8, prefix_len=16,
                      share_prefix=0.5)


def _serve(ckpt, cfg, trace, max_total, *, priorities, chunk):
    eng = PipeloadEngine(ckpt, cfg, mode="pipeload", num_agents=AGENTS,
                         page_size=PAGE)
    sched = BatchScheduler(eng, max_inflight=MAX_INFLIGHT,
                           max_total_len=max_total, page_size=PAGE,
                           chunk_prefill=chunk,
                           slo=SLO(ttft_rounds=SLO_TTFT_ROUNDS))
    rids = submit_trace(sched, trace, priorities=priorities)
    t0 = time.perf_counter()
    outs, st = sched.run()
    dt = time.perf_counter() - t0
    ttft = {t.rid: (sched.done[rids[t.rid]].first_token_round
                    - sched.done[rids[t.rid]].born_round + 1)
            for t in trace}
    ttft_s = {t.rid: (sched.done[rids[t.rid]].t_first
                      - sched.done[rids[t.rid]].t_arrival)
              for t in trace}
    del eng, sched
    return rids, outs, st, dt, ttft, ttft_s


def _p99(xs):
    return float(np.percentile(np.asarray(xs, float), 99)) if xs else 0.0


def run():
    cfg = _cfg()
    ckpt = _ckpt(cfg)
    trace = _trace(cfg.vocab_size)
    max_total = trace_max_len(trace) + PAGE

    f_rids, f_outs, f_st, f_s, f_ttft, f_ttft_s = _serve(
        ckpt, cfg, trace, max_total, priorities=False, chunk=0)
    s_rids, s_outs, s_st, s_s, s_ttft, s_ttft_s = _serve(
        ckpt, cfg, trace, max_total, priorities=True, chunk=CHUNK)

    hi = [t.rid for t in trace if t.priority >= 1]   # the SLO classes
    agree = np.mean([float(np.array_equal(s_outs[s_rids[t.rid]],
                                          f_outs[f_rids[t.rid]]))
                     for t in trace])
    f_p99 = _p99([f_ttft[r] for r in hi])
    s_p99 = _p99([s_ttft[r] for r in hi])
    tokens = sum(t.new_tokens for t in trace)
    # wall-clock goodput under a SHARED seconds target (the rounds
    # target priced at the FIFO arm's mean round time): rounds are not
    # comparable across arms — a chunk-joined round does a fraction of a
    # monolithic prefill's compute, so the slo arm runs MORE, CHEAPER
    # rounds — but seconds are
    target_s = SLO_TTFT_ROUNDS * f_s / max(f_st.rounds, 1)
    f_good_s = sum(t.new_tokens for t in trace
                   if f_ttft_s[t.rid] <= target_s)
    s_good_s = sum(t.new_tokens for t in trace
                   if s_ttft_s[t.rid] <= target_s)
    row = {
        "model": cfg.name, "requests": REQUESTS, "tenants": TENANTS,
        "seed": SEED, "page_size": PAGE, "chunk_prefill": CHUNK,
        "max_inflight": MAX_INFLIGHT, "slo_ttft_rounds": SLO_TTFT_ROUNDS,
        "slo_class_requests": len(hi),
        "fifo_ttft_p50_rounds": f_st.ttft_p50_rounds,
        "fifo_ttft_p99_rounds": f_st.ttft_p99_rounds,
        "fifo_class_ttft_p99_rounds": f_p99,
        "fifo_tpot_p99_rounds": f_st.tpot_p99_rounds,
        "fifo_goodput_tokens": f_st.goodput_tokens,
        "fifo_slo_attained": f_st.slo_attained,
        "fifo_rounds": f_st.rounds, "fifo_latency_s": f_s,
        "slo_ttft_p50_rounds": s_st.ttft_p50_rounds,
        "slo_ttft_p99_rounds": s_st.ttft_p99_rounds,
        "slo_class_ttft_p99_rounds": s_p99,
        "slo_tpot_p99_rounds": s_st.tpot_p99_rounds,
        "slo_goodput_tokens": s_st.goodput_tokens,
        "slo_slo_attained": s_st.slo_attained,
        "slo_rounds": s_st.rounds, "slo_latency_s": s_s,
        "preemptions": s_st.preemptions,
        "chunk_jobs": s_st.chunk_jobs,
        "prefix_hit_pages": s_st.prefix_hit_pages,
        "slo_ttft_target_s": target_s,
        "fifo_goodput_tokens_wallclock": f_good_s,
        "slo_goodput_tokens_wallclock": s_good_s,
        "p99_ttft_improvement": (f_p99 / s_p99) if s_p99 else 0.0,
        "goodput_improvement": s_good_s / max(f_good_s, 1),
        "latency_improvement": f_s / s_s,
        "tok_agree": float(agree),
    }
    emit([row], "serve_slo")
    return [csv_line(
        f"serve_slo[reqs={REQUESTS} tenants={TENANTS} chunk={CHUNK}]",
        s_s / tokens * 1e6,
        f"class_p99_ttft_rounds={s_p99:.1f}_vs_{f_p99:.1f},"
        f"p99_ttft_improvement={row['p99_ttft_improvement']:.2f},"
        f"goodput={s_good_s}_vs_{f_good_s},"
        f"latency_s={s_s:.2f}_vs_{f_s:.2f},"
        f"preemptions={s_st.preemptions},"
        f"chunk_jobs={s_st.chunk_jobs},"
        f"tok_agree={agree:.2f}")]


if __name__ == "__main__":
    for line in run():
        print(line)
