"""Table II reproduction: latency of Baseline / PipeSwitch / PIPELOAD
(2, 4, 6 Loading Agents) per paper workload; Speedup = T_baseline/T_other.

BERT/ViT: single inference.  GPT-style: prompt 4 tokens, 8 output tokens
(paper §V-B2 exactly)."""
from __future__ import annotations

import numpy as np

from repro.core import PipeloadEngine
from benchmarks.common import (PAPER_MODELS, csv_line, emit,
                               ensure_paper_ckpt, paper_cfg)

AGENT_COUNTS = (2, 4, 6)


def _run_once(eng, toks, gen):
    if gen:
        _, stats = eng.run_generate(toks, gen)
    else:
        _, stats = eng.run_single(toks)
    return stats


def run():
    rows, lines = [], []
    rng = np.random.default_rng(0)
    for name, spec in PAPER_MODELS.items():
        cfg, full_layers = paper_cfg(name)
        ckpt = ensure_paper_ckpt(name)
        seq = 196 if name == "vit_large" else (4 if spec["gen"] else 64)
        toks = rng.integers(0, cfg.vocab_size, (1, seq))
        gen = spec["gen"]

        res = {"model": name, "depth_frac": cfg.num_layers / full_layers,
               "gen_tokens": gen}
        base = PipeloadEngine(ckpt, cfg, mode="baseline").warmup(1, seq)
        res["baseline_s"] = _run_once(base, toks, gen).latency_s
        del base

        ps = PipeloadEngine(ckpt, cfg, mode="pipeswitch").warmup(1, seq)
        res["pipeswitch_s"] = _run_once(ps, toks, gen).latency_s
        del ps

        for m in AGENT_COUNTS:
            eng = PipeloadEngine(ckpt, cfg, mode="pipeload",
                                 num_agents=m).warmup(1, seq)
            res[f"pipeload{m}_s"] = _run_once(eng, toks, gen).latency_s
            del eng

        for k in ("pipeswitch_s", *(f"pipeload{m}_s" for m in AGENT_COUNTS)):
            res[k.replace("_s", "_speedup")] = res["baseline_s"] / res[k]
        rows.append(res)
        lines.append(csv_line(
            f"table2_latency[{name}]", res["pipeload6_s"] * 1e6,
            f"speedup_vs_baseline={res['pipeload6_speedup']:.2f}"))
    emit(rows, "table2_latency")
    return lines
