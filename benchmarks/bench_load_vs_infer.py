"""Fig. 3 reproduction: decomposition of loading vs inference latency.

Profiles each paper workload (Layer Profiler) and reports the per-layer
load/compute ratio (the paper observes ~10x for ~1GB models, ~2x for
GPT-J)."""
from __future__ import annotations

from repro.core import Hermes
from benchmarks.common import (PAPER_MODELS, csv_line, emit,
                               ensure_paper_ckpt, paper_cfg)


def run():
    rows, lines = [], []
    for name, spec in PAPER_MODELS.items():
        cfg, _ = paper_cfg(name)
        h = Hermes(ensure_paper_ckpt(name), cfg)
        seq = 196 if name == "vit_large" else 64
        prof = h.profile(batch=1, seq=seq, force=True)
        ratio = prof["layer_t_load"] / max(prof["layer_t_comp"], 1e-9)
        rows.append({"model": name,
                     "t_load_ms": prof["layer_t_load"] * 1e3,
                     "t_comp_ms": prof["layer_t_comp"] * 1e3,
                     "ratio": ratio})
        lines.append(csv_line(f"fig3_load_ms[{name}]",
                              prof["layer_t_load"] * 1e6,
                              f"ratio={ratio:.2f}"))
    emit(rows, "fig3_load_vs_infer")
    return lines
