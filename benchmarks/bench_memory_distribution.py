"""Fig. 2 reproduction: decomposition of per-layer memory usage.

Reports the encoder/decoder-layer share of total model bytes per paper
workload (the paper observes 70-95%)."""
from __future__ import annotations

from repro.checkpoint import load_manifest
from benchmarks.common import (PAPER_MODELS, csv_line, emit,
                               ensure_paper_ckpt, paper_cfg)


def run():
    rows = []
    lines = []
    for name in PAPER_MODELS:
        cfg, full_layers = paper_cfg(name)
        man = load_manifest(ensure_paper_ckpt(name))
        depth_frac = cfg.num_layers / full_layers
        layer_b = man["layer_bytes"]
        other_b = man["total_bytes"] - layer_b
        # extrapolate reduced-depth clones to full depth
        layer_full = layer_b / depth_frac
        frac = layer_full / (layer_full + other_b)
        rows.append({"model": name, "layer_bytes_full": layer_full,
                     "other_bytes": other_b, "layer_fraction": frac,
                     "depth_frac": depth_frac})
        lines.append(csv_line(f"fig2_layer_fraction[{name}]", 0.0,
                              f"{frac:.3f}"))
    emit(rows, "fig2_memory_distribution")
    return lines
