"""Fig. 2 reproduction: decomposition of per-layer memory usage.

Two views of the same figure:

* **Static split** (checkpoint metadata): the encoder/decoder-layer
  share of total model bytes per paper workload (the paper observes
  70-95%).
* **Measured attribution** (runtime): one live pipeload KV-cache
  generation, reporting the per-owner byte shares at the ledger peak
  (``RunStats.peak_breakdown``) — the same memory story reproduced from
  runtime accounting instead of manifest sizes.  The owner shares sum
  exactly to the recorded peak; the ``fig2_measured_exact`` line
  asserts that in the emitted CSV.
"""
from __future__ import annotations

import numpy as np

from repro.checkpoint import load_manifest
from repro.core import PipeloadEngine
from benchmarks.common import (PAPER_MODELS, csv_line, emit,
                               ensure_paper_ckpt, paper_cfg)

# live probe: a small causal decoder whose streamed KV-cache generation
# runs in seconds on CPU
_LIVE_MODEL = "gpt2_base"
_PROMPT_LEN = 32
_NEW_TOKENS = 4


def _measured_breakdown():
    """One pipeload KV-cache generation; returns ``(peak_bytes,
    {owner: bytes})`` from the run ledger's peak snapshot."""
    cfg, _ = paper_cfg(_LIVE_MODEL)
    ckpt = ensure_paper_ckpt(_LIVE_MODEL)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (1, _PROMPT_LEN))
    with PipeloadEngine(ckpt, cfg, mode="pipeload", num_agents=2) as eng:
        eng.warmup(1, _PROMPT_LEN, decode=True,
                   total_len=_PROMPT_LEN + _NEW_TOKENS)
        _, stats = eng.run_generate(toks, _NEW_TOKENS, kv_cache=True)
    return stats.peak_bytes, dict(stats.peak_breakdown)


def run():
    rows = []
    lines = []
    for name in PAPER_MODELS:
        cfg, full_layers = paper_cfg(name)
        man = load_manifest(ensure_paper_ckpt(name))
        depth_frac = cfg.num_layers / full_layers
        layer_b = man["layer_bytes"]
        other_b = man["total_bytes"] - layer_b
        # extrapolate reduced-depth clones to full depth
        layer_full = layer_b / depth_frac
        frac = layer_full / (layer_full + other_b)
        rows.append({"model": name, "layer_bytes_full": layer_full,
                     "other_bytes": other_b, "layer_fraction": frac,
                     "depth_frac": depth_frac})
        lines.append(csv_line(f"fig2_layer_fraction[{name}]", 0.0,
                              f"{frac:.3f}"))
    # measured per-owner attribution from one live run, alongside the
    # manifest-derived static split above
    peak, breakdown = _measured_breakdown()
    total = sum(breakdown.values())
    rows.append({"model": f"{_LIVE_MODEL}-live", "path": "pipeload+kv",
                 "prompt_len": _PROMPT_LEN, "new_tokens": _NEW_TOKENS,
                 "peak_bytes": peak, "peak_breakdown": breakdown,
                 "breakdown_total": total})
    for owner, nbytes in sorted(breakdown.items(),
                                key=lambda kv: (-kv[1], kv[0])):
        share = nbytes / peak if peak else 0.0
        lines.append(csv_line(f"fig2_measured_share[{owner}]", 0.0,
                              f"{share:.3f}"))
    lines.append(csv_line("fig2_measured_exact", 0.0,
                          str(int(total == peak))))
    emit(rows, "fig2_memory_distribution")
    return lines
