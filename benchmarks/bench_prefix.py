"""Paged KV + radix prefix sharing vs dense reservations, one budget.

The workload is a fleet of requests behind one long SHARED system
prompt (the multi-user serving shape the paged subsystem exists for):
every prompt opens with the same ``SHARED_LEN`` tokens and ends with a
per-request tail.  Both arms serve the SAME requests through the
continuous-batching scheduler under the SAME memory budget:

  * ``dense`` — today's reservation path: every request charges
    ``num_layers x cache_bytes(max_total_len)`` to the ledger for its
    whole lifetime, so the budget admits only a few requests at a time
    and the rest wait in waves.
  * ``paged`` — core/kv_pages.py: admission charges pages actually
    mapped, the radix tree maps the shared prefix's pages ONCE across
    the fleet, and decode grows one page at a time — so the same budget
    admits the whole fleet at once and each PIPELOAD round's weight
    stream serves every request.

The acceptance check is ``speedup >= 1.5`` (aggregate tokens/s) with a
LOWER KV ledger peak on the paged arm and ``tok_agree == 1.0``
(page-gathered decode is bit-identical to the dense padded cache, so
greedy outputs match token for token).  Results land in
``experiments/bench/prefix.json``; run.py writes the headline summary
to repo-root ``BENCH_prefix.json``.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.checkpoint import load_manifest, partition_and_save
from repro.configs import get_config
from repro.core import BatchScheduler, PipeloadEngine
from repro.models.api import build_model
from benchmarks.common import CKPT_ROOT, csv_line, emit

# KV-bound serving shape: small layers, long shared prompt — the regime
# where cache bytes (not weights) gate admission.
SHARED_LEN = 448            # shared system prompt (7 full pages)
UNIQ_LEN = 64               # per-request tail -> prompt_len = 512
NEW_TOKENS = 16
PAGE = 64                   # 512 + 16 -> 9 pages; MAX_TOTAL pads to 576
MAX_TOTAL = 576             # both arms pad caches here (bitwise parity)
REQUESTS = 8
AGENTS = 4


def _cfg():
    return get_config("gpt2_base").with_(
        name="gpt2-kvbench", num_layers=8, d_model=256, n_heads=8,
        n_kv_heads=8, head_dim=32, d_ff=1024, vocab_size=2000,
        vocab_pad_to=8, dtype="float32", remat=False)


def _ckpt(cfg):
    path = CKPT_ROOT / "gpt2_kvbench"
    if not (path / "manifest.json").exists():
        api = build_model(cfg)
        partition_and_save(api.init(jax.random.PRNGKey(0)), cfg, path)
    return path


def _serve(ckpt, cfg, prompts, budget, page_size):
    eng = PipeloadEngine(ckpt, cfg, mode="pipeload", num_agents=AGENTS,
                         budget_bytes=budget, page_size=page_size or None)
    sched = BatchScheduler(eng, max_inflight=REQUESTS,
                           max_total_len=MAX_TOTAL,
                           page_size=page_size or None)
    sched.warmup(prompt_lens=[SHARED_LEN + UNIQ_LEN])
    rids = [sched.submit(p, NEW_TOKENS) for p in prompts]
    t0 = time.perf_counter()
    outs, st = sched.run()
    dt = time.perf_counter() - t0
    del eng, sched
    return rids, outs, st, dt


def run():
    cfg = _cfg()
    ckpt = _ckpt(cfg)
    man = load_manifest(ckpt)
    layer_b = man["layer_bytes"] // cfg.num_layers
    other = man["total_bytes"] - man["layer_bytes"]
    per_req_dense = cfg.num_layers * cfg.cache_bytes(1, MAX_TOTAL)
    # ONE budget for both arms, sized so the dense reservation admits
    # ~3 concurrent requests (3.5 caches + other + one streaming layer)
    budget = other + layer_b + int(3.5 * per_req_dense)

    rng = np.random.default_rng(0)
    shared = rng.integers(0, cfg.vocab_size, (SHARED_LEN,))
    prompts = [np.concatenate([shared,
                               rng.integers(0, cfg.vocab_size, (UNIQ_LEN,))])
               for _ in range(REQUESTS)]

    d_rids, d_outs, d_st, d_s = _serve(ckpt, cfg, prompts, budget, 0)
    p_rids, p_outs, p_st, p_s = _serve(ckpt, cfg, prompts, budget, PAGE)

    tokens = REQUESTS * NEW_TOKENS
    agree = np.mean([float(np.array_equal(p_outs[pr], d_outs[dr]))
                     for pr, dr in zip(p_rids, d_rids)])
    speedup = (tokens / p_s) / (tokens / d_s)
    row = {
        "model": cfg.name, "requests": REQUESTS,
        "shared_prefix": SHARED_LEN, "prompt_len": SHARED_LEN + UNIQ_LEN,
        "new_tokens": NEW_TOKENS, "page_size": PAGE,
        "max_total_len": MAX_TOTAL, "budget_bytes": budget,
        "dense_latency_s": d_s, "dense_tokens_per_s": tokens / d_s,
        "dense_peak_bytes": d_st.peak_bytes,
        "dense_kv_peak_bytes": d_st.cache_bytes_peak,
        "dense_max_inflight": d_st.max_inflight_seen,
        "dense_rounds": d_st.rounds, "dense_loads": d_st.loads,
        "paged_latency_s": p_s, "paged_tokens_per_s": tokens / p_s,
        "paged_peak_bytes": p_st.peak_bytes,
        "paged_kv_peak_bytes": p_st.cache_bytes_peak,
        "paged_max_inflight": p_st.max_inflight_seen,
        "paged_rounds": p_st.rounds, "paged_loads": p_st.loads,
        "prefix_hit_pages": p_st.prefix_hit_pages,
        "pages_allocated": p_st.pages_allocated,
        "pool_pages_peak": p_st.pool_pages_peak,
        "cow_copies": p_st.cow_copies,
        "preemptions": p_st.preemptions,
        "speedup": speedup,
        "kv_peak_ratio": d_st.cache_bytes_peak / p_st.cache_bytes_peak,
        "within_budget": (p_st.peak_bytes <= budget
                          and d_st.peak_bytes <= budget),
        "tok_agree": float(agree),
    }
    emit([row], "prefix")
    return [csv_line(
        f"prefix[shared={SHARED_LEN} page={PAGE}]",
        p_s / tokens * 1e6,
        f"speedup_vs_dense={speedup:.2f},"
        f"tok_s={tokens / p_s:.1f},"
        f"inflight={p_st.max_inflight_seen}_vs_{d_st.max_inflight_seen},"
        f"kv_peak_mb={p_st.cache_bytes_peak / 2**20:.1f}"
        f"_vs_{d_st.cache_bytes_peak / 2**20:.1f},"
        f"prefix_hit_pages={p_st.prefix_hit_pages},"
        f"within_budget={row['within_budget']},"
        f"tok_agree={agree:.2f}")]
