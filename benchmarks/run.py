"""Benchmark harness: one bench per paper table/figure + roofline/kernels.

    PYTHONPATH=src python -m benchmarks.run [--only fig2,table2,...]

Prints ``name,us_per_call,derived`` CSV; detailed rows land in
experiments/bench/*.json, and each entry's headline CSV lines are also
written to a repo-root ``BENCH_<entry>.json`` so the perf trajectory
stays machine-readable across PRs without parsing stdout.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]

BENCHES = {
    "fig2": "benchmarks.bench_memory_distribution",
    "fig3": "benchmarks.bench_load_vs_infer",
    "table2": "benchmarks.bench_table2_latency",
    "table3": "benchmarks.bench_table3_memory",
    "fig7": "benchmarks.bench_fig7_constraints",
    "decode": "benchmarks.bench_decode",
    "batch_decode": "benchmarks.bench_batch_decode",
    "prefix": "benchmarks.bench_prefix",
    "serve_slo": "benchmarks.bench_serve_slo",
    "spec": "benchmarks.bench_spec_decode",
    "quant": "benchmarks.bench_quant",
    "moe": "benchmarks.bench_moe_stream",
    "roofline": "benchmarks.bench_roofline",
    "kernels": "benchmarks.bench_kernels",
}


def _headline_rows(lines):
    """Parse ``name,us_per_call,derived`` CSV lines into dicts."""
    rows = []
    for line in lines:
        name, us, derived = line.split(",", 2)
        rows.append({"name": name, "us_per_call": float(us),
                     "derived": derived})
    return rows


def write_summary(entry: str, lines, seconds: float) -> Path:
    out = ROOT / f"BENCH_{entry}.json"
    out.write_text(json.dumps(
        {"entry": entry, "seconds": round(seconds, 2),
         "rows": _headline_rows(lines)}, indent=1) + "\n")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(BENCHES))
    args = ap.parse_args()
    names = list(BENCHES) if not args.only else args.only.split(",")

    import importlib
    failures = 0
    print("name,us_per_call,derived")
    for name in names:
        mod = importlib.import_module(BENCHES[name])
        t0 = time.time()
        lines = []
        try:
            for line in mod.run():
                lines.append(line)
                print(line, flush=True)
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            failures += 1
        else:
            write_summary(name, lines, time.time() - t0)
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
    if failures:
        raise SystemExit(f"{failures} bench(es) failed")


if __name__ == "__main__":
    main()
