"""Benchmark harness: one bench per paper table/figure + roofline/kernels.

    PYTHONPATH=src python -m benchmarks.run [--only fig2,table2,...]

Prints ``name,us_per_call,derived`` CSV; detailed rows land in
experiments/bench/*.json, and each entry's headline CSV lines are also
written to a repo-root ``BENCH_<entry>.json`` so the perf trajectory
stays machine-readable across PRs without parsing stdout.  Every run
additionally APPENDS one JSONL line per entry (with the git sha and
date) to ``BENCH_history.jsonl`` — ``benchmarks/trajectory.py`` diffs
the two most recent runs of each entry and flags >10% regressions.
"""
from __future__ import annotations

import argparse
import datetime
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
HISTORY = ROOT / "BENCH_history.jsonl"

BENCHES = {
    "fig2": "benchmarks.bench_memory_distribution",
    "fig3": "benchmarks.bench_load_vs_infer",
    "table2": "benchmarks.bench_table2_latency",
    "table3": "benchmarks.bench_table3_memory",
    "fig7": "benchmarks.bench_fig7_constraints",
    "decode": "benchmarks.bench_decode",
    "batch_decode": "benchmarks.bench_batch_decode",
    "prefix": "benchmarks.bench_prefix",
    "serve_slo": "benchmarks.bench_serve_slo",
    "spec": "benchmarks.bench_spec_decode",
    "quant": "benchmarks.bench_quant",
    "moe": "benchmarks.bench_moe_stream",
    "roofline": "benchmarks.bench_roofline",
    "kernels": "benchmarks.bench_kernels",
}


def _headline_rows(lines):
    """Parse ``name,us_per_call,derived`` CSV lines into dicts."""
    rows = []
    for line in lines:
        name, us, derived = line.split(",", 2)
        rows.append({"name": name, "us_per_call": float(us),
                     "derived": derived})
    return rows


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=ROOT,
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "unknown"
    except Exception:  # noqa: BLE001 — no git / not a checkout
        return "unknown"


def append_history(entry: str, rows, seconds: float,
                   path: Path = HISTORY) -> None:
    """One JSONL line per bench run: the machine-readable perf
    trajectory ``benchmarks/trajectory.py`` regresses against."""
    rec = {"entry": entry, "sha": _git_sha(),
           "date": datetime.datetime.now(datetime.timezone.utc)
                   .strftime("%Y-%m-%dT%H:%M:%SZ"),
           "seconds": round(seconds, 2), "rows": rows}
    with path.open("a") as f:
        f.write(json.dumps(rec) + "\n")


def write_summary(entry: str, lines, seconds: float) -> Path:
    out = ROOT / f"BENCH_{entry}.json"
    rows = _headline_rows(lines)
    out.write_text(json.dumps(
        {"entry": entry, "seconds": round(seconds, 2),
         "rows": rows}, indent=1) + "\n")
    append_history(entry, rows, seconds)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(BENCHES))
    args = ap.parse_args()
    names = list(BENCHES) if not args.only else args.only.split(",")

    import importlib
    failures = 0
    print("name,us_per_call,derived")
    for name in names:
        mod = importlib.import_module(BENCHES[name])
        t0 = time.time()
        lines = []
        try:
            for line in mod.run():
                lines.append(line)
                print(line, flush=True)
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            failures += 1
        else:
            write_summary(name, lines, time.time() - t0)
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
    if failures:
        raise SystemExit(f"{failures} bench(es) failed")


if __name__ == "__main__":
    main()
