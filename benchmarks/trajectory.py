"""Perf-trajectory regression differ over ``BENCH_history.jsonl``.

    PYTHONPATH=src python -m benchmarks.trajectory [--history FILE]
        [--threshold 0.10] [--only entry,entry]

For every bench entry with at least two recorded runs, compare the
latest run's ``us_per_call`` per row against the previous run's.  A row
whose latency grew by more than ``threshold`` (default 10%) is a
REGRESSION; improvements and derived-metric changes are reported
informationally.  Exits non-zero when any regression was flagged, so CI
can gate on it.  Rows with a zero/absent baseline are skipped (many
figure-reproduction benches report ``us_per_call=0`` and carry their
result in ``derived``).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
DEFAULT_HISTORY = ROOT / "BENCH_history.jsonl"


def load_history(path: Path) -> dict:
    """{entry: [run, ...]} in file (= chronological) order."""
    runs: dict = {}
    if not path.exists():
        return runs
    for line in path.read_text().splitlines():
        line = line.strip()
        if not line:
            continue
        rec = json.loads(line)
        runs.setdefault(rec["entry"], []).append(rec)
    return runs


def diff_entry(prev: dict, latest: dict, threshold: float) -> list:
    """Row-by-row deltas between two runs of one entry.  Returns dicts
    with ``name`` / ``prev_us`` / ``latest_us`` / ``delta`` (fractional;
    None when no baseline) / ``regressed`` / ``derived`` pairs."""
    prev_rows = {r["name"]: r for r in prev.get("rows", [])}
    out = []
    for row in latest.get("rows", []):
        base = prev_rows.get(row["name"])
        prev_us = base.get("us_per_call", 0.0) if base else 0.0
        latest_us = row.get("us_per_call", 0.0)
        delta = ((latest_us - prev_us) / prev_us) if prev_us else None
        out.append({
            "name": row["name"], "prev_us": prev_us,
            "latest_us": latest_us, "delta": delta,
            "regressed": delta is not None and delta > threshold,
            "derived": (base.get("derived") if base else None,
                        row.get("derived")),
        })
    return out


def report(runs: dict, threshold: float, only=None) -> int:
    """Print the trajectory diff; returns the regression count."""
    regressions = 0
    entries = sorted(only) if only else sorted(runs)
    for entry in entries:
        hist = runs.get(entry, [])
        if len(hist) < 2:
            print(f"{entry}: {len(hist)} run(s) recorded — nothing to diff")
            continue
        prev, latest = hist[-2], hist[-1]
        print(f"{entry}: {prev['sha']} ({prev['date']}) -> "
              f"{latest['sha']} ({latest['date']})")
        for d in diff_entry(prev, latest, threshold):
            if d["delta"] is None:
                mark, delta = " ", "(no baseline)"
            else:
                delta = f"{d['delta']:+.1%}"
                mark = "!" if d["regressed"] else " "
            print(f"  {mark} {d['name']:<44} "
                  f"{d['prev_us']:>12.1f} -> {d['latest_us']:>12.1f} us "
                  f"{delta}")
            if d["regressed"]:
                regressions += 1
            p_der, l_der = d["derived"]
            if p_der is not None and p_der != l_der:
                print(f"      derived: {p_der} -> {l_der}")
    if regressions:
        print(f"\n{regressions} row(s) regressed more than "
              f"{threshold:.0%} vs the previous run")
    else:
        print("\nno regressions above threshold")
    return regressions


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--history", default=str(DEFAULT_HISTORY),
                    help="BENCH_history.jsonl path")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="fractional us_per_call growth that counts as a "
                    "regression (default 0.10 = 10%%)")
    ap.add_argument("--only", default=None,
                    help="comma-separated entry subset")
    args = ap.parse_args()
    runs = load_history(Path(args.history))
    if not runs:
        print(f"no history at {args.history} — run benchmarks.run first")
        return
    only = args.only.split(",") if args.only else None
    if report(runs, args.threshold, only):
        sys.exit(1)


if __name__ == "__main__":
    main()
