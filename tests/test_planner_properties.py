"""Property tests on the Pipeline Planner's analytic model + simulator."""
import math

import pytest
from helpers.hypothesis_compat import given, settings, st

from repro.core.planner import (analytic_latency, analytic_peak, plan,
                                simulate)


def synth_profile(n, t_load, t_comp, layer_bytes, other_bytes):
    return {
        "num_layers": n,
        "layer_t_load": t_load,
        "layer_t_comp": t_comp,
        "layer_bytes": layer_bytes,
        "other_bytes": other_bytes,
        "shards": (
            [{"name": "embed", "kind": "embed", "bytes": other_bytes,
              "t_load": 0.0, "t_comp": 0.0}]
            + [{"name": f"layer_{i:03d}", "kind": "layer",
                "bytes": layer_bytes, "t_load": t_load, "t_comp": t_comp}
               for i in range(n)]),
    }


@settings(max_examples=40, deadline=None)
@given(n=st.integers(2, 48), tl=st.floats(0.001, 0.2),
       tc=st.floats(0.0005, 0.05), m=st.integers(1, 8))
def test_simulated_latency_bounds(n, tl, tc, m):
    prof = synth_profile(n, tl, tc, 10, 5)
    lat, peak = simulate(prof, m)
    # lower bound: all compute is serial; one load must precede it
    assert lat >= n * tc - 1e-9
    assert lat >= tl + tc - 1e-9
    # upper bound: fully serial load+compute
    assert lat <= n * (tl + tc) + 1e-6
    # peak: at least 1 layer + other; at most whole model
    assert 5 + 10 <= peak <= 5 + 10 * n


@settings(max_examples=30, deadline=None)
@given(n=st.integers(4, 32), tl=st.floats(0.01, 0.2),
       tc=st.floats(0.0005, 0.02))
def test_more_agents_not_slower_unbudgeted(n, tl, tc):
    """With load-bound layers (paper Obs. II), adding agents must not hurt
    latency (and must not shrink peak memory)."""
    prof = synth_profile(n, tl, tc, 10, 5)
    lat_prev, peak_prev = simulate(prof, 1)
    for m in (2, 4):
        lat, peak = simulate(prof, m)
        assert lat <= lat_prev + 1e-9
        assert peak >= peak_prev - 1e-9
        lat_prev, peak_prev = lat, peak


@settings(max_examples=30, deadline=None)
@given(n=st.integers(4, 24), m=st.integers(1, 6),
       budget_layers=st.integers(1, 8))
def test_budget_respected(n, m, budget_layers):
    prof = synth_profile(n, 0.05, 0.005, 10, 5)
    budget = 5 + 10 * budget_layers
    lat, peak = simulate(prof, m, budget)
    if math.isfinite(lat):
        assert peak <= budget


def test_plan_monotone_in_budget():
    prof = synth_profile(24, 0.05, 0.004, 10, 5)
    budgets = [5 + 10 * b for b in (2, 4, 8)] + [None]
    entries = plan(prof, budgets)
    lats = [e.predicted_latency_s for e in entries]
    assert all(lats[i] >= lats[i + 1] - 1e-9 for i in range(len(lats) - 1))
    assert all(e.feasible for e in entries)


def test_analytic_model_trends():
    # latency falls with m; peak grows with m
    lats = [analytic_latency(24, m, 0.05, 0.004) for m in (1, 2, 4, 8)]
    assert all(lats[i] >= lats[i + 1] for i in range(3))
    peaks = [analytic_peak(m, 10, 5) for m in (1, 2, 4, 8)]
    assert all(peaks[i] < peaks[i + 1] for i in range(3))
