"""Property tests on the Pipeline Planner's analytic model + simulator."""
import math

import pytest
from helpers.hypothesis_compat import given, settings, st

from repro.core.planner import (analytic_latency, analytic_peak, plan,
                                plan_generate, simulate)


def synth_profile(n, t_load, t_comp, layer_bytes, other_bytes, seq=None):
    prof = {
        "num_layers": n,
        "layer_t_load": t_load,
        "layer_t_comp": t_comp,
        "layer_bytes": layer_bytes,
        "other_bytes": other_bytes,
        "shards": (
            [{"name": "embed", "kind": "embed", "bytes": other_bytes,
              "t_load": 0.0, "t_comp": 0.0}]
            + [{"name": f"layer_{i:03d}", "kind": "layer",
                "bytes": layer_bytes, "t_load": t_load, "t_comp": t_comp}
               for i in range(n)]),
    }
    if seq is not None:                  # generation-aware: decode timing
        prof["seq"] = seq
        for s in prof["shards"]:
            if s["kind"] == "layer":
                s["t_decode"] = t_comp / seq
    return prof


@settings(max_examples=40, deadline=None)
@given(n=st.integers(2, 48), tl=st.floats(0.001, 0.2),
       tc=st.floats(0.0005, 0.05), m=st.integers(1, 8))
def test_simulated_latency_bounds(n, tl, tc, m):
    prof = synth_profile(n, tl, tc, 10, 5)
    lat, peak = simulate(prof, m)
    # lower bound: all compute is serial; one load must precede it
    assert lat >= n * tc - 1e-9
    assert lat >= tl + tc - 1e-9
    # upper bound: fully serial load+compute
    assert lat <= n * (tl + tc) + 1e-6
    # peak: at least 1 layer + other; at most whole model
    assert 5 + 10 <= peak <= 5 + 10 * n


@settings(max_examples=30, deadline=None)
@given(n=st.integers(4, 32), tl=st.floats(0.01, 0.2),
       tc=st.floats(0.0005, 0.02))
def test_more_agents_not_slower_unbudgeted(n, tl, tc):
    """With load-bound layers (paper Obs. II), adding agents must not hurt
    latency (and must not shrink peak memory)."""
    prof = synth_profile(n, tl, tc, 10, 5)
    lat_prev, peak_prev = simulate(prof, 1)
    for m in (2, 4):
        lat, peak = simulate(prof, m)
        assert lat <= lat_prev + 1e-9
        assert peak >= peak_prev - 1e-9
        lat_prev, peak_prev = lat, peak


@settings(max_examples=30, deadline=None)
@given(n=st.integers(4, 24), m=st.integers(1, 6),
       budget_layers=st.integers(1, 8))
def test_budget_respected(n, m, budget_layers):
    prof = synth_profile(n, 0.05, 0.005, 10, 5)
    budget = 5 + 10 * budget_layers
    lat, peak = simulate(prof, m, budget)
    if math.isfinite(lat):
        assert peak <= budget


def test_plan_monotone_in_budget():
    prof = synth_profile(24, 0.05, 0.004, 10, 5)
    budgets = [5 + 10 * b for b in (2, 4, 8)] + [None]
    entries = plan(prof, budgets)
    lats = [e.predicted_latency_s for e in entries]
    assert all(lats[i] >= lats[i + 1] - 1e-9 for i in range(len(lats) - 1))
    assert all(e.feasible for e in entries)


def test_analytic_model_trends():
    # latency falls with m; peak grows with m
    lats = [analytic_latency(24, m, 0.05, 0.004) for m in (1, 2, 4, 8)]
    assert all(lats[i] >= lats[i + 1] for i in range(3))
    peaks = [analytic_peak(m, 10, 5) for m in (1, 2, 4, 8)]
    assert all(peaks[i] < peaks[i + 1] for i in range(3))


# ---------------------------------------------------------------------------
# batch dimension (continuous-batching serving tier)
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(n=st.integers(4, 16), tl=st.floats(0.01, 0.1),
       tc=st.floats(0.001, 0.02), cache=st.integers(1, 4),
       b1_slots=st.integers(1, 4), b2_extra=st.integers(0, 6))
def test_plan_generate_inflight_monotone_in_budget(n, tl, tc, cache,
                                                   b1_slots, b2_extra):
    """Larger budget => the chosen in-flight count never decreases, and
    the simulated peak never exceeds the budget (the satellite property
    of the capacity-first search)."""
    prof = synth_profile(n, tl, tc, 10, 5, seq=32)
    b1 = 5 + n * cache * b1_slots + 2 * 10
    b2 = b1 + b2_extra * 10 + n * cache * b2_extra
    entries = plan_generate(prof, [b1, b2], new_tokens=6,
                            cache_bytes_per_layer=cache, max_inflight=4)
    e1, e2 = entries
    for e, budget in zip(entries, (b1, b2)):
        if e.feasible:
            assert e.predicted_peak_bytes <= budget
            assert e.cache_bytes == n * cache * e.inflight
    if e1.feasible:
        assert e2.feasible
        assert e2.inflight >= e1.inflight


@settings(max_examples=20, deadline=None)
@given(n=st.integers(4, 16), tl=st.floats(0.01, 0.1),
       tc=st.floats(0.001, 0.02), r_cap=st.integers(1, 6))
def test_plan_generate_unbudgeted_batch_scales_throughput(n, tl, tc, r_cap):
    """Without a budget the planner admits the full in-flight cap, and
    aggregate throughput never falls as the cap rises (weight streams
    amortise; compute scales at worst linearly)."""
    prof = synth_profile(n, tl, tc, 10, 5, seq=32)
    prev = None
    for cap in range(1, r_cap + 1):
        e = plan_generate(prof, [None], new_tokens=6,
                          cache_bytes_per_layer=2, max_inflight=cap)[0]
        assert e.feasible and e.inflight == cap
        assert e.predicted_throughput_tps == pytest.approx(
            e.inflight / e.predicted_per_token_s)
        if prev is not None:
            assert e.predicted_throughput_tps >= prev - 1e-9
        prev = e.predicted_throughput_tps


@settings(max_examples=20, deadline=None)
@given(n=st.integers(4, 16), m=st.integers(1, 4),
       batch=st.integers(1, 6))
def test_simulate_batch_scales_compute_not_loads(n, m, batch):
    """The batch dimension multiplies Inference Agent compute but leaves
    the weight stream untouched: latency grows at most linearly with
    batch and never shrinks; peak is batch-independent (cache bytes are
    charged separately by the caller)."""
    prof = synth_profile(n, 0.05, 0.004, 10, 5, seq=32)
    lat1, peak1 = simulate(prof, m, t_comp_key="t_decode")
    latb, peakb = simulate(prof, m, t_comp_key="t_decode", batch=batch)
    assert latb >= lat1 - 1e-12
    assert latb <= batch * lat1 + 1e-9
    assert peakb == peak1


def test_plan_generate_default_matches_single_request():
    """max_inflight=1 (the default) must reproduce the pre-batch
    planner's choice exactly — serving is strictly additive."""
    prof = synth_profile(12, 0.05, 0.004, 10, 5, seq=32)
    budgets = [5 + 12 * 2 + k * 10 for k in (2, 4, 12)] + [None]
    for a, b in zip(plan_generate(prof, budgets, new_tokens=8,
                                  cache_bytes_per_layer=2),
                    plan_generate(prof, budgets, new_tokens=8,
                                  cache_bytes_per_layer=2, max_inflight=1)):
        assert (a.num_agents, a.pin_window, a.predicted_latency_s,
                a.predicted_peak_bytes, a.feasible) == \
               (b.num_agents, b.pin_window, b.predicted_latency_s,
                b.predicted_peak_bytes, b.feasible)
        assert b.inflight == 1
