"""KV-cache incremental decode: engine, modules and generation-aware
planner (beyond-paper §V-B2 replacement)."""
import math

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import load_manifest, partition_and_save
from repro.configs import get_config
from repro.core import Hermes, PipeloadEngine
from repro.core.modules import build_module_fns
from repro.core.planner import analytic_peak, plan_generate, simulate
from repro.models.api import build_model


@pytest.fixture(scope="module")
def gpt2s(tmp_path_factory):
    """Small-but-real GPT-2-geometry checkpoint on disk."""
    cfg = get_config("gpt2_base").with_(
        num_layers=6, d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
        d_ff=1024, vocab_size=1000, vocab_pad_to=8, remat=False)
    path = tmp_path_factory.mktemp("ckpt") / "gpt2s"
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    partition_and_save(params, cfg, path)
    return cfg, path


@pytest.fixture(scope="module")
def toks():
    return np.random.default_rng(1).integers(0, 1000, (1, 24))


# ---------------------------------------------------------------------------
# module-level logits equivalence: prefill+decode == full re-prefill
# ---------------------------------------------------------------------------
def test_layer_cache_decode_matches_full_forward(gpt2s):
    cfg, path = gpt2s
    fns = build_module_fns(cfg, attn_impl=None)
    eng = PipeloadEngine(path, cfg, mode="baseline")
    w = eng._load(eng.layer_names[0])

    s = 16
    x_full = jax.random.normal(jax.random.PRNGKey(3), (2, s + 1, cfg.d_model))
    want = fns["layer"](w, x_full)                    # full-seq forward

    out, cache = fns["layer_cache"](w, x_full[:, :s], s + 1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want[:, :s]),
                               atol=1e-4, rtol=1e-4)
    got, _ = fns["layer_decode"](w, x_full[:, s:], cache, s)
    np.testing.assert_allclose(np.asarray(got[:, 0]),
                               np.asarray(want[:, s]), atol=1e-4, rtol=1e-4)


def test_kv_generate_matches_reprefill_all_modes(gpt2s, toks):
    cfg, path = gpt2s
    new = 4
    ref = None
    for mode, kv in [("baseline", False), ("baseline", True),
                     ("pipeswitch", True), ("pipeload", True)]:
        eng = PipeloadEngine(path, cfg, mode=mode, num_agents=2)
        eng.warmup(1, toks.shape[1], decode=kv,
                   total_len=toks.shape[1] + new)
        out, stats = eng.run_generate(toks, new, kv_cache=kv)
        if ref is None:
            ref = np.asarray(out)
        else:
            np.testing.assert_array_equal(np.asarray(out), ref)
        if kv:
            assert stats.kv_cache and stats.cache_bytes > 0
            assert stats.new_tokens == new
            allocs = stats.event_log(["cache_alloc"])
            assert len(allocs) == cfg.num_layers
        assert stats.per_token_s > 0


def test_kv_pipeload_budget_respected(gpt2s, toks):
    cfg, path = gpt2s
    man = load_manifest(path)
    layer_b = man["layer_bytes"] // cfg.num_layers
    other = man["total_bytes"] - man["layer_bytes"]
    new = 3
    cache_total = cfg.num_layers * cfg.cache_bytes(1, toks.shape[1] + new)
    budget = other + cache_total + 3 * layer_b

    eng_b = PipeloadEngine(path, cfg, mode="baseline").warmup(1, 24)
    ref, _ = eng_b.run_generate(toks, new)
    eng = PipeloadEngine(path, cfg, mode="pipeload", num_agents=2,
                         budget_bytes=budget)
    eng.warmup(1, 24, decode=True, total_len=toks.shape[1] + new)
    out, stats = eng.run_generate(toks, new, kv_cache=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert stats.peak_bytes <= budget


def test_kv_budget_floor_raises(gpt2s, toks):
    cfg, path = gpt2s
    eng = PipeloadEngine(path, cfg, mode="pipeload", num_agents=2,
                         budget_bytes=1024)   # absurdly small
    with pytest.raises(ValueError, match="KV decode floor"):
        eng.run_generate(toks, 2, kv_cache=True)


def test_kv_pipeswitch_floor_is_whole_model(gpt2s, toks):
    """pipeswitch never destroys during a round: a budget that fits a few
    layers but not the whole model must raise, not deadlock."""
    cfg, path = gpt2s
    man = load_manifest(path)
    layer_b = man["layer_bytes"] // cfg.num_layers
    other = man["total_bytes"] - man["layer_bytes"]
    budget = other + 3 * layer_b          # fine for pipeload, not pipeswitch
    eng = PipeloadEngine(path, cfg, mode="pipeswitch", budget_bytes=budget)
    with pytest.raises(ValueError, match="KV decode floor"):
        eng.run_generate(toks, 2, kv_cache=True)


def test_kv_budget_at_floor_with_many_agents(gpt2s, toks):
    """Budget == the decode floor with m > 1: loaders must grant ledger
    bytes in layer order or an out-of-order agent steals the single slot
    of headroom and the pipeline deadlocks."""
    cfg, path = gpt2s
    man = load_manifest(path)
    layer_b = man["layer_bytes"] // cfg.num_layers
    other = man["total_bytes"] - man["layer_bytes"]
    new = 2
    cache_total = cfg.num_layers * cfg.cache_bytes(1, toks.shape[1] + new)
    floor = other + cache_total + layer_b
    eng = PipeloadEngine(path, cfg, mode="pipeload", num_agents=3,
                         budget_bytes=floor)
    eng.warmup(1, toks.shape[1], decode=True,
               total_len=toks.shape[1] + new)
    out, stats = eng.run_generate(toks, new, kv_cache=True)
    assert stats.peak_bytes <= floor
    eng_b = PipeloadEngine(path, cfg, mode="baseline").warmup(1, 24)
    ref, _ = eng_b.run_generate(toks, new)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_hermes_execute_infeasible_budget_raises(gpt2s, toks):
    cfg, path = gpt2s
    h = Hermes(path, cfg)
    h.profile(batch=1, seq=24, force=True)
    with pytest.raises(ValueError, match="no feasible generation"):
        h.execute(toks, generate=2, kv_cache=True, budget_bytes=1024)


def test_kv_zero_new_tokens_is_noop(gpt2s, toks):
    cfg, path = gpt2s
    eng = PipeloadEngine(path, cfg, mode="pipeload", num_agents=2)
    out, stats = eng.run_generate(toks, 0, kv_cache=True)
    assert out.shape == toks.shape
    assert stats.new_tokens == 0 and stats.loads == 0


def test_kv_pinned_window_reduces_reloads(gpt2s, toks):
    cfg, path = gpt2s
    new = 3
    eng = PipeloadEngine(path, cfg, mode="pipeload", num_agents=2,
                         pin_window=4)
    eng.warmup(1, 24, decode=True, total_len=toks.shape[1] + new)
    out_pin, st_pin = eng.run_generate(toks, new, kv_cache=True)
    eng2 = PipeloadEngine(path, cfg, mode="pipeload", num_agents=2)
    eng2.warmup(1, 24, decode=True, total_len=toks.shape[1] + new)
    out_ref, st_ref = eng2.run_generate(toks, new, kv_cache=True)
    np.testing.assert_array_equal(np.asarray(out_pin), np.asarray(out_ref))
    assert st_pin.loads < st_ref.loads


def test_pallas_decode_impl_matches_jnp(gpt2s):
    cfg, path = gpt2s
    fns_jnp = build_module_fns(cfg, attn_impl=None)
    fns_pl = build_module_fns(cfg, attn_impl="pallas")  # interpret on CPU
    eng = PipeloadEngine(path, cfg, mode="baseline")
    w = eng._load(eng.layer_names[0])
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 8, cfg.d_model))
    _, cache = fns_jnp["layer_cache"](w, x, 9)
    x1 = jax.random.normal(jax.random.PRNGKey(6), (1, 1, cfg.d_model))
    a, _ = fns_jnp["layer_decode"](w, x1, cache, 8)
    b, _ = fns_pl["layer_decode"](w, x1, cache, 8)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=2e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# generation-aware planner
# ---------------------------------------------------------------------------
def synth_profile(n, t_load, t_comp, layer_bytes, other_bytes, seq=32):
    return {
        "num_layers": n, "seq": seq,
        "layer_t_load": t_load, "layer_t_comp": t_comp,
        "layer_bytes": layer_bytes, "other_bytes": other_bytes,
        "shards": (
            [{"name": "embed", "kind": "embed", "bytes": other_bytes,
              "t_load": 0.0, "t_comp": 0.0}]
            + [{"name": f"layer_{i:03d}", "kind": "layer",
                "bytes": layer_bytes, "t_load": t_load, "t_comp": t_comp,
                "t_decode": t_comp / seq}
               for i in range(n)]),
    }


def test_plan_generate_respects_budget():
    n, lb, other, cache = 12, 10, 5, 2
    prof = synth_profile(n, 0.05, 0.004, lb, other)
    budgets = [other + n * cache + k * lb for k in (2, 4, n)] + [None]
    entries = plan_generate(prof, budgets, new_tokens=8,
                            cache_bytes_per_layer=cache)
    for e, budget in zip(entries, budgets):
        assert e.feasible
        assert e.cache_bytes == n * cache
        if budget is not None:
            assert e.predicted_peak_bytes <= budget
    # bigger budget -> no slower (planner can always ignore extra room)
    lats = [e.predicted_latency_s for e in entries]
    assert all(lats[i] >= lats[i + 1] - 1e-9 for i in range(len(lats) - 1))


def test_plan_generate_pins_when_unbudgeted():
    """Load-bound decode rounds: pinning everything kills the reloads, so
    the unconstrained plan should use a large pin window."""
    prof = synth_profile(8, 0.05, 0.004, 10, 5)
    e = plan_generate(prof, [None], new_tokens=16,
                      cache_bytes_per_layer=1)[0]
    assert e.pin_window == 8
    assert e.predicted_per_token_s < prof["layer_t_load"]


def test_plan_generate_fully_pinned_fits_exact_budget():
    """A budget that exactly fits the all-pinned stack (zero decode
    reloads) must surface that schedule — the tier-1 prune may not charge
    a phantom streaming window on top of a fully-pinned stack."""
    n, lb, other, cache = 8, 10, 5, 1
    prof = synth_profile(n, 0.05, 0.004, lb, other)
    budget = other + n * cache + n * lb
    e = plan_generate(prof, [budget], new_tokens=16,
                      cache_bytes_per_layer=cache)[0]
    assert e.feasible and e.pin_window == n
    assert e.predicted_per_token_s == pytest.approx(n * 0.004 / 32,
                                                    rel=1e-6)


def test_plan_generate_infeasible_budget():
    prof = synth_profile(8, 0.05, 0.004, 10, 5)
    # budget below other + cache + one layer: nothing fits
    e = plan_generate(prof, [10], new_tokens=4,
                      cache_bytes_per_layer=2)[0]
    assert not e.feasible


def test_simulate_pinned_and_cache_accounting():
    prof = synth_profile(8, 0.05, 0.004, 10, 5)
    lat0, peak0 = simulate(prof, 2)
    lat_pin, peak_pin = simulate(prof, 2, pin_window=3,
                                 extra_resident_bytes=7)
    # pinned layers skip their loads -> no slower; resident floor grows
    assert lat_pin <= lat0 + 1e-9
    assert peak_pin >= 5 + 7 + 3 * 10
    # fully pinned: latency is pure compute
    lat_all, _ = simulate(prof, 1, pin_window=8)
    assert lat_all == pytest.approx(8 * 0.004, rel=1e-6)


def test_analytic_peak_generation_terms():
    base = analytic_peak(2, 10, 5)
    assert analytic_peak(2, 10, 5, cache_bytes=33) == base + 33
    assert analytic_peak(2, 10, 5, pin_window=3) == base + 30


def test_hermes_plan_generate_end_to_end(gpt2s, toks):
    cfg, path = gpt2s
    h = Hermes(path, cfg)
    h.profile(batch=1, seq=24, force=True)
    man = load_manifest(path)
    layer_b = man["layer_bytes"] // cfg.num_layers
    other = man["total_bytes"] - man["layer_bytes"]
    new = 3
    cache_total = cfg.num_layers * cfg.cache_bytes(1, toks.shape[1] + new)
    budget = other + cache_total + 3 * layer_b
    g = h.plan_generate([budget], batch=1, prompt_len=toks.shape[1],
                        new_tokens=new)[0]
    assert g.feasible and g.predicted_peak_bytes <= budget
    assert math.isfinite(g.predicted_latency_s)
    # the planned schedule actually runs within budget
    stats = h.execute(toks, generate=new, kv_cache=True,
                      budget_bytes=budget)
    assert stats.peak_bytes <= budget
    assert stats.kv_cache
