"""Subprocess helper: multi-device sharding equivalence checks.

Run with XLA_FLAGS=--xla_force_host_platform_device_count=8 (set by the
calling test BEFORE python starts; jax pins the device count at init).
Exits 0 on success, asserts otherwise.
"""
import os
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[2] / "src"))

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.data.synthetic import make_batch
from repro.launch.mesh import compat_make_mesh
from repro.models.api import build_model, param_pspecs
from repro.models.config import DENSE, MOE, ModelConfig
from repro.sharding import ShardingCtx


def main():
    assert len(jax.devices()) == 8, jax.devices()
    mesh = compat_make_mesh((2, 4), ("data", "model"))
    ctx = ShardingCtx(mesh=mesh, batch_axes=("data",), model_axis="model")

    # ---- MoE expert-parallel loss == local loss
    cfg = ModelConfig("moe", MOE, 2, 128, 4, 2, 0, 500, head_dim=32,
                      n_experts=8, top_k=2, expert_d_ff=64,
                      capacity_factor=16.0, vocab_pad_to=4,
                      dtype="float32", remat=False)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 4, 16, seed=0)
    loss_local, _ = jax.jit(lambda p, b: api.loss(p, b, None))(params, batch)
    specs = param_pspecs(params, mesh)
    sh = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                      is_leaf=lambda x: isinstance(x, P))
    params_sh = jax.device_put(params, sh)
    batch_sh = jax.device_put(batch, NamedSharding(mesh, P("data")))
    loss_sh, _ = jax.jit(lambda p, b: api.loss(p, b, ctx))(params_sh,
                                                           batch_sh)
    assert abs(float(loss_local) - float(loss_sh)) < 1e-4, (
        float(loss_local), float(loss_sh))

    # ---- dense decode with seq-sharded cache == local decode
    cfg2 = ModelConfig("d", DENSE, 2, 128, 4, 2, 256, 500, head_dim=32,
                       vocab_pad_to=4, dtype="float32", remat=False)
    api2 = build_model(cfg2)
    p2 = api2.init(jax.random.PRNGKey(1))
    b2 = make_batch(cfg2, 4, 8, seed=1)
    b2.pop("labels")
    _, cache = jax.jit(lambda p, b: api2.prefill(p, b, None))(p2, b2)
    dcache = api2.empty_cache(4, 16)
    dcache = jax.tree.map(lambda e, f: e.at[:, :, :8].set(f), dcache, cache)
    tok = jnp.ones((4, 1), jnp.int32)
    lg_l, _ = jax.jit(lambda p, t, c: api2.decode(p, t, c, 8, None))(
        p2, tok, dcache)
    dcache_sh = jax.device_put(
        dcache, NamedSharding(mesh, P(None, "data", "model")))
    lg_s, _ = jax.jit(lambda p, t, c: api2.decode(p, t, c, 8, ctx))(
        p2, tok, dcache_sh)
    err = float(np.max(np.abs(np.asarray(lg_l) - np.asarray(lg_s))))
    assert err < 1e-4, err

    # ---- train step under sharding: loss finite & close to local
    from repro.launch.stepfns import make_train_step
    from repro.optim import adamw_init
    step_l = jax.jit(make_train_step(api2, None))
    step_s = jax.jit(make_train_step(api2, ctx))
    b3 = make_batch(cfg2, 4, 16, seed=2)
    o_l = step_l(p2, adamw_init(p2), b3)
    o_s = step_s(jax.device_put(p2, jax.tree.map(
        lambda s: NamedSharding(mesh, s), param_pspecs(p2, mesh),
        is_leaf=lambda x: isinstance(x, P))), adamw_init(p2),
        jax.device_put(b3, NamedSharding(mesh, P("data"))))
    assert abs(float(o_l[2]["loss"]) - float(o_s[2]["loss"])) < 1e-4
    print("SHARDED-CHECK-OK")


if __name__ == "__main__":
    main()
