"""Trace-replay fixture: the seeded multi-tenant serving traces.

The generator itself lives in ``repro.data.traces`` so the CLI
(``repro.launch.serve --trace/--tenants``) and the benchmark
(``benchmarks/bench_serve_slo.py``) replay EXACTLY the workload the
tests pin down — this module re-exports it for test imports and adds
the tiny-checkpoint default used by the serve-SLO suite.
"""
from repro.data.traces import (TraceRequest, load_trace,  # noqa: F401
                               make_trace, save_trace, submit_trace,
                               tenant_prefix, trace_max_len)


def tiny_trace(n_requests: int = 8, *, seed: int = 0, tenants: int = 2,
               max_total: int = 26, prefix_len: int = 0):
    """A trace sized for the 3-layer test checkpoint: prompts and
    outputs bounded so ``len(prompt) + new <= max_total``."""
    return make_trace(n_requests, tenants=tenants, seed=seed, vocab=300,
                      arrival_rate=1.5, prompt_mean=8,
                      max_prompt=max_total - 4, new_mean=2, max_new=4,
                      prefix_len=prefix_len, share_prefix=0.5)
