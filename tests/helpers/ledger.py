"""The suite-wide exact-drain invariant, in ONE place.

Every fault-injection / lifecycle test used to hand-roll its own
``assert ledger.resident == base`` at the end of a round; with
owner-attributed accounting the invariant is stronger — each owner's
balance must hit zero, not just the scalar total — and audit mode
(``REPRO_LEDGER_AUDIT=1``, default-on under pytest) can name the call
site that leaked.  Tests call :func:`assert_drained` instead of
re-implementing the checks.
"""
from repro.core.engine import _Ledger  # noqa: F401  (re-export for tests)


def assert_drained(ledger, *owners, base=0):
    """Assert the ledger drained exactly back to ``base`` resident bytes.

    ``owners`` names the tiers that must be at zero (e.g. ``"stream"``,
    ``"kv_pages"``); with none given and ``base == 0``, EVERY owner must
    be at zero.  When audit mode is on, the per-owner residue check also
    runs, so a failure names the outstanding acquire's call site instead
    of just the byte count.
    """
    assert ledger.resident == base, (
        f"ledger not drained: resident={ledger.resident}, expected {base} "
        f"(by_owner={ {o: b for o, b in ledger.by_owner.items() if b} })")
    check = owners or (tuple(ledger.by_owner) if base == 0 else ())
    for o in check:
        assert ledger.by_owner.get(o, 0) == 0, (
            f"owner '{o}' holds {ledger.by_owner[o]} bytes after drain")
    if check:
        ledger.audit_check_drained(*check)
