"""Optional-``hypothesis`` shim: property tests degrade, never explode.

The seed image does not ship ``hypothesis`` (it is an extra — see
``pyproject.toml``).  Importing it unconditionally made `pytest` fail at
COLLECTION time, taking every test in the module down with it.  Test
modules import ``given / settings / st`` from here instead:

  * with hypothesis installed, the real library is re-exported unchanged;
  * without it, ``@given`` becomes a deterministic smoke loop — each
    strategy draws ``N_EXAMPLES`` values from an RNG seeded by the test
    name, so the property still gets exercised (repeatably) on a handful
    of points instead of being skipped outright.

Only the strategy surface the suite uses is stubbed: ``integers``,
``floats``, ``sampled_from``, ``booleans``.

``HYPOTHESIS_MAX_EXAMPLES=<n>`` caps every test's example count from the
environment (CI's stress job sets it to stay inside the workflow time
budget).  The cap has to live HERE, not in a hypothesis profile: our
tests pass ``max_examples`` explicitly via ``@settings``, which takes
precedence over any loaded profile — so the shim min()s the explicit
value against the env cap before real hypothesis sees it.
"""
from __future__ import annotations

import functools
import inspect
import os
import random

_ENV_CAP = os.environ.get("HYPOTHESIS_MAX_EXAMPLES")
N_EXAMPLES = min(5, int(_ENV_CAP)) if _ENV_CAP else 5


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


class _FallbackStrategies:
    @staticmethod
    def integers(lo: int, hi: int) -> _Strategy:
        return _Strategy(lambda r: r.randint(lo, hi))

    @staticmethod
    def floats(lo: float, hi: float) -> _Strategy:
        return _Strategy(lambda r: r.uniform(lo, hi))

    @staticmethod
    def sampled_from(xs) -> _Strategy:
        xs = list(xs)
        return _Strategy(lambda r: xs[r.randrange(len(xs))])

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda r: bool(r.getrandbits(1)))


def _fallback_given(**strats):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            rnd = random.Random(fn.__name__)       # deterministic per test
            for _ in range(N_EXAMPLES):
                drawn = {k: s.draw(rnd) for k, s in strats.items()}
                fn(*args, **drawn, **kwargs)
        # hide the drawn params from pytest's fixture resolution (the
        # wrapper fills them) but KEEP the rest — like real hypothesis,
        # non-strategy params are pytest fixtures
        del wrapper.__wrapped__
        keep = [p for name, p in inspect.signature(fn).parameters.items()
                if name not in strats]
        wrapper.__signature__ = inspect.Signature(keep)
        return wrapper
    return deco


def _fallback_settings(**_kwargs):
    def deco(fn):
        return fn
    return deco


try:
    from hypothesis import given, strategies as st  # noqa: F401
    from hypothesis import settings as _hyp_settings
    HAVE_HYPOTHESIS = True

    if _ENV_CAP:
        def settings(*args, **kwargs):
            kwargs["max_examples"] = min(
                kwargs.get("max_examples", int(_ENV_CAP)), int(_ENV_CAP))
            return _hyp_settings(*args, **kwargs)
    else:
        settings = _hyp_settings
except ImportError:
    HAVE_HYPOTHESIS = False
    given = _fallback_given
    settings = _fallback_settings
    st = _FallbackStrategies
