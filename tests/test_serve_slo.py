"""Serving-tier (SLO/multi-tenant) suite: chunked prefill token
identity, priority-preemption safety properties, tenant namespace
isolation, SLO shedding, and the golden-trace policy regression.

Property tests run under ``helpers.hypothesis_compat`` (real hypothesis
when installed, deterministic smoke loop otherwise).  The golden test
replays ``tests/helpers/traces.tiny_trace`` — the SAME generator the
CLI and ``benchmarks/bench_serve_slo.py`` use — and pins the full
admission/preemption/retire sequence; regenerate with
``REPRO_UPDATE_GOLDEN=1 pytest tests/test_serve_slo.py -k golden``.
"""
import json
import os
from pathlib import Path

import numpy as np
import jax
import pytest
from helpers.hypothesis_compat import given, settings, st
from helpers.traces import submit_trace, tiny_trace

from repro.checkpoint import load_manifest, partition_and_save
from repro.configs import get_config
from repro.core import BatchScheduler, PipeloadEngine
from repro.core.scheduler import SLO
from repro.models.api import build_model

MAX_TOTAL = 26
GOLDEN = Path(__file__).parent / "golden" / "serve_slo_trace.json"


@pytest.fixture(scope="module")
def tiny(tmp_path_factory):
    """3-layer toy checkpoint (same geometry as the stress suite)."""
    cfg = get_config("gpt2_base").with_(
        num_layers=3, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=300, vocab_pad_to=4, remat=False)
    path = tmp_path_factory.mktemp("ckpt") / "tiny"
    api = build_model(cfg)
    partition_and_save(api.init(jax.random.PRNGKey(0)), cfg, path)
    man = load_manifest(path)
    layer_b = man["layer_bytes"] // cfg.num_layers
    other = man["total_bytes"] - man["layer_bytes"]
    return cfg, path, layer_b, other


def _sched(cfg, path, *, page_size=None, budget=None, **kw):
    eng = PipeloadEngine(path, cfg, mode="pipeload", num_agents=2,
                         budget_bytes=budget, page_size=page_size)
    return BatchScheduler(eng, max_total_len=MAX_TOTAL,
                          page_size=page_size, **kw)


# ---------------------------------------------------------------------------
# chunked prefill == monolithic prefill, token for token
# ---------------------------------------------------------------------------
@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 10_000),
       chunk=st.sampled_from([5, 8, 10]),
       page=st.sampled_from([5, 8]))
def test_chunked_prefill_token_identity(tiny, seed, chunk, page):
    """Across chunk sizes x page sizes {5, 8}: splitting long prompts
    into chunk-joined rounds must not change a single output token."""
    cfg, path, _, _ = tiny
    rng = np.random.default_rng(seed)
    lens = rng.integers(12, 21, 3).tolist()       # all exceed the chunk
    news = [int(min(n, MAX_TOTAL - lens[i]))
            for i, n in enumerate(rng.integers(2, 5, 3))]
    prompts = [rng.integers(0, cfg.vocab_size, (s,)) for s in lens]
    arrivals = rng.integers(0, 4, 3).tolist()

    def run(c):
        sched = _sched(cfg, path, page_size=page, max_inflight=3,
                       chunk_prefill=c)
        rids = [sched.submit(p, n, arrival_round=a)
                for p, n, a in zip(prompts, news, arrivals)]
        outs, stats = sched.run()
        return [outs[r] for r in rids], stats

    ref, s0 = run(0)
    out, s1 = run(chunk)
    assert s1.chunk_jobs > 0, "no prompt actually chunked"
    assert s1.chunk_size % page == 0          # page-aligned rounding
    for a, b in zip(ref, out):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# priority preemption: safety properties under a tight budget
# ---------------------------------------------------------------------------
@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000),
       max_inflight=st.integers(1, 3),
       cache_slots=st.integers(1, 2),
       paged=st.booleans())
def test_preemption_never_deadlocks_never_overruns(
        tiny, seed, max_inflight, cache_slots, paged):
    """Under priority traffic and a budget sized for ``cache_slots``
    concurrent caches: the run always completes, the budget is never
    exceeded, the ledger drains EXACTLY (the stress suite's property),
    and every preempted request still retires with its full token
    count — bounded priorities mean no starvation."""
    cfg, path, layer_b, other = tiny
    per_req = cfg.num_layers * cfg.cache_bytes(1, MAX_TOTAL)
    budget = other + cache_slots * per_req + 2 * layer_b
    trace = tiny_trace(6, seed=seed, max_total=MAX_TOTAL)
    sched = _sched(cfg, path, page_size=(5 if paged else None),
                   budget=budget, max_inflight=max_inflight)
    rids = submit_trace(sched, trace)
    outs, stats = sched.run()

    # every request retires with exactly its requested tokens
    assert stats.requests == len(trace)
    for t in trace:
        req = sched.done[rids[t.rid]]
        assert req.generated == t.new_tokens
        assert len(outs[rids[t.rid]]) == len(t.prompt) + t.new_tokens
    # budget honoured through every preemption/re-admission
    assert stats.peak_bytes <= budget
    # exact drain: bytes released on preemption AND retirement match
    assert not sched.inflight and not sched.queue
    assert sched._cache_resident == 0
    assert sched.ledger.resident == other
    # every preempted request eventually retired (no starvation)
    preempted = {rid for kind, rid, _, _ in stats.policy
                 if kind == "preempt"}
    for rid in preempted:
        assert sched.done[rid].finished_round >= 0
    # no runaway: serial service after the last arrival, plus one
    # re-prefill's worth of rounds per preemption, bounds the run
    horizon = (max(t.arrival_round for t in trace)
               + sum(t.new_tokens for t in trace) + len(trace)
               + len(preempted) * (MAX_TOTAL + 1) + 2)
    assert stats.rounds <= horizon


def test_priority_arrival_preempts_lowest_youngest(tiny):
    """Deterministic bounce: a priority-2 arrival at a full scheduler
    evicts the priority-0 in-flight request, serves first, and the
    victim's re-prefilled continuation is token-identical to a solo
    run."""
    cfg, path, _, _ = tiny
    rng = np.random.default_rng(7)
    p_low = rng.integers(0, cfg.vocab_size, (10,))
    p_high = rng.integers(0, cfg.vocab_size, (6,))

    solo = _sched(cfg, path, page_size=5, max_inflight=1)
    r = solo.submit(p_low, 8)
    ref = solo.run()[0][r]

    sched = _sched(cfg, path, page_size=5, max_inflight=1)
    lo = sched.submit(p_low, 8, arrival_round=0, priority=0)
    hi = sched.submit(p_high, 2, arrival_round=2, priority=2)
    outs, stats = sched.run()

    kinds = [(k, rid) for k, rid, _, _ in stats.policy]
    assert ("preempt", lo) in kinds
    assert stats.preemptions == 1
    hi_req, lo_req = sched.done[hi], sched.done[lo]
    assert hi_req.finished_round < lo_req.finished_round
    # TTFT accounting survives the bounce: born_round is the original
    # arrival even though the re-queue moved arrival_round forward
    assert lo_req.born_round == 0 and lo_req.arrival_round > 0
    np.testing.assert_array_equal(outs[lo], ref)


# ---------------------------------------------------------------------------
# tenant namespaces: share within, never across
# ---------------------------------------------------------------------------
def test_tenant_namespaces_isolate_identical_prompts(tiny):
    """Two tenants submit the SAME system prompt: pages share within
    each tenant but never across the boundary, and one tenant's
    retirement never frees the other's pages (outputs stay exact)."""
    cfg, path, _, _ = tiny
    rng = np.random.default_rng(11)
    system = rng.integers(0, cfg.vocab_size, (10,))   # two full pages @5
    tails = [rng.integers(0, cfg.vocab_size, (4,)) for _ in range(4)]
    prompts = [np.concatenate([system, t]) for t in tails]
    news = [2, 6, 2, 6]        # t0's requests retire while t1 decodes

    def run(**kw):
        sched = _sched(cfg, path, page_size=5, max_inflight=4, **kw)
        rids = [sched.submit(p, n, tenant=f"t{i % 2}")
                for i, (p, n) in enumerate(zip(prompts, news))]
        outs, stats = sched.run()
        return sched, [outs[r] for r in rids], stats

    ref_sched, ref, _ = run(prefix_cache=False)
    sched, out, stats = run()
    for a, b in zip(ref, out):
        np.testing.assert_array_equal(a, b)

    # each tenant's SECOND request hits its own tenant's two prefix
    # pages; a cross-tenant hit would double the count
    assert sched.tree is not None
    assert sched.tree.hits_by_tenant() == {"t0": 2, "t1": 2}
    assert stats.prefix_hit_pages == 4
    # sharing stayed within tenants: exactly one 2-page prefix dedup per
    # tenant, so 4 fewer allocs than the no-sharing run — a cross-tenant
    # share would save more, no sharing would save none
    assert (ref_sched.pool.stats.allocs - sched.pool.stats.allocs == 4)
    assert sched.pool.stats.shares == 4


# ---------------------------------------------------------------------------
# SLO shedding
# ---------------------------------------------------------------------------
def test_slo_shed_rejects_stale_admissions(tiny):
    """With ``SLO(shed=True)`` a burst beyond the concurrency the TTFT
    target allows is rejected at admission — rejected requests never
    produce tokens, everyone else completes in full."""
    cfg, path, _, _ = tiny
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, (8,)) for _ in range(5)]
    sched = _sched(cfg, path, page_size=5, max_inflight=1,
                   slo=SLO(ttft_rounds=3, shed=True))
    rids = [sched.submit(p, 4) for p in prompts]
    outs, stats = sched.run()

    shed = [r for r in rids if sched.done[r].rejected]
    served = [r for r in rids if not sched.done[r].rejected]
    assert stats.slo_rejections == len(shed) > 0
    assert len(served) >= 1
    for r in served:
        assert sched.done[r].generated == 4
    for r in shed:
        assert sched.done[r].generated == 0
        assert len(outs[r]) == 0       # never admitted, nothing produced
    rejects = [rid for k, rid, _, _ in stats.policy if k == "reject"]
    assert sorted(rejects) == sorted(shed)


# ---------------------------------------------------------------------------
# golden trace: the policy sequence is pinned, drift is a readable diff
# ---------------------------------------------------------------------------
def test_golden_trace_policy_sequence(tiny):
    """One seeded multi-tenant trace through the full tier (priorities +
    chunked prefill + per-tenant prefixes, slot-bound so the policy is
    purely combinatorial): the admission/preemption/retire sequence and
    the final ServeStats headline are pinned in tests/golden/."""
    cfg, path, _, _ = tiny
    trace = tiny_trace(8, seed=42, tenants=2, max_total=MAX_TOTAL,
                       prefix_len=5)
    sched = _sched(cfg, path, page_size=5, max_inflight=2,
                   chunk_prefill=10, slo=SLO(ttft_rounds=30))
    rids = submit_trace(sched, trace)
    _, stats = sched.run()

    got = {
        # t_wall (4th element) is timing, not policy — golden pins only
        # the deterministic triple
        "policy": [[k, rid, rnd] for k, rid, rnd, _ in stats.policy],
        "requests": {
            str(t.rid): {
                "tenant": t.tenant, "priority": t.priority,
                "born": sched.done[rids[t.rid]].born_round,
                "admitted": sched.done[rids[t.rid]].admitted_round,
                "finished": sched.done[rids[t.rid]].finished_round,
                "generated": sched.done[rids[t.rid]].generated,
            } for t in trace},
        "stats": {
            "rounds": stats.rounds,
            "preemptions": stats.preemptions,
            "slo_rejections": stats.slo_rejections,
            "chunk_jobs": stats.chunk_jobs,
            "prefix_hit_pages": stats.prefix_hit_pages,
            "goodput_tokens": stats.goodput_tokens,
            "ttft_p99_rounds": stats.ttft_p99_rounds,
            "tenants": stats.tenants,
        },
    }
    if os.environ.get("REPRO_UPDATE_GOLDEN"):
        GOLDEN.parent.mkdir(parents=True, exist_ok=True)
        GOLDEN.write_text(json.dumps(got, indent=1, sort_keys=True))
        pytest.skip("golden file regenerated")
    want = json.loads(GOLDEN.read_text())
    assert got == want, (
        "serving policy drifted from tests/golden/serve_slo_trace.json "
        "(intentional? REPRO_UPDATE_GOLDEN=1 to re-pin)")
