"""Fault-injection suite for the unified prefetch runtime
(core/prefetch.py): inject load failures / cancellations at every
lifecycle stage (acquire -> load -> publish -> consume -> destroy) and
assert the ledger drains byte-exact to its pre-round level — the
runtime's load-bearing invariant.  Extends the hypothesis-compat
exact-drain properties from ``tests/test_scheduler_stress.py`` down to
the runtime layer, plus regression tests for the engine loader leak and
the expert-fetch double-charge.
"""
import threading
import time

import numpy as np
import jax
import pytest
from helpers.hypothesis_compat import given, settings, st
from helpers.ledger import assert_drained

from repro.checkpoint import load_manifest, partition_and_save
from repro.configs import get_config
from repro.core import PipeloadEngine, PrefetchFault, PrefetchRuntime
from repro.core.engine import _Ledger
from repro.core.expert_stream import ExpertCache, ExpertStreamEngine
from repro.core.modules import build_module_fns
from repro.models.api import build_model
from repro.models.config import MOE, ModelConfig


# ---------------------------------------------------------------------------
# Runtime-level lifecycle properties (no model needed: a fake disk)
# ---------------------------------------------------------------------------
def _fake_shards(n, nbytes=100):
    keys = [f"shard{i}" for i in range(n)]
    sizes = [nbytes + i for i in range(n)]
    return keys, sizes


def _run_round(runtime, keys, sizes, ledger, *, fail_load=None,
               fail_apply=None, cancel_at=None, retries=0,
               preloaded=None):
    """Drive one consumer round; returns the exception seen (or None)."""
    def load(key):
        if fail_load is not None and key == keys[fail_load]:
            raise IOError(f"boom:{key}")
        time.sleep(0.001)
        return {"w": key}
    stream = runtime.stream(keys, sizes, load, ledger=ledger,
                            preloaded=preloaded or {}, retries=retries)
    try:
        with stream:
            for k in range(len(keys)):
                if cancel_at is not None and k == cancel_at:
                    return None                    # close() via __exit__
                w = stream.wait(k)
                if fail_apply is not None and k == fail_apply:
                    raise RuntimeError(f"apply:{k}")
                if k not in (preloaded or {}):
                    stream.destroy(k, w)
    except (IOError, RuntimeError) as e:
        return e
    return None


@settings(max_examples=10, deadline=None)
@given(n=st.integers(2, 8), workers=st.integers(1, 4),
       budget_slots=st.integers(1, 3), fail=st.integers(0, 7))
def test_load_fault_drains_exact(n, workers, budget_slots, fail):
    """A load failure at ANY position leaves the ledger byte-exact at
    its pre-round level (the engine-loader leak, as a property)."""
    keys, sizes = _fake_shards(n)
    ledger = _Ledger(budget_slots * (max(sizes) + 1))
    base = ledger.resident
    with PrefetchRuntime(workers=workers, name="t") as rt:
        err = _run_round(rt, keys, sizes, ledger, fail_load=fail % n)
        assert isinstance(err, IOError)
        assert_drained(ledger, "stream", base=base)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(2, 8), workers=st.integers(1, 4),
       fail=st.integers(0, 7), budgeted=st.booleans())
def test_consumer_fault_drains_exact(n, workers, fail, budgeted):
    """An Inference-Agent exception mid-round (weights consumed and
    published-but-unconsumed both outstanding) still drains exactly."""
    keys, sizes = _fake_shards(n)
    ledger = _Ledger(2 * (max(sizes) + 1) if budgeted else None)
    base = ledger.resident
    with PrefetchRuntime(workers=workers, name="t") as rt:
        err = _run_round(rt, keys, sizes, ledger, fail_apply=fail % n)
        assert isinstance(err, RuntimeError)
        assert_drained(ledger, "stream", base=base)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(2, 8), cancel=st.integers(0, 7))
def test_cancellation_drains_exact(n, cancel):
    """Closing a stream mid-round (nothing failed — the round was simply
    abandoned) releases every in-flight and published charge."""
    keys, sizes = _fake_shards(n)
    ledger = _Ledger(None)
    with PrefetchRuntime(workers=2, name="t") as rt:
        assert _run_round(rt, keys, sizes, ledger,
                          cancel_at=cancel % n) is None
        assert_drained(ledger)


def test_happy_path_in_order_and_exact():
    keys, sizes = _fake_shards(6)
    ledger = _Ledger(2 * (max(sizes) + 1))
    with PrefetchRuntime(workers=3, name="t") as rt:
        assert _run_round(rt, keys, sizes, ledger) is None
    assert_drained(ledger)
    assert ledger.peak <= ledger.budget


def test_preloaded_entries_never_charged():
    keys, sizes = _fake_shards(4)
    ledger = _Ledger(None)
    pre = {0: {"w": "resident0"}, 2: {"w": "resident2"}}
    with PrefetchRuntime(workers=2, name="t") as rt:
        assert _run_round(rt, keys, sizes, ledger, preloaded=pre) is None
    assert_drained(ledger)
    assert ledger.peak <= sizes[1] + sizes[3]


def test_keep_transfers_ownership():
    """keep() hands the charge to the caller: close() must NOT release
    it (pin window / pipeswitch semantics)."""
    keys, sizes = _fake_shards(3)
    ledger = _Ledger(None)
    with PrefetchRuntime(workers=2, name="t") as rt:
        stream = rt.stream(keys, sizes, lambda k: {"w": k}, ledger=ledger)
        with stream:
            kept = []
            for k in range(3):
                kept.append(stream.wait(k))
                stream.keep(k)
        assert ledger.resident == sum(sizes)     # still ours
        assert ledger.by_owner["stream"] == sum(sizes)
        for nb in sizes:
            ledger.release(nb, owner="stream")
    assert_drained(ledger)


def test_transient_fault_retries_to_success():
    """retries > 0 absorbs transient faults: the round completes and the
    ledger drains (CI's flaky-loader serve smoke, as a unit test)."""
    keys, sizes = _fake_shards(5)
    ledger = _Ledger(None)
    attempts = {}
    lock = threading.Lock()

    def flaky(key):
        with lock:
            attempts[key] = attempts.get(key, 0) + 1
            if attempts[key] < 3:
                raise PrefetchFault(f"transient:{key}")
        return {"w": key}
    with PrefetchRuntime(workers=2, name="t") as rt:
        stream = rt.stream(keys, sizes, flaky, ledger=ledger, retries=2)
        with stream:
            for k in range(5):
                stream.destroy(k, stream.wait(k))
    assert_drained(ledger)
    assert all(n == 3 for n in attempts.values())


def test_retries_exhausted_still_drains():
    keys, sizes = _fake_shards(3)
    ledger = _Ledger(None)
    with PrefetchRuntime(workers=2, name="t") as rt:
        err = _run_round(rt, keys, sizes, ledger, fail_load=1, retries=2)
        assert isinstance(err, IOError)
    assert_drained(ledger)


def test_env_fault_injection(monkeypatch):
    monkeypatch.setenv("REPRO_PREFETCH_FAULT_RATE", "1.0")
    ledger = _Ledger(None)
    keys, sizes = _fake_shards(3)
    with PrefetchRuntime(workers=1, name="t") as rt:
        assert rt.fault_rate == 1.0
        stream = rt.stream(keys, sizes, lambda k: {"w": k}, ledger=ledger)
        with stream:
            with pytest.raises(PrefetchFault):
                stream.wait(0)
    assert_drained(ledger)


def test_timed_load_and_submit():
    with PrefetchRuntime(workers=1, name="t") as rt:
        out, dt = rt.timed_load(lambda: sum(range(100)))
        assert out == sum(range(100)) and dt >= 0
        assert rt.submit(lambda: 7).result() == 7
    with pytest.raises(RuntimeError):
        rt.submit(lambda: 1)                     # closed runtime refuses


def test_demand_submit_never_queues_behind_parked_stream():
    """REGRESSION: demand loads issued by the consumer mid-layer (the
    expert-fetch path) must not share the stream workers' pool — a
    budgeted round parks every stream worker on S_stop until the
    consumer destroys a layer, so a demand load queued behind them
    deadlocks the round."""
    keys, sizes = _fake_shards(6)
    ledger = _Ledger(2 * (max(sizes) + 1))
    with PrefetchRuntime(workers=2, name="t") as rt:
        stream = rt.stream(keys, sizes, lambda k: {"w": k}, ledger=ledger)
        with stream:
            for k in range(6):
                w = stream.wait(k)
                # both stream workers may be parked right now; the
                # demand pool must still serve the consumer
                assert rt.submit(lambda v=k: v).result(timeout=10) == k
                stream.destroy(k, w)
    assert_drained(ledger)


def test_close_idempotent_and_joins_threads():
    rt = PrefetchRuntime(workers=2, name="joinme")
    rt.submit(lambda: 1).result()
    rt.close()
    rt.close()
    assert not any(t.name.startswith("joinme-")
                   for t in threading.enumerate())


# ---------------------------------------------------------------------------
# Engine regression: the loader leak (ISSUE satellite #1)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def tiny(tmp_path_factory):
    cfg = get_config("gpt2_base").with_(
        num_layers=3, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=300, vocab_pad_to=4, remat=False)
    path = tmp_path_factory.mktemp("ckpt") / "tiny"
    api = build_model(cfg)
    partition_and_save(api.init(jax.random.PRNGKey(0)), cfg, path)
    man = load_manifest(path)
    layer_b = man["layer_bytes"] // cfg.num_layers
    other = man["total_bytes"] - man["layer_bytes"]
    return cfg, path, layer_b, other


def _pipeline_fixture(tiny, budget_extra_layers=2):
    cfg, path, layer_b, other = tiny
    budget = other + budget_extra_layers * layer_b
    eng = PipeloadEngine(path, cfg, mode="pipeload", num_agents=2,
                         budget_bytes=budget)
    ledger = _Ledger(budget)
    events = []
    eng._ensure_aux(ledger, events, time.perf_counter())
    tokens = np.zeros((1, 8), np.int32)
    x = eng.fns["embed"](eng._resident["embed"], jax.numpy.asarray(tokens))
    return eng, ledger, events, x


def test_faulting_load_releases_ledger(tiny):
    """REGRESSION: a loader whose ``_load`` raises after its in-order
    acquire must release the charged bytes — the pre-runtime engine set
    ``done`` but leaked them, permanently eating session headroom."""
    eng, ledger, events, x = _pipeline_fixture(tiny)
    with eng:
        base = ledger.resident
        victim = eng.layer_names[1]
        orig = eng._load

        def flaky(name):
            if name == victim:
                raise IOError("disk hiccup")
            return orig(name)
        eng._load = flaky
        with pytest.raises(IOError):
            eng._run_pipeline(x, ledger, events, time.perf_counter(),
                              destroy=True)
        assert_drained(ledger, "stream", base=base)
        # and the engine recovers: the next round serves normally
        eng._load = orig
        eng._run_pipeline(x, ledger, events, time.perf_counter(),
                          destroy=True)
        assert_drained(ledger, "stream", base=base)


def test_consumer_fault_mid_round_releases_ledger(tiny):
    """Published-but-unconsumed weights (loaders ran ahead) are swept
    when the Inference Agent dies mid-round."""
    eng, ledger, events, x = _pipeline_fixture(tiny, budget_extra_layers=3)
    with eng:
        base = ledger.resident

        def exploding(k, w, h):
            if k == 1:
                raise RuntimeError("inference fault")
            return eng._apply_layer(w, h, k=k)
        with pytest.raises(RuntimeError):
            eng._run_pipeline(x, ledger, events, time.perf_counter(),
                              destroy=True, apply_fn=exploding)
        assert_drained(ledger, "stream", base=base)


def test_engine_close_joins_runtime(tiny):
    cfg, path, _, _ = tiny
    # scope the leak check to THIS engine: earlier suites may leave
    # unclosed (old-API) engines whose daemon workers share the prefix
    before = {t.ident for t in threading.enumerate()}
    eng = PipeloadEngine(path, cfg, mode="pipeload", num_agents=2)
    _, _ = eng.run_single(np.zeros((1, 8), np.int32))
    eng.close()
    assert eng.runtime.closed
    leaked = [t for t in threading.enumerate()
              if t.ident not in before and t.name.startswith("pipeload-")]
    assert not leaked


# ---------------------------------------------------------------------------
# Expert-stream regressions (ISSUE satellites #2 and #3)
# ---------------------------------------------------------------------------
MOE_CFG = ModelConfig("prefetch-moe-test", MOE, 2, 64, 4, 2, 0, 256,
                      head_dim=16, n_experts=8, top_k=2, expert_d_ff=32,
                      dtype="float32", vocab_pad_to=64, remat=False)


@pytest.fixture(scope="module")
def moe_ckpt(tmp_path_factory):
    path = tmp_path_factory.mktemp("moe") / "split"
    params = build_model(MOE_CFG).init(jax.random.PRNGKey(0))
    partition_and_save(params, MOE_CFG, path)
    return path


def _expert_engine(path, runtime=None):
    manifest = load_manifest(path)
    fns = build_module_fns(MOE_CFG)
    return ExpertStreamEngine(path, manifest, MOE_CFG, fns, workers=4,
                              runtime=runtime)


def test_concurrent_fetch_no_double_charge(moe_ckpt):
    """REGRESSION: two threads missing on the same (layer, expert)
    concurrently must charge its bytes ONCE — the lock was dropped
    between ``_make_room`` and ``cache.put``, so the loser's put
    overwrote the winner's entry and stranded its ledger charge."""
    es = _expert_engine(moe_ckpt)
    layer = next(iter(es.rows))
    ledger = _Ledger(None)                # unreserved: per-expert charges
    es.reserve(ledger, es.total_bytes, [], 0.0)
    assert not es._reserved_mode
    ids = list(es.rows[layer])[:4]
    errs = []

    def storm():
        try:
            for _ in range(5):
                es.fetch(layer, ids)
        except BaseException as e:  # noqa: BLE001
            errs.append(e)
    threads = [threading.Thread(target=storm) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    # every resident byte charged exactly once
    assert ledger.resident == es.cache.resident
    assert ledger.by_owner["expert_cache"] == es.cache.resident
    es.clear()
    assert_drained(ledger, "expert_cache")
    es.close()


def test_expert_cache_put_replace_no_double_count():
    c = ExpertCache()
    c.put(("l", 0), {"w": 1}, 100)
    c.put(("l", 0), {"w": 2}, 100)       # replace, not accumulate
    assert c.resident == 100
    assert c.evict_lru() == (("l", 0), 100)
    assert c.resident == 0


def test_expert_engine_close_joins_pool(moe_ckpt):
    before = {t.ident for t in threading.enumerate()}
    es = _expert_engine(moe_ckpt)
    layer = next(iter(es.rows))
    es.fetch(layer, list(es.rows[layer])[:2])
    es.close()
    leaked = [t for t in threading.enumerate()
              if t.ident not in before
              and t.name.startswith("expert-loader")]
    assert not leaked


def test_expert_engine_shared_runtime_not_closed(moe_ckpt):
    with PrefetchRuntime(workers=2, name="shared") as rt:
        es = _expert_engine(moe_ckpt, runtime=rt)
        layer = next(iter(es.rows))
        es.fetch(layer, list(es.rows[layer])[:2])
        es.close()                        # must NOT close the shared pool
        assert not rt.closed
        assert rt.submit(lambda: 3).result() == 3
