"""Substrate tests: data pipeline, optimizer, checkpoint partitioning,
chunked cross-entropy."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import (load_manifest, load_shard, partition_and_save,
                              shard_names)
from repro.configs import get_config
from repro.data.synthetic import make_batch
from repro.models import common
from repro.models.api import build_model
from repro.optim import adamw_init, adamw_update, cosine_lr


def test_data_deterministic_and_shapes():
    cfg = get_config("yi-9b").reduced()
    b1 = make_batch(cfg, 4, 32, seed=7)
    b2 = make_batch(cfg, 4, 32, seed=7)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    assert b1["tokens"].shape == (4, 32)
    # labels are next-token shifted
    assert b1["labels"].shape == (4, 32)
    assert int(b1["tokens"].max()) < cfg.vocab_size


def test_data_family_extras():
    vlm = get_config("qwen2-vl-2b").reduced()
    b = make_batch(vlm, 2, 32)
    assert b["patches"].shape == (2, vlm.num_patches, vlm.d_model)
    enc = get_config("seamless-m4t-medium").reduced()
    b = make_batch(enc, 2, 32)
    assert b["frames"].shape == (2, enc.enc_seq_len, enc.d_model)


def test_adamw_minimises_quadratic():
    params = {"w": jnp.array([3.0, -2.0])}
    opt = adamw_init(params)
    for _ in range(300):
        grads = {"w": 2 * params["w"]}
        params, opt = adamw_update(grads, opt, params, lr=5e-2,
                                   weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 1e-2
    assert int(opt["step"]) == 300


def test_cosine_lr_schedule():
    assert float(cosine_lr(jnp.array(0), base_lr=1.0, warmup=10,
                           total=100)) == 0.0
    assert abs(float(cosine_lr(jnp.array(10), base_lr=1.0, warmup=10,
                               total=100)) - 1.0) < 1e-6
    end = float(cosine_lr(jnp.array(100), base_lr=1.0, warmup=10, total=100,
                          min_frac=0.1))
    assert abs(end - 0.1) < 1e-6


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("gpt2_base").with_(num_layers=3, d_model=64, n_heads=2,
                                        n_kv_heads=2, head_dim=32, d_ff=128,
                                        vocab_size=100, vocab_pad_to=4,
                                        remat=False)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    man = partition_and_save(params, cfg, tmp_path / "ck")
    assert len(shard_names(man)) == cfg.num_layers + 2
    l1 = load_shard(tmp_path / "ck", "layer_001")
    want = jax.tree.map(lambda a: np.asarray(a[1]), params["layers"])
    got_leaves = jax.tree.leaves(l1)
    want_leaves = jax.tree.leaves(want)
    assert len(got_leaves) == len(want_leaves)
    for g, w in zip(got_leaves, want_leaves):
        np.testing.assert_array_equal(np.asarray(g), w)
    man2 = load_manifest(tmp_path / "ck")
    assert man2["total_bytes"] == man["total_bytes"]


def test_chunked_xent_matches_dense():
    key = jax.random.PRNGKey(0)
    b, s, d, v = 2, 16, 8, 32
    h = jax.random.normal(key, (b, s, d))
    head = jax.random.normal(jax.random.fold_in(key, 1), (d, v))
    y = jax.random.randint(jax.random.fold_in(key, 2), (b, s), 0, v)
    got = common.chunked_softmax_xent(h, head, y, n_chunks=4)
    logits = h @ head
    want = -jnp.mean(jnp.take_along_axis(
        jax.nn.log_softmax(logits, -1), y[..., None], -1))
    assert abs(float(got - want)) < 1e-5
    # gradient flows (remat'd body)
    g = jax.grad(lambda hh: common.chunked_softmax_xent(hh, head, y, 4))(h)
    assert bool(jnp.all(jnp.isfinite(g)))
