"""Continuous-batching scheduler: equivalence with sequential serving,
admission control, amortisation, and the multi-request budget floor."""
import numpy as np
import jax
import pytest

from repro.checkpoint import load_manifest, partition_and_save
from repro.configs import get_config
from repro.core import BatchScheduler, Hermes, PipeloadEngine
from repro.models.api import build_model


@pytest.fixture(scope="module")
def gpt2s(tmp_path_factory):
    """Small-but-real GPT-2-geometry checkpoint on disk."""
    cfg = get_config("gpt2_base").with_(
        num_layers=4, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=512, vocab_size=1000, vocab_pad_to=8, remat=False)
    path = tmp_path_factory.mktemp("ckpt") / "gpt2s"
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    partition_and_save(params, cfg, path)
    return cfg, path


def _mem(path, cfg):
    man = load_manifest(path)
    layer_b = man["layer_bytes"] // cfg.num_layers
    other = man["total_bytes"] - man["layer_bytes"]
    return layer_b, other


def _sequential(path, cfg, prompts, news):
    outs = []
    for p, n in zip(prompts, news):
        eng = PipeloadEngine(path, cfg, mode="pipeload", num_agents=2)
        out, _ = eng.run_generate(p[None], n, kv_cache=True)
        outs.append(np.asarray(out)[0])
    return outs


# ---------------------------------------------------------------------------
# equivalence: batched rounds == K sequential KV-cache runs, token for token
# ---------------------------------------------------------------------------
def test_batched_equals_sequential_same_lengths(gpt2s):
    cfg, path = gpt2s
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, 1000, (12,)) for _ in range(3)]
    news = [4, 4, 4]
    refs = _sequential(path, cfg, prompts, news)

    eng = PipeloadEngine(path, cfg, mode="pipeload", num_agents=2)
    sched = BatchScheduler(eng, max_inflight=3, max_total_len=16)
    rids = [sched.submit(p, n) for p, n in zip(prompts, news)]
    outs, stats = sched.run()
    for rid, ref in zip(rids, refs):
        np.testing.assert_array_equal(outs[rid], ref)
    assert stats.requests == 3 and stats.new_tokens == 12
    assert stats.max_inflight_seen == 3


def test_batched_equals_sequential_mixed_lengths(gpt2s):
    """Ragged prompts/targets AND a padded cache longer than any
    sequential run's: padding past a request's position is exactly masked
    out, so tokens still match bit for bit."""
    cfg, path = gpt2s
    rng = np.random.default_rng(2)
    lens, news = [8, 12, 10], [4, 3, 5]
    prompts = [rng.integers(0, 1000, (s,)) for s in lens]
    refs = _sequential(path, cfg, prompts, news)

    eng = PipeloadEngine(path, cfg, mode="pipeload", num_agents=2)
    sched = BatchScheduler(eng, max_inflight=3, max_total_len=20)
    rids = [sched.submit(p, n) for p, n in zip(prompts, news)]
    outs, _ = sched.run()
    for rid, ref in zip(rids, refs):
        np.testing.assert_array_equal(outs[rid], ref)


def test_batched_equals_sequential_staggered_arrivals(gpt2s):
    """Requests joining at later round boundaries (and retiring at
    different rounds) decode the same tokens as isolated runs."""
    cfg, path = gpt2s
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, 1000, (10,)) for _ in range(3)]
    news = [5, 3, 4]
    refs = _sequential(path, cfg, prompts, news)

    eng = PipeloadEngine(path, cfg, mode="pipeload", num_agents=2)
    sched = BatchScheduler(eng, max_inflight=2, max_total_len=16)
    rids = [sched.submit(p, n, arrival_round=a)
            for p, n, a in zip(prompts, news, [0, 1, 3])]
    outs, _ = sched.run()
    for rid, ref in zip(rids, refs):
        np.testing.assert_array_equal(outs[rid], ref)


# ---------------------------------------------------------------------------
# amortisation + memory accounting
# ---------------------------------------------------------------------------
def test_weight_stream_amortised(gpt2s):
    """4 concurrent requests must cost FEWER shard loads than 4
    sequential runs — one streamed layer serves every in-flight request."""
    cfg, path = gpt2s
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, 1000, (8,)) for _ in range(4)]
    news = [4] * 4

    seq_loads = 0
    for p, n in zip(prompts, news):
        eng = PipeloadEngine(path, cfg, mode="pipeload", num_agents=2)
        _, st = eng.run_generate(p[None], n, kv_cache=True)
        seq_loads += st.loads

    eng = PipeloadEngine(path, cfg, mode="pipeload", num_agents=2)
    sched = BatchScheduler(eng, max_inflight=4, max_total_len=12)
    for p, n in zip(prompts, news):
        sched.submit(p, n)
    _, stats = sched.run()
    # 4 decode rounds + aux vs 4x that for sequential
    assert stats.loads < seq_loads / 2
    assert stats.rounds == 4


def test_budget_respected_under_batching(gpt2s):
    cfg, path = gpt2s
    layer_b, other = _mem(path, cfg)
    T = 12
    per_req = cfg.num_layers * cfg.cache_bytes(1, T)
    budget = other + 3 * per_req + 3 * layer_b
    rng = np.random.default_rng(5)

    eng = PipeloadEngine(path, cfg, mode="pipeload", num_agents=2,
                         budget_bytes=budget)
    sched = BatchScheduler(eng, max_inflight=3, max_total_len=T)
    for _ in range(3):
        sched.submit(rng.integers(0, 1000, (8,)), 4)
    outs, stats = sched.run()
    assert stats.peak_bytes <= budget
    assert stats.requests == 3
    assert stats.cache_bytes_peak == 3 * per_req


def test_pinned_window_reduces_loads_in_serving(gpt2s):
    cfg, path = gpt2s
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, 1000, (8,)) for _ in range(2)]

    def serve(pin):
        eng = PipeloadEngine(path, cfg, mode="pipeload", num_agents=2,
                             pin_window=pin)
        sched = BatchScheduler(eng, max_inflight=2, max_total_len=12)
        for p in prompts:
            sched.submit(p, 4)
        return sched.run()

    outs0, st0 = serve(0)
    outs2, st2 = serve(2)
    for rid in outs0:
        np.testing.assert_array_equal(outs0[rid], outs2[rid])
    assert st2.loads < st0.loads


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------
def test_admission_respects_max_inflight(gpt2s):
    cfg, path = gpt2s
    rng = np.random.default_rng(7)
    eng = PipeloadEngine(path, cfg, mode="pipeload", num_agents=2)
    sched = BatchScheduler(eng, max_inflight=2, max_total_len=12)
    for _ in range(5):
        sched.submit(rng.integers(0, 1000, (8,)), 3)
    _, stats = sched.run()
    assert stats.requests == 5
    assert stats.max_inflight_seen <= 2


def test_submit_rejects_impossible_requests(gpt2s):
    cfg, path = gpt2s
    layer_b, other = _mem(path, cfg)
    eng = PipeloadEngine(path, cfg, mode="pipeload", num_agents=2,
                         budget_bytes=other + layer_b)   # no room for cache
    sched = BatchScheduler(eng, max_inflight=2, max_total_len=12)
    with pytest.raises(ValueError, match="KV decode floor"):
        sched.submit(np.arange(8), 2)
    with pytest.raises(ValueError, match="max_total_len"):
        BatchScheduler(PipeloadEngine(path, cfg, mode="pipeload"),
                       max_inflight=2, max_total_len=8).submit(
                           np.arange(8), 2)


def test_scheduler_rejects_baseline_mode(gpt2s):
    cfg, path = gpt2s
    eng = PipeloadEngine(path, cfg, mode="baseline")
    with pytest.raises(ValueError, match="pipelined mode"):
        BatchScheduler(eng, max_inflight=2, max_total_len=12)


# ---------------------------------------------------------------------------
# multi-request _check_kv_budget (the generalized floor)
# ---------------------------------------------------------------------------
def test_check_kv_budget_multi_request_floor_and_message(gpt2s):
    cfg, path = gpt2s
    layer_b, other = _mem(path, cfg)
    per_req = cfg.num_layers * cfg.cache_bytes(1, 12)
    # fits ONE request's pages but not four
    budget = other + per_req + layer_b
    eng = PipeloadEngine(path, cfg, mode="pipeload", num_agents=2,
                         budget_bytes=budget)
    eng._check_kv_budget(per_req, inflight=1)        # fits: no raise
    with pytest.raises(ValueError) as ei:
        eng._check_kv_budget(4 * per_req, inflight=4)
    msg = str(ei.value)
    assert "KV decode floor" in msg
    assert "4 in-flight request(s)" in msg
    assert f"4 x {per_req}" in msg
    # floor helper is exact: other + cache + one streaming layer (pin=0)
    assert eng._kv_floor(4 * per_req) == other + 4 * per_req + layer_b


def test_check_kv_budget_unbudgeted_is_noop(gpt2s):
    cfg, path = gpt2s
    eng = PipeloadEngine(path, cfg, mode="pipeload", num_agents=2)
    eng._check_kv_budget(10**12, inflight=64)        # no budget: no raise


# ---------------------------------------------------------------------------
# facade
# ---------------------------------------------------------------------------
def test_hermes_scheduler_facade(gpt2s):
    cfg, path = gpt2s
    h = Hermes(path, cfg)
    h.profile(batch=1, seq=8, force=True)
    layer_b, other = _mem(path, cfg)
    per_req = cfg.num_layers * cfg.cache_bytes(1, 12)
    budget = other + 2 * per_req + 3 * layer_b
    sched = h.scheduler(budget_bytes=budget, max_inflight=4,
                        prompt_len=8, new_tokens=4)
    assert 1 <= sched.max_inflight <= 4
    rng = np.random.default_rng(8)
    for _ in range(3):
        sched.submit(rng.integers(0, 1000, (8,)), 4)
    _, stats = sched.run()
    assert stats.requests == 3
    assert stats.peak_bytes <= budget


def test_hermes_scheduler_infeasible_raises(gpt2s):
    cfg, path = gpt2s
    h = Hermes(path, cfg)
    h.profile(batch=1, seq=8, force=True)
    with pytest.raises(ValueError, match="no feasible serving"):
        h.scheduler(budget_bytes=1024, prompt_len=8, new_tokens=4)
