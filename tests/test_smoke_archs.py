"""Per-architecture smoke tests (assignment requirement).

Every assigned architecture instantiates a REDUCED variant of the same
family (2 layers, d_model<=256, <=4 experts) and runs one forward/train
step on CPU, asserting output shapes and no NaNs.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs, list_paper_models
from repro.data.synthetic import make_batch
from repro.models.api import build_model
from repro.models.config import ENCDEC, VLM, XLSTM
from repro.launch.stepfns import make_train_step
from repro.optim import adamw_init

B, S = 2, 32


@pytest.fixture(scope="module")
def states():
    return {}


@pytest.mark.parametrize("arch", list_archs() + list_paper_models())
def test_reduced_smoke(arch):
    cfg = get_config(arch).reduced()
    cfg.validate()
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    if cfg.n_experts:
        assert cfg.n_experts <= 4
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, B, S, seed=1)

    # ---- one train step: finite loss, params updated, same structure
    opt = adamw_init(params)
    step = jax.jit(make_train_step(api, None))
    params2, opt2, metrics = step(params, opt, batch)
    assert jnp.isfinite(metrics["loss"]), arch
    assert jax.tree.structure(params2) == jax.tree.structure(params)
    changed = jax.tree.map(lambda a, b: bool(jnp.any(a != b)),
                           params, params2)
    assert any(jax.tree.leaves(changed)), f"{arch}: no param changed"

    # ---- prefill: logits shape + finite
    infer = {k: v for k, v in batch.items() if k != "labels"}
    logits, cache = jax.jit(api.prefill)(params, infer)
    assert logits.shape == (B, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), arch

    # ---- one decode step continuing the prefill
    tok = jnp.zeros((B, 1), jnp.int32)
    if cfg.family == XLSTM:
        dcache = cache
    elif cfg.family == "hybrid":
        dcache = api.empty_cache(B, S + 4)
        dcache["mamba"] = cache["mamba"]
        dcache["attn"] = jax.tree.map(
            lambda e, f: e.at[:, :, :f.shape[2]].set(f.astype(e.dtype)),
            dcache["attn"], cache["attn"])
    else:
        dcache = api.empty_cache(B, S + 4)
        dcache = jax.tree.map(
            lambda e, f: e.at[:, :, :f.shape[2]].set(f.astype(e.dtype)),
            dcache, cache)
    pos = S if cfg.family != VLM else S  # combined stream position
    logits2, _ = jax.jit(api.decode)(params, tok, dcache, pos)
    assert logits2.shape == (B, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits2))), arch
