"""Multi-device sharding checks, run in subprocesses (jax pins the device
count at first init, so forcing 8 host devices needs a fresh process)."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]


def _run(script, env_extra, timeout=900):
    env = dict(os.environ)
    env.update(env_extra)
    env["PYTHONPATH"] = str(ROOT / "src")
    return subprocess.run([sys.executable, str(script)], env=env,
                          capture_output=True, text=True, timeout=timeout)


def test_sharded_equivalence_8dev():
    r = _run(ROOT / "tests" / "helpers" / "sharded_check.py",
             {"XLA_FLAGS": "--xla_force_host_platform_device_count=8"})
    assert r.returncode == 0, r.stdout + r.stderr
    assert "SHARDED-CHECK-OK" in r.stdout


@pytest.mark.parametrize("combo", [
    ("yi-9b", "decode_32k", "pod"),
    ("qwen3-moe-30b-a3b", "train_4k", "multipod"),
    ("xlstm-1.3b", "long_500k", "pod"),
    ("minicpm3-4b", "prefill_32k", "multipod"),
])
def test_dryrun_combo_16dev(combo, tmp_path):
    """Dry-run lower+compile on a scaled-down 16-device mesh (the full
    512-device x 78-combo sweep runs via launch/dryrun.py; its committed
    results live in experiments/dryrun)."""
    arch, shape, mesh = combo
    env = {"REPRO_DRYRUN_DEVICES": "16"}
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--mesh", mesh, "--out", str(tmp_path)]
    penv = dict(os.environ)
    penv.update(env)
    penv["PYTHONPATH"] = str(ROOT / "src")
    r = subprocess.run(cmd, env=penv, capture_output=True, text=True,
                       timeout=1200)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "all dry-runs OK" in r.stdout
