import os
import sys
from pathlib import Path

# Tests see the normal single-CPU device world; only dryrun.py (and the
# subprocess helpers under tests/helpers) force a multi-device platform.
SRC = Path(__file__).resolve().parents[1] / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

# make `from helpers....` importable at collection time (hypothesis shim)
TESTS = Path(__file__).resolve().parent
if str(TESTS) not in sys.path:
    sys.path.insert(0, str(TESTS))

os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Ledger audit mode is ON by default under pytest: every acquire/release
# is recorded with its call site, double-releases raise immediately and
# drain points verify per-owner residue (engine._LedgerAudit).  Tests
# that need the production fast path (e.g. the audit on/off identity
# test) override the env per-ledger via monkeypatch + a fresh _Ledger.
os.environ.setdefault("REPRO_LEDGER_AUDIT", "1")

# Property-test example counts are capped from the environment by
# helpers/hypothesis_compat.py (HYPOTHESIS_MAX_EXAMPLES=<n>): explicit
# @settings(max_examples=...) in the tests would override a hypothesis
# profile, so CI's short budget has to clamp at the shim layer.
