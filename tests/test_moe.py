"""MoE dispatch semantics: capacity math, token dropping, determinism,
load-balance statistics."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from helpers.hypothesis_compat import given, settings, st

from repro.models import moe
from repro.models.config import MOE, ModelConfig

CFG = ModelConfig("moe", MOE, 2, 64, 4, 4, 0, 100, n_experts=4, top_k=2,
                  expert_d_ff=32, dtype="float32", remat=False)


def _setup(cfg, t=32, seed=0):
    key = jax.random.PRNGKey(seed)
    params = moe.moe_init(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, t // 2,
                                                       cfg.d_model))
    return params, x


def test_capacity_rounding():
    assert moe.capacity(CFG, 64) % 8 == 0
    assert moe.capacity(CFG, 64) >= 64 * CFG.top_k / CFG.n_experts


def test_no_drop_at_high_capacity_matches_dense():
    """With capacity >= T*K every pair is kept: output equals the dense
    per-token mixture of its top-k experts."""
    cfg = CFG.with_(capacity_factor=64.0)
    params, x = _setup(cfg)
    out, aux = jax.jit(lambda p, xx: moe.moe_apply(p, xx, cfg, None))(
        params, x)

    # dense reference: every expert on every token, weighted combine
    t = x.reshape(-1, cfg.d_model)
    logits = t @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    top_w, top_ids = jax.lax.top_k(probs, cfg.top_k)
    top_w = top_w / top_w.sum(-1, keepdims=True)
    gate = jax.nn.silu(jnp.einsum("td,edf->tef", t, params["w_gate"]))
    up = jnp.einsum("td,edf->tef", t, params["w_up"])
    all_out = jnp.einsum("tef,efd->ted", gate * up, params["w_down"])
    ref = jnp.zeros_like(t)
    for kk in range(cfg.top_k):
        sel = jnp.take_along_axis(all_out, top_ids[:, kk][:, None, None],
                                  axis=1)[:, 0]
        ref = ref + sel * top_w[:, kk][:, None]
    np.testing.assert_allclose(np.asarray(out.reshape(-1, cfg.d_model)),
                               np.asarray(ref), atol=1e-4, rtol=1e-4)


def test_dropping_reduces_output_norm():
    """Tiny capacity drops pairs; dropped tokens contribute zero."""
    params, x = _setup(CFG.with_(capacity_factor=64.0))
    out_full, _ = jax.jit(lambda p, xx: moe.moe_apply(
        p, xx, CFG.with_(capacity_factor=64.0), None))(params, x)
    cfg_tight = CFG.with_(capacity_factor=0.25)
    out_tight, _ = jax.jit(lambda p, xx: moe.moe_apply(
        p, xx, cfg_tight, None))(params, x)
    n_full = float(jnp.linalg.norm(out_full))
    n_tight = float(jnp.linalg.norm(out_tight))
    assert n_tight < n_full


def test_deterministic():
    params, x = _setup(CFG)
    f = jax.jit(lambda p, xx: moe.moe_apply(p, xx, CFG, None)[0])
    np.testing.assert_array_equal(np.asarray(f(params, x)),
                                  np.asarray(f(params, x)))


def test_aux_loss_bounds():
    """Switch load-balance loss is >= 1 (it equals E * sum f*p and is
    minimised at uniform routing), modulo the small z-loss term."""
    params, x = _setup(CFG.with_(capacity_factor=8.0))
    _, aux = jax.jit(lambda p, xx: moe.moe_apply(
        p, xx, CFG.with_(capacity_factor=8.0), None))(params, x)
    assert float(aux) >= 0.9


@settings(max_examples=15, deadline=None)
@given(t=st.integers(2, 16), e=st.sampled_from([2, 4, 8]),
       k=st.integers(1, 2), seed=st.integers(0, 2**30))
def test_dispatch_indices_properties(t, e, k, seed):
    """Slots are unique (no collisions), in range, and respect capacity."""
    k = min(k, e)
    cap = 8
    ids = jax.random.randint(jax.random.PRNGKey(seed), (t, k), 0, e)
    slots = np.asarray(moe._dispatch_indices(ids, k, e, cap, 0, e))
    kept = slots[slots < e * cap]
    assert len(np.unique(kept)) == len(kept)          # unique slots
    per_expert = {}
    for s in kept:
        per_expert[s // cap] = per_expert.get(s // cap, 0) + 1
    assert all(v <= cap for v in per_expert.values())  # capacity respected
