"""Speculative decoding on the paged KV cache (PR 6).

Covers the four speculative satellites: (1) a property suite driving
random speculate/accept/reject traces against PagePool/PrefixTree
refcount invariants (no leaked or double-freed pages, ``mapped_pages``
returns to baseline after a full rollback, shared prefix pages survive
a rejected sibling); (2) token equivalence — speculative greedy output
is BITWISE identical to dense and to non-speculative paged decode
across page sizes and depths, including the draft==target degenerate
100%-acceptance case and a weak 1-layer draft; (3) the stacked paged
verify kernel against its jnp oracle (``ref.paged_verify_ref``) over a
(page_size, seq, verify_width) sweep, with W=1 degenerating to the
plain paged-decode pair; (4) a regression pinning that releasing a COW
page mid-speculation while a sibling still holds it never returns the
page to the free list early.  Planner spec-depth search and the
scheduler's speculative serving round out the surface."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from helpers.hypothesis_compat import given, settings, st

from repro.checkpoint import partition_and_save
from repro.configs import get_config
from repro.core import BatchScheduler, PipeloadEngine
from repro.core.engine import DraftModel, SpecConfig, _Ledger
from repro.core.kv_pages import (BlockTable, PagePool, PrefixTree,
                                 pages_for)
from repro.core.planner import plan_generate
from repro.kernels import ops, ref
from repro.models.api import build_model

MAX_TOTAL = 16


@pytest.fixture(scope="module")
def gpt2s(tmp_path_factory):
    """Small-but-real GPT-2-geometry target checkpoint on disk."""
    cfg = get_config("gpt2_base").with_(
        num_layers=3, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=300, vocab_pad_to=4, remat=False)
    path = tmp_path_factory.mktemp("ckpt") / "gpt2s"
    api = build_model(cfg)
    partition_and_save(api.init(jax.random.PRNGKey(0)), cfg, path)
    return cfg, path


@pytest.fixture(scope="module")
def draft1(gpt2s, tmp_path_factory):
    """A deliberately WEAK draft: a 1-layer carve over the same vocab.

    Its proposals are near-random, so verify rejects almost everything —
    the correctness claim (token-identical output) must hold anyway."""
    cfg, _ = gpt2s
    dcfg = cfg.with_(name=cfg.name + "-d1", num_layers=1)
    path = tmp_path_factory.mktemp("ckpt") / "gpt2s_d1"
    api = build_model(dcfg)
    partition_and_save(api.init(jax.random.PRNGKey(1)), dcfg, path)
    return dcfg, path


# ---------------------------------------------------------------------------
# (1) speculate/accept/reject refcount invariants on the page pool
# ---------------------------------------------------------------------------
def test_rollback_returns_mapped_pages_to_baseline():
    """A fully rejected speculation window (pure appended pages, no COW
    inside the committed range) must leave the pool EXACTLY where it
    started — same mapped count, same ledger bytes."""
    led = _Ledger(None)
    pool = PagePool(4, 10, led)
    t = BlockTable([pool.alloc(), pool.alloc()], 0)
    pos, keep = 7, len(t.pages)                  # 7 committed tokens
    base_pages, base_bytes = pool.mapped_pages, led.resident
    # window [pos, pos+4] spills into pages 2 and (7+4)//4 = 2 — grow
    while len(t.pages) * 4 < pos + 4 + 1:
        t.pages.append(pool.alloc())
    assert pool.mapped_pages > base_pages
    t.rollback(pool, keep)                       # reject EVERYTHING
    assert pool.mapped_pages == base_pages
    assert led.resident == base_bytes
    t.release_all(pool)
    assert pool.mapped_pages == 0 and led.resident == 0


def test_shared_prefix_survives_rejected_sibling():
    """Two requests share prefix pages; one speculates into the shared
    partial page (COW), gets fully rejected, rolls back and retires.
    The survivor's prefix pages must still be mapped and intact."""
    pool, tree = PagePool(4, 1), PrefixTree(4)
    toks = list(range(10))                       # 2 full + 1 partial page
    t_a = BlockTable(*tree.insert(toks, pool))
    t_b = BlockTable(*tree.insert(toks, pool))
    assert t_b.n_shared == 3
    prefix = list(t_a.pages)
    # B speculates: COW the shared partial page, append a window page
    keep = len(t_b.pages)
    assert t_b.cow(2, pool) is not None          # shared -> private copy
    t_b.pages.append(pool.alloc())
    # verify rejects the whole window; B rolls back and retires
    t_b.rollback(pool, keep, tree)
    t_b.release_all(pool, tree)
    # A's pages all survive with exactly A's reference
    for pid in prefix:
        assert pool.refcount(pid) == 1
    t_a.release_all(pool, tree)
    assert pool.mapped_pages == 0


def test_cow_release_mid_speculation_never_frees_early():
    """Regression: B COWs a page A still holds, then B's speculation is
    rejected and B retires.  The shared page must NOT land on the free
    list while A references it — a fresh alloc may not recycle it."""
    pool, tree = PagePool(4, 1), PrefixTree(4)
    t_a = BlockTable(*tree.insert(list(range(4)), pool))
    t_b = BlockTable(*tree.insert(list(range(4)), pool))
    pid = t_a.pages[0]
    assert pool.refcount(pid) == 2
    old_new = t_b.cow(0, pool)                   # B's speculative write
    assert old_new is not None and old_new[0] == pid
    t_b.release_all(pool, tree)                  # rejected + retired
    fresh = pool.alloc()                         # must NOT hand out pid
    assert fresh != pid
    assert pool.refcount(pid) == 1               # A still holds it
    pool.release(fresh)
    t_a.release_all(pool, tree)
    assert pool.mapped_pages == 0


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), page_size=st.sampled_from([1, 3, 4]),
       depth=st.integers(1, 4), n_reqs=st.integers(2, 4))
def test_speculate_rollback_interleaving_property(seed, page_size, depth,
                                                 n_reqs):
    """Random speculate/accept/reject traces over shared-prefix tables:
    the ledger stays byte-exact with the pool at every step, no page is
    leaked or double-freed, retirement mid-speculation of a sibling is
    safe, and everything drains to zero at the pool's high-water mark."""
    rng = np.random.default_rng(seed)
    led = _Ledger(None)
    pool = PagePool(page_size, 10, led)
    tree = PrefixTree(page_size)
    shared = rng.integers(0, 5, (2 * page_size,)).tolist()
    live = {}
    for i in range(n_reqs):
        toks = shared + rng.integers(0, 5, (int(rng.integers(1, 6)),)).tolist()
        live[i] = [BlockTable(*tree.insert(toks, pool)), len(toks)]
    hw = pool.mapped_pages
    for _ in range(30):
        if not live:
            break
        assert led.resident == pool.mapped_bytes       # ledger exact
        i = int(rng.choice(list(live)))
        t, pos = live[i]
        # speculative window writes slots [pos, pos + depth]: grow the
        # table to cover it, COW any shared page in the write range
        lo, hi = pos // page_size, (pos + depth) // page_size
        while len(t.pages) <= hi:
            t.pages.append(pool.alloc())
        for idx in range(lo, hi + 1):
            t.cow(idx, pool)                           # None if private
        hw = max(hw, pool.mapped_pages)                # peak is mid-window
        a = int(rng.integers(0, depth + 1))            # accepted prefix
        pos += a + 1                                   # + bonus token
        t.rollback(pool, pages_for(pos, page_size), tree)
        live[i][1] = pos
        hw = max(hw, pool.mapped_pages)
        if pos >= 6 * page_size:                       # retire finished
            live.pop(i)[0].release_all(pool, tree)
        assert pool.capacity <= max(hw, pool.mapped_pages)
    for t, _ in live.values():
        t.release_all(pool, tree)
    assert pool.mapped_pages == 0 and led.resident == 0
    assert pool.capacity == hw


# ---------------------------------------------------------------------------
# (3) stacked paged verify kernel == jnp oracle
# ---------------------------------------------------------------------------
def _verify_case(rng, page, nb, w, b=2, kv=2, g=2, dh=32):
    n_pages = 2 * nb + 3
    kp = jnp.asarray(rng.normal(size=(n_pages, page, kv, dh)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(n_pages, page, kv, dh)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(b, w, kv, g, dh)), jnp.float32)
    tables = jnp.asarray(rng.integers(0, n_pages, (b, nb)), jnp.int32)
    # query i sits at slot lengths - w + i, so lengths >= w
    lengths = jnp.asarray(rng.integers(w, nb * page + 1, (b,)), jnp.int32)
    return q, kp, vp, tables, lengths


@pytest.mark.parametrize("page,nb,w", [(4, 3, 2), (5, 3, 4), (8, 2, 5),
                                       (16, 2, 3)])
def test_paged_verify_matches_oracle(page, nb, w):
    rng = np.random.default_rng(page * 100 + nb * 10 + w)
    q, kp, vp, tables, lengths = _verify_case(rng, page, nb, w)
    out = ops.paged_verify(q, kp, vp, tables, lengths)
    exp = ref.paged_verify_ref(q, kp, vp, tables, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)


def test_paged_verify_w1_degenerates_to_paged_decode():
    """A width-1 verify window IS a plain decode step: both the kernel
    and the oracle must agree with the paged-decode pair exactly."""
    rng = np.random.default_rng(42)
    q, kp, vp, tables, lengths = _verify_case(rng, 4, 3, 1)
    dec = ops.paged_decode(q[:, 0], kp, vp, tables, lengths)
    ver = ops.paged_verify(q, kp, vp, tables, lengths)
    np.testing.assert_allclose(np.asarray(ver[:, 0]), np.asarray(dec),
                               rtol=2e-5, atol=2e-5)
    ref_dec = ref.paged_decode_ref(q[:, 0], kp, vp, tables, lengths)
    ref_ver = ref.paged_verify_ref(q, kp, vp, tables, lengths)
    np.testing.assert_allclose(np.asarray(ref_ver[:, 0]),
                               np.asarray(ref_dec), rtol=1e-6, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), page=st.sampled_from([2, 4, 5]),
       nb=st.integers(1, 4), w=st.integers(1, 5))
def test_paged_verify_property(seed, page, nb, w):
    rng = np.random.default_rng(seed)
    b = int(rng.integers(1, 4))
    kv, g = int(rng.integers(1, 3)), int(rng.integers(1, 3))
    if nb * page < w:                # window must fit the live slots
        nb = pages_for(w, page)
    q, kp, vp, tables, lengths = _verify_case(rng, page, nb, w, b=b,
                                              kv=kv, g=g, dh=16)
    out = ops.paged_verify(q, kp, vp, tables, lengths)
    exp = ref.paged_verify_ref(q, kp, vp, tables, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# (2) token equivalence: speculative greedy == dense == non-spec paged
# ---------------------------------------------------------------------------
def _engine_gen(path, cfg, prompt, new, *, page_size=None, spec=None):
    eng = PipeloadEngine(path, cfg, mode="pipeload", num_agents=2,
                         page_size=page_size)
    out, st = eng.run_generate(prompt, new, kv_cache=True, speculative=spec)
    return np.asarray(out), st


@pytest.mark.parametrize("page_size", [5, 8])   # odd and power-of-two
def test_spec_greedy_identical_across_depths(gpt2s, page_size):
    """Self-speculation (draft == target) at every depth produces
    BITWISE the tokens of dense and non-speculative paged decode, and
    its acceptance rate is exactly 1.0 — the degenerate ceiling."""
    cfg, path = gpt2s
    rng = np.random.default_rng(page_size)
    prompt = rng.integers(0, 300, (1, 6))
    new = 8
    dense, _ = _engine_gen(path, cfg, prompt, new)
    paged, _ = _engine_gen(path, cfg, prompt, new, page_size=page_size)
    np.testing.assert_array_equal(dense, paged)
    for depth in (1, 2, 4):
        spec = SpecConfig(path, cfg, depth=depth)      # self-speculation
        out, st = _engine_gen(path, cfg, prompt, new, page_size=page_size,
                              spec=spec)
        np.testing.assert_array_equal(out, dense)
        assert st.acceptance_rate == 1.0
        assert st.spec_rounds < new                    # rounds amortised
        assert st.accepted_tokens > 0


def test_spec_identical_with_weak_draft(gpt2s, draft1):
    """Correctness must not depend on draft quality: a 1-layer random
    draft still yields bitwise-dense output (verify rejects, the bonus
    token keeps progress)."""
    cfg, path = gpt2s
    dcfg, dpath = draft1
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, 300, (1, 6))
    dense, _ = _engine_gen(path, cfg, prompt, 8)
    out, st = _engine_gen(path, cfg, prompt, 8, page_size=5,
                          spec=SpecConfig(dpath, dcfg, depth=4))
    np.testing.assert_array_equal(out, dense)
    assert st.spec_rounds >= 1
    assert st.acceptance_rate <= 1.0


def test_spec_requires_paged_cache(gpt2s):
    cfg, path = gpt2s
    eng = PipeloadEngine(path, cfg, mode="pipeload", num_agents=2)
    with pytest.raises(ValueError, match="paged KV"):
        eng.run_generate(np.arange(6)[None], 4, kv_cache=True,
                         speculative=SpecConfig(path, cfg, depth=2))


# ---------------------------------------------------------------------------
# scheduler: speculative serving == plain serving, token for token
# ---------------------------------------------------------------------------
def _serve(path, cfg, prompts, news, *, draft=None, depth=0, seed=None):
    eng = PipeloadEngine(path, cfg, mode="pipeload", num_agents=2,
                         page_size=5)
    sched = BatchScheduler(eng, max_inflight=3, max_total_len=MAX_TOTAL,
                           seed=seed, draft=draft, spec_depth=depth)
    rids = [sched.submit(p, n, arrival_round=(0 if i < 3 else 1))
            for i, (p, n) in enumerate(zip(prompts, news))]
    outs, stats = sched.run()
    return sched, rids, outs, stats


def test_scheduler_spec_serving_identical(gpt2s, draft1):
    """4 shared-prefix requests (one late arrival forcing admission
    mid-flight): speculative serving at depths 2 and 4 — self-draft AND
    the weak draft — retires everyone with the plain schedule's exact
    tokens, and the pool drains."""
    cfg, path = gpt2s
    dcfg, dpath = draft1
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, 300, (4, 8))
    prompts[:, :4] = prompts[0, :4]             # shared prefix
    news = [5] * 4
    _, rb, base, _ = _serve(path, cfg, prompts, news)
    for depth in (2, 4):
        s, rs, outs, st = _serve(path, cfg, prompts, news,
                                 draft=DraftModel(path, cfg), depth=depth)
        for a, b in zip(rs, rb):
            np.testing.assert_array_equal(outs[a], base[b])
        assert st.spec_depth == depth
        assert st.spec_rounds > 0
        assert st.acceptance_rate == 1.0        # self-draft ceiling
        assert s.pool.mapped_pages == 0
    s, rs, outs, st = _serve(path, cfg, prompts, news,
                             draft=DraftModel(dpath, dcfg), depth=4)
    for a, b in zip(rs, rb):
        np.testing.assert_array_equal(outs[a], base[b])
    assert s.pool.mapped_pages == 0


def test_scheduler_spec_requires_paged(gpt2s):
    cfg, path = gpt2s
    eng = PipeloadEngine(path, cfg, mode="pipeload", num_agents=2)
    with pytest.raises(ValueError, match="paged KV"):
        BatchScheduler(eng, max_inflight=2, max_total_len=MAX_TOTAL,
                       draft=DraftModel(path, cfg), spec_depth=4)


# ---------------------------------------------------------------------------
# planner: the speculative depth dimension
# ---------------------------------------------------------------------------
def _profile(n_layers=4, layer_b=1000, other=500):
    shards = [{"name": f"L{i}", "kind": "layer", "bytes": layer_b,
               "t_load": 1e-3, "t_comp": 1e-4, "t_decode": 1e-5}
              for i in range(n_layers)]
    return {"num_layers": n_layers, "layer_bytes": layer_b,
            "other_bytes": other, "shards": shards, "seq": 8,
            "quant": None}


def test_planner_spec_depth_amortises_load_bound_decode():
    """With a free, perfect draft the verify depth amortises the weight
    stream over depth+1 tokens per round — the planner must pick the
    deepest window and charge the draft's bytes."""
    prof = _profile()
    kw = dict(new_tokens=16, cache_bytes_per_layer=320, max_pin=0,
              page_sizes=(8,), total_len=32)
    draft = dict(bytes=100, cache_bytes=10, acceptance=1.0, t_token=0.0)
    plain = plan_generate(prof, [None], **kw)[0]
    spec = plan_generate(prof, [None], spec_depths=(2, 4),
                         spec_draft=draft, **kw)[0]
    assert spec.spec_depth == 4
    assert spec.draft_bytes > 0
    assert spec.predicted_latency_s < plain.predicted_latency_s
    assert plain.spec_depth == 0 and plain.draft_bytes == 0


def test_planner_spec_depth_zero_when_draft_busts_budget():
    """A draft too large for the budget must fall back to depth 0 (the
    non-speculative entry stays feasible)."""
    prof = _profile()
    budget = prof["other_bytes"] + 3 * prof["layer_bytes"] + 4 * 320
    draft = dict(bytes=10 ** 9, cache_bytes=10, acceptance=1.0)
    e = plan_generate(prof, [budget], new_tokens=8,
                      cache_bytes_per_layer=320, max_pin=0,
                      page_sizes=(8,), total_len=32,
                      spec_depths=(4,), spec_draft=draft)[0]
    assert e.feasible and e.spec_depth == 0 and e.draft_bytes == 0


def test_planner_spec_validation():
    prof = _profile()
    with pytest.raises(ValueError, match="spec_draft"):
        plan_generate(prof, [None], new_tokens=4, cache_bytes_per_layer=100,
                      page_sizes=(8,), total_len=16, spec_depths=(2,))
    with pytest.raises(ValueError, match="page_sizes"):
        plan_generate(prof, [None], new_tokens=4, cache_bytes_per_layer=100,
                      spec_depths=(2,),
                      spec_draft=dict(bytes=1, cache_bytes=1))
