"""Docs stay in lockstep with the CLI surface: every benchmark entry in
``benchmarks/run.py`` and every ``launch/serve.py`` flag must be
documented.  This is the CI "docs check" — it fails the moment a bench
or flag ships without its docs.
"""
import re
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]


def _docs_corpus() -> str:
    parts = [(REPO / "README.md").read_text()]
    parts += [p.read_text() for p in sorted((REPO / "docs").glob("*.md"))]
    return "\n".join(parts)


def test_every_benchmark_entry_documented():
    src = (REPO / "benchmarks" / "run.py").read_text()
    keys = re.findall(r'"(\w+)":\s*"benchmarks\.', src)
    assert keys, "could not parse BENCHES from benchmarks/run.py"
    docs = (REPO / "docs" / "benchmarks.md").read_text()
    missing = [k for k in keys if f"`{k}`" not in docs]
    assert not missing, (
        f"benchmarks/run.py entries missing from docs/benchmarks.md: "
        f"{missing}")


def test_every_serve_flag_documented():
    src = (REPO / "src" / "repro" / "launch" / "serve.py").read_text()
    flags = re.findall(r'add_argument\(\s*"(--[\w-]+)"', src)
    assert flags, "could not parse flags from launch/serve.py"
    docs = _docs_corpus()
    missing = [f for f in flags if f"`{f}" not in docs]
    assert not missing, (
        f"launch/serve.py flags undocumented (README.md or docs/): "
        f"{missing}")


def test_telemetry_flags_documented_in_observability_doc():
    """The telemetry flags get more than the corpus-wide mention: the
    observability guide itself must cover both exports."""
    doc = (REPO / "docs" / "observability.md").read_text()
    for flag in ("--trace-out", "--metrics-out"):
        assert f"`{flag}" in doc, (
            f"{flag} missing from docs/observability.md")
    assert "ui.perfetto.dev" in doc
