"""Unit tests for the HLO cost analyzer (trip counts, collectives, bytes)."""
import textwrap

import pytest

from repro.analysis.hlo import analyze_hlo, parse_blocks

HLO = textwrap.dedent("""\
    HloModule test

    %body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
      %p = (s32[], f32[8,16]{1,0}) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
      %w = f32[16,16]{1,0} constant({...})
      %dot.1 = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ag = f32[8,16]{1,0} all-gather(%dot.1), channel_id=1, replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={1}
      %one = s32[] constant(1)
      %i2 = s32[] add(%i, %one)
      ROOT %t = (s32[], f32[8,16]{1,0}) tuple(%i2, %ag)
    }

    %cond (p: (s32[], f32[8,16])) -> pred[] {
      %p.1 = (s32[], f32[8,16]{1,0}) parameter(0)
      %i.1 = s32[] get-tuple-element(%p.1), index=0
      %n = s32[] constant(5)
      ROOT %lt = pred[] compare(%i.1, %n), direction=LT
    }

    ENTRY %main (arg: f32[8,16]) -> f32[8,16] {
      %arg = f32[8,16]{1,0} parameter(0)
      %zero = s32[] constant(0)
      %init = (s32[], f32[8,16]{1,0}) tuple(%zero, %arg)
      %wh = (s32[], f32[8,16]{1,0}) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
      ROOT %out = f32[8,16]{1,0} get-tuple-element(%wh), index=1
    }
""")


def test_trip_count_multiplication():
    res = analyze_hlo(HLO, total_devices=8)
    # dot: 2 * 8*16 result * 16 contraction = 4096 flops, x5 trips
    assert res["dot_flops"] == 5 * 2 * 8 * 16 * 16
    ag = res["collectives"]["all-gather"]
    assert ag["count"] == 5
    # payload 8*16*4 = 512 bytes; group size 4 -> wire = 3/4 * 512
    assert ag["payload_bytes"] == 5 * 512
    assert abs(ag["wire_bytes"] - 5 * 0.75 * 512) < 1e-6


def test_parse_blocks_structure():
    blocks = parse_blocks(HLO)
    assert "__entry__" in blocks
    assert any(op.kind == "while" for op in blocks["__entry__"].ops)
    body = blocks["body"]
    assert any(op.kind == "dot" for op in body.ops)


def test_tuple_shapes_with_comments():
    txt = HLO.replace("(s32[], f32[8,16]{1,0})",
                      "(s32[], /*index=1*/f32[8,16]{1,0})")
    res = analyze_hlo(txt, total_devices=8)
    assert res["dot_flops"] == 5 * 2 * 8 * 16 * 16
