"""Flash custom-VJP attention vs. reference autodiff + decode helpers."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from helpers.hypothesis_compat import given, settings, st

from repro.models.attention import (_masked_attention_fallback,
                                    chunked_attention, flash_decode,
                                    cache_update)


def _qkv(key, b, sq, sk, kv, g, dh, dv):
    q = jax.random.normal(key, (b, sq, kv, g, dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, sk, kv, dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, sk, kv, dv))
    return q, k, v


@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 24)])
def test_flash_fwd_and_grad_match_reference(causal, window):
    q, k, v = _qkv(jax.random.PRNGKey(0), 2, 64, 64, 2, 3, 16, 24)

    def f_flash(q, k, v):
        return (chunked_attention(q, k, v, causal=causal, window=window,
                                  block_q=16, block_k=16) ** 2).sum()

    def f_ref(q, k, v):
        out = _masked_attention_fallback(
            q, k, v, causal=causal, q_offset=0, window=window,
            valid_len=jnp.full((2,), 64), block_q=16, block_k=16)
        return (out ** 2).sum()

    o1, o2 = jax.jit(f_flash)(q, k, v), jax.jit(f_ref)(q, k, v)
    assert abs(float(o1 - o2)) < 1e-3
    g1 = jax.jit(jax.grad(f_flash, argnums=(0, 1, 2)))(q, k, v)
    g2 = jax.jit(jax.grad(f_ref, argnums=(0, 1, 2)))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-3)


@settings(max_examples=8, deadline=None)
@given(nq=st.integers(1, 3), bq=st.sampled_from([8, 16]),
       seed=st.integers(0, 2**30))
def test_flash_block_size_invariance(nq, bq, seed):
    """Output must not depend on the block decomposition."""
    s = 16 * nq
    q, k, v = _qkv(jax.random.PRNGKey(seed), 1, s, s, 1, 2, 8, 8)
    a = chunked_attention(q, k, v, causal=True, block_q=bq, block_k=bq)
    b = chunked_attention(q, k, v, causal=True, block_q=s, block_k=s)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_flash_decode_no_ctx_matches_full_softmax():
    key = jax.random.PRNGKey(1)
    b, s, kv, g, dh = 2, 32, 2, 2, 16
    q = jax.random.normal(key, (b, kv, g, dh))
    kc = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kv, dh))
    vc = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kv, dh))
    valid = jnp.broadcast_to(jnp.arange(s)[None] < 20, (b, s))
    got = flash_decode(q, kc, vc, valid, None)

    sc = jnp.einsum("bkgd,bskd->bkgs", q / jnp.sqrt(dh * 1.0), kc)
    sc = jnp.where(valid[:, None, None], sc, -1e30)
    p = jax.nn.softmax(sc, -1)
    want = jnp.einsum("bkgs,bskd->bkgd", p, vc)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-4)


def test_cache_update_no_ctx():
    cache = jnp.zeros((2, 8, 2, 4))
    new = jnp.ones((2, 2, 4))
    out = cache_update(cache, new, 5, None)
    assert float(out[:, 5].sum()) == 2 * 2 * 4
    assert float(out.sum()) == 2 * 2 * 4
