"""Scheduler stress/property suite: random arrival orders, mixed
prompt/generation lengths and tight budgets must never deadlock the
ledger, never exceed the byte budget, and always retire every request.

Runs under ``helpers.hypothesis_compat``: real hypothesis when installed
(CI caps examples via ``HYPOTHESIS_MAX_EXAMPLES=10``), a deterministic
5-point smoke loop otherwise.
"""
import numpy as np
import jax
import pytest
from helpers.hypothesis_compat import given, settings, st

from repro.checkpoint import load_manifest, partition_and_save
from repro.configs import get_config
from repro.core import BatchScheduler, PipeloadEngine
from repro.models.api import build_model

MAX_TOTAL = 14          # every request: prompt + new <= this


@pytest.fixture(scope="module")
def tiny(tmp_path_factory):
    """3-layer toy checkpoint: small enough that a property example is a
    few pipeline rounds, real enough to exercise every thread role."""
    cfg = get_config("gpt2_base").with_(
        num_layers=3, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=300, vocab_pad_to=4, remat=False)
    path = tmp_path_factory.mktemp("ckpt") / "tiny"
    api = build_model(cfg)
    partition_and_save(api.init(jax.random.PRNGKey(0)), cfg, path)
    man = load_manifest(path)
    layer_b = man["layer_bytes"] // cfg.num_layers
    other = man["total_bytes"] - man["layer_bytes"]
    return cfg, path, layer_b, other


def _serve(cfg, path, *, seed, n_reqs, max_inflight, budget, arrivals,
           news, lens, num_agents=2):
    rng = np.random.default_rng(seed)
    eng = PipeloadEngine(path, cfg, mode="pipeload",
                         num_agents=num_agents, budget_bytes=budget)
    sched = BatchScheduler(eng, max_inflight=max_inflight,
                           max_total_len=MAX_TOTAL)
    rids = []
    for i in range(n_reqs):
        p = rng.integers(0, cfg.vocab_size, (lens[i],))
        rids.append(sched.submit(p, news[i], arrival_round=arrivals[i]))
    outs, stats = sched.run()
    return sched, rids, outs, stats


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000),
       n_reqs=st.integers(1, 5),
       max_inflight=st.integers(1, 3),
       cache_slots=st.integers(1, 2),     # how many requests' pages fit
       extra_layers=st.integers(1, 3))    # streaming headroom above floor
def test_random_arrivals_tight_budget_all_retire(
        tiny, seed, n_reqs, max_inflight, cache_slots, extra_layers):
    cfg, path, layer_b, other = tiny
    rng = np.random.default_rng(seed)
    per_req = cfg.num_layers * cfg.cache_bytes(1, MAX_TOTAL)
    budget = other + cache_slots * per_req + extra_layers * layer_b
    lens = rng.integers(3, 9, n_reqs).tolist()
    news = [int(min(n, MAX_TOTAL - lens[i]))
            for i, n in enumerate(rng.integers(1, 5, n_reqs))]
    arrivals = rng.integers(0, 7, n_reqs).tolist()

    sched, rids, outs, stats = _serve(
        cfg, path, seed=seed, n_reqs=n_reqs, max_inflight=max_inflight,
        budget=budget, arrivals=arrivals, news=news, lens=lens)

    # every request retires with exactly its requested token count
    assert stats.requests == n_reqs
    assert sorted(outs) == sorted(rids)
    for i, rid in enumerate(rids):
        req = sched.done[rid]
        assert req.generated == news[i]
        assert len(outs[rid]) == lens[i] + news[i]
        assert req.admitted_round >= arrivals[i]
        assert req.finished_round >= req.admitted_round
    # the ledger never exceeded the budget, and every admission kept the
    # decode floor (other + caches + one streaming layer) under it
    assert stats.peak_bytes <= budget
    assert other + stats.cache_bytes_peak + layer_b <= budget
    # no deadlock / runaway: the worst case is fully serial service after
    # the last arrival, one request at a time
    assert stats.rounds <= max(arrivals) + sum(news) + n_reqs + 1


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), n_reqs=st.integers(1, 4),
       max_inflight=st.integers(1, 3))
def test_ledger_drains_after_serving(tiny, seed, n_reqs, max_inflight):
    """After the queue drains, every cache page is back in the budget:
    resident == the up-front aux (embed+head) bytes, cache accounting
    returns to zero, and nothing is left in flight."""
    cfg, path, layer_b, other = tiny
    rng = np.random.default_rng(seed)
    lens = rng.integers(3, 9, n_reqs).tolist()
    news = rng.integers(1, 4, n_reqs).tolist()
    arrivals = rng.integers(0, 4, n_reqs).tolist()
    sched, _, _, stats = _serve(
        cfg, path, seed=seed, n_reqs=n_reqs, max_inflight=max_inflight,
        budget=None, arrivals=arrivals, news=news, lens=lens)
    assert not sched.inflight and not sched.queue
    assert sched._cache_resident == 0
    assert sched.ledger.resident == other      # embed + head stay loaded
    assert stats.new_tokens == sum(news)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000), pin=st.integers(0, 3))
def test_pinned_serving_respects_budget(tiny, seed, pin):
    """Pinned layers + caches + one streaming layer all share the budget;
    the floor with a pinned window is higher but still honoured."""
    cfg, path, layer_b, other = tiny
    per_req = cfg.num_layers * cfg.cache_bytes(1, MAX_TOTAL)
    budget = (other + per_req + pin * layer_b
              + (layer_b if pin < cfg.num_layers else 0))
    rng = np.random.default_rng(seed)
    eng = PipeloadEngine(path, cfg, mode="pipeload", num_agents=2,
                         pin_window=pin, budget_bytes=budget)
    sched = BatchScheduler(eng, max_inflight=2, max_total_len=MAX_TOTAL)
    for _ in range(2):
        sched.submit(rng.integers(0, cfg.vocab_size, (6,)), 3)
    _, stats = sched.run()
    assert stats.requests == 2
    assert stats.peak_bytes <= budget


def test_midstream_retirement_frees_pages_for_queued_request(tiny):
    """The budget holds exactly ONE request's cache pages.  A second
    queued request must be admitted at the boundary immediately after the
    first retires — its pages are the freed bytes — with no idle round
    and no deadlock."""
    cfg, path, layer_b, other = tiny
    per_req = cfg.num_layers * cfg.cache_bytes(1, MAX_TOTAL)
    budget = other + per_req + layer_b
    rng = np.random.default_rng(0)
    eng = PipeloadEngine(path, cfg, mode="pipeload", num_agents=2,
                         budget_bytes=budget)
    sched = BatchScheduler(eng, max_inflight=3, max_total_len=MAX_TOTAL)
    r0 = sched.submit(rng.integers(0, cfg.vocab_size, (6,)), 3)
    r1 = sched.submit(rng.integers(0, cfg.vocab_size, (6,)), 2)
    outs, stats = sched.run()
    a, b = sched.done[r0], sched.done[r1]
    # serial service: r1's pages ARE r0's freed pages
    assert a.admitted_round == 0
    assert b.admitted_round == a.finished_round + 1   # very next boundary
    assert stats.rounds == 3 + 2                      # no idle rounds
    assert stats.peak_bytes <= budget
    assert stats.cache_bytes_peak == per_req          # never both resident
    # and the freed-page reuse really happened through the ledger
    retires = [e for e in stats.events if e[1] == "retire"]
    admits = [e for e in stats.events if e[1] == "admit"]
    assert len(retires) == 2 and len(admits) == 2
    assert retires[0][0] <= admits[1][0]   # r0 freed before r1 granted


def test_finish_same_round_as_admission(tiny):
    """A 1-token request retires in its admission round (prefill IS its
    only round) and its pages free immediately for the next in line."""
    cfg, path, layer_b, other = tiny
    per_req = cfg.num_layers * cfg.cache_bytes(1, MAX_TOTAL)
    budget = other + per_req + layer_b
    rng = np.random.default_rng(1)
    eng = PipeloadEngine(path, cfg, mode="pipeload", num_agents=2,
                         budget_bytes=budget)
    sched = BatchScheduler(eng, max_inflight=2, max_total_len=MAX_TOTAL)
    r0 = sched.submit(rng.integers(0, cfg.vocab_size, (5,)), 1)
    r1 = sched.submit(rng.integers(0, cfg.vocab_size, (5,)), 1)
    outs, stats = sched.run()
    assert sched.done[r0].admitted_round == sched.done[r0].finished_round
    assert sched.done[r1].admitted_round == 1
    assert stats.rounds == 2
    assert len(outs[r0]) == 6 and len(outs[r1]) == 6
