"""Expert-streaming PIPELOAD: partition layout, oracle equivalence,
ExpertCache residency/eviction, ledger accounting, scheduler + planner
integration, and the unsupported-family error contract."""
import json

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import (load_manifest, partition_and_save,
                              requantize)
from repro.configs import get, names
from repro.core import (BatchScheduler, ExpertCache, Hermes,
                        PipeloadEngine, expected_unique_experts,
                        plan_generate, profile_model)
from repro.core.modules import build_module_fns
from repro.models.api import build_model
from repro.models.config import MOE, XLSTM, ModelConfig

CFG = ModelConfig("moe-stream-test", MOE, 3, 64, 4, 2, 0, 256,
                  head_dim=16, n_experts=8, top_k=2, expert_d_ff=32,
                  dtype="float32", vocab_pad_to=64, remat=False)
PROMPT, NEW = 12, 5
TOTAL = PROMPT + NEW


@pytest.fixture(scope="module")
def params():
    p = build_model(CFG).init(jax.random.PRNGKey(0))
    # random-init routers are near-uniform, so ANY perturbation (e.g.
    # int8 attention noise) flips top-k picks; trained routers are
    # decisive.  Sharpen the margins so the int8 tolerance test measures
    # quantization error, not tie-breaking luck.
    p["layers"]["moe"]["router"] = p["layers"]["moe"]["router"] * 8.0
    return p


@pytest.fixture(scope="module")
def ckpts(params, tmp_path_factory):
    root = tmp_path_factory.mktemp("moe_stream")
    paths = {"split": root / "split", "whole": root / "whole",
             "int8": root / "split-int8"}
    partition_and_save(params, CFG, paths["split"])   # MoE default: split
    partition_and_save(params, CFG, paths["whole"], expert_split=False)
    requantize(paths["split"], paths["int8"], "int8")
    return paths


@pytest.fixture(scope="module")
def toks():
    return np.random.default_rng(0).integers(0, CFG.vocab_size, (2, PROMPT))


def _budget(path, extra_experts=6, batch=1):
    man = load_manifest(path)
    other = sum(s["bytes"] for s in man["shards"]
                if s["kind"] in ("embed", "head"))
    lb = max(s["bytes"] for s in man["shards"] if s["kind"] == "layer")
    eb = max(s["bytes"] for s in man["shards"] if s["kind"] == "expert")
    kv = CFG.num_layers * CFG.cache_bytes(batch, TOTAL)
    return other + kv + 2 * lb + extra_experts * eb


# ---------------------------------------------------------------------------
# Checkpoint layout
# ---------------------------------------------------------------------------
def test_manifest_expert_layout(ckpts):
    man = load_manifest(ckpts["split"])
    assert man["expert_split"] is True
    experts = [s for s in man["shards"] if s["kind"] == "expert"]
    layers = [s for s in man["shards"] if s["kind"] == "layer"]
    assert len(experts) == CFG.num_layers * CFG.n_experts
    assert len(layers) == CFG.num_layers
    assert man["experts_per_layer"] == CFG.n_experts
    for s in experts:
        assert s["bytes"] > 0 and 0 <= s["expert"] < CFG.n_experts
        assert 0 <= s["index"] < CFG.num_layers
        assert s["name"] == f"layer_{s['index']:03d}_expert_{s['expert']:03d}"
    # attention+router shards no longer carry the expert bytes
    man_w = load_manifest(ckpts["whole"])
    assert man["layer_bytes"] < man_w["layer_bytes"]
    assert (man["layer_bytes"] + man["expert_total_bytes"]
            == man_w["layer_bytes"])


def test_requantize_preserves_expert_layout(ckpts):
    man = load_manifest(ckpts["int8"])
    assert man["expert_split"] is True and man["quant"] == "int8"
    experts = [s for s in man["shards"] if s["kind"] == "expert"]
    assert len(experts) == CFG.num_layers * CFG.n_experts
    assert all("expert" in s for s in experts)
    # int8 expert shards are ~4x smaller than fp32 ones
    fp = load_manifest(ckpts["split"])
    assert man["expert_total_bytes"] < fp["expert_total_bytes"] / 3


# ---------------------------------------------------------------------------
# Oracle equivalence
# ---------------------------------------------------------------------------
def test_single_pass_matches_oracle(ckpts, params, toks):
    eng = PipeloadEngine(ckpts["split"], CFG, mode="pipeload", num_agents=2)
    logits, stats = eng.run_single(toks)
    ref, _ = jax.jit(build_model(CFG).prefill)(
        params, {"tokens": jnp.asarray(toks, jnp.int32)})
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)
    assert stats.expert_misses > 0
    assert stats.unique_experts_per_round <= CFG.num_layers * CFG.n_experts


def test_generation_token_for_token_vs_whole_layer(ckpts, toks):
    e_split = PipeloadEngine(ckpts["split"], CFG, mode="pipeload",
                             num_agents=2)
    e_whole = PipeloadEngine(ckpts["whole"], CFG, mode="pipeload",
                             num_agents=2)
    out_s, st_s = e_split.run_generate(toks, NEW, kv_cache=True)
    out_w, st_w = e_whole.run_generate(toks, NEW, kv_cache=True)
    np.testing.assert_array_equal(np.asarray(out_s), np.asarray(out_w))
    # routing reuse across decode rounds turns into cache hits
    assert st_s.expert_hit_rate > 0
    assert st_s.streamed_bytes < st_w.streamed_bytes


def test_int8_within_documented_tolerance(ckpts, toks):
    e_fp = PipeloadEngine(ckpts["split"], CFG, mode="pipeload",
                          num_agents=2)
    e_q = PipeloadEngine(ckpts["int8"], CFG, mode="pipeload", num_agents=2)
    l_fp, st_fp = e_fp.run_single(toks)
    l_q, st = e_q.run_single(toks)
    l_fp, l_q = np.asarray(l_fp), np.asarray(l_q)
    # docs/quantization.md MoE tolerances: greedy tokens match fp32 (the
    # fp32 router keeps routing aligned); logit error is looser than the
    # dense 5% because SwiGLU experts compound three quantized matmuls
    # at smoke-dims expert widths
    np.testing.assert_array_equal(l_q.argmax(-1), l_fp.argmax(-1))
    rel = np.abs(l_q - l_fp).max() / np.abs(l_fp).max()
    assert rel < 0.25
    assert st.expert_misses > 0
    # quantized expert shards stream fewer bytes on the same cold run
    assert st.streamed_bytes < st_fp.streamed_bytes


# ---------------------------------------------------------------------------
# ExpertCache unit behaviour
# ---------------------------------------------------------------------------
def test_expert_cache_lru_order_and_counters():
    c = ExpertCache()
    for e in range(3):
        assert c.get(("L0", e)) is None                # 3 misses
        c.put(("L0", e), {"w": e}, 10)
    assert len(c) == 3 and c.resident == 30
    assert c.get(("L0", 0))["w"] == 0                  # 0 becomes MRU
    key, freed = c.evict_lru()                         # LRU is now 1
    assert key == ("L0", 1) and freed == 10
    assert c.resident == 20 and c.evictions == 1
    # exclusion protects the round's locked working set
    key, _ = c.evict_lru(exclude=frozenset({("L0", 2)}))
    assert key == ("L0", 0)
    assert c.evict_lru(exclude=frozenset({("L0", 2)})) is None
    assert c.hits == 1 and c.misses == 3


def test_budgeted_run_respects_budget_and_evicts(ckpts, toks):
    # the floor is worst-case: a 24-token prefill may lock all 8 experts
    # of one layer, so the budget must clear E experts + headroom for
    # the cache to be under pressure (11 slots vs 24 touched -> evicts)
    budget = _budget(ckpts["split"], extra_experts=9, batch=2)
    eng = PipeloadEngine(ckpts["split"], CFG, mode="pipeload",
                         num_agents=2, budget_bytes=budget)
    out, st = eng.run_generate(toks, NEW, kv_cache=True)
    assert st.peak_bytes <= budget
    assert st.expert_evictions > 0          # cache pressure was real
    assert st.expert_cache_bytes >= eng.expert.min_ws
    # identical tokens to the unbudgeted run
    ref, _ = PipeloadEngine(ckpts["whole"], CFG, mode="pipeload",
                            num_agents=2).run_generate(toks, NEW,
                                                       kv_cache=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_cache_too_small_raises_clear_error(ckpts, toks):
    man = load_manifest(ckpts["split"])
    eb = max(s["bytes"] for s in man["shards"] if s["kind"] == "expert")
    eng = PipeloadEngine(ckpts["split"], CFG, mode="pipeload",
                         num_agents=2, budget_bytes=_budget(ckpts["split"]),
                         expert_cache_bytes=CFG.top_k * eb)
    # a 2-sequence prefill activates more experts than top_k; the fetch
    # must name the problem instead of deadlocking
    with pytest.raises(ValueError, match="expert cache too small"):
        eng.run_single(toks)


def test_budget_below_expert_floor_raises(ckpts, toks):
    man = load_manifest(ckpts["split"])
    other = sum(s["bytes"] for s in man["shards"]
                if s["kind"] in ("embed", "head"))
    lb = max(s["bytes"] for s in man["shards"] if s["kind"] == "layer")
    eng = PipeloadEngine(ckpts["split"], CFG, mode="pipeload",
                         num_agents=2, budget_bytes=other + lb + 1)
    with pytest.raises(ValueError, match="expert cache"):
        eng.run_single(toks)


# ---------------------------------------------------------------------------
# Scheduler integration
# ---------------------------------------------------------------------------
def test_scheduler_batched_moe_token_identical(ckpts):
    eng = PipeloadEngine(ckpts["split"], CFG, mode="pipeload", num_agents=2)
    sched = BatchScheduler(eng, max_inflight=3, max_total_len=TOTAL)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, CFG.vocab_size, (6 + 2 * i,))
               for i in range(3)]
    rids = [sched.submit(p, 4) for p in prompts]
    outs, stats = sched.run()
    ref_eng = PipeloadEngine(ckpts["whole"], CFG, mode="pipeload",
                             num_agents=2)
    for rid, p in zip(rids, prompts):
        seq, _ = ref_eng.run_generate(p[None], 4, kv_cache=True)
        np.testing.assert_array_equal(outs[rid], np.asarray(seq)[0])
    assert stats.expert_hit_rate > 0
    assert stats.expert_misses > 0
    assert stats.unique_experts_per_round > 0


def test_scheduler_admission_shrinks_expert_cache(ckpts):
    """A queued request's pages win over cold cached experts: the
    reservation shrinks (LRU eviction through the ledger) instead of the
    request waiting forever."""
    budget = _budget(ckpts["split"], extra_experts=14, batch=1)
    eng = PipeloadEngine(ckpts["split"], CFG, mode="pipeload",
                         num_agents=2, budget_bytes=budget)
    sched = BatchScheduler(eng, max_inflight=2, max_total_len=TOTAL)
    rng = np.random.default_rng(2)
    rids = [sched.submit(rng.integers(0, CFG.vocab_size, (6,)), 3)
            for _ in range(2)]
    outs, stats = sched.run()
    assert sorted(outs) == sorted(rids)
    assert stats.peak_bytes <= budget


# ---------------------------------------------------------------------------
# Planner + profiler + facade
# ---------------------------------------------------------------------------
def test_expected_unique_experts_model():
    assert expected_unique_experts(128, 8, 1) == pytest.approx(8.0)
    assert expected_unique_experts(8, 2, 10 ** 6) == pytest.approx(8.0)
    assert expected_unique_experts(8, 2, 0) == 0.0
    # monotone in tokens, bounded by the pool
    us = [expected_unique_experts(128, 8, t) for t in (1, 4, 16, 64, 256)]
    assert us == sorted(us) and us[-1] <= 128


def test_profile_and_plan_moe(ckpts):
    prof = profile_model(ckpts["split"], CFG, batch=1, seq=PROMPT,
                         repeats=1)
    assert prof["expert_split"] and prof["n_experts"] == CFG.n_experts
    assert prof["expert_bytes"] > 0 and prof["expert_t_load"] > 0
    expert_rows = [s for s in prof["shards"] if s["kind"] == "expert"]
    assert len(expert_rows) == CFG.num_layers * CFG.n_experts
    assert all(r["t_load"] > 0 for r in expert_rows)
    # attention+router shards stay the planner's "layer_bytes"
    man = load_manifest(ckpts["split"])
    assert prof["layer_bytes"] < man["layer_bytes"] + man[
        "expert_total_bytes"]

    budget = _budget(ckpts["split"], extra_experts=10)
    cb = CFG.cache_bytes(1, TOTAL)
    [g] = plan_generate(prof, [budget], new_tokens=NEW,
                        cache_bytes_per_layer=cb, max_agents=3)
    assert g.feasible
    assert g.expert_cache_bytes > 0
    assert g.predicted_peak_bytes <= budget
    # an unconstrained budget caches the whole expert pool
    [g_inf] = plan_generate(prof, [None], new_tokens=NEW,
                            cache_bytes_per_layer=cb, max_agents=3)
    assert g_inf.expert_cache_bytes == prof["expert_bytes"] * \
        CFG.num_layers * CFG.n_experts


def test_hermes_facade_moe_end_to_end(ckpts, toks):
    hermes = Hermes(ckpts["split"], CFG)
    budget = _budget(ckpts["split"], extra_experts=12, batch=1)
    stats = hermes.execute(toks[:1], generate=3, kv_cache=True,
                           budget_bytes=budget)
    assert stats.peak_bytes <= budget
    assert stats.expert_misses > 0
    assert stats.new_tokens == 3


# ---------------------------------------------------------------------------
# Unsupported-family + registry error contracts
# ---------------------------------------------------------------------------
def test_unsupported_family_partition_raises():
    cfg = get("xlstm_1_3b").reduced()
    assert cfg.family == XLSTM
    with pytest.raises(ValueError, match="xlstm"):
        partition_and_save({}, cfg, "/tmp/never-written")


def test_unsupported_family_modules_raise():
    cfg = get("zamba2_1_2b").reduced()
    with pytest.raises(ValueError, match="hybrid"):
        build_module_fns(cfg)


def test_expert_split_needs_moe(params, tmp_path):
    dense = get("gpt2_base").reduced()
    with pytest.raises(ValueError, match="MoE"):
        partition_and_save({}, dense, tmp_path / "x", expert_split=True)


def test_registry_get_and_names():
    assert "qwen3_moe_30b_a3b" in names()
    assert "gpt2_base" in names()
    assert get("qwen3-moe-30b-a3b").family == MOE   # dashes tolerated
    with pytest.raises(ValueError, match="choices"):
        get("no_such_arch")
