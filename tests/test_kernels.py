"""Per-kernel validation: shape/dtype sweeps + hypothesis property tests,
all against the pure-jnp oracles in kernels/ref.py (interpret=True on CPU).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from helpers.hypothesis_compat import given, settings, st

from repro.kernels import ops, ref

ATOL = {jnp.float32: 2e-4, jnp.bfloat16: 6e-2}


def rnd(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# streamed_matmul
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (128, 128, 128, 128, 128, 128),     # single tile
    (256, 512, 128, 128, 128, 128),     # multi-tile K streaming
    (384, 256, 512, 128, 256, 256),     # uneven grid
])
def test_matmul_sweep(m, k, n, bm, bn, bk, dtype):
    key = jax.random.PRNGKey(m + n + k)
    x, w = rnd(key, (m, k), dtype), rnd(jax.random.fold_in(key, 1),
                                        (k, n), dtype)
    got = ops.matmul(x, w, block_m=bm, block_n=bn, block_k=bk)
    want = ref.matmul_ref(x, w)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=ATOL[dtype] * np.sqrt(k), rtol=1e-2)


@settings(max_examples=12, deadline=None)
@given(mi=st.integers(1, 3), ki=st.integers(1, 4), ni=st.integers(1, 3),
       seed=st.integers(0, 2**30))
def test_matmul_property(mi, ki, ni, seed):
    m, k, n = 64 * mi, 64 * ki, 64 * ni
    key = jax.random.PRNGKey(seed)
    x, w = rnd(key, (m, k), jnp.float32), rnd(jax.random.fold_in(key, 1),
                                              (k, n), jnp.float32)
    got = ops.matmul(x, w, block_m=64, block_n=64, block_k=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.matmul_ref(
        x, w)), atol=1e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 48)])
@pytest.mark.parametrize("sq,sk,dh", [(128, 128, 64), (64, 256, 32)])
def test_flash_attention_sweep(sq, sk, dh, causal, window, dtype):
    if causal and sq != sk:
        pytest.skip("causal requires square here")
    key = jax.random.PRNGKey(sq + dh)
    q = rnd(key, (4, sq, dh), dtype)
    k = rnd(jax.random.fold_in(key, 1), (4, sk, dh), dtype)
    v = rnd(jax.random.fold_in(key, 2), (4, sk, dh), dtype)
    got = ops.attention(q, k, v, causal=causal, window=window,
                        block_q=64, block_k=64)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=ATOL[dtype], rtol=1e-2)


@settings(max_examples=10, deadline=None)
@given(nq=st.integers(1, 3), dh=st.sampled_from([32, 64]),
       causal=st.booleans(), seed=st.integers(0, 2**30))
def test_flash_attention_property(nq, dh, causal, seed):
    s = 64 * nq
    key = jax.random.PRNGKey(seed)
    q = rnd(key, (2, s, dh), jnp.float32)
    k = rnd(jax.random.fold_in(key, 1), (2, s, dh), jnp.float32)
    v = rnd(jax.random.fold_in(key, 2), (2, s, dh), jnp.float32)
    got = ops.attention(q, k, v, causal=causal, block_q=64, block_k=64)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# flash_decode
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("s,dh,bk", [(256, 64, 64), (512, 128, 128)])
def test_flash_decode_sweep(s, dh, bk, dtype):
    key = jax.random.PRNGKey(s)
    q = rnd(key, (6, dh), dtype)
    k = rnd(jax.random.fold_in(key, 1), (6, s, dh), dtype)
    v = rnd(jax.random.fold_in(key, 2), (6, s, dh), dtype)
    valid = jnp.broadcast_to(jnp.arange(s)[None] < (s - 17), (6, s))
    got = ops.decode(q, k, v, valid, block_k=bk)
    want = ref.decode_ref(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=ATOL[dtype], rtol=1e-2)


def test_flash_decode_partials_combine():
    """Splitting the cache over 'shards' and combining partials must equal
    the single-shard result — the invariant the sequence-sharded decode
    path relies on."""
    key = jax.random.PRNGKey(7)
    s, dh, shards = 256, 32, 4
    q = rnd(key, (3, dh), jnp.float32)
    k = rnd(jax.random.fold_in(key, 1), (3, s, dh), jnp.float32)
    v = rnd(jax.random.fold_in(key, 2), (3, s, dh), jnp.float32)
    valid = jnp.broadcast_to(jnp.arange(s)[None] < 200, (3, s))
    want = ref.decode_ref(q, k, v, valid)

    os_, ms_, ls_ = [], [], []
    for i in range(shards):
        sl = slice(i * s // shards, (i + 1) * s // shards)
        o, m, l = ops.decode_partial(q, k[:, sl], v[:, sl], valid[:, sl],
                                     block_k=32)
        os_.append(o), ms_.append(m), ls_.append(l)
    m_all = jnp.stack(ms_)
    m_star = m_all.max(0)
    w = jnp.exp(m_all - m_star[None])
    l_star = (jnp.stack(ls_) * w).sum(0)
    o_star = (jnp.stack(os_) * w).sum(0)
    got = o_star / jnp.maximum(l_star, 1e-30)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=1e-3)


@settings(max_examples=10, deadline=None)
@given(ns=st.integers(1, 4), dh=st.sampled_from([32, 64]),
       nvalid_frac=st.floats(0.1, 1.0), seed=st.integers(0, 2**30))
def test_flash_decode_property(ns, dh, nvalid_frac, seed):
    s = 64 * ns
    key = jax.random.PRNGKey(seed)
    q = rnd(key, (2, dh), jnp.float32)
    k = rnd(jax.random.fold_in(key, 1), (2, s, dh), jnp.float32)
    v = rnd(jax.random.fold_in(key, 2), (2, s, dh), jnp.float32)
    nvalid = max(int(s * nvalid_frac), 1)
    valid = jnp.broadcast_to(jnp.arange(s)[None] < nvalid, (2, s))
    got = ops.decode(q, k, v, valid, block_k=64)
    want = ref.decode_ref(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=1e-3)
