"""Prefill(S+T) last-logits == prefill(S) + T decode steps, per family.

This is the invariant that catches cache-layout, rope-offset and recurrence
bugs.  MoE uses a large capacity factor (capacity dropping legitimately
breaks prefill/decode equality; see test_moe.py for dropping semantics).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.data.synthetic import make_batch
from repro.models.api import build_model
from repro.models.config import (DENSE, ENCDEC, MAMBA_HYBRID, MOE, VLM,
                                 XLSTM, ModelConfig)

CASES = [
    ModelConfig("dense-gqa", DENSE, 4, 128, 4, 2, 256, 997,
                head_dim=32, vocab_pad_to=8, dtype="float32", remat=False),
    ModelConfig("dense-mla", DENSE, 4, 128, 4, 4, 256, 997, attention="mla",
                q_lora_rank=64, kv_lora_rank=32, rope_head_dim=16,
                v_head_dim=32, vocab_pad_to=8, dtype="float32", remat=False),
    ModelConfig("dense-win", DENSE, 4, 128, 4, 2, 256, 997, head_dim=32,
                sliding_window=16, qkv_bias=True, vocab_pad_to=8,
                dtype="float32", remat=False),
    ModelConfig("moe", MOE, 4, 128, 4, 2, 0, 997, head_dim=32, n_experts=4,
                top_k=2, expert_d_ff=64, capacity_factor=32.0,
                vocab_pad_to=8, dtype="float32", remat=False),
    ModelConfig("xlstm", XLSTM, 4, 128, 4, 4, 0, 997, slstm_every=2,
                ssm_chunk=8, vocab_pad_to=8, dtype="float32", remat=False),
    ModelConfig("zamba", MAMBA_HYBRID, 4, 128, 4, 4, 256, 997, head_dim=32,
                shared_attn_every=2, ssm_state=16, ssm_chunk=8,
                vocab_pad_to=8, dtype="float32", remat=False),
    ModelConfig("encdec", ENCDEC, 2, 128, 4, 4, 256, 997, enc_layers=2,
                enc_seq_len=16, head_dim=32, vocab_pad_to=8,
                dtype="float32", remat=False),
    ModelConfig("vlm", VLM, 4, 128, 4, 2, 256, 997, head_dim=32,
                num_patches=16, mrope_sections=(4, 6, 6), vocab_pad_to=8,
                dtype="float32", remat=False),
]

B, S, T = 2, 24, 4


@pytest.mark.parametrize("cfg", CASES, ids=lambda c: c.name)
def test_prefill_decode_equivalence(cfg):
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    full = make_batch(cfg, B, S + T, seed=3)
    full.pop("labels")
    ref_logits, _ = jax.jit(api.prefill)(params, full)

    short = dict(full)
    n_cut = T
    short["tokens"] = full["tokens"][:, :-n_cut]
    logits, cache = jax.jit(api.prefill)(params, short)

    if cfg.family == XLSTM:
        dcache = cache
    elif cfg.family == MAMBA_HYBRID:
        dcache = api.empty_cache(B, S + T)
        dcache["mamba"] = cache["mamba"]
        dcache["attn"] = jax.tree.map(
            lambda e, f: e.at[:, :, :f.shape[2]].set(f.astype(e.dtype)),
            dcache["attn"], cache["attn"])
    else:
        dcache = api.empty_cache(B, S + T)
        dcache = jax.tree.map(
            lambda e, f: e.at[:, :, :f.shape[2]].set(f.astype(e.dtype)),
            dcache, cache)

    decode = jax.jit(api.decode)
    for t in range(T):
        pos = S + t
        tok = full["tokens"][:, -(T - t)][:, None]
        logits, dcache = decode(params, tok, dcache, pos)

    ref, got = np.asarray(ref_logits), np.asarray(logits)
    scale = np.max(np.abs(ref)) + 1e-9
    assert np.max(np.abs(got - ref)) / scale < 2e-3, cfg.name
