"""Owner-attributed ledger audit suite.

With ``REPRO_LEDGER_AUDIT=1`` (default-on under pytest, see conftest)
the ledger records every charge/credit with its owner, detail tag and
calling site.  These tests arm that machinery the way a real leak
would: skip a release at each PrefetchStream lifecycle exit path and
assert the audit *names the owner* (not just a byte count); drain each
transient owner byte-exact under injected load faults; drain each
request's tagged pages after retire AND after preemption; and pin that
turning the audit on changes nothing about the computation.
"""
import time

import numpy as np
import jax
import pytest
from helpers.ledger import assert_drained

from repro.checkpoint import load_manifest, partition_and_save
from repro.configs import get_config
from repro.core import BatchScheduler, PipeloadEngine, PrefetchRuntime
from repro.core.engine import (LEDGER_OWNERS, LedgerAuditError, _Ledger)
from repro.models.api import build_model

MAX_TOTAL = 16


@pytest.fixture(scope="module")
def gpt2s(tmp_path_factory):
    """Small-but-real GPT-2-geometry checkpoint on disk."""
    cfg = get_config("gpt2_base").with_(
        num_layers=3, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=300, vocab_pad_to=4, remat=False)
    path = tmp_path_factory.mktemp("ckpt") / "gpt2s"
    api = build_model(cfg)
    partition_and_save(api.init(jax.random.PRNGKey(0)), cfg, path)
    return cfg, path


def _mem(path, cfg):
    man = load_manifest(path)
    layer_b = man["layer_bytes"] // cfg.num_layers
    other = man["total_bytes"] - man["layer_bytes"]
    return layer_b, other


def _serve(path, cfg, prompts, news, *, page_size=None, budget=None,
           max_inflight=4, prefix_cache=True, seed=None):
    eng = PipeloadEngine(path, cfg, mode="pipeload", num_agents=2,
                         budget_bytes=budget, page_size=page_size)
    sched = BatchScheduler(eng, max_inflight=max_inflight,
                           max_total_len=MAX_TOTAL,
                           prefix_cache=prefix_cache, seed=seed)
    rids = [sched.submit(p, n) for p, n in zip(prompts, news)]
    outs, stats = sched.run()
    return sched, rids, outs, stats


# ---------------------------------------------------------------------------
# leak injection: skip ONE release per lifecycle exit path, audit names
# the owning subsystem and the leaked acquire's call site
# ---------------------------------------------------------------------------
def _skip_next_release(ledger, skip_owner, skips=1):
    """Monkey-wrench the ledger: silently drop the next ``skips``
    releases tagged ``skip_owner`` — the exact shape of a forgotten
    release on one exit path."""
    real = ledger.release
    state = {"left": skips}

    def release(nbytes, *, owner="untagged", detail=None):
        if owner == skip_owner and state["left"] > 0:
            state["left"] -= 1
            return
        real(nbytes, owner=owner, detail=detail)

    ledger.release = release


def _run_round(runtime, keys, sizes, ledger, *, fail_load=None,
               cancel_at=None):
    def load(key):
        if fail_load is not None and key == keys[fail_load]:
            raise IOError(f"boom:{key}")
        time.sleep(0.001)
        return {"w": key}

    stream = runtime.stream(keys, sizes, load, ledger=ledger)
    try:
        with stream:
            for k in range(len(keys)):
                if cancel_at is not None and k == cancel_at:
                    return          # close() sweep via __exit__
                w = stream.wait(k)
                stream.destroy(k, w)
    except IOError:
        pass


@pytest.mark.parametrize("stage", ["destroy", "cancel", "load-failure"])
def test_skipped_release_names_owner_and_site(stage):
    """A release skipped on the destroy path, the close() cancellation
    sweep, or the load-failure path leaves per-owner residue the audit
    reports by OWNER NAME with the leaked acquire's file:line."""
    keys = [f"shard{i}" for i in range(4)]
    sizes = [100 + i for i in range(4)]
    ledger = _Ledger(None)
    _skip_next_release(ledger, "stream")
    with PrefetchRuntime(workers=2, name="audit") as rt:
        if stage == "destroy":
            _run_round(rt, keys, sizes, ledger)
        elif stage == "cancel":
            _run_round(rt, keys, sizes, ledger, cancel_at=2)
        else:
            _run_round(rt, keys, sizes, ledger, fail_load=2)
    assert ledger.by_owner["stream"] > 0          # the leak is real
    with pytest.raises(LedgerAuditError) as ei:
        ledger.audit_check_drained("stream")
    msg = str(ei.value)
    assert "stream" in msg
    assert ".py:" in msg                          # an acquiring call site


def test_double_release_raises_at_the_releasing_site():
    """Releasing more than an owner ever acquired raises IMMEDIATELY
    (not at drain time), naming the owner that went negative."""
    ledger = _Ledger(None)
    ledger.acquire(100, owner="kv_pages")
    ledger.release(100, owner="kv_pages")
    with pytest.raises(LedgerAuditError, match="kv_pages"):
        ledger.release(100, owner="kv_pages")


def test_wrong_owner_release_is_caught():
    """Bytes acquired as one owner and released as another is the
    miscounting the scalar ledger could never see."""
    ledger = _Ledger(None)
    ledger.acquire(64, owner="stream")
    with pytest.raises(LedgerAuditError, match="kv_pages"):
        ledger.release(64, owner="kv_pages")


# ---------------------------------------------------------------------------
# per-owner exact drain under injected load faults (the serving path)
# ---------------------------------------------------------------------------
def test_per_owner_drain_under_faults(gpt2s, monkeypatch):
    """Transient-fault retries churn the stream owner hard; every
    transient owner still drains byte-exact and the audit agrees."""
    cfg, path = gpt2s
    monkeypatch.setenv("REPRO_PREFETCH_FAULT_RATE", "0.2")
    monkeypatch.setenv("REPRO_PREFETCH_FAULT_SEED", "3")
    monkeypatch.setenv("REPRO_PREFETCH_RETRIES", "6")
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, 300, (8,)) for _ in range(3)]
    sched, _, _, stats = _serve(path, cfg, prompts, [4] * 3,
                                page_size=4, seed=5)
    assert stats.retries > 0                    # faults were exercised
    for owner in ("stream", "kv_pages", "spec_headroom"):
        assert sched.ledger.by_owner.get(owner, 0) == 0, owner
    sched.ledger.audit_check_drained("stream", "kv_pages",
                                     "spec_headroom")
    sched.close()


# ---------------------------------------------------------------------------
# per-request drain: retire and preemption both clear the rid's tag
# ---------------------------------------------------------------------------
def test_request_tagged_pages_drain_on_retire_and_preempt(gpt2s):
    """With prefix sharing off, every page a request maps carries its
    ``req<rid>`` detail tag; after the run (which forced at least one
    preemption) each request's tagged balance is exactly zero."""
    cfg, path = gpt2s
    layer_b, other = _mem(path, cfg)
    ps = 4
    page_b = cfg.num_layers * cfg.cache_bytes(1, ps)
    # room for exactly 7 pages above one streaming layer: three 1-page
    # prompts admit but grow to 4 pages each over decode -> preemption
    budget = other + 7 * page_b + layer_b
    rng = np.random.default_rng(8)
    prompts = [rng.integers(0, 300, (4,)) for _ in range(3)]
    sched, rids, outs, stats = _serve(
        path, cfg, prompts, [12] * 3, budget=budget, page_size=ps,
        max_inflight=3, prefix_cache=False, seed=8)
    assert stats.preemptions >= 1
    for i, rid in enumerate(rids):
        assert len(outs[rid]) == 4 + 12
        assert sched.ledger.audit_residue("kv_pages", f"req{rid}") == 0
        assert sched.ledger.audit_residue("spec_headroom",
                                          f"req{rid}") == 0
    assert_drained(sched.ledger, "kv_pages", "stream",
                   base=sched.ledger.resident)
    sched.close()


# ---------------------------------------------------------------------------
# the audit must observe, never steer
# ---------------------------------------------------------------------------
def test_audit_on_vs_off_identity(gpt2s, monkeypatch):
    """Tokens and every accounting outcome are bitwise identical with
    the audit enabled and disabled — frame-walking and event recording
    never change what the engine computes."""
    cfg, path = gpt2s
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, 300, (8,)) for _ in range(3)]

    def go(audit):
        monkeypatch.setenv("REPRO_LEDGER_AUDIT", "1" if audit else "0")
        sched, rids, outs, stats = _serve(path, cfg, prompts, [4] * 3,
                                          page_size=4, seed=11)
        assert (sched.ledger.audit is not None) is audit
        sched.close()
        return [np.asarray(outs[r]) for r in rids], stats

    outs0, s0 = go(False)
    outs1, s1 = go(True)
    for a, b in zip(outs0, outs1):
        np.testing.assert_array_equal(a, b)
    assert [p[:3] for p in s0.policy] == [p[:3] for p in s1.policy]
    assert (s0.new_tokens, s0.rounds, s0.pages_allocated,
            s0.peak_bytes, s0.peak_breakdown) == \
           (s1.new_tokens, s1.rounds, s1.pages_allocated,
            s1.peak_bytes, s1.peak_breakdown)


# ---------------------------------------------------------------------------
# peak breakdown: shares sum EXACTLY to the recorded peak
# ---------------------------------------------------------------------------
def test_peak_breakdown_sums_exactly_to_peak(gpt2s):
    cfg, path = gpt2s
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, 300, (8,)) for _ in range(2)]
    sched, _, _, stats = _serve(path, cfg, prompts, [4] * 2,
                                page_size=4, seed=2)
    assert stats.peak_bytes > 0
    assert set(stats.peak_breakdown) <= set(LEDGER_OWNERS) | {"untagged"}
    assert sum(stats.peak_breakdown.values()) == stats.peak_bytes
    assert all(b > 0 for b in stats.peak_breakdown.values())
    sched.close()


def test_peak_breakdown_engine_run(gpt2s):
    """The engine-level RunStats carries the same exact attribution."""
    cfg, path = gpt2s
    rng = np.random.default_rng(4)
    toks = rng.integers(0, 300, (1, 8))
    with PipeloadEngine(path, cfg, mode="pipeload", num_agents=2) as eng:
        _, stats = eng.run_generate(toks, 4, kv_cache=True)
    assert sum(stats.peak_breakdown.values()) == stats.peak_bytes
    assert "kv_pages" in stats.peak_breakdown
