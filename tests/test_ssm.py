"""SSM/xLSTM recurrence invariants: chunkwise prefill == step-by-step decode,
and chunk-size invariance of the chunked scan."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models import ssm
from repro.models.config import MAMBA_HYBRID, XLSTM, ModelConfig

CFG = ModelConfig("t", MAMBA_HYBRID, 2, 64, 4, 4, 128, 100, ssm_state=16,
                  ssm_chunk=8, dtype="float32", remat=False)
B, S, D = 2, 24, 64


def _init_x(key, shape):
    return jax.random.normal(key, shape) * 0.5


@pytest.mark.parametrize("cell", ["mamba2", "mlstm", "slstm"])
def test_prefill_equals_stepwise_decode(cell):
    key = jax.random.PRNGKey(0)
    init = getattr(ssm, f"{cell}_init")
    prefill = getattr(ssm, f"{cell}_prefill")
    decode = getattr(ssm, f"{cell}_decode")
    params = init(key, CFG, D)
    x = _init_x(jax.random.fold_in(key, 1), (B, S, D))

    y_ref, st_ref = jax.jit(lambda pp, xx: prefill(pp, xx, CFG))(params, x)

    if cell == "mamba2":
        st = ssm.mamba2_empty_state(CFG, D, B)
    elif cell == "mlstm":
        st = ssm.mlstm_empty_state(CFG, D, B)
    else:
        st = ssm.slstm_empty_state(CFG, D, B)
    dec = jax.jit(lambda pp, xx, ss: decode(pp, xx, CFG, ss))
    ys = []
    for t in range(S):
        y, st = dec(params, x[:, t:t + 1], st)
        ys.append(y)
    y_seq = jnp.concatenate(ys, axis=1)

    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_ref),
                               atol=2e-4, rtol=1e-3)
    # final recurrent states agree too
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(st_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=1e-3)


@pytest.mark.parametrize("cell", ["mamba2", "mlstm"])
def test_chunk_size_invariance(cell):
    """The chunkwise-parallel scan must be exact for ANY chunk size."""
    key = jax.random.PRNGKey(1)
    init = getattr(ssm, f"{cell}_init")
    prefill = getattr(ssm, f"{cell}_prefill")
    params = init(key, CFG, D)
    x = _init_x(jax.random.fold_in(key, 2), (B, S, D))
    outs = []
    for chunk in (4, 8, 24):
        cfg = CFG.with_(ssm_chunk=chunk)
        y, _ = jax.jit(lambda p, xx: prefill(p, xx, cfg))(params, x)
        outs.append(np.asarray(y))
    np.testing.assert_allclose(outs[0], outs[1], atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(outs[0], outs[2], atol=2e-4, rtol=1e-3)


def test_prefill_state_carry():
    """prefill(x1) then prefill(x2, state) == prefill(concat(x1, x2))."""
    key = jax.random.PRNGKey(2)
    params = ssm.mamba2_init(key, CFG, D)
    x = _init_x(jax.random.fold_in(key, 3), (B, S, D))
    y_full, _ = jax.jit(lambda pp, xx: ssm.mamba2_prefill(pp, xx, CFG))(params, x)
    y1, st = jax.jit(lambda pp, xx: ssm.mamba2_prefill(pp, xx, CFG))(params, x[:, :16])
    y2, _ = jax.jit(lambda p, xx, s: ssm.mamba2_prefill(p, xx, CFG, s))(
        params, x[:, 16:], st)
    got = jnp.concatenate([y1, y2], axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(y_full),
                               atol=2e-4, rtol=1e-3)
