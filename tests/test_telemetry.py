"""Telemetry suite: the zero-cost disabled path (no-op singleton
identity — the CI overhead guard), tracer recording + Chrome-trace
export structure, metrics-registry semantics (reset-in-place), the
golden structural trace over a deterministic 2-request serve
(regenerate with ``REPRO_UPDATE_GOLDEN=1``, mirroring
tests/golden/serve_slo_trace.json), the enabled-vs-disabled
token/ledger identity property, fault-outcome surfacing in
RunStats/ServeStats and the planner drift report."""
import json
import os
import threading
from pathlib import Path
from types import SimpleNamespace

import numpy as np
import jax
import pytest

from repro.analysis.report import drift_report, format_drift
from repro.checkpoint import partition_and_save
from repro.configs import get_config
from repro.core import BatchScheduler, PipeloadEngine
from repro.core import telemetry as tele
from repro.models.api import build_model

MAX_TOTAL = 26
GOLDEN = Path(__file__).parent / "golden" / "telemetry_trace.json"


@pytest.fixture(scope="module")
def tiny(tmp_path_factory):
    """3-layer toy checkpoint (same geometry as the serving suites)."""
    cfg = get_config("gpt2_base").with_(
        num_layers=3, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=300, vocab_pad_to=4, remat=False)
    path = tmp_path_factory.mktemp("ckpt") / "tiny"
    api = build_model(cfg)
    partition_and_save(api.init(jax.random.PRNGKey(0)), cfg, path)
    return cfg, path


@pytest.fixture(autouse=True)
def _clean_telemetry():
    """Every test starts (and leaves) with tracing off and a zeroed
    registry — telemetry is process-global state."""
    tele.disable()
    tele.metrics().reset()
    yield
    tele.disable()
    tele.metrics().reset()


def _serve(cfg, path, *, seed=7, requests=2, prompt_len=8, new_tokens=4,
           page=5):
    """One deterministic small serve; returns per-request outputs and
    the ServeStats."""
    eng = PipeloadEngine(path, cfg, mode="pipeload", num_agents=2,
                         page_size=page)
    sched = BatchScheduler(eng, max_inflight=2, max_total_len=MAX_TOTAL,
                           page_size=page, seed=seed)
    rng = np.random.default_rng(seed)
    rids = [sched.submit(rng.integers(0, cfg.vocab_size, (prompt_len,)),
                         new_tokens) for _ in range(requests)]
    outs, stats = sched.run()
    sched.close()
    return [np.asarray(outs[r]) for r in rids], stats


# ---------------------------------------------------------------------------
# zero-cost disabled path: the no-op singletons, by identity
# ---------------------------------------------------------------------------
def test_disabled_path_allocates_nothing():
    """Disabled tracing hands out the SAME shared objects on every call:
    no span instance, no buffer append, no argument capture — the
    structural form of the "zero tracer allocations" overhead guard."""
    tr = tele.get_tracer()
    assert tr is tele.NULL_TRACER
    assert tr.enabled is False
    s1 = tr.span("shard_load", key="h.0", bytes=123)
    s2 = tr.span("compute", layer="h.1")
    assert s1 is tele.NULL_SPAN and s2 is tele.NULL_SPAN
    with s1:
        pass                                   # context protocol is a no-op
    assert tr.instant("admit", rid=0) is None
    assert tr.counter("ledger_resident_bytes", 7) is None


def test_enable_disable_roundtrip():
    t = tele.enable()
    assert tele.get_tracer() is t and t.enabled
    tele.disable()
    assert tele.get_tracer() is tele.NULL_TRACER
    mine = tele.Tracer()
    assert tele.enable(mine) is mine and tele.get_tracer() is mine


# ---------------------------------------------------------------------------
# tracer recording + Chrome trace-event export structure
# ---------------------------------------------------------------------------
def test_export_chrome_trace_structure(tmp_path):
    t = tele.enable()
    with t.span("alpha", n=1):
        pass
    t.instant("beta", rid=7)
    t.counter("gamma", 3)
    t.counter("gamma", 5)

    def work():
        with t.span("alpha", n=2):
            pass
    th = threading.Thread(target=work, name="w_0")
    th.start()
    th.join()

    out = tmp_path / "trace.json"
    trace = tele.export_chrome_trace(out)
    assert json.loads(out.read_text()) == trace
    evs = trace["traceEvents"]
    metas = [e for e in evs if e["ph"] == "M"]
    assert evs[:len(metas)] == metas           # metadata rows lead
    assert {e["args"]["name"] for e in metas} == {"MainThread", "w_0"}
    xs = [e for e in evs if e["ph"] == "X"]
    assert {e["name"] for e in xs} == {"alpha"}
    assert {e["tid"] for e in xs} == {e["tid"] for e in metas}
    (inst,) = [e for e in evs if e["ph"] == "i"]
    assert inst["name"] == "beta" and inst["s"] == "t"
    assert inst["args"] == {"rid": 7}
    cs = [e for e in evs if e["ph"] == "C"]
    assert [e["args"]["value"] for e in cs] == [3.0, 5.0]
    assert all(e["ts"] >= 0 and e["dur"] >= 0 if e["ph"] == "X"
               else e.get("ts", 0) >= 0 for e in evs if e["ph"] != "M")


def test_export_requires_enabled(tmp_path):
    with pytest.raises(ValueError, match="no active tracer"):
        tele.export_chrome_trace(tmp_path / "x.json")


# ---------------------------------------------------------------------------
# metrics registry: instruments survive reset() (call sites cache them)
# ---------------------------------------------------------------------------
def test_metrics_registry_reset_in_place():
    m = tele.metrics()
    c, g, h = m.counter("t.count"), m.gauge("t.gauge"), m.histogram("t.h")
    c.inc()
    c.inc(2)
    g.set(5)
    g.set(2)
    h.observe(1.0)
    h.observe(3.0)
    snap = m.snapshot()
    assert snap["counters"]["t.count"] == 3
    assert snap["gauges"]["t.gauge"] == {"last": 2.0, "min": 2.0,
                                         "max": 5.0, "n": 2}
    assert snap["histograms"]["t.h"]["count"] == 2
    assert snap["histograms"]["t.h"]["max"] == 3.0
    m.reset()
    assert m.counter("t.count") is c and c.value == 0
    assert m.gauge("t.gauge") is g and g.n == 0
    assert m.histogram("t.h") is h and not h.values
    assert tele.counter_values("t.count", "never.touched") == (0, 0)


def test_summary_table():
    txt = tele.summary_table({"a": 1, "longer_name": "x"}, title="t")
    lines = txt.splitlines()
    assert lines[0] == "t:"
    assert lines[1].startswith("  a") and lines[2].endswith("x")
    assert tele.summary_table({}) == "metrics: (empty)"


# ---------------------------------------------------------------------------
# golden structural trace: span/instant/counter names + thread tracks
# ---------------------------------------------------------------------------
def _track(tname: str) -> str:
    """Normalize pool thread names (``pipeload-worker_3`` →
    ``pipeload-worker``): which NUMBERED worker records a span is
    scheduling-dependent, the pool it belongs to is not."""
    stem, _, idx = tname.rpartition("_")
    return stem if stem and idx.isdigit() else tname


def test_golden_trace_structure(tiny):
    """The trace SHAPE of a deterministic 2-request paged serve is
    pinned: which span/instant/counter names fire and which thread
    tracks record them.  Timestamps stay free; the ledger counter
    series must be time-ordered and non-negative."""
    cfg, path = tiny
    tracer = tele.enable()
    try:
        _, stats = _serve(cfg, path)
    finally:
        tele.disable()
    assert stats.requests == 2 and stats.new_tokens > 0
    got = {
        "spans": sorted({s[0] for s in tracer.spans}),
        "instants": sorted({i[0] for i in tracer.instants}),
        "counters": sorted({c[0] for c in tracer.counters}),
        "tracks": sorted({_track(s[1]) for s in tracer.spans}
                         | {_track(i[1]) for i in tracer.instants}),
    }
    ledger = [(t, v) for n, t, v in tracer.counters
              if n == "ledger_resident_bytes"]
    assert ledger, "ledger counter track missing"
    assert all(v >= 0 for _, v in ledger)
    assert [t for t, _ in ledger] == sorted(t for t, _ in ledger)
    if os.environ.get("REPRO_UPDATE_GOLDEN"):
        GOLDEN.parent.mkdir(exist_ok=True)
        GOLDEN.write_text(json.dumps(got, indent=1) + "\n")
        pytest.skip("golden file regenerated")
    want = json.loads(GOLDEN.read_text())
    assert got == want, (
        "telemetry trace structure drifted from "
        "tests/golden/telemetry_trace.json "
        "(intentional? REPRO_UPDATE_GOLDEN=1 to re-pin)")


def test_traced_serve_exports_loadable_json(tiny, tmp_path):
    cfg, path = tiny
    tele.enable()
    try:
        _serve(cfg, path, requests=1, new_tokens=2)
        out = tmp_path / "trace.json"
        trace = tele.export_chrome_trace(out)
    finally:
        tele.disable()
    loaded = json.loads(out.read_text())
    assert loaded["displayTimeUnit"] == "ms"
    tracks = {e["args"]["name"] for e in loaded["traceEvents"]
              if e["ph"] == "M"}
    assert {"MainThread", "pipeload-worker"} <= {_track(t) for t in tracks}
    assert any(e["ph"] == "C" and e["name"] == "ledger_resident_bytes"
               for e in loaded["traceEvents"])
    assert trace == loaded


# ---------------------------------------------------------------------------
# telemetry must not change the computation: enabled == disabled
# ---------------------------------------------------------------------------
def test_enabled_vs_disabled_identity(tiny):
    """Tokens, the policy triple sequence, page accounting and cache
    peaks are bitwise identical with tracing on and off — observability
    never steers the schedule."""
    cfg, path = tiny

    def go(enabled):
        tele.metrics().reset()
        if enabled:
            tele.enable()
        try:
            return _serve(cfg, path, seed=11, requests=3)
        finally:
            tele.disable()

    outs0, s0 = go(False)
    outs1, s1 = go(True)
    for a, b in zip(outs0, outs1):
        np.testing.assert_array_equal(a, b)
    assert [p[:3] for p in s0.policy] == [p[:3] for p in s1.policy]
    assert (s0.new_tokens, s0.rounds, s0.pages_allocated,
            s0.pool_pages_peak, s0.cache_bytes_peak) == \
           (s1.new_tokens, s1.rounds, s1.pages_allocated,
            s1.pool_pages_peak, s1.cache_bytes_peak)


# ---------------------------------------------------------------------------
# fault-injection outcomes surface in RunStats / ServeStats
# ---------------------------------------------------------------------------
def test_fault_outcomes_surface_in_serve_stats(tiny, monkeypatch):
    cfg, path = tiny
    monkeypatch.setenv("REPRO_PREFETCH_FAULT_RATE", "0.2")
    monkeypatch.setenv("REPRO_PREFETCH_FAULT_SEED", "3")
    monkeypatch.setenv("REPRO_PREFETCH_RETRIES", "6")
    _, stats = _serve(cfg, path, seed=5, requests=2)
    assert stats.retries > 0
    assert stats.faults_absorbed > 0
    assert stats.retries >= stats.faults_absorbed
    # clean run from the same (zeroed) registry reports zero
    monkeypatch.setenv("REPRO_PREFETCH_FAULT_RATE", "0")
    tele.metrics().reset()
    _, clean = _serve(cfg, path, seed=5, requests=2)
    assert clean.retries == 0 and clean.faults_absorbed == 0


def test_fault_outcomes_surface_in_run_stats(tiny, monkeypatch):
    cfg, path = tiny
    monkeypatch.setenv("REPRO_PREFETCH_FAULT_RATE", "0.2")
    monkeypatch.setenv("REPRO_PREFETCH_FAULT_SEED", "3")
    monkeypatch.setenv("REPRO_PREFETCH_RETRIES", "6")
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (1, 8)).astype(np.int32)
    eng = PipeloadEngine(path, cfg, mode="pipeload", num_agents=2)
    try:
        _, stats = eng.run_generate(toks, 3, kv_cache=True)
    finally:
        eng.close()
    assert stats.retries > 0
    assert stats.faults_absorbed > 0


# ---------------------------------------------------------------------------
# planner drift report
# ---------------------------------------------------------------------------
def test_drift_report_rows():
    plan = SimpleNamespace(predicted_ttft_s=0.5, predicted_tpot_s=0.1,
                           predicted_throughput_tps=20.0,
                           predicted_peak_bytes=1000)
    stats = SimpleNamespace(ttft_p50_s=1.0, tpot_p50_s=0.1,
                            tokens_per_s=10.0, peak_bytes=500)
    rep = drift_report(plan, stats)
    by = {r["metric"]: r for r in rep["rows"]}
    assert set(by) == {"ttft_s", "tpot_s", "throughput_tps", "peak_bytes"}
    assert by["ttft_s"]["ratio"] == pytest.approx(2.0)
    assert by["tpot_s"]["ratio"] == pytest.approx(1.0)
    assert by["throughput_tps"]["ratio"] == pytest.approx(0.5)
    assert by["peak_bytes"]["ratio"] == pytest.approx(0.5)
    txt = format_drift(rep)
    assert "ttft_s" in txt and "2.00x" in txt


def test_drift_report_handles_missing_predictions():
    rep = drift_report(SimpleNamespace(), SimpleNamespace(peak_bytes=5))
    assert all(r["ratio"] is None for r in rep["rows"])
    assert "—" in format_drift(rep)          # renders, no crash
