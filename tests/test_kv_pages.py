"""Paged KV-cache subsystem: PagePool/PrefixTree bookkeeping, the
Pallas paged flash-decode kernel vs its jnp oracle, paged scheduler
serving (token equivalence, shared-page refcounts, COW, preemption,
budget/drain properties) and the page-size-aware planner."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from helpers.hypothesis_compat import given, settings, st

from repro.checkpoint import load_manifest, partition_and_save
from repro.configs import get_config
from repro.core import BatchScheduler, Hermes, PipeloadEngine
from repro.core.engine import _Ledger
from repro.core.kv_pages import (BlockTable, PagePool, PrefixTree,
                                 pages_for)
from repro.core.planner import plan_generate
from repro.kernels import ops, ref
from repro.models.api import build_model

MAX_TOTAL = 16


@pytest.fixture(scope="module")
def gpt2s(tmp_path_factory):
    """Small-but-real GPT-2-geometry checkpoint on disk."""
    cfg = get_config("gpt2_base").with_(
        num_layers=3, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=300, vocab_pad_to=4, remat=False)
    path = tmp_path_factory.mktemp("ckpt") / "gpt2s"
    api = build_model(cfg)
    partition_and_save(api.init(jax.random.PRNGKey(0)), cfg, path)
    return cfg, path


def _mem(path, cfg):
    man = load_manifest(path)
    layer_b = man["layer_bytes"] // cfg.num_layers
    other = man["total_bytes"] - man["layer_bytes"]
    return layer_b, other


# ---------------------------------------------------------------------------
# PagePool bookkeeping
# ---------------------------------------------------------------------------
def test_pool_alloc_share_release_ledger_exact():
    led = _Ledger(None)
    pool = PagePool(4, 100, led)
    a, b = pool.alloc(), pool.alloc()
    assert led.resident == 200 and pool.mapped_bytes == 200
    pool.share(a)                       # refcount bump: no new bytes
    assert led.resident == 200
    assert not pool.release(a)          # sibling still holds it
    assert led.resident == 200
    assert pool.release(a)              # last reference -> freed
    assert led.resident == 100
    assert pool.release(b)
    assert led.resident == 0 and pool.mapped_pages == 0


def test_pool_free_list_reuse_keeps_high_water():
    pool = PagePool(4, 1)
    pids = [pool.alloc() for _ in range(5)]
    for p in pids:
        pool.release(p)
    again = [pool.alloc() for _ in range(5)]
    assert sorted(again) == sorted(pids)       # recycled, not grown
    assert pool.capacity == 5                  # high-water mark
    assert pool.stats.reuses == 5


def test_pool_errors():
    pool = PagePool(4, 1)
    with pytest.raises(KeyError):
        pool.release(0)
    with pytest.raises(KeyError):
        pool.share(7)
    with pytest.raises(ValueError):
        PagePool(0, 1)


# ---------------------------------------------------------------------------
# PrefixTree sharing semantics
# ---------------------------------------------------------------------------
def test_tree_full_page_prefix_sharing():
    pool, tree = PagePool(4, 1), PrefixTree(4)
    p1, s1 = tree.insert(list(range(10)), pool)         # 2 full + partial
    assert len(p1) == 3 and s1 == 0
    # same first 8 tokens, different tail: shares the 2 full pages only
    p2, s2 = tree.insert(list(range(8)) + [99, 98], pool)
    assert s2 == 2 and p2[:2] == p1[:2] and p2[2] != p1[2]
    assert pool.refcount(p1[0]) == 2
    # identical prompt: shares ALL pages including the partial one
    p3, s3 = tree.insert(list(range(10)), pool)
    assert s3 == 3 and p3 == p1
    # diverging first page: nothing shared
    p4, s4 = tree.insert([5, 4, 3, 2, 1], pool)
    assert s4 == 0 and not set(p4) & set(p1)


def test_tree_prunes_on_forget_and_drains():
    pool, tree = PagePool(4, 1), PrefixTree(4)
    t1 = BlockTable(*tree.insert(list(range(8)), pool))
    t2 = BlockTable(*tree.insert(list(range(8)), pool))
    assert t2.n_shared == 2
    t1.release_all(pool, tree)
    assert pool.mapped_pages == 2          # t2 still holds both pages
    t2.release_all(pool, tree)
    assert pool.mapped_pages == 0
    # pruned: a new identical prompt re-allocates instead of sharing
    _, s = tree.insert(list(range(8)), pool)
    assert s == 0


def test_cow_release_of_last_reference_must_prune_tree():
    """The scheduler's COW drops one reference on the old shared page;
    if the sibling was preempted mid-COW that drop is the LAST one and
    the tree node must be pruned with it, or a later identical prompt
    would share a recycled page id holding someone else's K/V."""
    pool, tree = PagePool(4, 1), PrefixTree(4)
    t_a = BlockTable(*tree.insert(list(range(4)), pool))
    t_b = BlockTable(*tree.insert(list(range(4)), pool))
    pid = t_a.pages[0]
    assert pool.refcount(pid) == 2
    t_b.release_all(pool, tree)            # sibling preempted mid-COW
    # A's COW now drops the LAST reference — scheduler must forget(pid)
    if pool.release(pid):
        tree.forget(pid)
    t_a.pages[0] = pool.alloc()            # the private COW copy
    # a newcomer with the same prompt must NOT hit the stale node
    pids, shared = tree.insert(list(range(4)), pool)
    assert shared == 0 and pool.refcount(pids[0]) == 1


def test_pages_for():
    assert pages_for(0, 4) == 0
    assert pages_for(1, 4) == 1
    assert pages_for(4, 4) == 1
    assert pages_for(5, 4) == 2


# ---------------------------------------------------------------------------
# property: any alloc/share/free interleaving keeps the ledger exact,
# never overruns the accounted budget, drains to zero, and the pool
# plateaus at its high-water mark
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), n_reqs=st.integers(1, 6),
       page_size=st.sampled_from([1, 2, 4]))
def test_pool_interleaving_property(seed, n_reqs, page_size):
    rng = np.random.default_rng(seed)
    led = _Ledger(None)
    pool = PagePool(page_size, 10, led)
    tree = PrefixTree(page_size)
    live = {}
    hw = 0
    for step in range(40):
        assert led.resident == pool.mapped_bytes       # ledger exact
        hw = max(hw, pool.mapped_pages)
        op = rng.integers(0, 3)
        if op == 0 and len(live) < n_reqs:             # admit
            toks = rng.integers(0, 3, rng.integers(1, 10)).tolist()
            live[step] = BlockTable(*tree.insert(toks, pool))
        elif op == 1 and live:                          # grow one page
            t = live[rng.choice(list(live))]
            t.pages.append(pool.alloc())
        elif op == 2 and live:                          # retire
            k = rng.choice(list(live))
            live.pop(k).release_all(pool, tree)
        assert pool.capacity <= max(hw, pool.mapped_pages)  # high-water
    for t in list(live.values()):
        t.release_all(pool, tree)
    assert pool.mapped_pages == 0 and led.resident == 0  # exact drain
    assert pool.capacity == hw


# ---------------------------------------------------------------------------
# Pallas paged kernel == jnp oracle across a (page, seq) sweep
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("page,nb", [(4, 3), (8, 4), (16, 2), (64, 2)])
def test_paged_kernel_matches_oracle(page, nb):
    rng = np.random.default_rng(page * 100 + nb)
    b, kv, g, dh, n_pages = 3, 2, 2, 32, 2 * nb + 3
    kp = jnp.asarray(rng.normal(size=(n_pages, page, kv, dh)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(n_pages, page, kv, dh)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(b, kv, g, dh)), jnp.float32)
    tables = jnp.asarray(rng.integers(0, n_pages, (b, nb)), jnp.int32)
    lengths = jnp.asarray(rng.integers(1, nb * page + 1, (b,)), jnp.int32)
    out = ops.paged_decode(q, kp, vp, tables, lengths)
    exp = ref.paged_decode_ref(q, kp, vp, tables, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), page=st.sampled_from([2, 4, 8]),
       nb=st.integers(1, 4))
def test_paged_kernel_property(seed, page, nb):
    rng = np.random.default_rng(seed)
    b, dh = int(rng.integers(1, 4)), 16
    kv, g = int(rng.integers(1, 3)), int(rng.integers(1, 3))
    n_pages = nb + int(rng.integers(1, 4))
    kp = jnp.asarray(rng.normal(size=(n_pages, page, kv, dh)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(n_pages, page, kv, dh)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(b, kv, g, dh)), jnp.float32)
    tables = jnp.asarray(rng.integers(0, n_pages, (b, nb)), jnp.int32)
    lengths = jnp.asarray(rng.integers(1, nb * page + 1, (b,)), jnp.int32)
    out = ops.paged_decode(q, kp, vp, tables, lengths)
    exp = ref.paged_decode_ref(q, kp, vp, tables, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# paged serving == dense serving, token for token
# ---------------------------------------------------------------------------
def _serve(path, cfg, prompts, news, *, page_size=None, budget=None,
           max_inflight=4, prefix_cache=True, seed=None, pin=0):
    eng = PipeloadEngine(path, cfg, mode="pipeload", num_agents=2,
                         budget_bytes=budget, pin_window=pin,
                         page_size=page_size)
    sched = BatchScheduler(eng, max_inflight=max_inflight,
                           max_total_len=MAX_TOTAL,
                           prefix_cache=prefix_cache, seed=seed)
    rids = [sched.submit(p, n) for p, n in zip(prompts, news)]
    outs, stats = sched.run()
    return sched, rids, outs, stats


def test_paged_equals_dense_shared_prefixes(gpt2s):
    cfg, path = gpt2s
    rng = np.random.default_rng(1)
    shared = rng.integers(0, 300, (8,))
    prompts = [np.concatenate([shared, rng.integers(0, 300, (4,))])
               for _ in range(3)]
    news = [4, 2, 3]
    _, rd, outs_d, st_d = _serve(path, cfg, prompts, news)
    s, rp, outs_p, st_p = _serve(path, cfg, prompts, news, page_size=4)
    for a, b in zip(rp, rd):
        np.testing.assert_array_equal(outs_p[a], outs_d[b])
    assert st_p.prefix_hit_pages > 0            # the shared prompt hit
    assert st_p.cache_bytes_peak < st_d.cache_bytes_peak
    assert s.pool.mapped_pages == 0             # drained


def test_paged_equals_sequential_odd_page_size(gpt2s):
    """Page size that does NOT divide max_total_len still decodes the
    right tokens (the gathered cache is just padded a little longer)."""
    cfg, path = gpt2s
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, 300, (7,)) for _ in range(2)]
    refs = []
    for p in prompts:
        eng = PipeloadEngine(path, cfg, mode="pipeload", num_agents=2)
        out, _ = eng.run_generate(p[None], 4, kv_cache=True)
        refs.append(np.asarray(out)[0])
    _, rids, outs, _ = _serve(path, cfg, prompts, [4, 4], page_size=5)
    for rid, r in zip(rids, refs):
        np.testing.assert_array_equal(outs[rid], r)


def test_paged_with_pinned_window(gpt2s):
    cfg, path = gpt2s
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, 300, (8,)) for _ in range(2)]
    _, rd, outs_d, _ = _serve(path, cfg, prompts, [3, 3])
    _, rp, outs_p, st = _serve(path, cfg, prompts, [3, 3], page_size=4,
                               pin=2)
    for a, b in zip(rp, rd):
        np.testing.assert_array_equal(outs_p[a], outs_d[b])


def test_paged_equals_dense_mla(tmp_path):
    """MLA caches ({c, kr} latent leaves) ride the generic
    gather -> layer_decode -> scatter path."""
    cfg = get_config("minicpm3_4b").reduced().with_(
        num_layers=2, vocab_size=300, vocab_pad_to=4)
    assert cfg.attention == "mla"
    path = tmp_path / "mla"
    api = build_model(cfg)
    partition_and_save(api.init(jax.random.PRNGKey(0)), cfg, path)
    rng = np.random.default_rng(12)
    prompts = [rng.integers(0, 300, (6,)) for _ in range(2)]
    _, rd, outs_d, _ = _serve(path, cfg, prompts, [3, 3])
    _, rp, outs_p, _ = _serve(path, cfg, prompts, [3, 3], page_size=4)
    for a, b in zip(rp, rd):
        np.testing.assert_array_equal(outs_p[a], outs_d[b])


def test_prefix_cache_off_allocates_private_pages(gpt2s):
    cfg, path = gpt2s
    rng = np.random.default_rng(4)
    p = rng.integers(0, 300, (8,))
    _, _, outs_on, st_on = _serve(path, cfg, [p, p], [3, 3], page_size=4)
    _, _, outs_off, st_off = _serve(path, cfg, [p, p], [3, 3], page_size=4,
                                    prefix_cache=False)
    assert st_on.prefix_hit_pages > 0
    assert st_off.prefix_hit_pages == 0
    assert st_off.pages_allocated > st_on.pages_allocated
    for rid in outs_on:
        np.testing.assert_array_equal(outs_on[rid], outs_off[rid])


# ---------------------------------------------------------------------------
# satellite regression: refcounted shared pages are NOT freed while a
# sibling request is still live (page-granular exact drain on retire)
# ---------------------------------------------------------------------------
def test_shared_pages_survive_sibling_retirement(gpt2s):
    cfg, path = gpt2s
    rng = np.random.default_rng(5)
    shared = rng.integers(0, 300, (8,))       # 2 full pages at ps=4
    p1 = np.concatenate([shared, rng.integers(0, 300, (2,))])
    p2 = np.concatenate([shared, rng.integers(0, 300, (2,))])
    eng = PipeloadEngine(path, cfg, mode="pipeload", num_agents=2,
                         page_size=4)
    sched = BatchScheduler(eng, max_inflight=2, max_total_len=MAX_TOTAL)
    r1 = sched.submit(p1, 1)                  # retires after one round
    r2 = sched.submit(p2, 5)                  # keeps decoding
    sched.step()                              # both admitted + prefilled
    sched.step()                              # r1 retires here
    assert r1 in sched.done and r2 not in sched.done
    live = sched.inflight[0].table
    shared_pids = live.pages[:live.n_shared]
    assert shared_pids, "prefix pages should be shared"
    # the retired sibling dropped ITS references; the pages survive
    for pid in shared_pids:
        assert sched.pool.refcount(pid) == 1
    # and the survivor keeps decoding the same tokens as a solo run
    while sched.step():
        pass
    eng2 = PipeloadEngine(path, cfg, mode="pipeload", num_agents=2)
    ref_out, _ = eng2.run_generate(p2[None], 5, kv_cache=True)
    np.testing.assert_array_equal(sched.done[r2].tokens,
                                  np.asarray(ref_out)[0])
    assert sched.pool.mapped_pages == 0       # full drain at the end


def test_cow_on_identical_prompts(gpt2s):
    """Two identical prompts share even the partial last page; the
    first divergent decode write must copy-on-write, not clobber."""
    cfg, path = gpt2s
    rng = np.random.default_rng(6)
    p = rng.integers(0, 300, (10,))           # 2 full + 1 partial page
    s, rids, outs, st = _serve(path, cfg, [p, p], [4, 4], page_size=4)
    assert st.cow_copies >= 1
    np.testing.assert_array_equal(outs[rids[0]], outs[rids[1]])
    eng = PipeloadEngine(path, cfg, mode="pipeload", num_agents=2)
    ref_out, _ = eng.run_generate(p[None], 4, kv_cache=True)
    np.testing.assert_array_equal(outs[rids[0]], np.asarray(ref_out)[0])


# ---------------------------------------------------------------------------
# budget: paged admission floor, growth preemption, exact drain
# ---------------------------------------------------------------------------
def test_paged_admits_more_than_dense_at_same_budget(gpt2s):
    cfg, path = gpt2s
    layer_b, other = _mem(path, cfg)
    per_req = cfg.num_layers * cfg.cache_bytes(1, MAX_TOTAL)
    # one streaming layer + 2.5 dense caches: dense admits 2, pages fit 3
    budget = other + layer_b + int(2.5 * per_req)
    rng = np.random.default_rng(7)
    shared = rng.integers(0, 300, (12,))          # 3 shared pages of 4
    prompts = [np.concatenate([shared, rng.integers(0, 300, (1,))])
               for _ in range(4)]
    news = [3] * 4
    _, _, outs_d, st_d = _serve(path, cfg, prompts, news, budget=budget)
    _, _, outs_p, st_p = _serve(path, cfg, prompts, news, budget=budget,
                                page_size=4)
    assert st_d.max_inflight_seen == 2
    assert st_p.max_inflight_seen > st_d.max_inflight_seen
    assert st_p.peak_bytes <= budget
    for rid in outs_d:
        np.testing.assert_array_equal(outs_p[rid], outs_d[rid])


def test_growth_preemption_recovers_and_finishes(gpt2s):
    """Admission lets several short-prompt requests in, but their decode
    growth outruns the budget: the youngest is preempted, re-queued and
    finished later — nobody deadlocks, everyone gets every token."""
    cfg, path = gpt2s
    layer_b, other = _mem(path, cfg)
    ps = 4
    page_b = cfg.num_layers * cfg.cache_bytes(1, ps)
    # room for EXACTLY 7 pages above one streaming layer: three 1-page
    # prompts admit (3 mapped + 3 headroom), but each grows to 4 pages
    # (16 tokens) over decode — 12 > 7 forces preemption
    budget = other + 7 * page_b + layer_b
    rng = np.random.default_rng(8)
    prompts = [rng.integers(0, 300, (4,)) for _ in range(3)]
    news = [12] * 3
    s, rids, outs, st = _serve(path, cfg, prompts, news, budget=budget,
                               page_size=ps, max_inflight=3)
    assert st.requests == 3
    for i, rid in enumerate(rids):
        assert len(outs[rid]) == 4 + news[i]
    assert st.preemptions >= 1
    assert st.peak_bytes <= budget
    assert s.pool.mapped_pages == 0


def test_preemption_victim_is_youngest_even_when_growing(gpt2s):
    """Strict age order: when growth cannot clear the floor, the
    YOUNGEST request is bounced — even if it is the one growing — and
    the oldest is never preempted."""
    cfg, path = gpt2s
    layer_b, other = _mem(path, cfg)
    ps = 4
    page_b = cfg.num_layers * cfg.cache_bytes(1, ps)
    budget = other + 6 * page_b + layer_b
    rng = np.random.default_rng(13)
    eng = PipeloadEngine(path, cfg, mode="pipeload", num_agents=2,
                         budget_bytes=budget, page_size=ps)
    sched = BatchScheduler(eng, max_inflight=2, max_total_len=MAX_TOTAL)
    r_old = sched.submit(rng.integers(0, 300, (4,)), 12)
    r_new = sched.submit(rng.integers(0, 300, (4,)), 12, arrival_round=1)
    outs, st = sched.run()
    assert st.requests == 2
    assert all(len(outs[r]) == 16 for r in (r_old, r_new))
    preempted = {e[2] for e in st.event_log(["preempt"])}
    assert preempted == {f"req{r_new}"}       # never the oldest
    assert st.peak_bytes <= budget


def test_submit_rejects_budget_without_admission_headroom(gpt2s):
    """A budget fitting a request's pages EXACTLY but not the one-page
    admission headroom must be rejected at submit() — accepting it
    would leave the request queued forever (regression: run() used to
    spin)."""
    cfg, path = gpt2s
    layer_b, other = _mem(path, cfg)
    ps = 8
    page_b = cfg.num_layers * cfg.cache_bytes(1, ps)
    # prompt 6 + 2 new tokens = 1 page; admission needs 1 + 1 headroom
    budget = other + layer_b + page_b
    eng = PipeloadEngine(path, cfg, mode="pipeload", num_agents=2,
                         budget_bytes=budget, page_size=ps)
    sched = BatchScheduler(eng, max_inflight=2, max_total_len=MAX_TOTAL)
    with pytest.raises(ValueError, match="KV decode floor"):
        sched.submit(np.arange(6), 2)
    # one more page of budget and the same request serves fine
    eng2 = PipeloadEngine(path, cfg, mode="pipeload", num_agents=2,
                         budget_bytes=budget + page_b, page_size=ps)
    sched2 = BatchScheduler(eng2, max_inflight=2, max_total_len=MAX_TOTAL)
    rid = sched2.submit(np.arange(6), 2)
    outs, st = sched2.run()
    assert len(outs[rid]) == 8 and st.requests == 1


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000), n_reqs=st.integers(1, 4),
       page_size=st.sampled_from([2, 4, 5]),
       cache_pages=st.integers(6, 14),
       share=st.booleans())
def test_paged_serving_property(gpt2s, seed, n_reqs, page_size,
                                cache_pages, share):
    """Random paged workloads under tight budgets: never deadlock,
    never exceed the budget, retire every request with its full token
    count, and drain the pool to zero."""
    cfg, path = gpt2s
    layer_b, other = _mem(path, cfg)
    page_b = cfg.num_layers * cfg.cache_bytes(1, page_size)
    need = pages_for(MAX_TOTAL, page_size) + 1
    budget = other + max(cache_pages, need) * page_b + 2 * layer_b
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, 300, (6,)) if share else None
    prompts, news = [], []
    for i in range(n_reqs):
        tail = rng.integers(0, 300, (int(rng.integers(1, 5)),))
        p = np.concatenate([shared, tail]) if share else tail
        prompts.append(p)
        news.append(int(rng.integers(1, MAX_TOTAL - len(p) + 1)))
    s, rids, outs, st = _serve(path, cfg, prompts, news, budget=budget,
                               page_size=page_size, max_inflight=3)
    assert st.requests == n_reqs
    for i, rid in enumerate(rids):
        assert len(outs[rid]) == len(prompts[i]) + news[i]
    assert st.peak_bytes <= budget
    assert s.pool.mapped_pages == 0
    assert s.ledger.resident == sum(
        s.engine.shards[a]["bytes"] for a in ("embed", "head"))


# ---------------------------------------------------------------------------
# engine: single-request paged accounting lowers the ledger peak
# ---------------------------------------------------------------------------
def test_engine_paged_generate_same_tokens_lower_peak(gpt2s):
    cfg, path = gpt2s
    layer_b, other = _mem(path, cfg)
    cache = cfg.num_layers * cfg.cache_bytes(1, 14)
    budget = other + cache + 3 * layer_b
    rng = np.random.default_rng(9)
    p = rng.integers(0, 300, (6,))
    eng_d = PipeloadEngine(path, cfg, mode="pipeload", num_agents=2,
                           budget_bytes=budget)
    out_d, st_d = eng_d.run_generate(p[None], 8, kv_cache=True)
    eng_p = PipeloadEngine(path, cfg, mode="pipeload", num_agents=2,
                           budget_bytes=budget, page_size=2)
    out_p, st_p = eng_p.run_generate(p[None], 8, kv_cache=True)
    np.testing.assert_array_equal(np.asarray(out_d), np.asarray(out_p))
    assert st_p.cache_bytes <= st_d.cache_bytes
    assert st_p.peak_bytes <= st_d.peak_bytes
    # paged run reserves page-by-page: more cache_reserve events
    assert len(st_p.event_log(["cache_reserve"])) > 1


def test_engine_paged_falls_back_dense_for_expert_split(tmp_path):
    """page_size + expert-split MoE: _bind_expert sizes the ExpertCache
    from ledger headroom at bind time, so incremental page charging
    would hand the decode pages' bytes to the cache and deadlock the
    first growth (regression).  The engine must reserve up front."""
    from repro.models.config import MOE, ModelConfig
    cfg = ModelConfig("moe-paged-test", MOE, 2, 64, 4, 2, 0, 256,
                      head_dim=16, n_experts=4, top_k=2, expert_d_ff=32,
                      dtype="float32", vocab_pad_to=64, remat=False)
    path = tmp_path / "moe"
    partition_and_save(build_model(cfg).init(jax.random.PRNGKey(0)),
                       cfg, path)
    man = load_manifest(path)
    assert man["expert_split"]
    budget = man["total_bytes"] + cfg.num_layers * cfg.cache_bytes(1, 10)
    eng_d = PipeloadEngine(path, cfg, mode="pipeload", num_agents=2,
                           budget_bytes=budget)
    out_d, _ = eng_d.run_generate(np.arange(6)[None], 4, kv_cache=True)
    eng_p = PipeloadEngine(path, cfg, mode="pipeload", num_agents=2,
                           budget_bytes=budget, page_size=2)
    out_p, st = eng_p.run_generate(np.arange(6)[None], 4, kv_cache=True)
    np.testing.assert_array_equal(np.asarray(out_d), np.asarray(out_p))
    # up-front reservation: exactly ONE cache_reserve event
    assert len(st.event_log(["cache_reserve"])) == 1


def test_engine_paged_budget_floor_still_enforced(gpt2s):
    cfg, path = gpt2s
    layer_b, other = _mem(path, cfg)
    eng = PipeloadEngine(path, cfg, mode="pipeload", num_agents=2,
                         budget_bytes=other + layer_b, page_size=4)
    with pytest.raises(ValueError, match="KV decode floor"):
        eng.run_generate(np.arange(6)[None], 4, kv_cache=True)


# ---------------------------------------------------------------------------
# scheduler config surface
# ---------------------------------------------------------------------------
def test_seed_recorded_in_serve_stats(gpt2s):
    cfg, path = gpt2s
    rng = np.random.default_rng(10)
    p = rng.integers(0, 300, (6,))
    _, _, _, st = _serve(path, cfg, [p], [2], seed=1234)
    assert st.seed == 1234
    _, _, _, st2 = _serve(path, cfg, [p], [2])
    assert st2.seed is None


def test_scheduler_inherits_engine_page_size(gpt2s):
    cfg, path = gpt2s
    eng = PipeloadEngine(path, cfg, mode="pipeload", num_agents=2,
                         page_size=4)
    sched = BatchScheduler(eng, max_inflight=2, max_total_len=MAX_TOTAL)
    assert sched.page_size == 4 and sched.pool is not None


# ---------------------------------------------------------------------------
# planner: page-size dimension
# ---------------------------------------------------------------------------
def _profile(n_layers=4, layer_b=1000, other=500):
    shards = [{"name": f"L{i}", "kind": "layer", "bytes": layer_b,
               "t_load": 1e-3, "t_comp": 1e-4, "t_decode": 1e-5}
              for i in range(n_layers)]
    return {"num_layers": n_layers, "layer_bytes": layer_b,
            "other_bytes": other, "shards": shards, "seq": 8,
            "quant": None}


def test_planner_paged_admits_more_inflight_with_sharing():
    prof = _profile()
    total, cbl = 32, 32 * 10              # 10 bytes per token per layer
    budget = prof["other_bytes"] + 2 * prof["layer_bytes"] \
        + 4 * 2 * cbl                     # ~2 dense requests' caches
    dense = plan_generate(prof, [budget], new_tokens=8,
                          cache_bytes_per_layer=cbl, max_pin=0,
                          max_inflight=8)[0]
    paged = plan_generate(prof, [budget], new_tokens=8,
                          cache_bytes_per_layer=cbl, max_pin=0,
                          max_inflight=8, page_sizes=(8,), total_len=total,
                          shared_prefix_len=24)[0]
    assert paged.feasible and dense.feasible
    assert paged.page_size == 8
    assert paged.inflight > dense.inflight
    assert paged.cache_bytes < dense.cache_bytes * paged.inflight


def test_planner_page_size_requires_total_len():
    with pytest.raises(ValueError, match="total_len"):
        plan_generate(_profile(), [None], new_tokens=4,
                      cache_bytes_per_layer=100, page_sizes=(8,))


def test_planner_dense_entry_unchanged_without_pages():
    prof = _profile()
    e = plan_generate(prof, [None], new_tokens=4,
                      cache_bytes_per_layer=100)[0]
    assert e.page_size == 0


def test_hermes_scheduler_facade_paged(gpt2s, tmp_path):
    cfg, path = gpt2s
    h = Hermes(path, cfg)
    h.profile(batch=1, seq=8, force=True)
    layer_b, other = _mem(path, cfg)
    page_b = cfg.num_layers * cfg.cache_bytes(1, 4)
    budget = other + 14 * page_b + 3 * layer_b
    sched = h.scheduler(budget_bytes=budget, max_inflight=3,
                        prompt_len=8, new_tokens=4, page_sizes=(4,),
                        shared_prefix_len=8, seed=7)
    assert sched.page_size in (0, 4, None) or sched.page_size == 4
    rng = np.random.default_rng(11)
    shared = rng.integers(0, 300, (8,))
    for _ in range(3):
        sched.submit(shared, 4)
    outs, stats = sched.run()
    assert stats.requests == 3
    assert stats.peak_bytes <= budget
    assert stats.seed == 7


def test_paged_rejects_expert_split(gpt2s):
    cfg, path = gpt2s
    eng = PipeloadEngine(path, cfg, mode="pipeload", num_agents=2)
    eng.expert = object()     # simulate an expert-split engine
    with pytest.raises(ValueError, match="expert-split"):
        BatchScheduler(eng, max_inflight=2, max_total_len=MAX_TOTAL,
                       page_size=4)
