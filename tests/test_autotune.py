"""Kernel autotune cache (kernels/autotune.py): candidate fitting, the
disk cache round-trip, cache hits skipping the timing sweep, and how
selections flow into the jitted wrappers (ops._resolve_tiles) and the
auto attn-impl choice (core.modules.resolve_attn_impl)."""
import json

import jax
import pytest

from repro.configs import get_config
from repro.core.modules import resolve_attn_impl
from repro.kernels import autotune, ops
from repro.kernels.autotune import AutotuneCache, _fit, _tile_candidates


@pytest.fixture(autouse=True)
def _clean_tuned():
    """Every test starts and ends with no installed selections (the
    tuned defaults are process-wide)."""
    ops.set_tuned()
    yield
    ops.set_tuned()


def test_fit_finds_largest_divisor():
    assert _fit(256, 256) == 256
    assert _fit(256, 64) == 64          # clamped to the dim
    assert _fit(128, 192) == 96         # largest divisor <= 128
    assert _fit(512, 300) == 300        # dim itself when nothing smaller


def test_tile_candidates_divide_and_int4_even():
    for bm, bn, bk in _tile_candidates(256, 768, 3072, None):
        assert 256 % bm == 0 and 3072 % bn == 0 and 768 % bk == 0
    for _, _, bk in _tile_candidates(8, 70, 64, 4):
        assert bk % 2 == 0              # int4 packs two k-rows per byte


def test_cache_roundtrip_and_versioning(tmp_path):
    path = tmp_path / "at.json"
    c = AutotuneCache(path)
    entry = {"block_m": 64, "block_n": 64, "block_k": 128, "t_us": 10.0}
    c.put("matmul", entry, arch="cpu", dtype="float32")
    c.save()
    assert AutotuneCache.key("matmul", arch="cpu", dtype="float32") == \
        "matmul|cpu|float32|page=-"
    assert AutotuneCache.key("paged_decode", arch="cpu", dtype="float32",
                             page_size=4) == \
        "paged_decode|cpu|float32|page=4"
    again = AutotuneCache(path)
    assert again.get("matmul", arch="cpu", dtype="float32") == entry
    assert again.get("matmul", arch="tpu", dtype="float32") is None
    # a version bump discards stale entries instead of misusing them
    blob = json.loads(path.read_text())
    blob["version"] = autotune.VERSION + 1
    path.write_text(json.dumps(blob))
    assert AutotuneCache(path).entries == {}


def test_tune_matmul_caches_winner(tmp_path, monkeypatch):
    cache = AutotuneCache(tmp_path / "at.json")
    calls = {"n": 0}
    real = autotune._median_time

    def counting(fn, reps=3):
        calls["n"] += 1
        return real(fn, reps=1)
    monkeypatch.setattr(autotune, "_median_time", counting)
    entry = autotune.tune_matmul(8, 64, 128, cache=cache, reps=1)
    assert 8 % min(entry["block_m"], 8) == 0
    assert 64 % min(entry["block_k"], 64) == 0
    assert 128 % min(entry["block_n"], 128) == 0
    assert entry["shape"] == [8, 64, 128] and entry["t_us"] > 0
    assert calls["n"] > 0
    assert (tmp_path / "at.json").exists()
    # second call: served from the cache, no timing sweep
    calls["n"] = 0
    hit = autotune.tune_matmul(8, 64, 128, cache=cache, reps=1)
    assert hit == entry and calls["n"] == 0
    # force re-runs the sweep
    autotune.tune_matmul(8, 64, 128, cache=cache, reps=1, force=True)
    assert calls["n"] > 0


def test_tune_quant_matmul_int4(tmp_path):
    cache = AutotuneCache(tmp_path / "at.json")
    entry = autotune.tune_matmul(8, 64, 64, bits=4, dtype="int4",
                                 cache=cache, reps=1)
    assert min(entry["block_k"], 64) % 2 == 0
    assert cache.get("quant_matmul4", arch=autotune.device_arch(),
                     dtype="int4") == entry


def test_tune_paged_decode_picks_an_impl(tmp_path):
    cache = AutotuneCache(tmp_path / "at.json")
    entry = autotune.tune_paged_decode(4, kv_heads=2, groups=2,
                                       head_dim=8, cache=cache, reps=1)
    assert entry["impl"] in ("pallas", "reference")
    assert entry["t_us"] <= entry["t_us_other"]
    hit = cache.get("paged_decode", arch=autotune.device_arch(),
                    dtype="float32", page_size=4)
    assert hit == entry


def test_resolve_tiles_precedence():
    m, k, n = 256, 512, 256
    # untuned: built-in defaults
    t = ops._resolve_tiles("matmul", m, k, n, None, None, None)
    assert t == ops._DEFAULT_TILES
    # tuned and divisible: tuned wins
    ops.set_tuned(matmul={"block_m": 64, "block_n": 128, "block_k": 256})
    t = ops._resolve_tiles("matmul", m, k, n, None, None, None)
    assert t == {"block_m": 64, "block_n": 128, "block_k": 256}
    # explicit args beat the tuned entry
    t = ops._resolve_tiles("matmul", m, k, n, 32, None, None)
    assert t["block_m"] == 32 and t["block_n"] == 128
    # tuned tile that does not divide the call's shape: fall back whole
    t = ops._resolve_tiles("matmul", 100, 70, 30, None, None, None)
    assert t == ops._DEFAULT_TILES


def test_apply_tuning_installs_paged_impl():
    backend_default = "pallas" if jax.default_backend() == "tpu" else None
    assert resolve_attn_impl("auto") == backend_default
    autotune.apply_tuning({"matmul": {"block_m": 64, "block_n": 64,
                                      "block_k": 128},
                           "paged_decode": {"impl": "pallas"}})
    assert ops.tuned_paged_impl() == "pallas"
    assert resolve_attn_impl("auto") == "pallas"
    autotune.apply_tuning({"paged_decode": {"impl": "reference"}})
    assert resolve_attn_impl("auto") is None       # jnp gather path
    assert resolve_attn_impl("pallas") == "pallas"  # explicit untouched


def test_tune_for_model_seeds_and_applies(tmp_path):
    cfg = get_config("gpt2_base").with_(d_model=64, d_ff=128, n_heads=2,
                                        n_kv_heads=2, head_dim=8)
    profile = {"ckpt_dtype": "float32", "layer_t_comp": 0.01,
               "layer_t_load": 0.02}
    out = autotune.tune_for_model(cfg, profile, page_size=4,
                                  cache_path=tmp_path / "at.json",
                                  tokens=8, reps=1)
    assert out["matmul"]["seed"] == {"layer_t_comp": 0.01,
                                     "layer_t_load": 0.02}
    assert out["paged_decode"]["impl"] in ("pallas", "reference")
    assert ops._TUNED["matmul"] is not None        # applied
    # seed metadata survives the disk round-trip
    blob = json.loads((tmp_path / "at.json").read_text())
    key = AutotuneCache.key("matmul", arch=autotune.device_arch(),
                            dtype="float32")
    assert blob["entries"][key]["seed"]["layer_t_load"] == 0.02
