"""End-to-end behaviour tests for the Hermes/PIPELOAD system."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import load_manifest, partition_and_save
from repro.configs import get_config
from repro.core import Hermes, PipeloadEngine
from repro.models.api import build_model


@pytest.fixture(scope="module")
def gpt2s(tmp_path_factory):
    """Small-but-real GPT-2-geometry checkpoint on disk."""
    cfg = get_config("gpt2_base").with_(
        num_layers=8, d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
        d_ff=1024, vocab_size=1000, vocab_pad_to=8, remat=False)
    path = tmp_path_factory.mktemp("ckpt") / "gpt2s"
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    partition_and_save(params, cfg, path)
    return cfg, path


@pytest.fixture(scope="module")
def toks():
    return np.random.default_rng(0).integers(0, 1000, (1, 32))


def test_partition_manifest(gpt2s):
    cfg, path = gpt2s
    man = load_manifest(path)
    kinds = [s["kind"] for s in man["shards"]]
    assert kinds.count("layer") == cfg.num_layers
    assert kinds.count("embed") == 1 and kinds.count("head") == 1
    # Observation I: encoder/decoder layers dominate the bytes for LLM-like
    # vocab/layer ratios; with this tiny vocab just check accounting adds up
    assert man["total_bytes"] == sum(s["bytes"] for s in man["shards"])


def test_all_modes_same_logits(gpt2s, toks):
    cfg, path = gpt2s
    ref_logits = None
    for mode, agents in [("baseline", 1), ("pipeswitch", 1),
                         ("pipeload", 1), ("pipeload", 3)]:
        eng = PipeloadEngine(path, cfg, mode=mode, num_agents=agents)
        eng.warmup(1, toks.shape[1])
        lg, stats = eng.run_single(toks)
        assert stats.latency_s > 0
        if ref_logits is None:
            ref_logits = lg
        else:
            np.testing.assert_allclose(np.asarray(lg),
                                       np.asarray(ref_logits), atol=1e-4)


def test_pipeload_reduces_peak_memory(gpt2s, toks):
    cfg, path = gpt2s
    peaks = {}
    for mode, agents in [("baseline", 1), ("pipeload", 2)]:
        eng = PipeloadEngine(path, cfg, mode=mode, num_agents=agents)
        eng.warmup(1, toks.shape[1])
        _, stats = eng.run_single(toks)
        peaks[mode] = stats.peak_bytes
    # the paper's core claim: destruction keeps the peak well below baseline
    assert peaks["pipeload"] < peaks["baseline"]


def test_budget_respected_and_correct(gpt2s, toks):
    cfg, path = gpt2s
    man = load_manifest(path)
    layer_b = man["layer_bytes"] // cfg.num_layers
    other = man["total_bytes"] - man["layer_bytes"]
    budget = other + 3 * layer_b
    eng_b = PipeloadEngine(path, cfg, mode="baseline").warmup(1, 32)
    ref, _ = eng_b.run_single(toks)
    eng = PipeloadEngine(path, cfg, mode="pipeload", num_agents=2,
                         budget_bytes=budget).warmup(1, 32)
    lg, stats = eng.run_single(toks)
    assert stats.peak_bytes <= budget
    np.testing.assert_allclose(np.asarray(lg), np.asarray(ref), atol=1e-4)


def test_generate_matches_baseline(gpt2s, toks):
    cfg, path = gpt2s
    eng_b = PipeloadEngine(path, cfg, mode="baseline").warmup(1, 32)
    out_b, _ = eng_b.run_generate(toks, 3)
    eng_p = PipeloadEngine(path, cfg, mode="pipeload",
                           num_agents=2).warmup(1, 32)
    out_p, stats = eng_p.run_generate(toks, 3)
    np.testing.assert_array_equal(np.asarray(out_b), np.asarray(out_p))
    # pipeload reloads per token (paper §V-B2): 8 layers x 3 tokens
    assert stats.loads >= 3 * cfg.num_layers


def test_pinned_window_reduces_reloads(gpt2s, toks):
    cfg, path = gpt2s
    eng = PipeloadEngine(path, cfg, mode="pipeload", num_agents=2,
                         pin_window=4).warmup(1, 32)
    out_pin, st_pin = eng.run_generate(toks, 3)
    eng2 = PipeloadEngine(path, cfg, mode="pipeload",
                          num_agents=2).warmup(1, 32)
    out_ref, st_ref = eng2.run_generate(toks, 3)
    np.testing.assert_array_equal(np.asarray(out_pin), np.asarray(out_ref))
    assert st_pin.loads < st_ref.loads     # beyond-paper: fewer reloads


def test_hermes_planner_end_to_end(gpt2s, toks):
    cfg, path = gpt2s
    h = Hermes(path, cfg)
    prof = h.profile(batch=1, seq=32, force=True)
    assert prof["num_layers"] == cfg.num_layers
    lb, other = prof["layer_bytes"], prof["other_bytes"]
    entries = h.plan([other + 3 * lb, other + 8 * lb, None])
    lats = [e.predicted_latency_s for e in entries]
    agents = [e.num_agents for e in entries]
    # Fig. 7 trends: bigger budget -> no fewer agents, no more latency
    assert agents[0] <= agents[1] <= agents[2] or lats[0] >= lats[2]
    assert lats[0] >= lats[2] - 1e-9
    assert all(e.feasible for e in entries)
