"""Quantized weight streaming: int8/int4 shard format, fused
dequant-matmul kernel, engine ledger accounting and dtype-aware planner.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from helpers.hypothesis_compat import given, settings, st

from repro.checkpoint import (QuantizedTensor, ensure_quantized,
                              load_manifest, load_shard,
                              partition_and_save, requantize)
from repro.checkpoint import quant as qz
from repro.configs import get_config
from repro.core import Hermes, PipeloadEngine
from repro.core.planner import plan, plan_generate
from repro.kernels import ops, ref
from repro.models.api import build_model

# documented int8 logit tolerance (docs/quantization.md): max |delta|
# relative to the fp32 logit range on the gpt2 test geometry
INT8_LOGIT_RTOL = 0.05


@pytest.fixture(scope="module")
def gpt2q(tmp_path_factory):
    """Small-but-real GPT-2-geometry checkpoint in fp32/int8/int4."""
    cfg = get_config("gpt2_base").with_(
        num_layers=6, d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
        d_ff=1024, vocab_size=1000, vocab_pad_to=8, remat=False)
    root = tmp_path_factory.mktemp("qckpt")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    paths = {"fp32": root / "fp32"}
    partition_and_save(params, cfg, paths["fp32"])
    for q in ("int8", "int4"):
        paths[q] = root / q
        requantize(paths["fp32"], paths[q], q)
    return cfg, paths


@pytest.fixture(scope="module")
def toks():
    return np.random.default_rng(1).integers(0, 1000, (1, 24))


# ---------------------------------------------------------------------------
# round-trip fidelity of the quantization scheme
# ---------------------------------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(k=st.integers(3, 64), n=st.integers(1, 32),
       quant=st.sampled_from(["int8", "int4"]), seed=st.integers(0, 2**30))
def test_quantize_roundtrip_halfstep_bound(k, n, quant, seed):
    """Per-channel symmetric rounding: |dequant - w| <= scale/2 per
    element, scale = colmax / qmax."""
    w = np.random.default_rng(seed).normal(size=(k, n)).astype(np.float32)
    qt = qz.quantize_array(w, quant)
    deq = np.asarray(qt.dequantize())
    assert deq.shape == w.shape and str(deq.dtype) == "float32"
    qmax = qz.QUANT_SCHEMES[quant][1]
    halfstep = np.abs(w).max(axis=0, keepdims=True) / qmax / 2
    assert np.all(np.abs(deq - w) <= halfstep + 1e-7)


def test_int4_packing_shapes_and_bytes():
    w = np.random.default_rng(0).normal(size=(37, 16)).astype(np.float32)
    qt = qz.quantize_array(w, "int4")
    assert qt.q.shape == (19, 16) and qt.q.dtype == np.uint8
    assert qt.shape == (37, 16)
    # ~1/8 the fp32 payload (+ scales)
    assert qt.nbytes < w.nbytes / 4
    # packed values round-trip exactly at the integer level
    ints = np.clip(np.rint(w / np.asarray(qt.scale)), -7, 7)
    np.testing.assert_array_equal(np.asarray(qt.unpacked()), ints)


def test_quantize_flat_passes_1d_through():
    flat = {"attn.w_q": np.ones((8, 8), np.float32),
            "attn_norm": np.ones((8,), np.float32)}
    stored = qz.quantize_flat(flat, "int8")
    assert "attn_norm" in stored                    # untouched
    assert "attn.w_q.__q__" in stored and "attn.w_q.__scale__" in stored
    assert "attn.w_q" not in stored


def test_zero_channel_has_unit_scale():
    w = np.zeros((8, 4), np.float32)
    w[:, 0] = 3.0
    qt = qz.quantize_array(w, "int8")
    assert np.asarray(qt.scale)[1] == 1.0
    np.testing.assert_allclose(np.asarray(qt.dequantize()), w, atol=0.02)


# ---------------------------------------------------------------------------
# partitioned-checkpoint round trip
# ---------------------------------------------------------------------------
def test_partition_quant_manifest_and_bytes(gpt2q):
    cfg, paths = gpt2q
    m32 = load_manifest(paths["fp32"])
    m8 = load_manifest(paths["int8"])
    m4 = load_manifest(paths["int4"])
    assert m32["quant"] is None and m8["quant"] == "int8"
    assert m4["quant_scheme"] == qz.SCHEME and m4["quant_bits"] == 4
    # layer shards are ~all 2-D matmul weight: big shrink end to end
    assert m32["layer_bytes"] / m8["layer_bytes"] > 3.5
    assert m32["layer_bytes"] / m4["layer_bytes"] > 7.0
    for man in (m8, m4):
        assert man["total_bytes"] == sum(s["bytes"] for s in man["shards"])
        for s in man["shards"]:
            assert s["dtype"] == man["quant"]
            assert s["bytes"] < s["fp_bytes"]
            assert s["scale_bytes"] > 0 and s["n_quantized"] > 0


def test_load_shard_restores_quantized_tree(gpt2q):
    cfg, paths = gpt2q
    fp = load_shard(paths["fp32"], "layer_000")
    q8 = load_shard(paths["int8"], "layer_000")
    assert isinstance(q8["attn"]["w_q"], QuantizedTensor)
    assert isinstance(q8["attn_norm"], np.ndarray)       # 1-D stays float
    np.testing.assert_array_equal(q8["attn_norm"], fp["attn_norm"])
    deq = np.asarray(q8["attn"]["w_q"].dequantize())
    w = fp["attn"]["w_q"]
    assert np.abs(deq - w).max() <= np.abs(w).max() / 127  # < one step
    # pytree round trip through device put (what the engine does)
    dev = jax.tree.map(jnp.asarray, q8)
    assert isinstance(dev["attn"]["w_q"], QuantizedTensor)


def test_requantize_rejects_quantized_source(gpt2q, tmp_path):
    cfg, paths = gpt2q
    with pytest.raises(ValueError, match="full-precision"):
        requantize(paths["int8"], tmp_path / "x", "int4")


def test_ensure_quantized_retranscodes_stale_variant(tmp_path):
    """Re-partitioning the source in place must invalidate the derived
    int8 shards — otherwise --quant serves the OLD weights silently."""
    cfg = get_config("gpt2_base").with_(
        num_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=64, vocab_pad_to=8, remat=False)
    api = build_model(cfg)
    src, dst = tmp_path / "fp", tmp_path / "q8"
    partition_and_save(api.init(jax.random.PRNGKey(0)), cfg, src)
    ensure_quantized(src, dst, "int8")
    w0 = np.asarray(load_shard(dst, "layer_000")["attn"]["w_q"]
                    .dequantize())
    # same source: reuse (no re-transcode) — shard bytes stay identical
    ensure_quantized(src, dst, "int8")
    np.testing.assert_array_equal(
        np.asarray(load_shard(dst, "layer_000")["attn"]["w_q"]
                   .dequantize()), w0)
    # new weights at the same path: the variant must be rebuilt
    bigger = cfg.with_(d_ff=256)       # different bytes -> new fingerprint
    partition_and_save(build_model(bigger).init(jax.random.PRNGKey(1)),
                       bigger, src)
    ensure_quantized(src, dst, "int8")
    man = load_manifest(dst)
    assert man["source_total_bytes"] == load_manifest(src)["total_bytes"]
    w1 = np.asarray(load_shard(dst, "layer_000")["attn"]["w_q"]
                    .dequantize())
    assert w1.shape == w0.shape and not np.array_equal(w1, w0)


# ---------------------------------------------------------------------------
# fused dequant-matmul kernel vs the jnp oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("bits,quant", [(8, "int8"), (4, "int4")])
@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (64, 64, 64, 64, 64, 64),          # single tile
    (128, 256, 64, 64, 64, 64),        # multi-tile K streaming
    (64, 128, 192, 64, 64, 128),       # uneven grid
])
def test_quant_matmul_sweep(m, k, n, bm, bn, bk, bits, quant):
    rng = np.random.default_rng(m + k + n + bits)
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    qt = qz.quantize_array(rng.normal(size=(k, n)).astype(np.float32),
                           quant)
    w_q, scale = jnp.asarray(qt.q), jnp.asarray(qt.scale)
    got = ops.quant_matmul(x, w_q, scale, bits=bits, block_m=bm,
                           block_n=bn, block_k=bk)
    want = ref.quant_matmul_ref(x, w_q, scale, bits=bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-3, rtol=1e-3)
    # the oracle itself equals a dense matmul over dequantized weights
    dense = np.asarray(x) @ np.asarray(qt.dequantize())
    np.testing.assert_allclose(np.asarray(want), dense, atol=1e-3,
                               rtol=1e-3)


@settings(max_examples=10, deadline=None)
@given(mi=st.integers(1, 2), ki=st.integers(1, 3), ni=st.integers(1, 2),
       bits=st.sampled_from([8, 4]), seed=st.integers(0, 2**30))
def test_quant_matmul_property(mi, ki, ni, bits, seed):
    m, k, n = 64 * mi, 64 * ki, 64 * ni
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    qt = qz.quantize_array(rng.normal(size=(k, n)).astype(np.float32),
                           "int8" if bits == 8 else "int4")
    got = ops.quant_matmul(x, jnp.asarray(qt.q), jnp.asarray(qt.scale),
                           bits=bits, block_m=64, block_n=64, block_k=64)
    want = ref.quant_matmul_ref(x, jnp.asarray(qt.q),
                                jnp.asarray(qt.scale), bits=bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# engine: quantized checkpoints stream through PIPELOAD
# ---------------------------------------------------------------------------
def test_int8_generate_matches_fp32_within_tolerance(gpt2q, toks):
    cfg, paths = gpt2q
    ref_eng = PipeloadEngine(paths["fp32"], cfg, mode="pipeload",
                             num_agents=2)
    ref_logits, ref_stats = ref_eng.run_single(toks)
    eng = PipeloadEngine(paths["int8"], cfg, mode="pipeload", num_agents=2)
    logits, stats = eng.run_single(toks)
    err = np.abs(np.asarray(logits) - np.asarray(ref_logits)).max()
    assert err <= INT8_LOGIT_RTOL * np.abs(np.asarray(ref_logits)).max()
    # the stream itself shrank ~4x for the same load count
    assert stats.loads == ref_stats.loads
    assert ref_stats.streamed_bytes / stats.streamed_bytes > 3.5


def test_int8_kv_decode_tokens_match_fp32(gpt2q, toks):
    cfg, paths = gpt2q
    new = 4
    outs = {}
    for d in ("fp32", "int8"):
        eng = PipeloadEngine(paths[d], cfg, mode="pipeload", num_agents=2)
        eng.warmup(1, toks.shape[1], decode=True,
                   total_len=toks.shape[1] + new)
        out, stats = eng.run_generate(toks, new, kv_cache=True)
        outs[d] = np.asarray(out)
        assert stats.kv_cache and stats.cache_bytes > 0
    np.testing.assert_array_equal(outs["int8"], outs["fp32"])


def test_int4_runs_and_streams_fewer_bytes(gpt2q, toks):
    cfg, paths = gpt2q
    eng = PipeloadEngine(paths["int4"], cfg, mode="pipeload", num_agents=2)
    eng.warmup(1, toks.shape[1], decode=True, total_len=toks.shape[1] + 2)
    out, stats = eng.run_generate(toks, 2, kv_cache=True)
    assert out.shape == (1, toks.shape[1] + 2)
    m32 = load_manifest(paths["fp32"])
    assert stats.streamed_bytes < m32["total_bytes"] / 4


def test_ledger_floor_uses_quantized_bytes(gpt2q, toks):
    """A budget far below the fp32 decode floor still runs int8 within
    budget — the ledger and _kv_floor account quantized shard bytes."""
    cfg, paths = gpt2q
    new = 3
    cache_total = cfg.num_layers * cfg.cache_bytes(1, toks.shape[1] + new)
    floors = {}
    for d in ("fp32", "int8"):
        eng = PipeloadEngine(paths[d], cfg, mode="pipeload", num_agents=2)
        floors[d] = eng._kv_floor(cache_total)
    assert floors["fp32"] / floors["int8"] > 2.0

    m8 = load_manifest(paths["int8"])
    layer8 = m8["layer_bytes"] // cfg.num_layers
    budget = floors["int8"] + 2 * layer8
    assert budget < floors["fp32"]      # fp32 would refuse this budget
    eng = PipeloadEngine(paths["fp32"], cfg, mode="pipeload", num_agents=2,
                         budget_bytes=budget)
    with pytest.raises(ValueError, match="KV decode floor"):
        eng.run_generate(toks, new, kv_cache=True)

    eng = PipeloadEngine(paths["int8"], cfg, mode="pipeload", num_agents=2,
                         budget_bytes=budget)
    eng.warmup(1, toks.shape[1], decode=True,
               total_len=toks.shape[1] + new)
    out, stats = eng.run_generate(toks, new, kv_cache=True)
    assert stats.peak_bytes <= budget


def test_batch_round_scheduler_quantized(gpt2q):
    """Continuous batching over int8 shards: same tokens as sequential
    int8 runs, budget honoured at a level fp32 cannot reach."""
    from repro.core import BatchScheduler
    cfg, paths = gpt2q
    new, plen = 3, 12
    rng = np.random.default_rng(3)
    prompts = rng.integers(0, 1000, (2, plen))
    m8 = load_manifest(paths["int8"])
    layer8 = m8["layer_bytes"] // cfg.num_layers
    cache2 = 2 * cfg.num_layers * cfg.cache_bytes(1, plen + new)
    eng = PipeloadEngine(paths["int8"], cfg, mode="pipeload", num_agents=2,
                         budget_bytes=None)
    budget = eng._kv_floor(cache2) + 2 * layer8
    eng = PipeloadEngine(paths["int8"], cfg, mode="pipeload", num_agents=2,
                         budget_bytes=budget)
    sched = BatchScheduler(eng, max_inflight=2, max_total_len=plen + new)
    sched.warmup(prompt_lens=[plen])
    for i in range(2):
        sched.submit(prompts[i], new)
    outs, stats = sched.run()
    assert stats.peak_bytes <= budget
    for i in range(2):
        seq = PipeloadEngine(paths["int8"], cfg, mode="pipeload",
                             num_agents=2)
        seq.warmup(1, plen, decode=True, total_len=plen + new)
        want, _ = seq.run_generate(prompts[i:i + 1], new, kv_cache=True)
        np.testing.assert_array_equal(outs[i], np.asarray(want)[0])


# ---------------------------------------------------------------------------
# planner: dtype joins the schedule search
# ---------------------------------------------------------------------------
def synth_profile(n, t_load, t_comp, layer_bytes, other_bytes, seq=32):
    return {
        "num_layers": n, "seq": seq,
        "layer_t_load": t_load, "layer_t_comp": t_comp,
        "layer_bytes": layer_bytes, "other_bytes": other_bytes,
        "shards": (
            [{"name": "embed", "kind": "embed", "bytes": other_bytes,
              "t_load": 0.0, "t_comp": 0.0}]
            + [{"name": f"layer_{i:03d}", "kind": "layer",
                "bytes": layer_bytes, "t_load": t_load, "t_comp": t_comp,
                "t_decode": t_comp / seq}
               for i in range(n)]),
    }


def quant_profiles(n, t_load, t_comp, layer_bytes, other_bytes):
    """fp32 profile + its idealised int8 shadow (4x fewer bytes, 4x
    faster loads, same compute)."""
    return {
        "fp32": synth_profile(n, t_load, t_comp, layer_bytes, other_bytes),
        "int8": synth_profile(n, t_load / 4, t_comp,
                              max(layer_bytes // 4, 1),
                              max(other_bytes // 4, 1)),
    }


@settings(max_examples=20, deadline=None)
@given(n=st.integers(4, 16), tl=st.floats(0.01, 0.1),
       tc=st.floats(0.001, 0.02), spare=st.integers(1, 3))
def test_planner_prefers_int8_under_tight_budget(n, tl, tc, spare):
    """A budget below the fp32 floor but with int8 headroom must choose
    the int8 shards — the satellite property of the dtype search."""
    lb, other, cache = 40, 20, 2
    profs = quant_profiles(n, tl, tc, lb, other)
    # below fp32's floor (other + cache + one layer)…
    fp32_floor = other + n * cache + lb
    budget = min(fp32_floor - 1,
                 other // 4 + n * cache + (spare + 1) * (lb // 4) + 1)
    entries = plan_generate(profs, [budget], new_tokens=6,
                            cache_bytes_per_layer=cache)
    e = entries[0]
    assert e.feasible and e.dtype == "int8"
    assert e.predicted_peak_bytes <= budget


@settings(max_examples=15, deadline=None)
@given(n=st.integers(4, 12), tl=st.floats(0.01, 0.1),
       tc=st.floats(0.001, 0.01), cap=st.integers(2, 4))
def test_planner_int8_admits_no_fewer_inflight(n, tl, tc, cap):
    """With the dtype search widened, the capacity-first planner never
    admits FEWER requests than the fp32-only search at the same
    budget."""
    lb, other, cache = 40, 20, 4
    profs = quant_profiles(n, tl, tc, lb, other)
    budget = other + n * cache * cap + 4 * lb
    only32 = plan_generate(profs["fp32"], [budget], new_tokens=6,
                           cache_bytes_per_layer=cache, max_inflight=cap)[0]
    joint = plan_generate(profs, [budget], new_tokens=6,
                          cache_bytes_per_layer=cache, max_inflight=cap)[0]
    assert joint.feasible
    if only32.feasible:
        assert joint.inflight >= only32.inflight


def test_plan_dict_tags_dtype_and_single_profile_is_untagged():
    prof = synth_profile(8, 0.05, 0.004, 40, 20)
    single = plan(prof, [None])[0]
    assert single.dtype is None
    tagged = plan({"fp32": prof}, [None])[0]
    assert tagged.dtype == "fp32"
    assert tagged.num_agents == single.num_agents
    assert tagged.predicted_latency_s == single.predicted_latency_s


def test_hermes_quantized_plan_end_to_end(gpt2q, toks):
    """Hermes facade: quants= search picks a quantized dtype under a
    budget fp32 cannot satisfy, and the planned engine runs within it."""
    cfg, paths = gpt2q
    h = Hermes(paths["fp32"], cfg)
    h.profile(batch=1, seq=24, force=True)
    new = 3
    m8 = load_manifest(paths["int8"])
    layer8 = m8["layer_bytes"] // cfg.num_layers
    other8 = m8["total_bytes"] - m8["layer_bytes"]
    cache_total = cfg.num_layers * cfg.cache_bytes(1, toks.shape[1] + new)
    budget = other8 + cache_total + 4 * layer8
    # sanity: this budget sits below the fp32 decode floor, so only the
    # int8 shards can satisfy it
    fp_eng = PipeloadEngine(paths["fp32"], cfg, mode="pipeload")
    assert budget < fp_eng._kv_floor(cache_total)
    g = h.plan_generate([budget], batch=1, prompt_len=toks.shape[1],
                        new_tokens=new, quants=("fp32", "int8"))[0]
    assert g.feasible and g.dtype == "int8"
    hq = h.quantized(g.dtype)
    assert hq.dir != h.dir
    eng = PipeloadEngine(hq.dir, cfg, mode="pipeload",
                         num_agents=g.num_agents, pin_window=g.pin_window,
                         budget_bytes=budget)
    eng.warmup(1, toks.shape[1], decode=True,
               total_len=toks.shape[1] + new)
    _, stats = eng.run_generate(toks, new, kv_cache=True)
    assert stats.peak_bytes <= budget
