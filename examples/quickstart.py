"""Quickstart: train a ~100M-param dense model end-to-end on synthetic data.

    PYTHONPATH=src python examples/quickstart.py [--steps 300]

Uses the yi-9b architecture family at ~100M scale; loss should fall from
~10.0 toward the synthetic distribution's entropy floor.
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax

from repro.configs import get_config
from repro.data.synthetic import batch_iterator
from repro.launch.stepfns import make_train_step
from repro.models.api import build_model
from repro.optim import adamw_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    # ~100M params: 12 layers x d512 with the yi GQA geometry
    cfg = get_config("yi-9b").with_(
        num_layers=12, d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
        d_ff=1536, vocab_size=32000, dtype="float32", remat=False,
        name="yi-100m")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {cfg.name}  params={n/1e6:.1f}M")

    opt = adamw_init(params)
    step = jax.jit(make_train_step(api, None), donate_argnums=(0, 1))
    it = batch_iterator(cfg, args.batch, args.seq)
    for i in range(args.steps):
        params, opt, m = step(params, opt, next(it))
        if i % 25 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(m['loss']):.4f}  "
                  f"lr {float(m['lr']):.2e}", flush=True)


if __name__ == "__main__":
    main()
