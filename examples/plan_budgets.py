"""Pipeline-Planner walkthrough: schedule table across memory budgets
(the paper's Fig. 6b/Fig. 7 flow) for any config in the registry.

    PYTHONPATH=src python examples/plan_budgets.py --arch gpt2_base
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax

from repro.checkpoint import partition_and_save
from repro.configs import get_config
from repro.core import Hermes
from repro.models.api import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2_base")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale variant (fast)")
    ap.add_argument("--quant", action="store_true",
                    help="search shard dtype (fp32/int8/int4) jointly "
                    "with the schedule")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced().with_(num_layers=8)
    ckpt = Path(f"/tmp/repro_plan_{cfg.name.replace('.', '_')}")
    if not (ckpt / "manifest.json").exists():
        api = build_model(cfg)
        partition_and_save(api.init(jax.random.PRNGKey(0)), cfg, ckpt)

    h = Hermes(ckpt, cfg)
    prof = h.profile()
    lb, other = prof["layer_bytes"], prof["other_bytes"]
    budgets = [other + k * lb for k in (2, 3, 4, 6, 8, 12)] + [None]
    quants = ("fp32", "int8", "int4") if args.quant else None
    print(f"{'budget':>12} {'agents':>7} {'dtype':>6} "
          f"{'pred latency':>13} {'pred peak':>10}")
    for b, e in zip(budgets, h.plan(budgets, quants=quants)):
        bs = "unlimited" if b is None else f"{b/2**20:.0f}MB"
        print(f"{bs:>12} {e.num_agents:>7} {e.dtype or 'fp32':>6} "
              f"{e.predicted_latency_s*1e3:>10.1f}ms "
              f"{e.predicted_peak_bytes/2**20:>8.1f}MB")


if __name__ == "__main__":
    main()
