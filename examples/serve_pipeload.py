"""Serve a GPT-2-class model with PIPELOAD under a memory budget.

    PYTHONPATH=src python examples/serve_pipeload.py --budget-mb 400

Shows the full Hermes flow: partition -> profile -> plan -> execute, and
compares baseline / pipeswitch / pipeload / pipeload+kv latency+memory on
this machine (pipeload+kv is the beyond-paper KV-cache decode path; its
(num_agents, pin_window) come from the generation-aware planner).

``--poisson RATE`` adds the continuous-batching finale: RATE requests
per round arrive as a Poisson process and the scheduler amortises each
weight-stream round across everyone in flight — watch the per-request
admitted/finished rounds interleave while peak memory stays put.

``--quant int8|int4`` closes with quantized weight streaming: the same
KV-cache run over per-channel integer shards — same schedule, ~4x/8x
fewer bytes streamed and resident (greedy tokens usually match at int8).
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro.checkpoint import partition_and_save
from repro.configs import get_config
from repro.core import BatchScheduler, Hermes, PipeloadEngine
from repro.models.api import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget-mb", type=float, default=400.0)
    ap.add_argument("--new-tokens", type=int, default=6)
    ap.add_argument("--poisson", type=float, default=0.5,
                    help="continuous-batching demo arrival rate "
                    "(requests/round; 0 disables the demo)")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--quant", default="int8",
                    choices=["none", "int8", "int4"],
                    help="quantized-streaming finale dtype "
                    "('none' disables it)")
    args = ap.parse_args()

    cfg = get_config("gpt2_base")
    ckpt = Path("/tmp/repro_example_gpt2")
    if not (ckpt / "manifest.json").exists():
        print("building + partitioning gpt2-base checkpoint (one-off)...")
        api = build_model(cfg)
        partition_and_save(api.init(jax.random.PRNGKey(0)), cfg, ckpt)

    toks = np.random.default_rng(0).integers(0, cfg.vocab_size, (1, 4))
    budget = int(args.budget_mb * 2**20)

    h = Hermes(ckpt, cfg)
    prof = h.profile(batch=1, seq=4)
    print(f"profile: t_load={prof['layer_t_load']*1e3:.1f}ms "
          f"t_comp={prof['layer_t_comp']*1e3:.1f}ms "
          f"layer={prof['layer_bytes']/2**20:.1f}MB")
    entry = h.plan([budget])[0]
    print(f"planner @ {args.budget_mb:.0f}MB -> {entry.num_agents} agents "
          f"(predicted {entry.predicted_latency_s*1e3:.0f}ms/pass)")

    for mode, agents, bud in [("baseline", 1, None), ("pipeswitch", 1, None),
                              ("pipeload", entry.num_agents, budget)]:
        eng = PipeloadEngine(ckpt, cfg, mode=mode, num_agents=agents,
                             budget_bytes=bud).warmup(1, 4)
        out, st = eng.run_generate(toks, args.new_tokens)
        print(f"{mode:11s} m={agents}: {st.latency_s:6.2f}s  "
              f"peak={st.peak_bytes/2**20:7.1f}MB  loads={st.loads}")

    g = h.plan_generate([budget], batch=1, prompt_len=toks.shape[1],
                        new_tokens=args.new_tokens)[0]
    eng = PipeloadEngine(ckpt, cfg, mode="pipeload",
                         num_agents=g.num_agents, pin_window=g.pin_window,
                         budget_bytes=budget if g.feasible else None)
    eng.warmup(1, 4, decode=True, total_len=toks.shape[1] + args.new_tokens)
    out, st = eng.run_generate(toks, args.new_tokens, kv_cache=True)
    print(f"pipeload+kv m={g.num_agents} pin={g.pin_window}: "
          f"{st.latency_s:6.2f}s  peak={st.peak_bytes/2**20:7.1f}MB  "
          f"loads={st.loads}  cache={st.cache_bytes/2**20:.1f}MB")

    if args.quant != "none":
        # ---- quantized weight streaming: same schedule, integer shards
        hq = h.quantized(args.quant)
        qeng = PipeloadEngine(hq.dir, cfg, mode="pipeload",
                              num_agents=g.num_agents,
                              pin_window=g.pin_window,
                              budget_bytes=budget if g.feasible else None)
        qeng.warmup(1, 4, decode=True,
                    total_len=toks.shape[1] + args.new_tokens)
        qout, qst = qeng.run_generate(toks, args.new_tokens, kv_cache=True)
        match = bool(np.array_equal(np.asarray(qout), np.asarray(out)))
        print(f"pipeload+kv[{args.quant}]: {qst.latency_s:6.2f}s  "
              f"peak={qst.peak_bytes/2**20:7.1f}MB  "
              f"streamed={qst.streamed_bytes/2**20:.0f}MB "
              f"(vs {st.streamed_bytes/2**20:.0f}MB fp32)  "
              f"tokens_match={match}")

    if args.poisson:
        # ---- continuous batching: Poisson arrivals share weight streams
        n = args.requests
        gs = h.plan_generate([budget], prompt_len=toks.shape[1],
                             new_tokens=args.new_tokens,
                             max_inflight=n)[0]
        fits = gs.feasible
        if not fits:          # demo fallback, like the pipeload+kv run
            gs = h.plan_generate([None], prompt_len=toks.shape[1],
                                 new_tokens=args.new_tokens,
                                 max_inflight=n)[0]
        eng = PipeloadEngine(ckpt, cfg, mode="pipeload",
                             num_agents=gs.num_agents,
                             pin_window=gs.pin_window,
                             budget_bytes=budget if fits else None)
        sched = BatchScheduler(
            eng, max_inflight=gs.inflight,
            max_total_len=toks.shape[1] + args.new_tokens)
        sched.warmup(prompt_lens=[toks.shape[1]])
        rng = np.random.default_rng(0)
        arrivals = np.floor(np.cumsum(
            rng.exponential(1.0 / args.poisson, size=n))).astype(int)
        for i in range(n):
            p = rng.integers(0, cfg.vocab_size, (toks.shape[1],))
            sched.submit(p, args.new_tokens, arrival_round=int(arrivals[i]))
        outs, ss = sched.run()
        print(f"scheduler   m={gs.num_agents} pin={gs.pin_window} "
              f"inflight<={gs.inflight}: {ss.latency_s:6.2f}s  "
              f"peak={ss.peak_bytes/2**20:7.1f}MB  loads={ss.loads}  "
              f"{ss.tokens_per_s:.1f} tok/s over {ss.rounds} rounds")
        for rid, req in sorted(sched.done.items()):
            print(f"  req{rid}: arrived r{req.arrival_round} admitted "
                  f"r{req.admitted_round} finished r{req.finished_round}")


if __name__ == "__main__":
    main()
