"""Minimal pytree-generic AdamW + cosine schedule (pure JAX)."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp


def adamw_init(params, moment_dtype=jnp.float32) -> Dict[str, Any]:
    """``moment_dtype=bf16`` halves optimizer-state memory (mu AND nu in
    bf16) — the documented tradeoff used for the 235B config where f32
    moments alone exceed the per-chip HBM budget on a single pod."""
    zeros_like = lambda p: jnp.zeros(p.shape, moment_dtype)
    return {
        "mu": jax.tree.map(zeros_like, params),
        "nu": jax.tree.map(zeros_like, params),
        "step": jnp.zeros((), jnp.int32),
    }


def cosine_lr(step, *, base_lr=3e-4, warmup=100, total=10_000,
              min_frac=0.1):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(warmup, 1)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return base_lr * jnp.minimum(warm, cos)


def adamw_update(grads, opt_state, params, *, lr=3e-4, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.1):
    step = opt_state["step"] + 1
    b1t = 1 - b1 ** step.astype(jnp.float32)
    b2t = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = (b1 * m.astype(jnp.float32) + (1 - b1) * gf).astype(m.dtype)
        v_new = (b2 * v.astype(jnp.float32)
                 + (1 - b2) * gf * gf).astype(v.dtype)
        mh = m_new.astype(jnp.float32) / b1t
        vh = v_new.astype(jnp.float32) / b2t
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(
            jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["mu"])
    flat_v = treedef.flatten_up_to(opt_state["nu"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    params_new = treedef.unflatten([o[0] for o in out])
    mu_new = treedef.unflatten([o[1] for o in out])
    nu_new = treedef.unflatten([o[2] for o in out])
    return params_new, {"mu": mu_new, "nu": nu_new, "step": step}
