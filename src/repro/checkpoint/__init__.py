from repro.checkpoint.partition import (  # noqa: F401
    ensure_quantized, load_manifest, load_shard, partition_and_save,
    requantize, shard_names)
from repro.checkpoint.quant import (  # noqa: F401
    QUANT_SCHEMES, QuantizedTensor, dequant_tree, quantize_array)
