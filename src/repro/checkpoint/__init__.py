from repro.checkpoint.partition import (  # noqa: F401
    load_manifest, load_shard, partition_and_save, shard_names)
