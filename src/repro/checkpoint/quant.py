"""Per-channel symmetric weight quantization for PIPELOAD shards.

Hermes' wins come from shrinking what must be resident and overlapping
loads with compute — but the disk -> memory weight path bounds edge
throughput, so every byte a shard does NOT carry is load time and ledger
headroom won back.  This module defines the on-disk and in-memory form of
int8/int4 shards:

  * **scheme** — symmetric per-output-channel scaling: a 2-D float weight
    ``W (K, N)`` becomes ``q = clip(round(W / scale), -qmax, qmax)`` with
    ``scale (N,) = max|W[:, j]| / qmax`` (int8: qmax=127, int4: qmax=7).
    1-D params (norms, biases) stay in the checkpoint dtype — they are
    a rounding error of the byte total and accuracy-critical.
  * **int4 packing** — two values per byte along the K axis (row ``2i``
    in the low nibble, ``2i+1`` in the high nibble), so an int4 shard is
    ~1/8 the fp32 bytes plus the f32 scale vector.
  * **in-memory form** — ``QuantizedTensor``, a registered pytree whose
    leaves are the integer payload + scales.  The ledger accounts these
    quantized bytes; dequantization happens *inside* the jitted module
    fns (or in-kernel via ``kernels.streamed_matmul.quantized_matmul``),
    so the fp copy of at most the layer being computed is transient and
    never resident between rounds.

``quantize_flat`` / ``restore_tree`` are the npz serialisation halves
used by ``checkpoint/partition.py``: a quantized array at flat key ``k``
is stored as ``k.__q__`` / ``k.__scale__`` / ``k.__meta__`` /
``k.__dtype__`` so the existing dotted-key unflattening nests them into
a dict that ``restore_tree`` folds back into a ``QuantizedTensor``.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# quant name -> (bits, qmax)
QUANT_SCHEMES: Dict[str, Tuple[int, int]] = {"int8": (8, 127), "int4": (4, 7)}
SCHEME = "symmetric-per-channel"

_Q, _SCALE, _META, _DTYPE = "__q__", "__scale__", "__meta__", "__dtype__"


def qmax_for(bits: int) -> int:
    return 127 if bits == 8 else 7


@jax.tree_util.register_pytree_node_class
class QuantizedTensor:
    """Integer weight + per-channel scales; ``dequantize()`` reconstructs.

    ``q`` is int8 for 8-bit, or uint8 nibble-packed along axis 0 for
    4-bit; ``scale`` is float32 ``(N,)``; ``shape`` is the original
    (unpacked) shape and ``dtype`` the original float dtype name.  Being
    a pytree with static (bits, shape, dtype) aux data, it passes
    through ``jax.tree.map(jnp.asarray, ...)`` and jitted module fns
    unchanged — the engine keeps the *quantized* form resident.
    """

    def __init__(self, q, scale, bits: int, shape: Tuple[int, ...],
                 dtype: str):
        self.q = q
        self.scale = scale
        self.bits = int(bits)
        self.shape = tuple(int(s) for s in shape)
        self.dtype = str(dtype)

    def tree_flatten(self):
        return (self.q, self.scale), (self.bits, self.shape, self.dtype)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], *aux)

    @property
    def nbytes(self) -> int:
        """Resident bytes: payload + scales (what the ledger charges)."""
        return int(self.q.nbytes + self.scale.nbytes)

    def unpacked(self) -> jax.Array:
        """Integer values at the original shape (int8 even for 4-bit)."""
        q = jnp.asarray(self.q)
        if self.bits == 4:
            q = unpack_int4(q, self.shape[0])
        return q

    def dequantize(self) -> jax.Array:
        return (self.unpacked().astype(jnp.float32)
                * jnp.asarray(self.scale)).astype(self.dtype)

    def take_rows(self, idx) -> jax.Array:
        """Dequantized gather of rows (embedding lookup fast path): for
        8-bit, gather the int payload then scale — the full fp table is
        never materialised."""
        if self.bits == 8:
            rows = jnp.asarray(self.q)[idx]
            return (rows.astype(jnp.float32)
                    * jnp.asarray(self.scale)).astype(self.dtype)
        return self.dequantize()[idx]

    def __repr__(self):
        return (f"QuantizedTensor(int{self.bits}, shape={self.shape}, "
                f"dtype={self.dtype})")


def is_quantized(leaf) -> bool:
    return isinstance(leaf, QuantizedTensor)


# ---------------------------------------------------------------------------
# int4 nibble packing (axis 0, row 2i low nibble / row 2i+1 high nibble)
# ---------------------------------------------------------------------------
def pack_int4(q: np.ndarray) -> np.ndarray:
    """(K, N) int values in [-8, 7] -> (ceil(K/2), N) uint8."""
    k = q.shape[0]
    if k % 2:
        q = np.concatenate([q, np.zeros((1,) + q.shape[1:], q.dtype)])
    lo = (q[0::2] & 0xF).astype(np.uint8)
    hi = (q[1::2] & 0xF).astype(np.uint8)
    return lo | (hi << 4)


def unpack_int4(packed, rows: int):
    """Inverse of ``pack_int4`` (jnp: used inside jitted dequant)."""
    p = jnp.asarray(packed).astype(jnp.uint8)
    lo = (p & 0xF).astype(jnp.int8)
    hi = ((p >> 4) & 0xF).astype(jnp.int8)
    lo = jnp.where(lo > 7, lo - 16, lo)
    hi = jnp.where(hi > 7, hi - 16, hi)
    full = jnp.stack([lo, hi], axis=1).reshape((-1,) + p.shape[1:])
    return full[:rows]


# ---------------------------------------------------------------------------
# Quantize / dequantize arrays
# ---------------------------------------------------------------------------
def quantizable(a: np.ndarray, key: Optional[str] = None) -> bool:
    """Only the 2-D matmul weights carry the bytes worth shrinking.

    MoE routers are exempt even though they are 2-D: a router is a
    rounding error of the byte total (d_model x n_experts) but
    routing-CRITICAL — an int8 rounding flip changes the top-k expert
    set discretely, which moves whole experts' worth of output, not an
    epsilon.  Keeping it at checkpoint dtype keeps quantized MoE token
    selection aligned with fp32 routing."""
    if key is not None and key.split(".")[-1] == "router":
        return False
    a = np.asarray(a)
    return a.ndim == 2 and jnp.issubdtype(a.dtype, jnp.floating)


def quantize_array(a, quant: str) -> QuantizedTensor:
    bits, qmax = QUANT_SCHEMES[quant]
    dtype = str(jnp.asarray(a).dtype)
    a32 = np.asarray(a).astype(np.float32)
    amax = np.abs(a32).max(axis=0)
    scale = np.where(amax > 0, amax / qmax, 1.0).astype(np.float32)
    q = np.clip(np.rint(a32 / scale), -qmax, qmax).astype(np.int8)
    payload = pack_int4(q) if bits == 4 else q
    return QuantizedTensor(payload, scale, bits, a32.shape, dtype)


def dequant_tree(tree):
    """Map QuantizedTensor leaves back to float arrays (jit-safe); plain
    arrays pass through untouched."""
    return jax.tree.map(
        lambda leaf: leaf.dequantize() if is_quantized(leaf) else leaf,
        tree, is_leaf=is_quantized)


# ---------------------------------------------------------------------------
# npz (de)serialisation of flat {dotted_key: array} shard dicts
# ---------------------------------------------------------------------------
def quantize_flat(flat: Dict[str, np.ndarray],
                  quant: Optional[str]) -> Dict[str, np.ndarray]:
    """Replace every quantizable array in a flat shard dict with its
    ``__q__/__scale__/__meta__/__dtype__`` quadruple."""
    if quant is None:
        return dict(flat)
    out: Dict[str, np.ndarray] = {}
    for key, arr in flat.items():
        if quantizable(arr, key):
            qt = quantize_array(arr, quant)
            out[f"{key}.{_Q}"] = np.asarray(qt.q)
            out[f"{key}.{_SCALE}"] = np.asarray(qt.scale)
            out[f"{key}.{_META}"] = np.array([qt.bits, *qt.shape], np.int64)
            out[f"{key}.{_DTYPE}"] = np.str_(qt.dtype)
        else:
            out[key] = arr
    return out


def restore_tree(tree):
    """Fold ``{__q__, __scale__, __meta__, __dtype__}`` dicts (produced
    by unflattening a quantized npz) back into QuantizedTensor leaves."""
    if not isinstance(tree, dict):
        return tree
    if _Q in tree:
        meta = np.asarray(tree[_META])
        return QuantizedTensor(tree[_Q], tree[_SCALE], int(meta[0]),
                               tuple(int(s) for s in meta[1:]),
                               str(tree[_DTYPE]))
    return {k: restore_tree(v) for k, v in tree.items()}
