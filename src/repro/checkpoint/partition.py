"""Layer-based model partitioning (Hermes paper §III-A step ①).

A model checkpoint is pre-processed into per-layer shards on disk:

    <dir>/manifest.json
    <dir>/embed.npz          # embedding ("other layers" in the paper)
    <dir>/layer_000.npz ...  # encoder/decoder layers (the 70-95% bulk)
    <dir>/head.npz           # final norm + lm/classifier head

Each shard is an .npz of named arrays; the manifest records byte sizes,
kinds and per-shard dtype/scale metadata so the Pipeline Planner can
reason about the schedule without opening shards.  Loading a shard is a
real disk read (np.load with regular I/O).

``quant="int8" | "int4"`` writes per-channel-scaled integer shards
(``checkpoint/quant.py``): 2-D matmul weights are stored as integer
payload + f32 scales, 1-D params keep the checkpoint dtype, and every
manifest ``bytes`` figure is the *quantized* size — so the planner, the
engine's ledger and the KV decode floor all shrink by ~4x (int8) / ~8x
(int4) without opening a shard.  ``load_shard`` restores quantized
arrays as ``QuantizedTensor`` pytree leaves; dequantization happens
inside the jitted module fns (core/modules.py).

MoE-family checkpoints default to the **expert split**
(``expert_split=True``): each layer becomes an attention+router shard
(kind ``"layer"`` — still the pipeline stage the Loading Agents stripe)
plus ONE SHARD PER EXPERT (kind ``"expert"``, named
``layer_<i>_expert_<e>``, carrying its owning layer's ``index`` and its
``expert`` id).  Per-expert byte sizes land in the manifest so the
Pipeline Planner and the ExpertCache reason about routing-sparse
streaming without opening shards; ``requantize`` transcodes expert
shards like any other, so int8/int4 expert streaming falls out for
free.  ``expert_split=False`` keeps the paper's whole-layer shards (the
bench baseline).
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint import quant as qz
from repro.models.config import DENSE, MOE, VLM, ModelConfig

# Families whose param trees use the dense layout this partitioner (and
# the engine's module fns) understand.
PARTITION_FAMILIES = (DENSE, MOE, VLM)


def _flatten(tree, prefix="") -> Dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}."))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def _unflatten(flat: Dict[str, np.ndarray]) -> dict:
    tree: dict = {}
    for key, val in flat.items():
        parts = key.split(".")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return tree


def _save_shard(path: Path, name: str, flat: Dict[str, np.ndarray],
                kind: str, index: int, quant: Optional[str],
                base_dtype: str, extra: Optional[dict] = None) -> dict:
    """Write one (possibly quantized) shard and return its manifest row."""
    fp_bytes = int(sum(a.nbytes for a in flat.values()))
    stored = qz.quantize_flat(flat, quant)
    np.savez(path / f"{name}.npz", **stored)
    nbytes = int(sum(np.asarray(a).nbytes for a in stored.values()))
    row = {"name": name, "kind": kind, "index": index, "bytes": nbytes,
           "dtype": quant or base_dtype}
    if extra:
        row.update(extra)
    if quant:
        row["fp_bytes"] = fp_bytes
        row["scale_bytes"] = int(sum(
            np.asarray(a).nbytes for k, a in stored.items()
            if k.endswith(".__scale__")))
        row["n_quantized"] = sum(1 for k in stored if k.endswith(".__q__"))
    return row


def partition_and_save(params: dict, cfg: ModelConfig, path, *,
                       quant: Optional[str] = None,
                       expert_split: Optional[bool] = None) -> dict:
    """Split a dense-family param tree (stacked layers) into shards.

    ``quant`` in {None, "int8", "int4"} selects the shard precision.
    ``expert_split`` (MoE only; defaults to True for MoE families)
    splits each layer into an attention+router shard plus one shard per
    expert — the expert-streaming checkpoint layout."""
    assert quant is None or quant in qz.QUANT_SCHEMES, quant
    if cfg.family not in PARTITION_FAMILIES:
        raise ValueError(
            f"model family '{cfg.family}' ({cfg.name}) is not supported "
            f"by the layer partitioner / PIPELOAD engine; supported "
            f"families: {', '.join(PARTITION_FAMILIES)}")
    if expert_split is None:
        expert_split = cfg.family == MOE
    if expert_split and cfg.family != MOE:
        raise ValueError(
            f"expert_split needs an MoE-family config; '{cfg.name}' is "
            f"family '{cfg.family}'")
    path = Path(path)
    path.mkdir(parents=True, exist_ok=True)
    params = jax.tree.map(np.asarray, params)

    shards: List[dict] = []

    def save(name: str, tree: dict, kind: str, index: int = -1,
             extra: Optional[dict] = None):
        shards.append(_save_shard(path, name, _flatten(tree), kind, index,
                                  quant, cfg.dtype, extra))

    embed_tree = {"embed": params["embed"]}
    if "patch_proj" in params:
        embed_tree["patch_proj"] = params["patch_proj"]
    save("embed", embed_tree, "embed")

    stacked = params["layers"]
    for i in range(cfg.num_layers):
        layer = jax.tree.map(lambda a: a[i], stacked)
        if expert_split:
            moe_p = layer.pop("moe")
            layer["moe"] = {"router": moe_p["router"]}
            save(f"layer_{i:03d}", layer, "layer", i)
            for e in range(cfg.n_experts):
                ex = {k: moe_p[k][e] for k in ("w_gate", "w_up", "w_down")}
                save(f"layer_{i:03d}_expert_{e:03d}", ex, "expert", i,
                     extra={"expert": e})
        else:
            save(f"layer_{i:03d}", layer, "layer", i)

    head_tree = {"final_norm": params["final_norm"]}
    if "lm_head" in params:
        head_tree["lm_head"] = params["lm_head"]
    save("head", head_tree, "head")

    manifest = _build_manifest(cfg.name, cfg.num_layers, cfg.dtype, shards,
                               quant, expert_split=expert_split)
    (path / "manifest.json").write_text(json.dumps(manifest, indent=1))
    return manifest


def _build_manifest(model: str, num_layers: int, dtype: str,
                    shards: List[dict], quant: Optional[str], *,
                    expert_split: bool = False) -> dict:
    manifest = {
        "model": model,
        "num_layers": num_layers,
        "dtype": dtype,
        "quant": quant,
        "shards": shards,
        "total_bytes": int(sum(s["bytes"] for s in shards)),
        "layer_bytes": int(sum(s["bytes"] for s in shards
                               if s["kind"] == "layer")),
    }
    if expert_split:
        expert_rows = [s for s in shards if s["kind"] == "expert"]
        manifest["expert_split"] = True
        manifest["expert_total_bytes"] = int(sum(s["bytes"]
                                                 for s in expert_rows))
        manifest["experts_per_layer"] = (len(expert_rows) // num_layers
                                         if num_layers else 0)
    if quant:
        manifest["quant_scheme"] = qz.SCHEME
        manifest["quant_bits"] = qz.QUANT_SCHEMES[quant][0]
    return manifest


def requantize(src, dst, quant: str) -> dict:
    """Re-write a full-precision partitioned checkpoint as quantized
    shards — no model init needed, shards are transcoded one at a time
    (peak host memory = one shard).  The manifest records the source's
    byte total so ``ensure_quantized`` can detect a stale transcode."""
    assert quant in qz.QUANT_SCHEMES, quant
    src, dst = Path(src), Path(dst)
    src_man = load_manifest(src)
    if src_man.get("quant"):
        raise ValueError(
            f"requantize needs a full-precision source checkpoint; "
            f"{src} is already {src_man['quant']}")
    dst.mkdir(parents=True, exist_ok=True)
    shards = []
    for s in src_man["shards"]:
        with np.load(src / f"{s['name']}.npz") as z:
            flat = {k: z[k] for k in z.files}
        extra = {"expert": s["expert"]} if "expert" in s else None
        shards.append(_save_shard(dst, s["name"], flat, s["kind"],
                                  s["index"], quant, src_man["dtype"],
                                  extra))
    manifest = _build_manifest(src_man["model"], src_man["num_layers"],
                               src_man["dtype"], shards, quant,
                               expert_split=bool(
                                   src_man.get("expert_split")))
    manifest["source_total_bytes"] = src_man["total_bytes"]
    (dst / "manifest.json").write_text(json.dumps(manifest, indent=1))
    return manifest


def ensure_quantized(src, dst, quant: str) -> Path:
    """Transcode ``src`` into quantized shards at ``dst`` unless a
    CURRENT transcode already sits there.  "Current" means the existing
    manifest carries the right ``quant`` tag and the source fingerprint
    (its manifest byte total) — without the check, re-partitioning the
    source in place would leave derived int8/int4 shards silently
    serving the *old* weights."""
    src, dst = Path(src), Path(dst)
    if (dst / "manifest.json").exists():
        dst_man = load_manifest(dst)
        src_man = load_manifest(src)
        if (dst_man.get("quant") == quant
                and dst_man.get("source_total_bytes")
                == src_man["total_bytes"]):
            return dst
    requantize(src, dst, quant)
    return dst


def load_manifest(path) -> dict:
    return json.loads((Path(path) / "manifest.json").read_text())


def shard_names(manifest: dict) -> List[str]:
    return [s["name"] for s in manifest["shards"]]


def load_shard(path, name: str) -> dict:
    """Real disk read -> nested dict of np arrays (quantized entries come
    back as QuantizedTensor leaves)."""
    with np.load(Path(path) / f"{name}.npz") as z:
        flat = {k: z[k] for k in z.files}   # forces the read
    return qz.restore_tree(_unflatten(flat))
