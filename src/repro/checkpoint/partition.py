"""Layer-based model partitioning (Hermes paper §III-A step ①).

A model checkpoint is pre-processed into per-layer shards on disk:

    <dir>/manifest.json
    <dir>/embed.npz          # embedding ("other layers" in the paper)
    <dir>/layer_000.npz ...  # encoder/decoder layers (the 70-95% bulk)
    <dir>/head.npz           # final norm + lm/classifier head

Each shard is an .npz of named arrays; the manifest records byte sizes and
kinds so the Pipeline Planner can reason about the schedule without opening
shards.  Loading a shard is a real disk read (np.load with regular I/O).
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List

import jax
import numpy as np

from repro.models.config import ModelConfig


def _flatten(tree, prefix="") -> Dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}."))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def _unflatten(flat: Dict[str, np.ndarray]) -> dict:
    tree: dict = {}
    for key, val in flat.items():
        parts = key.split(".")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return tree


def partition_and_save(params: dict, cfg: ModelConfig, path) -> dict:
    """Split a dense-family param tree (stacked layers) into shards."""
    path = Path(path)
    path.mkdir(parents=True, exist_ok=True)
    params = jax.tree.map(np.asarray, params)

    shards: List[dict] = []

    def save(name: str, tree: dict, kind: str, index: int = -1):
        flat = _flatten(tree)
        np.savez(path / f"{name}.npz", **flat)
        nbytes = int(sum(a.nbytes for a in flat.values()))
        shards.append({"name": name, "kind": kind, "index": index,
                       "bytes": nbytes})

    embed_tree = {"embed": params["embed"]}
    if "patch_proj" in params:
        embed_tree["patch_proj"] = params["patch_proj"]
    save("embed", embed_tree, "embed")

    stacked = params["layers"]
    for i in range(cfg.num_layers):
        layer = jax.tree.map(lambda a: a[i], stacked)
        save(f"layer_{i:03d}", layer, "layer", i)

    head_tree = {"final_norm": params["final_norm"]}
    if "lm_head" in params:
        head_tree["lm_head"] = params["lm_head"]
    save("head", head_tree, "head")

    manifest = {
        "model": cfg.name,
        "num_layers": cfg.num_layers,
        "dtype": cfg.dtype,
        "shards": shards,
        "total_bytes": int(sum(s["bytes"] for s in shards)),
        "layer_bytes": int(sum(s["bytes"] for s in shards
                               if s["kind"] == "layer")),
    }
    (path / "manifest.json").write_text(json.dumps(manifest, indent=1))
    return manifest


def load_manifest(path) -> dict:
    return json.loads((Path(path) / "manifest.json").read_text())


def shard_names(manifest: dict) -> List[str]:
    return [s["name"] for s in manifest["shards"]]


def load_shard(path, name: str) -> dict:
    """Real disk read -> nested dict of np arrays."""
    with np.load(Path(path) / f"{name}.npz") as z:
        flat = {k: z[k] for k in z.files}   # forces the read
    return _unflatten(flat)
