"""Sharding context threaded through model code.

Model code is written in global view; the two hot spots that need explicit
collective control (expert-parallel MoE, sequence-sharded flash-decode) use
``shard_map`` through this context.  ``ctx=None`` (unit tests, single CPU
device) falls back to purely local dense paths.
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover - jax < 0.6 location
    from jax.experimental.shard_map import shard_map as _shard_map  # type: ignore

# replication-check kwarg renamed check_rep -> check_vma across jax versions
_NO_REP_CHECK = {
    ("check_vma" if "check_vma" in inspect.signature(_shard_map).parameters
     else "check_rep"): False}


@dataclasses.dataclass(frozen=True)
class ShardingCtx:
    mesh: Mesh
    batch_axes: Tuple[str, ...] = ("data",)   # ("pod","data") when multi-pod
    model_axis: str = "model"
    shard_batch: bool = True                  # False when batch indivisible
    # PartitionSpec tree for ONE layer's params (stacked dim dropped).  When
    # set, layer-scan bodies constrain their param slice back to the storage
    # sharding so remat residuals stay FSDP-sharded instead of keeping the
    # all-gathered weights alive per layer (94 gathered MoE layers = tens of
    # GB of residuals otherwise).
    layer_param_specs: Optional[object] = dataclasses.field(
        default=None, compare=False, hash=False)

    @property
    def batch_spec(self):
        return self.batch_axes if self.shard_batch else None

    @property
    def model_size(self) -> int:
        return self.mesh.shape[self.model_axis]

    @property
    def batch_size(self) -> int:
        n = 1
        for a in self.batch_axes:
            n *= self.mesh.shape[a]
        return n

    def p(self, *specs) -> P:
        return P(*specs)

    def constraint(self, x, *specs):
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*specs)))


def constrain(ctx: Optional[ShardingCtx], x, *specs):
    """Apply a sharding constraint when a mesh is present; identity otherwise."""
    if ctx is None:
        return x
    return ctx.constraint(x, *specs)


def constrain_layer_params(ctx: Optional[ShardingCtx], layer_params):
    """FSDP weight regathering INSIDE the layer-scan body.

    With GSPMD annotations alone, the partitioner reshards the whole
    stacked parameter array ONCE before the while loop (a loop-invariant
    all-gather — tens of GB live for a 94-layer MoE).  Doing the data-axis
    all-gather EXPLICITLY via shard_map on the per-layer slice makes the
    gather depend on the loop induction variable, so it cannot be hoisted:
    weights stream layer by layer, exactly the PIPELOAD pattern at the
    pod tier, and remat re-gathers in the backward pass instead of saving
    gathered weights as residuals.
    """
    if ctx is None or ctx.layer_param_specs is None:
        return layer_params
    from jax.sharding import PartitionSpec as _P

    shard_map = _shard_map

    def f(x, spec: _P):
        if not isinstance(spec, _P):
            return x
        entries = list(spec) + [None] * (x.ndim - len(spec))

        def has_data(e):
            return e == "data" or (isinstance(e, tuple) and "data" in e)

        if not any(has_data(e) for e in entries):
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(ctx.mesh, spec))
        axis = next(i for i, e in enumerate(entries) if has_data(e))
        gather_axes = (entries[axis] if isinstance(entries[axis], tuple)
                       else (entries[axis],))
        out_entries = [None if i == axis else a
                       for i, a in enumerate(entries)]

        def gather(w):
            return jax.lax.all_gather(w, gather_axes, axis=axis, tiled=True)

        # replication check off (check_vma on jax >= 0.6, check_rep
        # before): the checker can't statically prove all-gather output
        # replication, but a full tiled all_gather over 'data' is
        # replicated on that axis by construction
        return shard_map(gather, mesh=ctx.mesh, in_specs=_P(*entries),
                         out_specs=_P(*out_entries), **_NO_REP_CHECK)(x)

    return jax.tree.map(f, layer_params, ctx.layer_param_specs,
                        is_leaf=lambda v: isinstance(v, _P))


def seq_shard(ctx: Optional[ShardingCtx], x):
    """Megatron-style sequence parallelism between layers: activations
    (B, S, D) sharded on the model axis along S.  Keeps the per-layer scan
    carry (the remat residual) at 1/model_size per chip — without this the
    48-62 saved layer inputs alone overflow HBM on the train shape."""
    if ctx is None or x.ndim != 3:
        return x
    if x.shape[1] % ctx.model_size:
        return x
    return ctx.constraint(x, ctx.batch_spec, ctx.model_axis, None)
