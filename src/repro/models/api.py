"""Uniform model API across families + parameter partition-spec rules."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import dense_lm, encdec_lm, recurrent_lm
from repro.models.config import (DENSE, ENCDEC, MAMBA_HYBRID, MOE, VLM,
                                 XLSTM, ModelConfig)


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig
    init: Callable[..., Any]          # (key) -> params
    loss: Callable[..., Any]          # (params, batch, ctx) -> (loss, metrics)
    prefill: Callable[..., Any]       # (params, batch, ctx) -> (logits, cache)
    decode: Callable[..., Any]        # (params, tok, cache, pos, ctx) -> ...
    empty_cache: Callable[..., Any]   # (batch, seq, dtype?) -> cache


def build_model(cfg: ModelConfig) -> ModelAPI:
    cfg.validate()
    if cfg.family in (DENSE, MOE, VLM):
        return ModelAPI(
            cfg=cfg,
            init=lambda k: dense_lm.init_params(k, cfg),
            loss=lambda p, b, ctx=None: dense_lm.loss_fn(p, b, cfg, ctx),
            prefill=lambda p, b, ctx=None: dense_lm.prefill_fn(p, b, cfg, ctx),
            decode=lambda p, t, c, pos, ctx=None: dense_lm.decode_fn(
                p, t, c, pos, cfg, ctx),
            empty_cache=lambda b, s, dt=None: dense_lm.empty_cache(
                cfg, b, s, dt),
        )
    if cfg.family == ENCDEC:
        return ModelAPI(
            cfg=cfg,
            init=lambda k: encdec_lm.init_params(k, cfg),
            loss=lambda p, b, ctx=None: encdec_lm.loss_fn(p, b, cfg, ctx),
            prefill=lambda p, b, ctx=None: encdec_lm.prefill_fn(p, b, cfg, ctx),
            decode=lambda p, t, c, pos, ctx=None: encdec_lm.decode_fn(
                p, t, c, pos, cfg, ctx),
            empty_cache=lambda b, s, dt=None: encdec_lm.empty_cache(
                cfg, b, s, dt),
        )
    if cfg.family == XLSTM:
        return ModelAPI(
            cfg=cfg,
            init=lambda k: recurrent_lm.xlstm_init(k, cfg),
            loss=lambda p, b, ctx=None: recurrent_lm.xlstm_loss(p, b, cfg, ctx),
            prefill=lambda p, b, ctx=None: recurrent_lm.xlstm_prefill(
                p, b, cfg, ctx),
            decode=lambda p, t, c, pos, ctx=None: recurrent_lm.xlstm_decode(
                p, t, c, pos, cfg, ctx),
            empty_cache=lambda b, s, dt=None: recurrent_lm.xlstm_empty_state(
                cfg, b),
        )
    if cfg.family == MAMBA_HYBRID:
        return ModelAPI(
            cfg=cfg,
            init=lambda k: recurrent_lm.zamba_init(k, cfg),
            loss=lambda p, b, ctx=None: recurrent_lm.zamba_loss(p, b, cfg, ctx),
            prefill=lambda p, b, ctx=None: recurrent_lm.zamba_prefill(
                p, b, cfg, ctx),
            decode=lambda p, t, c, pos, ctx=None: recurrent_lm.zamba_decode(
                p, t, c, pos, cfg, ctx),
            empty_cache=lambda b, s, dt=None: recurrent_lm.zamba_empty_cache(
                cfg, b, s, dt),
        )
    raise ValueError(cfg.family)


# ===========================================================================
# Partition specs for parameters (and optimizer state, which mirrors them)
# ===========================================================================
# rule: leaf-name -> (base_ndim, spec for the unstacked leaf)
_NAME_RULES = {
    "embed": (2, ("model", None)),
    "lm_head": (2, (None, "model")),
    "w_q": (2, (None, "model")),
    "w_k": (2, (None, "model")),
    "w_v": (2, (None, "model")),
    "w_o": (2, ("model", None)),
    "b_q": (1, ("model",)),
    "b_k": (1, ("model",)),
    "b_v": (1, ("model",)),
    "w_up": (2, (None, "model")),
    "w_gate": (2, (None, "model")),
    "w_down": (2, ("model", None)),
    # MLA up-projections (low-rank downs stay replicated by default)
    "w_uq_nope": (2, (None, "model")),
    "w_uq_rope": (2, (None, "model")),
    "w_uk": (2, (None, "model")),
    "w_uv": (2, (None, "model")),
}
# 3D expert weights (E, D, F): shard the expert dim on the model axis
_MOE_RULES = {
    "w_gate": (3, ("model", None, None)),
    "w_up": (3, ("model", None, None)),
    "w_down": (3, ("model", None, None)),
}


def _spec_for_leaf(path, leaf) -> P:
    name = None
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            name = entry.key
            break
    if name is None:
        return P()
    in_moe = any(isinstance(e, jax.tree_util.DictKey) and e.key == "moe"
                 for e in path)
    rule = None
    if in_moe:
        rule = _MOE_RULES.get(name)       # router & norms fall through to P()
        if rule is None and name == "router":
            return P()
    elif name in _NAME_RULES:
        rule = _NAME_RULES[name]
    if rule is None:
        return P()
    base_ndim, spec = rule
    extra = leaf.ndim - base_ndim
    if extra < 0:
        return P()
    return P(*((None,) * extra + tuple(spec)))


def _shard_size_ok(leaf, spec: P, mesh_shape: dict) -> bool:
    for dim, ax in zip(leaf.shape, spec):
        if ax is None:
            continue
        n = mesh_shape.get(ax, 1)
        if dim % n:
            return False
    return True


def param_pspecs(params_shape, mesh=None):
    """Pytree of PartitionSpec matching ``params_shape`` (arrays or
    ShapeDtypeStructs).  Falls back to replication when a dim does not
    divide the mesh axis."""
    mesh_shape = dict(mesh.shape) if mesh is not None else {}

    def f(path, leaf):
        spec = _spec_for_leaf(path, leaf)
        if mesh is not None and not _shard_size_ok(leaf, spec, mesh_shape):
            return P()
        return spec

    return jax.tree_util.tree_map_with_path(f, params_shape)


def is_moe_leaf(path) -> bool:
    return any(isinstance(e, jax.tree_util.DictKey) and e.key == "moe"
               for e in path)
