"""Recurrent-family LMs: xLSTM (mLSTM/sLSTM stack) and Zamba2 hybrid
(Mamba2 backbone + weight-tied shared attention block).

xLSTM layout (7:1): ``num_layers`` splits into super-blocks of
(slstm_every - 1) mLSTM layers followed by one sLSTM layer; the stack scans
over super-blocks (outer) and mLSTM layers (inner).

Zamba2 layout: groups of ``shared_attn_every`` Mamba2 layers, after each of
which the single *shared* (weight-tied) attention+MLP block runs on
``concat(hidden, original_embedding)`` (2*D -> attention -> D), per
arXiv:2411.15242.  Each application site has its own KV cache.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import common, ssm
from repro.models.config import ModelConfig
from repro.sharding import ShardingCtx, seq_shard

# ===========================================================================
# xLSTM
# ===========================================================================


def _xlstm_shape(cfg: ModelConfig) -> Tuple[int, int]:
    n_sb = cfg.num_layers // cfg.slstm_every
    m_per = cfg.slstm_every - 1
    assert n_sb * cfg.slstm_every == cfg.num_layers, (
        "num_layers must be a multiple of slstm_every")
    return n_sb, m_per


def xlstm_init(key, cfg: ModelConfig) -> dict:
    n_sb, m_per = _xlstm_shape(cfg)
    ke, km, ks, kh = jax.random.split(key, 4)

    def m_init(k):
        return {"norm": common.ones((cfg.d_model,), cfg.jnp_dtype),
                "cell": ssm.mlstm_init(k, cfg, cfg.d_model)}

    def s_init(k):
        return {"norm": common.ones((cfg.d_model,), cfg.jnp_dtype),
                "cell": ssm.slstm_init(k, cfg, cfg.d_model)}

    mkeys = jax.random.split(km, n_sb * m_per).reshape(n_sb, m_per, 2)
    skeys = jax.random.split(ks, n_sb)
    return {
        "embed": common.embed_init(ke, cfg.padded_vocab, cfg.d_model,
                                   cfg.jnp_dtype),
        "mlstm": jax.vmap(jax.vmap(m_init))(mkeys),
        "slstm": jax.vmap(s_init)(skeys),
        "final_norm": common.ones((cfg.d_model,), cfg.jnp_dtype),
        "lm_head": common.dense_init(kh, cfg.d_model, cfg.padded_vocab,
                                     cfg.jnp_dtype),
    }


def xlstm_empty_state(cfg: ModelConfig, batch: int) -> dict:
    n_sb, m_per = _xlstm_shape(cfg)
    m_one = ssm.mlstm_empty_state(cfg, cfg.d_model, batch)
    s_one = ssm.slstm_empty_state(cfg, cfg.d_model, batch)
    return {
        "mlstm": jax.tree.map(
            lambda a: jnp.broadcast_to(a[None, None],
                                       (n_sb, m_per) + a.shape), m_one),
        "slstm": jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_sb,) + a.shape), s_one),
    }


def _xlstm_pass(params, x, cfg: ModelConfig, state: Optional[dict],
                decode: bool, ctx=None):
    """Shared stack traversal.  state=None -> fresh prefill state."""
    b = x.shape[0]
    if state is None:
        state = xlstm_empty_state(cfg, b)
    m_fn = ssm.mlstm_decode if decode else ssm.mlstm_prefill
    s_fn = ssm.slstm_decode if decode else ssm.slstm_prefill

    def inner(h, xs):
        p, st = xs
        y, st_new = m_fn(p["cell"],
                         common.rms_norm(h, p["norm"], cfg.norm_eps),
                         cfg, st)
        return h + y, st_new

    if cfg.remat and not decode:
        # nested remat: the super-block backward replays mLSTM layers one
        # at a time (matrix-memory chunk states are ~4 GB/layer otherwise)
        inner = jax.checkpoint(inner)

    def outer(h, xs):
        p_m, st_m, p_s, st_s = xs
        h, st_m_new = jax.lax.scan(inner, h, (p_m, st_m))
        y, st_s_new = s_fn(p_s["cell"],
                           common.rms_norm(h, p_s["norm"], cfg.norm_eps),
                           cfg, st_s)
        h = h + y
        if not decode:
            h = seq_shard(ctx, h)
        return h, (st_m_new, st_s_new)

    outer_fn = jax.checkpoint(outer) if (cfg.remat and not decode) else outer
    x, (st_m, st_s) = jax.lax.scan(
        outer_fn, x,
        (params["mlstm"], state["mlstm"], params["slstm"], state["slstm"]))
    return x, {"mlstm": st_m, "slstm": st_s}


def xlstm_loss(params, batch, cfg, ctx):
    x = params["embed"][batch["tokens"]]
    x, _ = _xlstm_pass(params, x, cfg, None, decode=False, ctx=ctx)
    x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
    loss = common.chunked_softmax_xent(x, params["lm_head"], batch["labels"])
    return loss, {"xent": loss}


def xlstm_prefill(params, batch, cfg, ctx):
    x = params["embed"][batch["tokens"]]
    x, state = _xlstm_pass(params, x, cfg, None, decode=False, ctx=ctx)
    x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x[:, -1] @ params["lm_head"]
    return logits.astype(jnp.float32), state


def xlstm_decode(params, tokens, cache, pos, cfg, ctx):
    x = params["embed"][tokens]
    x, state = _xlstm_pass(params, x, cfg, cache, decode=True, ctx=ctx)
    x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x[:, -1] @ params["lm_head"]
    return logits.astype(jnp.float32), state


# ===========================================================================
# Zamba2 hybrid
# ===========================================================================
def _zamba_groups(cfg: ModelConfig):
    every = cfg.shared_attn_every
    n_full = cfg.num_layers // every
    rem = cfg.num_layers - n_full * every
    sizes = [every] * n_full + ([rem] if rem else [])
    return sizes, n_full  # n_full == number of shared-attn sites


def zamba_init(key, cfg: ModelConfig) -> dict:
    sizes, n_sites = _zamba_groups(cfg)
    ke, km, ka, kp, kh = jax.random.split(key, 5)

    def m_init(k):
        return {"norm": common.ones((cfg.d_model,), cfg.jnp_dtype),
                "cell": ssm.mamba2_init(k, cfg, cfg.d_model)}

    mkeys = jax.random.split(km, cfg.num_layers)
    k1, k2 = jax.random.split(ka)
    shared = {
        "norm": common.ones((2 * cfg.d_model,), cfg.jnp_dtype),
        "attn": attn.gqa_init(k1, cfg, d_model=2 * cfg.d_model),
        "mlp_norm": common.ones((cfg.d_model,), cfg.jnp_dtype),
        "mlp": common.mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.jnp_dtype),
    }
    # output projection maps attention back to d_model
    shared["attn"]["w_o"] = common.dense_init(
        jax.random.fold_in(ka, 7), cfg.n_heads * cfg.head_dim, cfg.d_model,
        cfg.jnp_dtype)
    return {
        "embed": common.embed_init(ke, cfg.padded_vocab, cfg.d_model,
                                   cfg.jnp_dtype),
        "mamba": jax.vmap(m_init)(mkeys),
        "shared": shared,
        "final_norm": common.ones((cfg.d_model,), cfg.jnp_dtype),
        "lm_head": common.dense_init(kh, cfg.d_model, cfg.padded_vocab,
                                     cfg.jnp_dtype),
    }


def zamba_empty_cache(cfg: ModelConfig, batch: int, seq: int,
                      dtype=None) -> dict:
    _, n_sites = _zamba_groups(cfg)
    m_one = ssm.mamba2_empty_state(cfg, cfg.d_model, batch)
    s = seq if cfg.sliding_window is None else min(seq, cfg.sliding_window)
    a_one = attn.gqa_empty_cache(cfg, batch, s, dtype)
    return {
        "mamba": jax.tree.map(
            lambda a: jnp.broadcast_to(
                a[None], (cfg.num_layers,) + a.shape), m_one),
        "attn": jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_sites,) + a.shape),
            a_one),
    }


def _slice_tree(tree, lo, hi):
    return jax.tree.map(lambda a: a[lo:hi], tree)


def _shared_attn_prefill(shared, h, x0, cfg, ctx, positions, make_cache):
    cat = jnp.concatenate([h, x0], axis=-1)
    cat = common.rms_norm(cat, shared["norm"], cfg.norm_eps)
    a, cache = attn.gqa_prefill(shared["attn"], cat, cfg, ctx, positions,
                                make_cache=make_cache)
    h = h + a
    f = common.rms_norm(h, shared["mlp_norm"], cfg.norm_eps)
    return h + common.mlp_apply(shared["mlp"], f), cache


def _shared_attn_decode(shared, h, x0, cfg, ctx, cache, pos):
    cat = jnp.concatenate([h, x0], axis=-1)
    cat = common.rms_norm(cat, shared["norm"], cfg.norm_eps)
    a, cache = attn.gqa_decode(shared["attn"], cat, cfg, ctx, cache, pos)
    h = h + a
    f = common.rms_norm(h, shared["mlp_norm"], cfg.norm_eps)
    return h + common.mlp_apply(shared["mlp"], f), cache


def _zamba_pass(params, x, cfg: ModelConfig, ctx, cache: Optional[dict],
                pos, decode: bool, make_cache: bool):
    """Traverse groups; returns (x, new_cache | None)."""
    sizes, n_sites = _zamba_groups(cfg)
    b, s, _ = x.shape
    x0 = x if decode else seq_shard(ctx, x)
    if not decode:
        positions = jnp.broadcast_to(jnp.arange(pos, pos + s)[None], (b, s))
    m_fn = ssm.mamba2_decode if decode else ssm.mamba2_prefill

    def group_body(h, xs):
        p, st = xs
        y, st_new = m_fn(p["cell"],
                         common.rms_norm(h, p["norm"], cfg.norm_eps),
                         cfg, st)
        h = h + y
        if not decode:
            h = seq_shard(ctx, h)
        return h, st_new

    body = (jax.checkpoint(group_body)
            if (cfg.remat and not decode) else group_body)

    shared_prefill = _shared_attn_prefill
    if cfg.remat and not decode and not make_cache:
        # loss path: remat each shared-attention site (its flash residuals
        # are full-sequence q/k/v/out tensors otherwise)
        shared_prefill = jax.checkpoint(_shared_attn_prefill,
                                        static_argnums=(3, 4, 6))

    new_m_states, new_a_caches = [], []
    lo = 0
    if decode:
        m_states = cache["mamba"]
    else:
        m_states = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.num_layers,) + a.shape),
            ssm.mamba2_empty_state(cfg, cfg.d_model, b))
    for gi, size in enumerate(sizes):
        p_g = _slice_tree(params["mamba"], lo, lo + size)
        st_g = _slice_tree(m_states, lo, lo + size)
        x, st_new = jax.lax.scan(body, x, (p_g, st_g))
        new_m_states.append(st_new)
        if gi < n_sites:
            if decode:
                a_cache = jax.tree.map(lambda a: a[gi], cache["attn"])
                x, a_new = _shared_attn_decode(params["shared"], x, x0, cfg,
                                               ctx, a_cache, pos)
            else:
                x, a_new = shared_prefill(params["shared"], x, x0, cfg,
                                          ctx, positions, make_cache)
            new_a_caches.append(a_new)
        lo += size

    out_cache = None
    if decode or make_cache:
        m_all = jax.tree.map(lambda *xs: jnp.concatenate(xs, 0),
                             *new_m_states)
        a_all = (jax.tree.map(lambda *xs: jnp.stack(xs, 0), *new_a_caches)
                 if new_a_caches[0] is not None else None)
        out_cache = {"mamba": m_all, "attn": a_all}
    return x, out_cache


def zamba_loss(params, batch, cfg, ctx):
    x = params["embed"][batch["tokens"]]
    x, _ = _zamba_pass(params, x, cfg, ctx, None, 0, decode=False,
                       make_cache=False)
    x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
    loss = common.chunked_softmax_xent(x, params["lm_head"], batch["labels"])
    return loss, {"xent": loss}


def zamba_prefill(params, batch, cfg, ctx):
    x = params["embed"][batch["tokens"]]
    x, cache = _zamba_pass(params, x, cfg, ctx, None, 0, decode=False,
                           make_cache=True)
    x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x[:, -1] @ params["lm_head"]
    return logits.astype(jnp.float32), cache


def zamba_decode(params, tokens, cache, pos, cfg, ctx):
    x = params["embed"][tokens]
    x, new_cache = _zamba_pass(params, x, cfg, ctx, cache, pos, decode=True,
                               make_cache=True)
    x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x[:, -1] @ params["lm_head"]
    return logits.astype(jnp.float32), new_cache
