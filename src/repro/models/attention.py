"""Attention: GQA (full / sliding-window) and MLA, prefill + decode paths.

Prefill/train uses a chunked online-softmax ("flash in jnp") so the (S, S)
score matrix is never materialised — required for the 32k prefill shape.

Decode uses flash-decoding with the KV cache sharded on the *sequence*
dimension across the ``model`` mesh axis: every shard attends over its cache
chunk and the per-shard partial (o, m, l) statistics are combined with one
small all-gather.  This is uniform in kv_heads, so any GQA geometry shards
over a 16-wide model axis without divisibility constraints.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

try:  # jax>=0.6 moved shard_map to the top level
    from jax import shard_map as _shard_map_mod
    shard_map = _shard_map_mod  # type: ignore[assignment]
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map  # type: ignore

from jax.sharding import PartitionSpec as P

from repro.models import common
from repro.models.config import ModelConfig
from repro.sharding import ShardingCtx, constrain

NEG_INF = -1e30


# ===========================================================================
# Parameter initialisation
# ===========================================================================
def gqa_init(key, cfg: ModelConfig, d_model: Optional[int] = None) -> dict:
    d = d_model or cfg.d_model
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = common.split_keys(key, 4)
    p = {
        "w_q": common.dense_init(ks[0], d, h * dh, cfg.jnp_dtype),
        "w_k": common.dense_init(ks[1], d, kv * dh, cfg.jnp_dtype),
        "w_v": common.dense_init(ks[2], d, kv * dh, cfg.jnp_dtype),
        "w_o": common.dense_init(ks[3], h * dh, d, cfg.jnp_dtype),
    }
    if cfg.qkv_bias:
        p["b_q"] = common.zeros((h * dh,), cfg.jnp_dtype)
        p["b_k"] = common.zeros((kv * dh,), cfg.jnp_dtype)
        p["b_v"] = common.zeros((kv * dh,), cfg.jnp_dtype)
    return p


def mla_init(key, cfg: ModelConfig) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    dq, dc = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.v_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    ks = common.split_keys(key, 8)
    return {
        "w_dq": common.dense_init(ks[0], d, dq, cfg.jnp_dtype),
        "q_norm": common.ones((dq,), cfg.jnp_dtype),
        "w_uq_nope": common.dense_init(ks[1], dq, h * dn, cfg.jnp_dtype),
        "w_uq_rope": common.dense_init(ks[2], dq, h * dr, cfg.jnp_dtype),
        "w_dkv": common.dense_init(ks[3], d, dc, cfg.jnp_dtype),
        "kv_norm": common.ones((dc,), cfg.jnp_dtype),
        "w_kr": common.dense_init(ks[4], d, dr, cfg.jnp_dtype),
        "w_uk": common.dense_init(ks[5], dc, h * dn, cfg.jnp_dtype),
        "w_uv": common.dense_init(ks[6], dc, h * dv, cfg.jnp_dtype),
        "w_o": common.dense_init(ks[7], h * dv, d, cfg.jnp_dtype),
    }


def attn_init(key, cfg: ModelConfig) -> dict:
    return mla_init(key, cfg) if cfg.attention == "mla" else gqa_init(key, cfg)


# ===========================================================================
# Chunked online-softmax attention (prefill / train)
#
# ``chunked_attention`` carries a flash-attention custom VJP: the backward
# pass recomputes per-block attention probabilities from saved (out, lse)
# instead of letting scan-of-scan autodiff store every (bq, bk) probability
# block — without it, a 4k-train layer keeps O(S^2) f32 residuals alive and
# no long-context shape fits HBM.
# ===========================================================================
def _pick_block(s: int, want: int) -> int:
    b = min(s, want)
    while s % b:
        b //= 2
    return max(b, 1)


def _block_bias(q_ids, k_ids, causal: bool, window: Optional[int]):
    """Additive (bq, bk) mask bias, or None when nothing is masked."""
    if not causal and window is None:
        return None
    ok = jnp.ones((q_ids.shape[0], k_ids.shape[0]), bool)
    if causal:
        ok &= k_ids[None, :] <= q_ids[:, None]
    if window is not None:
        ok &= k_ids[None, :] > q_ids[:, None] - window
    return jnp.where(ok, 0.0, NEG_INF)


def chunked_attention(q, k, v, *, causal: bool = True, q_offset=0,
                      window: Optional[int] = None,
                      valid_len: Optional[jax.Array] = None,
                      block_q: int = 512, block_k: int = 512) -> jax.Array:
    """q: (B,Sq,KV,G,dhq)  k: (B,Sk,KV,dhk)  v: (B,Sk,KV,dhv) -> (B,Sq,KV,G,dhv).

    Online softmax over kv blocks; outer sequential map over q blocks keeps
    the peak score tensor at (B,KV,G,Bq,Bk).  Differentiable via a flash
    custom VJP (valid_len is a non-differentiable inference-only extra).
    """
    if valid_len is None:
        f = _flash_fn(causal, window, int(q_offset), block_q, block_k)
        return f(q, k, v)
    return _masked_attention_fallback(q, k, v, causal=causal,
                                      q_offset=q_offset, window=window,
                                      valid_len=valid_len, block_q=block_q,
                                      block_k=block_k)


def _masked_attention_fallback(q, k, v, *, causal, q_offset, window,
                               valid_len, block_q, block_k) -> jax.Array:
    """Original (non-custom-vjp) path, used only with ``valid_len``."""
    b, sq, kvh, g, dhq = q.shape
    sk, dhv = k.shape[1], v.shape[-1]
    bq, bk = _pick_block(sq, block_q), _pick_block(sk, block_k)
    nq, nk = sq // bq, sk // bk
    scale = 1.0 / jnp.sqrt(jnp.array(dhq, jnp.float32))

    kb = k.reshape(b, nk, bk, kvh, -1).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nk, bk, kvh, dhv).transpose(1, 0, 2, 3, 4)

    def one_q_block(qi):
        qblk = jax.lax.dynamic_slice_in_dim(q, qi * bq, bq, axis=1)
        qf = qblk * jnp.asarray(scale, q.dtype)
        q_ids = q_offset + qi * bq + jnp.arange(bq)

        def kv_step(carry, xs):
            m, l, acc = carry
            kblk, vblk, ki = xs
            k_ids = ki * bk + jnp.arange(bk)
            # scores: (B, KV, G, Bq, Bk).  f32 accumulation via
            # preferred_element_type (casting inputs would materialise f32
            # copies of K/V and double the HBM traffic).
            s = jnp.einsum("bqkgd,bpkd->bkgqp", qf, kblk,
                           preferred_element_type=jnp.float32)
            # masking as a SMALL additive bias (bq, bk): a boolean mask
            # select gets hoisted by XLA into a precomputed
            # (nq, nk, B, KV, G, bq, bk) buffer — gigabytes of loop state.
            if causal or window is not None:
                ok = jnp.ones((bq, bk), bool)
                if causal:
                    ok &= k_ids[None, :] <= q_ids[:, None]
                if window is not None:
                    ok &= k_ids[None, :] > q_ids[:, None] - window
                s = s + jnp.where(ok, 0.0, NEG_INF)[None, None, None]
            if valid_len is not None:
                vbias = jnp.where(k_ids[None] < valid_len[:, None],
                                  0.0, NEG_INF)                # (B, Bk)
                s = s + vbias[:, None, None, None]
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            pv = jnp.einsum("bkgqp,bpkd->bkgqd",
                            p.astype(v.dtype), vblk,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kvh, g, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, bq), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, bq, dhv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (kb, vb, jnp.arange(nk)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.transpose(0, 3, 1, 2, 4)                   # (B,Bq,KV,G,dhv)

    out = jax.lax.map(one_q_block, jnp.arange(nq))            # (nq,B,Bq,...)
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, kvh, g, dhv)
    return out.astype(v.dtype)


# ---------------------------------------------------------------------------
# Flash attention with custom VJP (recompute-based backward)
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _flash_fn(causal: bool, window: Optional[int], q_offset: int,
              block_q: int, block_k: int):
    """Build (and cache) the custom-vjp flash attention for one mask config."""

    def fwd_pass(q, k, v):
        b, sq, kvh, g, dhq = q.shape
        sk, dhv = k.shape[1], v.shape[-1]
        bq, bk = _pick_block(sq, block_q), _pick_block(sk, block_k)
        nq, nk = sq // bq, sk // bk
        scale = jnp.asarray(1.0 / (dhq ** 0.5), q.dtype)
        # Block access pattern (hard-won on the dry-run memory reports):
        #   * inner loops scan over PRE-STACKED bf16 copies of K/V (a
        #     dynamic_slice with a traced index on a seq-SHARDED tensor
        #     triggers GSPMD "involuntary full rematerialization");
        #   * outer loops are STATIC python loops (lax.map would stack the
        #     per-block f32 outputs into a whole-tensor temp).
        kb = k.reshape(b, nk, bk, kvh, dhq).transpose(1, 0, 2, 3, 4)
        vb = v.reshape(b, nk, bk, kvh, dhv).transpose(1, 0, 2, 3, 4)

        def one_q_block(qi):
            qblk = jax.lax.dynamic_slice_in_dim(q, qi * bq, bq, 1) * scale
            q_ids = q_offset + qi * bq + jnp.arange(bq)

            def kv_step(carry, xs):
                m, l, acc = carry
                kblk, vblk, ki = xs
                k_ids = ki * bk + jnp.arange(bk)
                s = jnp.einsum("bqkgd,bpkd->bkgqp", qblk, kblk,
                               preferred_element_type=jnp.float32)
                bias = _block_bias(q_ids, k_ids, causal, window)
                if bias is not None:
                    s = s + bias[None, None, None]
                m_new = jnp.maximum(m, s.max(-1))
                p = jnp.exp(s - m_new[..., None])
                corr = jnp.exp(m - m_new)
                l_new = l * corr + p.sum(-1)
                pv = jnp.einsum("bkgqp,bpkd->bkgqd", p.astype(v.dtype),
                                vblk, preferred_element_type=jnp.float32)
                return (m_new, l_new, acc * corr[..., None] + pv), None

            m0 = jnp.full((b, kvh, g, bq), NEG_INF, jnp.float32)
            l0 = jnp.zeros((b, kvh, g, bq), jnp.float32)
            a0 = jnp.zeros((b, kvh, g, bq, dhv), jnp.float32)
            (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                          (kb, vb, jnp.arange(nk)))
            out = acc / jnp.maximum(l, 1e-30)[..., None]
            lse = m + jnp.log(jnp.maximum(l, 1e-30))           # (B,KV,G,bq)
            return out.transpose(0, 3, 1, 2, 4).astype(v.dtype), lse

        out, lse = jax.lax.map(one_q_block, jnp.arange(nq))
        out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, kvh, g, dhv)
        lse = lse.transpose(1, 2, 3, 0, 4).reshape(b, kvh, g, sq)
        return out, lse

    def f(q, k, v):
        return fwd_pass(q, k, v)[0]

    def f_fwd(q, k, v):
        out, lse = fwd_pass(q, k, v)
        return out, (q, k, v, out, lse)

    def f_bwd(res, d_out):
        q, k, v, out, lse = res
        b, sq, kvh, g, dhq = q.shape
        sk, dhv = k.shape[1], v.shape[-1]
        bq, bk = _pick_block(sq, block_q), _pick_block(sk, block_k)
        nq, nk = sq // bq, sk // bk
        scale = jnp.asarray(1.0 / (dhq ** 0.5), q.dtype)
        qs = q * scale
        # cotangent arrives f32 from upstream norm math; carry it at the
        # model dtype (delta keeps f32 accuracy via preferred_element_type)
        delta = jnp.einsum("bqkgd,bqkgd->bkgq", d_out, out,
                           preferred_element_type=jnp.float32)
        d_out = d_out.astype(v.dtype)

        def sl(t, i, blk):
            return jax.lax.dynamic_slice_in_dim(t, i * blk, blk, 1)

        def sl_stat(t, qi):   # (B,KV,G,Sq) -> (B,KV,G,bq)
            return jax.lax.dynamic_slice_in_dim(t, qi * bq, bq, 3)

        kb = k.reshape(b, nk, bk, kvh, dhq).transpose(1, 0, 2, 3, 4)
        vb = v.reshape(b, nk, bk, kvh, dhv).transpose(1, 0, 2, 3, 4)
        qsb = qs.reshape(b, nq, bq, kvh, g, dhq).transpose(1, 0, 2, 3, 4, 5)
        dob = d_out.reshape(b, nq, bq, kvh, g, dhv).transpose(
            1, 0, 2, 3, 4, 5)

        def p_block(qi, ki, qblk, kblk, lse_q):
            q_ids = q_offset + qi * bq + jnp.arange(bq)
            k_ids = ki * bk + jnp.arange(bk)
            s = jnp.einsum("bqkgd,bpkd->bkgqp", qblk, kblk,
                           preferred_element_type=jnp.float32)
            bias = _block_bias(q_ids, k_ids, causal, window)
            if bias is not None:
                s = s + bias[None, None, None]
            return jnp.exp(s - lse_q[..., None])               # (B,KV,G,bq,bk)

        # ---- dQ: static python loop over q blocks, scan over kv blocks
        def dq_block(qi):
            qblk = jax.lax.dynamic_slice_in_dim(qsb, qi, 1, 0)[0]
            do_q = jax.lax.dynamic_slice_in_dim(dob, qi, 1, 0)[0]
            lse_q, delta_q = sl_stat(lse, qi), sl_stat(delta, qi)

            def step(acc, xs):
                kblk, vblk, ki = xs
                p = p_block(qi, ki, qblk, kblk, lse_q)
                dp = jnp.einsum("bqkgd,bpkd->bkgqp", do_q, vblk,
                                preferred_element_type=jnp.float32)
                ds = p * (dp - delta_q[..., None])
                dq = jnp.einsum("bkgqp,bpkd->bqkgd", ds.astype(k.dtype),
                                kblk, preferred_element_type=jnp.float32)
                return acc + dq, None

            acc0 = jnp.zeros((b, bq, kvh, g, dhq), jnp.float32)
            acc, _ = jax.lax.scan(step, acc0, (kb, vb, jnp.arange(nk)))
            return (acc * jnp.float32(scale)).astype(q.dtype)

        dq = jax.lax.map(dq_block, jnp.arange(nq))
        dq = dq.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, kvh, g, dhq)

        # ---- dK, dV: static python loop over kv blocks, scan over q blocks
        def dkv_block(ki):
            kblk = jax.lax.dynamic_slice_in_dim(kb, ki, 1, 0)[0]
            vblk = jax.lax.dynamic_slice_in_dim(vb, ki, 1, 0)[0]

            def step(carry, xs):
                dk_acc, dv_acc = carry
                qblk, doblk, qi = xs
                lse_q = jax.lax.dynamic_slice_in_dim(lse, qi * bq, bq, 3)
                delta_q = jax.lax.dynamic_slice_in_dim(delta, qi * bq, bq, 3)
                p = p_block(qi, ki, qblk, kblk, lse_q)
                dv = jnp.einsum("bkgqp,bqkgd->bpkd", p.astype(q.dtype),
                                doblk, preferred_element_type=jnp.float32)
                dp = jnp.einsum("bqkgd,bpkd->bkgqp", doblk, vblk,
                                preferred_element_type=jnp.float32)
                ds = p * (dp - delta_q[..., None])
                dk = jnp.einsum("bkgqp,bqkgd->bpkd", ds.astype(q.dtype),
                                qblk, preferred_element_type=jnp.float32)
                return (dk_acc + dk, dv_acc + dv), None

            z_k = jnp.zeros((b, bk, kvh, dhq), jnp.float32)
            z_v = jnp.zeros((b, bk, kvh, dhv), jnp.float32)
            (dk, dv), _ = jax.lax.scan(
                step, (z_k, z_v), (qsb, dob, jnp.arange(nq)))
            return dk.astype(k.dtype), dv.astype(v.dtype)

        dk, dv = jax.lax.map(dkv_block, jnp.arange(nk))
        dk = dk.transpose(1, 0, 2, 3, 4).reshape(b, sk, kvh, dhq)
        dv = dv.transpose(1, 0, 2, 3, 4).reshape(b, sk, kvh, dhv)
        return dq, dk, dv

    flash = jax.custom_vjp(f)
    flash.defvjp(f_fwd, f_bwd)
    return flash


# ===========================================================================
# Flash-decoding: one query token against a (possibly seq-sharded) cache
# ===========================================================================
def _decode_partial(q, k, v, valid):
    """Local attention partials.  q: (B,KV,G,dhq) k: (B,S,KV,dhk)
    v: (B,S,KV,dhv) valid: (B,S) -> (o, m, l) unnormalised."""
    dhq = q.shape[-1]
    scale = jnp.asarray(1.0 / (dhq ** 0.5), q.dtype)
    s = jnp.einsum("bkgd,bskd->bkgs", q * scale, k,
                   preferred_element_type=jnp.float32)
    s = s + jnp.where(valid, 0.0, NEG_INF)[:, None, None, :]
    m = s.max(-1)                                             # (B,KV,G)
    p = jnp.exp(s - m[..., None])
    l = p.sum(-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o, m, l


def _combine_partials(o, m, l):
    """Combine per-shard partials stacked on axis 0."""
    m_star = m.max(0)
    w = jnp.exp(m - m_star[None])
    l_star = (l * w).sum(0)
    o_star = (o * w[..., None]).sum(0)
    return o_star / jnp.maximum(l_star, 1e-30)[..., None]


def _pallas_decode(q, k_cache, v_cache, valid):
    """Route single-device decode through the Pallas flash-decoding kernel
    (kernels/flash_decode.py; interpret=True off-TPU via kernels.ops).

    The kernel works in a flat (BH, ...) layout with one KV row per query
    head, so the grouped cache is broadcast across the G query heads — the
    G-fold read amplification is the price of the kernel's HBM->VMEM
    streaming pipeline and only applies on this explicitly-requested path.
    Requires dhk == dhv (GQA; MLA's asymmetric latent head falls back).

    Batched ragged decode rides through unchanged: the (B, S) ``valid``
    mask is per ROW, so a stacked batch of requests at different cache
    positions is one kernel call over BH query rows — exactly how the
    continuous-batching scheduler amortises the cache stream.
    """
    from repro.kernels import ops

    b, kv, g, dh = q.shape
    s, dv = k_cache.shape[1], v_cache.shape[-1]
    bh = b * kv * g
    qf = q.reshape(bh, dh)
    kf = jnp.broadcast_to(k_cache.transpose(0, 2, 1, 3)[:, :, None],
                          (b, kv, g, s, dh)).reshape(bh, s, dh)
    vf = jnp.broadcast_to(v_cache.transpose(0, 2, 1, 3)[:, :, None],
                          (b, kv, g, s, dv)).reshape(bh, s, dv)
    validf = jnp.broadcast_to(valid[:, None, None], (b, kv, g, s)
                              ).reshape(bh, s)
    out = ops.decode(qf, kf, vf, validf, block_k=_pick_block(s, 512))
    return out.reshape(b, kv, g, dv)


def flash_decode(q, k_cache, v_cache, valid, ctx: Optional[ShardingCtx],
                 impl: Optional[str] = None):
    """q: (B,KV,G,dhq); caches: (B,S,KV,dh*); valid: (B,S) -> (B,KV,G,dhv).

    With ``ctx``: cache sequence dim sharded over the model axis; partials
    combined with an all-gather of (o, m, l) (tiny: no seq dim).

    ``impl="pallas"`` (single-device only) runs the Pallas flash-decoding
    kernel instead of the jnp online softmax — the engine's KV decode path
    selects it so the cache streams HBM -> VMEM in blocks.
    """
    if ctx is None:
        if (impl == "pallas"
                and k_cache.shape[-1] == v_cache.shape[-1]):
            return _pallas_decode(q, k_cache, v_cache, valid)
        o, m, l = _decode_partial(q, k_cache, v_cache, valid)
        return _combine_partials(o[None], m[None], l[None]).astype(v_cache.dtype)

    bs, ax = ctx.batch_spec, ctx.model_axis

    def local(qq, kk, vv, va):
        o, m, l = _decode_partial(qq, kk, vv, va)
        # psum-based softmax combine: pmax the running max, then psum the
        # rescaled (l, o) partials — cheaper than all-gathering partials and
        # provably model-axis-invariant (keeps shard_map's VMA check happy).
        m_star = jax.lax.pmax(m, ax)
        w = jnp.exp(m - m_star)
        l_star = jax.lax.psum(l * w, ax)
        o_star = jax.lax.psum(o * w[..., None], ax)
        return (o_star / jnp.maximum(l_star, 1e-30)[..., None]).astype(
            vv.dtype)

    return shard_map(
        local, mesh=ctx.mesh,
        in_specs=(P(bs, None, None, None), P(bs, ax, None, None),
                  P(bs, ax, None, None), P(bs, ax)),
        out_specs=P(bs, None, None, None))(q, k_cache, v_cache, valid)


def _pallas_paged_decode(q, k_pool, v_pool, tables, pos):
    """Route paged decode through the Pallas block-table kernel
    (kernels/paged_decode.py).  The kernel consumes the scheduler's
    native (P, page, KV, dh) pool layout directly — its BlockSpec
    index_map dereferences the scalar-prefetched table per (row, block)
    and slices the kv head, so no transpose/densify of the pool is ever
    materialised.  The grouped tile is re-read per q-head group (the
    same G-fold read amplification as ``_pallas_decode``, the price of
    the HBM -> VMEM streaming pipeline).
    """
    from repro.kernels import ops

    return ops.paged_decode(q, k_pool, v_pool, tables,
                            (pos + 1).astype(jnp.int32))


def _pallas_paged_verify(q, k_pool, v_pool, tables, lengths):
    """Stacked W-query sibling of ``_pallas_paged_decode``: one kernel
    call scores a whole speculation window, each query applying its own
    causal frontier inside the block-table gather."""
    from repro.kernels import ops

    return ops.paged_verify(q, k_pool, v_pool, tables,
                            lengths.astype(jnp.int32))


def gqa_decode_paged(params, x, cfg: ModelConfig, pools, tables, pos, *,
                     attn_impl=None):
    """GQA decode against the PAGED cache: pools{k,v}: (P, page, KV, dh);
    tables: (B, NB) block tables; pos: (B,) ragged positions.

    The new token's K/V is written straight into its page
    (``tables[b, pos // page]``, slot ``pos % page`` — the scheduler
    guarantees that page is private, copy-on-writing shared pages at
    the round boundary).  ``attn_impl="pallas"`` runs the block-table
    kernel; the jnp path gathers the row's pages into the logically
    contiguous cache, which is bit-identical to a dense decode over the
    same padded length.  Full causal attention only (the paged serving
    path does not model sliding windows).
    """
    b = x.shape[0]
    kv, g, dh = cfg.n_kv_heads, cfg.q_heads_per_kv, cfg.head_dim
    page = pools["k"].shape[1]
    nb = tables.shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    pos_b = (pos.reshape(b, 1) if pos.ndim
             else jnp.full((b, 1), pos, jnp.int32))
    posv = pos_b[:, 0]
    q, k, v = _project_qkv(params, x, cfg)
    q = common.apply_rope(q, pos_b, cfg.rope_theta)
    k = common.apply_rope(k, pos_b, cfg.rope_theta)

    rows = jnp.arange(b)
    pids = tables[rows, posv // page]
    k_pool = pools["k"].at[pids, posv % page].set(
        k[:, 0].astype(pools["k"].dtype))
    v_pool = pools["v"].at[pids, posv % page].set(
        v[:, 0].astype(pools["v"].dtype))

    qh = q.reshape(b, kv, g, dh)
    if attn_impl == "pallas":
        out = _pallas_paged_decode(qh, k_pool, v_pool, tables, posv)
    else:
        k_cache = k_pool[tables].reshape(b, nb * page, kv, dh)
        v_cache = v_pool[tables].reshape(b, nb * page, kv, dh)
        valid = jnp.arange(nb * page)[None, :] <= pos_b
        out = flash_decode(qh, k_cache, v_cache,
                           jnp.broadcast_to(valid, (b, nb * page)), None)
    out = out.reshape(b, 1, kv * g * dh) @ params["w_o"]
    return out, {"k": k_pool, "v": v_pool}


def gqa_verify_paged(params, x, cfg: ModelConfig, pools, tables, pos, *,
                     attn_impl=None):
    """Stacked multi-token GQA decode against the PAGED cache — the
    speculative-verify sibling of ``gqa_decode_paged``.

    ``x``: (B, W, D) — W consecutive tokens per row (the last committed
    token followed by the draft's proposals); ``pos``: (B,) cache slot
    of the FIRST stacked token.  All W tokens' K/V are written into
    their pages up front (the scheduler guarantees the write-range pages
    are private), then each query attends causally up to its own slot —
    token i sees slots ``<= pos + i`` — so row i's output equals what W
    sequential ``gqa_decode_paged`` calls would produce, in ONE pass
    over the pool.  Rejected suffixes leave garbage K/V past the
    accepted length; it is masked by every later valid-length mask and
    overwritten before it ever unmasks.

    ``attn_impl="pallas"`` runs the stacked block-table kernel; the jnp
    path flattens (B, W) into the batch dim and reuses the EXACT decode
    attention (``flash_decode``) so verify logits are bit-identical to
    the sequential jnp decode path.
    """
    b, w, _ = x.shape
    kv, g, dh = cfg.n_kv_heads, cfg.q_heads_per_kv, cfg.head_dim
    page = pools["k"].shape[1]
    nb = tables.shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    pos_b = (pos.reshape(b, 1) if pos.ndim
             else jnp.full((b, 1), pos, jnp.int32))
    positions = pos_b + jnp.arange(w, dtype=jnp.int32)[None, :]  # (B, W)
    q, k, v = _project_qkv(params, x, cfg)
    q = common.apply_rope(q, positions, cfg.rope_theta)
    k = common.apply_rope(k, positions, cfg.rope_theta)

    pids = tables[jnp.arange(b)[:, None], positions // page]     # (B, W)
    slots = positions % page
    k_pool = pools["k"].at[pids, slots].set(k.astype(pools["k"].dtype))
    v_pool = pools["v"].at[pids, slots].set(v.astype(pools["v"].dtype))

    qh = q.reshape(b, w, kv, g, dh)
    if attn_impl == "pallas":
        out = _pallas_paged_verify(qh, k_pool, v_pool, tables,
                                   pos_b[:, 0] + w)
    else:
        s_tot = nb * page
        k_cache = k_pool[tables].reshape(b, s_tot, kv, dh)
        v_cache = v_pool[tables].reshape(b, s_tot, kv, dh)
        valid = (jnp.arange(s_tot)[None, None, :]
                 <= positions[:, :, None])                    # (B, W, S)
        qf = qh.reshape(b * w, kv, g, dh)
        kf = jnp.broadcast_to(k_cache[:, None],
                              (b, w, s_tot, kv, dh)
                              ).reshape(b * w, s_tot, kv, dh)
        vf = jnp.broadcast_to(v_cache[:, None],
                              (b, w, s_tot, kv, dh)
                              ).reshape(b * w, s_tot, kv, dh)
        out = flash_decode(qf, kf, vf, valid.reshape(b * w, s_tot), None)
        out = out.reshape(b, w, kv, g, dh)
    out = out.reshape(b, w, kv * g * dh) @ params["w_o"]
    return out, {"k": k_pool, "v": v_pool}


def cache_update(cache, new, pos, ctx: Optional[ShardingCtx]):
    """Write ``new`` (B, KV, dh) into ``cache`` (B, S, KV, dh) at index ``pos``.

    ``pos`` may be a scalar (one write slot for the whole batch — the
    single-request decode path) or a (B,) vector of RAGGED per-row slots:
    the continuous-batching scheduler stacks requests whose sequences are
    at different lengths, so each row writes its own cache slot.

    Sequence dim may be sharded over the model axis: each shard applies a
    masked write iff ``pos`` lands in its range (no cross-shard traffic).
    """
    if ctx is None:
        pos = jnp.asarray(pos)
        if pos.ndim == 0:
            return jax.lax.dynamic_update_slice_in_dim(
                cache, new[:, None].astype(cache.dtype), pos, axis=1)
        row_write = jax.vmap(
            lambda c, n, p: jax.lax.dynamic_update_slice_in_dim(
                c, n[None], p, axis=0))
        return row_write(cache, new.astype(cache.dtype), pos)
    if jnp.ndim(pos):
        raise NotImplementedError(
            "ragged per-row cache positions are single-device only "
            "(the seq-sharded serving cache keeps one slot per step)")

    bs, ax = ctx.batch_spec, ctx.model_axis

    def local(c, n):
        s_loc = c.shape[1]
        start = jax.lax.axis_index(ax) * s_loc
        idx = pos - start
        in_range = (idx >= 0) & (idx < s_loc)
        idx = jnp.clip(idx, 0, s_loc - 1)
        # out-of-range shards overwrite the slot with its EXISTING row —
        # a row-level select instead of where(in_range, updated, c), which
        # materialises a full second copy of the cache per layer step
        old_row = jax.lax.dynamic_slice_in_dim(c, idx, 1, axis=1)
        val = jnp.where(in_range, n[:, None].astype(c.dtype), old_row)
        return jax.lax.dynamic_update_slice_in_dim(c, val, idx, axis=1)

    return shard_map(
        local, mesh=ctx.mesh,
        in_specs=(P(bs, ax, None, None), P(bs, None, None)),
        out_specs=P(bs, ax, None, None))(cache, new)


# ===========================================================================
# GQA block: prefill + decode
# ===========================================================================
def _project_qkv(params, x, cfg: ModelConfig):
    b, s, _ = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ params["w_q"]
    k = x @ params["w_k"]
    v = x @ params["w_v"]
    if cfg.qkv_bias:
        q, k, v = q + params["b_q"], k + params["b_k"], v + params["b_v"]
    return (q.reshape(b, s, h, dh), k.reshape(b, s, kv, dh),
            v.reshape(b, s, kv, dh))


def gqa_prefill(params, x, cfg: ModelConfig, ctx, positions, *,
                causal=True, make_cache=True):
    """x: (B,S,D) -> (out (B,S,D), cache | None)."""
    b, s, _ = x.shape
    kv, g, dh = cfg.n_kv_heads, cfg.q_heads_per_kv, cfg.head_dim
    q, k, v = _project_qkv(params, x, cfg)
    q = common.apply_rope(q, positions, cfg.rope_theta)
    k = common.apply_rope(k, positions, cfg.rope_theta)
    qg = q.reshape(b, s, kv, g, dh)
    out = chunked_attention(qg, k, v, causal=causal,
                            window=cfg.sliding_window)
    out = out.reshape(b, s, kv * g * dh) @ params["w_o"]
    cache = None
    if make_cache:
        if ctx is not None:  # live seq-sharded for the decode phase
            k = constrain(ctx, k, ctx.batch_spec, ctx.model_axis)
            v = constrain(ctx, v, ctx.batch_spec, ctx.model_axis)
        cache = {"k": k, "v": v}
    return out, cache


def gqa_mrope_prefill(params, x, cfg: ModelConfig, ctx, positions3, *,
                      make_cache=True):
    """Qwen2-VL style prefill with 3-section M-RoPE positions (3,B,S)."""
    b, s, _ = x.shape
    kv, g, dh = cfg.n_kv_heads, cfg.q_heads_per_kv, cfg.head_dim
    q, k, v = _project_qkv(params, x, cfg)
    q = common.apply_mrope(q, positions3, cfg.mrope_sections, cfg.rope_theta)
    k = common.apply_mrope(k, positions3, cfg.mrope_sections, cfg.rope_theta)
    qg = q.reshape(b, s, kv, g, dh)
    out = chunked_attention(qg, k, v, causal=True, window=cfg.sliding_window)
    out = out.reshape(b, s, kv * g * dh) @ params["w_o"]
    cache = None
    if make_cache:
        if ctx is not None:
            k = constrain(ctx, k, ctx.batch_spec, ctx.model_axis)
            v = constrain(ctx, v, ctx.batch_spec, ctx.model_axis)
        cache = {"k": k, "v": v}
    return out, cache


def gqa_decode(params, x, cfg: ModelConfig, ctx, cache, pos, *,
               mrope_positions3=None, attn_impl=None):
    """x: (B,1,D); cache{k,v}: (B,S,KV,dh); pos: scalar or RAGGED (B,)
    vector of per-row cache positions -> (out, cache)."""
    b = x.shape[0]
    kv, g, dh = cfg.n_kv_heads, cfg.q_heads_per_kv, cfg.head_dim
    pos = jnp.asarray(pos, jnp.int32)
    pos_b = pos.reshape(b, 1) if pos.ndim else jnp.full((b, 1), pos,
                                                        jnp.int32)
    q, k, v = _project_qkv(params, x, cfg)
    if mrope_positions3 is not None:
        q = common.apply_mrope(q, mrope_positions3, cfg.mrope_sections,
                               cfg.rope_theta)
        k = common.apply_mrope(k, mrope_positions3, cfg.mrope_sections,
                               cfg.rope_theta)
    else:
        q = common.apply_rope(q, pos_b, cfg.rope_theta)
        k = common.apply_rope(k, pos_b, cfg.rope_theta)
    s_cache = cache["k"].shape[1]
    write_idx = pos % s_cache                       # ring buffer for windows
    k_cache = cache_update(cache["k"], k[:, 0], write_idx, ctx)
    v_cache = cache_update(cache["v"], v[:, 0], write_idx, ctx)
    idx = jnp.arange(s_cache)
    if cfg.sliding_window is not None and cfg.sliding_window < s_cache:
        # full-length cache, windowed mask (writes are positional)
        valid = ((idx[None, :] <= pos_b)
                 & (idx[None, :] > pos_b - cfg.sliding_window))
    elif cfg.sliding_window is not None:
        # ring cache at window size: every written slot is a valid key
        # (keys carry absolute rope; softmax is permutation-invariant)
        valid = idx[None, :] < jnp.minimum(pos_b + 1, s_cache)
    else:
        valid = idx[None, :] <= pos_b
    valid = jnp.broadcast_to(valid, (b, s_cache))
    qh = q.reshape(b, kv, g, dh)
    out = flash_decode(qh, k_cache, v_cache, valid, ctx, impl=attn_impl)
    out = out.reshape(b, 1, kv * g * dh) @ params["w_o"]
    return out, {"k": k_cache, "v": v_cache}


def gqa_empty_cache(cfg: ModelConfig, batch: int, seq: int, dtype=None):
    dh, kv = cfg.head_dim, cfg.n_kv_heads
    dt = dtype or cfg.jnp_dtype
    return {"k": jnp.zeros((batch, seq, kv, dh), dt),
            "v": jnp.zeros((batch, seq, kv, dh), dt)}


# ===========================================================================
# Cross attention (encoder-decoder)
# ===========================================================================
def cross_attn_prefill_kv(params, enc_out, cfg: ModelConfig, ctx):
    """Compute the static cross-attention KV cache from encoder output."""
    b, s, _ = enc_out.shape
    kv, dh = cfg.n_kv_heads, cfg.head_dim
    k = (enc_out @ params["w_k"]).reshape(b, s, kv, dh)
    v = (enc_out @ params["w_v"]).reshape(b, s, kv, dh)
    if cfg.qkv_bias:
        k, v = k + params["b_k"].reshape(kv, dh), v + params["b_v"].reshape(kv, dh)
    if ctx is not None:
        k = constrain(ctx, k, ctx.batch_spec, ctx.model_axis)
        v = constrain(ctx, v, ctx.batch_spec, ctx.model_axis)
    return {"k": k, "v": v}


def cross_attn_apply(params, x, kv_cache, cfg: ModelConfig, ctx):
    """x: (B,Sq,D) attends (non-causal) over encoder KV."""
    b, sq, _ = x.shape
    kv, g, dh = cfg.n_kv_heads, cfg.q_heads_per_kv, cfg.head_dim
    q = (x @ params["w_q"]).reshape(b, sq, cfg.n_heads, dh)
    if cfg.qkv_bias:
        q = q + params["b_q"].reshape(cfg.n_heads, dh)
    k, v = kv_cache["k"], kv_cache["v"]
    if sq == 1:
        valid = jnp.ones((b, k.shape[1]), bool)
        out = flash_decode(q.reshape(b, kv, g, dh), k, v, valid, ctx)
        out = out.reshape(b, 1, kv * g * dh)
    else:
        qg = q.reshape(b, sq, kv, g, dh)
        out = chunked_attention(qg, k, v, causal=False)
        out = out.reshape(b, sq, kv * g * dh)
    return out @ params["w_o"]


# ===========================================================================
# MLA (Multi-head Latent Attention) — MiniCPM3 / DeepSeek style
# ===========================================================================
def mla_prefill(params, x, cfg: ModelConfig, ctx, positions, *,
                make_cache=True):
    b, s, _ = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.v_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    dc = cfg.kv_lora_rank

    qc = common.rms_norm(x @ params["w_dq"], params["q_norm"], cfg.norm_eps)
    q_nope = (qc @ params["w_uq_nope"]).reshape(b, s, h, dn)
    q_rope = (qc @ params["w_uq_rope"]).reshape(b, s, h, dr)
    q_rope = common.apply_rope(q_rope, positions, cfg.rope_theta)

    c = common.rms_norm(x @ params["w_dkv"], params["kv_norm"], cfg.norm_eps)
    k_rope = common.apply_rope((x @ params["w_kr"]).reshape(b, s, 1, dr),
                               positions, cfg.rope_theta)
    k_nope = (c @ params["w_uk"]).reshape(b, s, h, dn)
    v = (c @ params["w_uv"]).reshape(b, s, h, dv)

    # Assemble per-head q/k of width (dn + dr); kv_heads == n_heads here.
    q_full = jnp.concatenate([q_nope, q_rope], -1)             # (B,S,H,dn+dr)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, s, h, dr))], -1)
    qg = q_full.reshape(b, s, h, 1, dn + dr)
    out = chunked_attention(qg, k_full, v, causal=True)
    out = out.reshape(b, s, h * dv) @ params["w_o"]
    cache = None
    if make_cache:
        if ctx is not None:
            c = constrain(ctx, c, ctx.batch_spec, ctx.model_axis)
            k_rope = constrain(ctx, k_rope, ctx.batch_spec, ctx.model_axis)
        cache = {"c": c, "kr": k_rope[:, :, 0]}
    return out, cache


def mla_decode(params, x, cfg: ModelConfig, ctx, cache, pos):
    """Absorbed-matrix MLA decode over the latent cache.

    The latent cache is treated as a single virtual KV head of width
    (kv_lora_rank + rope_head_dim); W_uk is absorbed into the query and
    W_uv into the output projection, so decode never expands per-head K/V.
    """
    b = x.shape[0]
    h, dn, dr = cfg.n_heads, cfg.v_head_dim, cfg.rope_head_dim
    dc, dv = cfg.kv_lora_rank, cfg.v_head_dim

    qc = common.rms_norm(x @ params["w_dq"], params["q_norm"], cfg.norm_eps)
    q_nope = (qc @ params["w_uq_nope"]).reshape(b, 1, h, dn)
    q_rope = (qc @ params["w_uq_rope"]).reshape(b, 1, h, dr)
    pos = jnp.asarray(pos, jnp.int32)
    pos_b = pos.reshape(b, 1) if pos.ndim else jnp.full((b, 1), pos,
                                                        jnp.int32)
    q_rope = common.apply_rope(q_rope, pos_b, cfg.rope_theta)

    # Absorb W_uk: q_abs[h] = q_nope[h] @ W_uk[h].T  -> latent space (dc)
    w_uk = params["w_uk"].reshape(dc, h, dn)
    q_abs = jnp.einsum("bhn,chn->bhc", q_nope[:, 0], w_uk)     # (B,H,dc)
    q_eff = jnp.concatenate([q_abs, q_rope[:, 0]], -1)         # (B,H,dc+dr)

    c_new = common.rms_norm(x @ params["w_dkv"], params["kv_norm"],
                            cfg.norm_eps)[:, 0]                # (B,dc)
    kr_new = common.apply_rope(
        (x @ params["w_kr"]).reshape(b, 1, 1, dr), pos_b,
        cfg.rope_theta)[:, 0, 0]                               # (B,dr)

    s_cache = cache["c"].shape[1]
    kv_eff_new = jnp.concatenate([c_new, kr_new], -1)          # (B,dc+dr)
    # store latent + rope jointly: cache c:(B,S,dc), kr:(B,S,dr)
    c_cache = _cache_update_2d(cache["c"], c_new, pos, ctx)
    kr_cache = _cache_update_2d(cache["kr"], kr_new, pos, ctx)

    k_eff = jnp.concatenate([c_cache, kr_cache], -1)[:, :, None]  # (B,S,1,·)
    v_eff = c_cache[:, :, None]                                   # (B,S,1,dc)
    idx = jnp.arange(s_cache)
    valid = jnp.broadcast_to(idx[None] <= pos_b, (b, s_cache))
    o_lat = flash_decode(q_eff[:, None], k_eff, v_eff, valid, ctx)  # (B,1,H,dc)
    # Un-absorb W_uv: out[h] = o_lat[h] @ W_uv[h]
    w_uv = params["w_uv"].reshape(dc, h, dv)
    out = jnp.einsum("bhc,chv->bhv", o_lat[:, 0], w_uv).reshape(b, 1, h * dv)
    return out @ params["w_o"], {"c": c_cache, "kr": kr_cache}


def _cache_update_2d(cache, new, pos, ctx: Optional[ShardingCtx]):
    """cache: (B,S,F); new: (B,F)."""
    c4 = cache[:, :, None, :]
    out = cache_update(c4, new[:, None, :], pos, ctx)
    return out[:, :, 0, :]


def mla_empty_cache(cfg: ModelConfig, batch: int, seq: int, dtype=None):
    dt = dtype or cfg.jnp_dtype
    return {"c": jnp.zeros((batch, seq, cfg.kv_lora_rank), dt),
            "kr": jnp.zeros((batch, seq, cfg.rope_head_dim), dt)}
