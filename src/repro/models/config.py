"""Unified model configuration for every architecture family in the zoo.

One ``ModelConfig`` describes any of: dense GQA/MLA transformers, MoE
transformers, xLSTM stacks, Mamba2 hybrids, encoder-decoder models and
VLM/audio decoder backbones.  ``reduced()`` produces the CPU-smoke variant
mandated by the assignment (<=2 layers, d_model<=512, <=4 experts).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

# Architecture families.
DENSE = "dense"          # pre-norm GQA decoder (llama-style)
MOE = "moe"              # dense attention + MoE FFN (qwen3-moe style)
XLSTM = "xlstm"          # mLSTM/sLSTM stack (arXiv:2405.04517)
MAMBA_HYBRID = "hybrid"  # Mamba2 backbone + shared attention (zamba2)
ENCDEC = "encdec"        # encoder-decoder (seamless-m4t backbone)
VLM = "vlm"              # decoder backbone w/ M-RoPE consuming patch embeds

FAMILIES = (DENSE, MOE, XLSTM, MAMBA_HYBRID, ENCDEC, VLM)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str
    num_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 128
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0

    # Attention flavour -----------------------------------------------------
    attention: str = "gqa"            # "gqa" | "mla"
    causal: bool = True               # False for encoder-only (BERT / ViT)
    gated_mlp: bool = True            # False = classic 2-matrix MLP
    sliding_window: Optional[int] = None  # window size; None = full attention
    # MLA (MiniCPM3 / DeepSeek-style latent attention)
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    rope_head_dim: int = 32
    v_head_dim: int = 64

    # MoE --------------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    capacity_factor: float = 1.25
    n_shared_experts: int = 0

    # SSM / xLSTM ------------------------------------------------------------
    ssm_state: int = 64
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_chunk: int = 128              # chunk size for SSD / chunkwise mLSTM
    slstm_every: int = 8              # 7:1 mLSTM:sLSTM ratio -> every 8th
    shared_attn_every: int = 6        # zamba2: shared attn block period

    # Encoder-decoder ----------------------------------------------------------
    enc_layers: int = 0               # encoder depth (ENCDEC only)
    enc_seq_len: int = 1024           # encoder (audio-frame) length stub

    # VLM ---------------------------------------------------------------------
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # t/h/w rope split
    num_patches: int = 1024           # vision patch embeds length stub

    # Numerics / misc ----------------------------------------------------------
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    vocab_pad_to: int = 2048          # pad vocab so the model axis divides it
    remat: bool = True                # activation checkpointing on layer scan

    # ---------------------------------------------------------------------
    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def q_heads_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return (self.vocab_size + p - 1) // p * p

    @property
    def kv_cache_dim(self) -> int:
        """Per-token per-layer cache width (features)."""
        if self.attention == "mla":
            return self.kv_lora_rank + self.rope_head_dim
        return 2 * self.n_kv_heads * self.head_dim

    def cache_bytes(self, batch: int, seq: int) -> int:
        """Per-LAYER KV-cache bytes for a (batch, seq) decode workload.

        The engine charges this to the memory ledger per layer and the
        Pipeline Planner adds ``num_layers * cache_bytes`` to its peak
        model, so weights + cache share one budget."""
        return int(batch * seq * self.kv_cache_dim * self.jnp_dtype.itemsize)

    def validate(self) -> None:
        assert self.family in FAMILIES, self.family
        if self.n_kv_heads:
            assert self.n_heads % self.n_kv_heads == 0, (
                f"{self.name}: n_heads={self.n_heads} not divisible by "
                f"n_kv_heads={self.n_kv_heads}")
        if self.family == MOE:
            assert self.n_experts > 0 and self.top_k > 0
        if self.family == ENCDEC:
            assert self.enc_layers > 0

    # ---------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """CPU-smoke variant of the same family (assignment carve-down)."""
        d_model = min(self.d_model, 256)
        head_dim = 32
        n_kv = min(self.n_kv_heads, 2) or 1
        n_heads = n_kv * min(self.q_heads_per_kv, 2)
        changes = dict(
            name=self.name + "-smoke",
            num_layers=2,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            vocab_pad_to=128,
            dtype="float32",
            ssm_state=16,
            ssm_chunk=16,
            enc_seq_len=32,
            num_patches=16,
            q_lora_rank=64,
            kv_lora_rank=32,
            rope_head_dim=16,
            v_head_dim=32,
            slstm_every=2,
            shared_attn_every=2,
            mrope_sections=(4, 6, 6),  # sums to reduced head_dim // 2
            remat=False,
        )
        if self.family == MOE:
            changes.update(n_experts=4, top_k=2, expert_d_ff=64)
        if self.family == ENCDEC:
            changes.update(enc_layers=2)
        if self.sliding_window is not None:
            changes.update(sliding_window=16)
        return dataclasses.replace(self, **changes)

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)
