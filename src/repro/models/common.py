"""Shared building blocks: norms, rotary embeddings, MLPs, initializers."""
from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Initialisation helpers.  Param trees are plain nested dicts of jnp arrays.
# ---------------------------------------------------------------------------
def dense_init(key, in_dim: int, out_dim: int, dtype) -> jax.Array:
    scale = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale
            ).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02
            ).astype(dtype)


def zeros(shape, dtype) -> jax.Array:
    return jnp.zeros(shape, dtype)


def ones(shape, dtype) -> jax.Array:
    return jnp.ones(shape, dtype)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies for the rotary half of ``head_dim``."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    head_dim = x.shape[-1]
    inv = rope_freqs(head_dim, theta)                      # (half,)
    ang = positions[..., None].astype(jnp.float32) * inv   # (..., seq, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions3: jax.Array,
                sections: Sequence[int], theta: float) -> jax.Array:
    """Multimodal rotary (Qwen2-VL).  positions3: (3, ..., seq) t/h/w ids.

    The rotary half is split into ``sections`` (sum == head_dim // 2); each
    section takes its angle from the matching position stream.
    """
    head_dim = x.shape[-1]
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    inv = rope_freqs(head_dim, theta)                      # (half,)
    # (3, ..., seq, half) angles, then pick sections per stream.
    ang_all = positions3[..., None].astype(jnp.float32) * inv
    parts = []
    start = 0
    for i, sec in enumerate(sections):
        parts.append(ang_all[i, ..., start:start + sec])
        start += sec
    ang = jnp.concatenate(parts, axis=-1)                  # (..., seq, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------
def mlp_init(key, d_model: int, d_ff: int, dtype, gated: bool = True) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype),
    }
    if gated:
        p["w_gate"] = dense_init(k1, d_model, d_ff, dtype)
    return p


def mlp_apply(params: dict, x: jax.Array) -> jax.Array:
    if "w_gate" in params:
        gate = jax.nn.silu(x @ params["w_gate"])
        return (gate * (x @ params["w_up"])) @ params["w_down"]
    return jax.nn.gelu(x @ params["w_up"]) @ params["w_down"]


# ---------------------------------------------------------------------------
# Chunked cross-entropy (never materialises (B, S, V) at once)
# ---------------------------------------------------------------------------
def chunked_softmax_xent(hidden: jax.Array, lm_head: jax.Array,
                         labels: jax.Array, n_chunks: int = 8) -> jax.Array:
    """hidden: (B, S, D); lm_head: (D, V); labels: (B, S) int32.

    Scans over sequence chunks so the peak logits tensor is (B, S/c, V).
    Returns mean token loss (float32).
    """
    b, s, d = hidden.shape
    while s % n_chunks:
        n_chunks //= 2
    hs = hidden.reshape(b, n_chunks, s // n_chunks, d).swapaxes(0, 1)
    ls = labels.reshape(b, n_chunks, s // n_chunks).swapaxes(0, 1)

    # checkpoint: the backward recomputes each chunk's logits instead of
    # keeping the full (B, S, V) residual alive ("fused" cross-entropy).
    @jax.checkpoint
    def body(tot, xs):
        h, y = xs
        logits = (h @ lm_head).astype(jnp.float32)         # (B, s/c, V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
        return tot + jnp.sum(lse - gold), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hs, ls))
    return total / (b * s)
