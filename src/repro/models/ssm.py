"""Recurrent sequence-mixing blocks: Mamba2 (SSD) and xLSTM (mLSTM/sLSTM).

All three expose the same triple of entry points:

    *_init(key, cfg, d_model)             -> params
    *_prefill(params, x, cfg)             -> (y, final_state)   # full seq
    *_decode(params, x_tok, cfg, state)   -> (y_tok, new_state) # 1 token

The prefill paths are chunkwise-parallel (linear time, O(chunk^2) intra-chunk
work) so the 500k-token long-context shape lowers with O(1) recurrent state.
The decode paths are exact single-step recurrences; tests assert prefill and
step-by-step decode agree.

Simplifications vs. the source papers (recorded in DESIGN.md):
  * mLSTM exponential input gate is clipped at exp(8) in BOTH paths instead
    of carrying the running-max stabiliser; the n-normaliser bounds outputs,
    and clipping identically in both paths keeps them mathematically equal.
  * Mamba2 uses n_groups=1 (B/C shared across heads), as in zamba2-1.2b.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.config import ModelConfig


# ===========================================================================
# Mamba2 (State Space Duality, chunked)
# ===========================================================================
def _mamba_dims(cfg: ModelConfig, d_model: int):
    d_inner = cfg.ssm_expand * d_model
    head_p = 64 if d_inner % 64 == 0 else max(d_inner // 4, 1)
    n_heads = d_inner // head_p
    return d_inner, n_heads, head_p


def mamba2_init(key, cfg: ModelConfig, d_model: int) -> dict:
    d_inner, nh, hp = _mamba_dims(cfg, d_model)
    n = cfg.ssm_state
    conv_dim = d_inner + 2 * n
    ks = common.split_keys(key, 5)
    dt = cfg.jnp_dtype
    return {
        # order: [x(d_inner), B(n), C(n), z(d_inner), dt(nh)]
        "w_in": common.dense_init(ks[0], d_model,
                                  2 * d_inner + 2 * n + nh, dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim),
                                     jnp.float32) * 0.2).astype(dt),
        "conv_b": common.zeros((conv_dim,), dt),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh).astype(jnp.float32)),
        "d_skip": common.ones((nh,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.linspace(1e-3, 1e-1, nh).astype(jnp.float32))),
        "out_norm": common.ones((d_inner,), dt),
        "w_out": common.dense_init(ks[2], d_inner, d_model, dt),
    }


def _mamba_split(params, x, cfg: ModelConfig, d_model: int):
    d_inner, nh, hp = _mamba_dims(cfg, d_model)
    n = cfg.ssm_state
    z = x @ params["w_in"]
    xin = z[..., :d_inner]
    bc = z[..., d_inner:d_inner + 2 * n]
    gate = z[..., d_inner + 2 * n:2 * d_inner + 2 * n]
    dt_raw = z[..., 2 * d_inner + 2 * n:]
    return xin, bc, gate, dt_raw


def _causal_conv(seq, conv_w, conv_b, tail=None):
    """seq: (B, S, C) depthwise causal conv, kernel K.

    ``tail``: (B, K-1, C) carried conv inputs from a previous segment
    (zeros for a fresh sequence).
    """
    k = conv_w.shape[0]
    if tail is None:
        pad = jnp.pad(seq, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        pad = jnp.concatenate([tail.astype(seq.dtype), seq], axis=1)
    out = sum(pad[:, i:i + seq.shape[1]] * conv_w[i] for i in range(k))
    return jax.nn.silu(out + conv_b)


def _conv_step(state, new, conv_w, conv_b):
    """state: (B, K-1, C); new: (B, C) -> (out (B, C), new state)."""
    window = jnp.concatenate([state, new[:, None]], axis=1)   # (B, K, C)
    out = jnp.einsum("bkc,kc->bc", window, conv_w) + conv_b
    return jax.nn.silu(out), window[:, 1:]


def mamba2_empty_state(cfg: ModelConfig, d_model: int, batch: int) -> dict:
    d_inner, nh, hp = _mamba_dims(cfg, d_model)
    n = cfg.ssm_state
    conv_dim = d_inner + 2 * n
    return {
        "ssm": jnp.zeros((batch, nh, n, hp), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), cfg.jnp_dtype),
    }


def mamba2_prefill(params, x, cfg: ModelConfig,
                   state: dict | None = None) -> Tuple[jax.Array, dict]:
    b, s, d_model = x.shape
    d_inner, nh, hp = _mamba_dims(cfg, d_model)
    n = cfg.ssm_state
    q = min(cfg.ssm_chunk, s)
    while s % q:
        q //= 2
    nc = s // q

    xin, bc, gate, dt_raw = _mamba_split(params, x, cfg, d_model)
    conv_in = jnp.concatenate([xin, bc], axis=-1)
    conv_out = _causal_conv(conv_in, params["conv_w"], params["conv_b"],
                            tail=None if state is None else state["conv"])
    xc = conv_out[..., :d_inner].reshape(b, s, nh, hp)
    bmat = conv_out[..., d_inner:d_inner + n]
    cmat = conv_out[..., d_inner + n:]

    a = -jnp.exp(params["a_log"])                              # (H,)
    dtv = jax.nn.softplus(dt_raw.astype(jnp.float32)
                          + params["dt_bias"])                 # (B,S,H)

    xcf = xc.astype(jnp.float32).reshape(b, nc, q, nh, hp)
    bf = bmat.astype(jnp.float32).reshape(b, nc, q, n)
    cf = cmat.astype(jnp.float32).reshape(b, nc, q, n)
    dtc = dtv.reshape(b, nc, q, nh)

    if state is None:
        s0 = jnp.zeros((b, nh, n, hp), jnp.float32)
    else:
        s0 = state["ssm"]

    @jax.checkpoint
    def chunk_step(carry, xs):
        # checkpointed: the backward replays the intra-chunk math instead of
        # keeping every chunk's decay/score tensors alive (the saved
        # residual is just the carried state)
        st = carry                                             # (B,H,N,P)
        xck, bk, ck, dtk = xs                                  # per-chunk
        da = dtk * a                                           # (B,Q,H)
        cum = jnp.cumsum(da, axis=1)
        # intra-chunk (masked attention-like)
        decay = jnp.exp(cum[:, :, None] - cum[:, None, :])     # (B,Q,P?,H)
        mask = jnp.tril(jnp.ones((q, q), bool))
        cb = jnp.einsum("bqn,bpn->bqp", ck, bk)
        m = cb[..., None] * decay * dtk[:, None]               # (B,Q,Qp,H)
        m = jnp.where(mask[None, :, :, None], m, 0.0)
        y_intra = jnp.einsum("bqph,bphd->bqhd", m, xck)
        # inter-chunk (carry-in state)
        y_inter = jnp.einsum("bqn,bqh,bhnd->bqhd", ck, jnp.exp(cum), st)
        # state passing
        decay_out = jnp.exp(cum[:, -1:, :] - cum)              # (B,Q,H)
        st_new = (jnp.exp(cum[:, -1])[..., None, None] * st
                  + jnp.einsum("bqh,bqn,bqhd->bhnd",
                               decay_out * dtk, bk, xck))
        return st_new, y_intra + y_inter

    xs = (xcf.swapaxes(0, 1), bf.swapaxes(0, 1), cf.swapaxes(0, 1),
          dtc.swapaxes(0, 1))
    s_final, ych = jax.lax.scan(chunk_step, s0, xs)
    y = ych.swapaxes(0, 1).reshape(b, s, nh, hp)
    y = y + params["d_skip"][None, None, :, None] * xc.astype(jnp.float32)
    y = y.reshape(b, s, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(gate)
    y = common.rms_norm(y, params["out_norm"], cfg.norm_eps)
    out = y @ params["w_out"]

    # conv state = last K-1 raw conv inputs (including any carried tail so
    # segment-wise prefill composes exactly)
    k = cfg.ssm_conv
    prev = (state["conv"].astype(conv_in.dtype) if state is not None
            else jnp.zeros((b, k - 1, conv_in.shape[-1]), conv_in.dtype))
    full_in = jnp.concatenate([prev, conv_in], axis=1)
    tail = full_in[:, -(k - 1):]
    return out, {"ssm": s_final, "conv": tail.astype(cfg.jnp_dtype)}


def mamba2_decode(params, x, cfg: ModelConfig,
                  state: dict) -> Tuple[jax.Array, dict]:
    """x: (B, 1, D)."""
    b, _, d_model = x.shape
    d_inner, nh, hp = _mamba_dims(cfg, d_model)
    n = cfg.ssm_state

    xin, bc, gate, dt_raw = _mamba_split(params, x[:, 0], cfg, d_model)
    conv_in = jnp.concatenate([xin, bc], axis=-1)              # (B, C)
    conv_out, conv_state = _conv_step(state["conv"], conv_in,
                                      params["conv_w"], params["conv_b"])
    xc = conv_out[..., :d_inner].reshape(b, nh, hp).astype(jnp.float32)
    bk = conv_out[..., d_inner:d_inner + n].astype(jnp.float32)
    ck = conv_out[..., d_inner + n:].astype(jnp.float32)

    a = -jnp.exp(params["a_log"])
    dtv = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    da = jnp.exp(dtv * a)                                      # (B,H)
    st = (da[..., None, None] * state["ssm"]
          + jnp.einsum("bh,bn,bhd->bhnd", dtv, bk, xc))
    y = jnp.einsum("bn,bhnd->bhd", ck, st)
    y = y + params["d_skip"][None, :, None] * xc
    y = y.reshape(b, d_inner).astype(x.dtype) * jax.nn.silu(gate)
    y = common.rms_norm(y, params["out_norm"], cfg.norm_eps)
    out = (y @ params["w_out"])[:, None]
    return out, {"ssm": st, "conv": conv_state}


# ===========================================================================
# xLSTM: mLSTM (matrix memory, chunkwise-parallel) + sLSTM (scalar, scan)
# ===========================================================================
def _mlstm_dims(cfg: ModelConfig, d_model: int):
    d_inner = 2 * d_model
    nh = 4
    dv = d_inner // nh
    dk = dv // 2
    return d_inner, nh, dk, dv


I_CLIP = 8.0


def mlstm_init(key, cfg: ModelConfig, d_model: int) -> dict:
    d_inner, nh, dk, dv = _mlstm_dims(cfg, d_model)
    ks = common.split_keys(key, 6)
    dt = cfg.jnp_dtype
    return {
        "w_up": common.dense_init(ks[0], d_model, 2 * d_inner, dt),
        "w_q": common.dense_init(ks[1], d_inner, nh * dk, dt),
        "w_k": common.dense_init(ks[2], d_inner, nh * dk, dt),
        "w_v": common.dense_init(ks[3], d_inner, nh * dv, dt),
        "w_if": common.dense_init(ks[4], d_inner, 2 * nh, dt),
        "if_bias": jnp.concatenate(
            [jnp.zeros((nh,), jnp.float32),
             jnp.linspace(3.0, 6.0, nh).astype(jnp.float32)]),
        "out_norm": common.ones((d_inner,), dt),
        "w_down": common.dense_init(ks[5], d_inner, d_model, dt),
    }


def mlstm_empty_state(cfg: ModelConfig, d_model: int, batch: int) -> dict:
    _, nh, dk, dv = _mlstm_dims(cfg, d_model)
    return {"c": jnp.zeros((batch, nh, dk, dv), jnp.float32),
            "n": jnp.zeros((batch, nh, dk), jnp.float32)}


def _mlstm_gates(params, xi, nh):
    raw = (xi @ params["w_if"]).astype(jnp.float32) + params["if_bias"]
    i_raw, f_raw = raw[..., :nh], raw[..., nh:]
    i = jnp.exp(jnp.minimum(i_raw, I_CLIP))
    log_f = jax.nn.log_sigmoid(f_raw)
    return i, log_f


def mlstm_prefill(params, x, cfg: ModelConfig,
                  state: dict | None = None) -> Tuple[jax.Array, dict]:
    b, s, d_model = x.shape
    d_inner, nh, dk, dv = _mlstm_dims(cfg, d_model)
    q_len = min(cfg.ssm_chunk, s)
    while s % q_len:
        q_len //= 2
    nc = s // q_len

    up = x @ params["w_up"]
    xi, gate = up[..., :d_inner], up[..., d_inner:]
    scale = 1.0 / math.sqrt(dk)
    qm = (xi @ params["w_q"]).reshape(b, s, nh, dk).astype(jnp.float32) * scale
    km = (xi @ params["w_k"]).reshape(b, s, nh, dk).astype(jnp.float32)
    vm = (xi @ params["w_v"]).reshape(b, s, nh, dv).astype(jnp.float32)
    i_gate, log_f = _mlstm_gates(params, xi, nh)               # (B,S,H)

    qc = qm.reshape(b, nc, q_len, nh, dk)
    kc = km.reshape(b, nc, q_len, nh, dk)
    vc = vm.reshape(b, nc, q_len, nh, dv)
    ic = i_gate.reshape(b, nc, q_len, nh)
    fc = log_f.reshape(b, nc, q_len, nh)

    if state is None:
        c0 = jnp.zeros((b, nh, dk, dv), jnp.float32)
        n0 = jnp.zeros((b, nh, dk), jnp.float32)
    else:
        c0, n0 = state["c"], state["n"]

    @jax.checkpoint
    def chunk_step(carry, xs):
        c_st, n_st = carry
        qk, kk, vk, ik, fk = xs
        cum = jnp.cumsum(fk, axis=1)                           # (B,Q,H)
        # intra-chunk decay: prod of f in (p, q]  = exp(cum_q - cum_p)
        decay = jnp.exp(cum[:, :, None] - cum[:, None, :])     # (B,Q,P,H)
        mask = jnp.tril(jnp.ones((q_len, q_len), bool))
        scores = jnp.einsum("bqhd,bphd->bqph", qk, kk)
        m = scores * decay * ik[:, None]
        m = jnp.where(mask[None, :, :, None], m, 0.0)
        y_intra = jnp.einsum("bqph,bphd->bqhd", m, vk)
        y_inter = jnp.einsum("bqhd,bqh,bhdv->bqhv", qk, jnp.exp(cum), c_st)
        # normaliser: n_t.q_t = sum_p decay*i*(k_p.q_t) + exp(cum)*n_carry.q
        # the intra part is exactly the row-sum of m.
        nq_intra = m.sum(axis=2)                               # (B,Q,H)
        nq_inter = jnp.einsum("bqhd,bqh,bhd->bqh", qk, jnp.exp(cum), n_st)
        denom = jnp.maximum(jnp.abs(nq_intra + nq_inter), 1.0)
        y = (y_intra + y_inter) / denom[..., None]
        # state update
        decay_out = jnp.exp(cum[:, -1:, :] - cum)              # (B,Q,H)
        c_new = (jnp.exp(cum[:, -1])[..., None, None] * c_st
                 + jnp.einsum("bqh,bqhd,bqhv->bhdv",
                              decay_out * ik, kk, vk))
        n_new = (jnp.exp(cum[:, -1])[..., None] * n_st
                 + jnp.einsum("bqh,bqhd->bhd", decay_out * ik, kk))
        return (c_new, n_new), y

    xs = tuple(t.swapaxes(0, 1) for t in (qc, kc, vc, ic, fc))
    (c_f, n_f), ych = jax.lax.scan(chunk_step, (c0, n0), xs)
    y = ych.swapaxes(0, 1).reshape(b, s, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(gate)
    y = common.rms_norm(y, params["out_norm"], cfg.norm_eps)
    return y @ params["w_down"], {"c": c_f, "n": n_f}


def mlstm_decode(params, x, cfg: ModelConfig,
                 state: dict) -> Tuple[jax.Array, dict]:
    b, _, d_model = x.shape
    d_inner, nh, dk, dv = _mlstm_dims(cfg, d_model)
    up = x[:, 0] @ params["w_up"]
    xi, gate = up[..., :d_inner], up[..., d_inner:]
    scale = 1.0 / math.sqrt(dk)
    qv = (xi @ params["w_q"]).reshape(b, nh, dk).astype(jnp.float32) * scale
    kv = (xi @ params["w_k"]).reshape(b, nh, dk).astype(jnp.float32)
    vv = (xi @ params["w_v"]).reshape(b, nh, dv).astype(jnp.float32)
    i_gate, log_f = _mlstm_gates(params, xi, nh)               # (B,H)
    f = jnp.exp(log_f)
    c_new = (f[..., None, None] * state["c"]
             + i_gate[..., None, None] * kv[..., :, None] * vv[..., None, :])
    n_new = f[..., None] * state["n"] + i_gate[..., None] * kv
    num = jnp.einsum("bhd,bhdv->bhv", qv, c_new)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qv, n_new)), 1.0)
    y = (num / den[..., None]).reshape(b, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(gate)
    y = common.rms_norm(y, params["out_norm"], cfg.norm_eps)
    return (y @ params["w_down"])[:, None], {"c": c_new, "n": n_new}


# ---------------------------------------------------------------------------
# sLSTM: scalar-memory recurrent cell with exponential gating + stabiliser
# ---------------------------------------------------------------------------
def slstm_init(key, cfg: ModelConfig, d_model: int) -> dict:
    nh = 4
    dh = d_model // nh
    ks = common.split_keys(key, 3)
    dt = cfg.jnp_dtype
    return {
        # gates z,i,f,o each (D, D) input + per-head recurrent R (H, dh, dh)
        "w_zifo": common.dense_init(ks[0], d_model, 4 * d_model, dt),
        "r_zifo": (jax.random.normal(ks[1], (4, nh, dh, dh), jnp.float32)
                   / math.sqrt(dh)).astype(dt),
        "b_zifo": common.zeros((4 * d_model,), jnp.float32),
        "out_norm": common.ones((d_model,), dt),
        "w_out": common.dense_init(ks[2], d_model, d_model, dt),
    }


def slstm_empty_state(cfg: ModelConfig, d_model: int, batch: int) -> dict:
    return {"c": jnp.zeros((batch, d_model), jnp.float32),
            "n": jnp.zeros((batch, d_model), jnp.float32),
            "m": jnp.full((batch, d_model), -1e30, jnp.float32),
            "h": jnp.zeros((batch, d_model), jnp.float32)}


def _slstm_cell(params, xt, st, nh, dh):
    """One sLSTM step.  xt: (B, 4*D) pre-projected input contribution."""
    b = xt.shape[0]
    h_prev = st["h"]
    hh = h_prev.reshape(b, nh, dh)
    rec = jnp.einsum("bhd,ghde->gbhe", hh.astype(params["r_zifo"].dtype),
                     params["r_zifo"]).reshape(4, b, nh * dh)
    zifo = (xt.reshape(b, 4, -1).swapaxes(0, 1).astype(jnp.float32)
            + rec.astype(jnp.float32)
            + params["b_zifo"].reshape(4, -1)[:, None].swapaxes(0, 1)
            .reshape(4, 1, -1))
    z = jnp.tanh(zifo[0])
    log_i = zifo[1]
    log_f = jax.nn.log_sigmoid(zifo[2])
    o = jax.nn.sigmoid(zifo[3])
    m_new = jnp.maximum(log_f + st["m"], log_i)
    i_s = jnp.exp(log_i - m_new)
    f_s = jnp.exp(log_f + st["m"] - m_new)
    c_new = f_s * st["c"] + i_s * z
    n_new = f_s * st["n"] + i_s
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return {"c": c_new, "n": n_new, "m": m_new, "h": h_new}


def slstm_prefill(params, x, cfg: ModelConfig,
                  state: dict | None = None) -> Tuple[jax.Array, dict]:
    b, s, d_model = x.shape
    nh, dh = 4, d_model // 4
    if state is None:
        state = slstm_empty_state(cfg, d_model, b)
    xz = x @ params["w_zifo"]                                  # (B,S,4D)

    def step(st, xt):
        st2 = _slstm_cell(params, xt, st, nh, dh)
        return st2, st2["h"]

    st_f, hs = jax.lax.scan(step, state, xz.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).astype(x.dtype)                      # (B,S,D)
    y = common.rms_norm(y, params["out_norm"], cfg.norm_eps)
    return y @ params["w_out"], st_f


def slstm_decode(params, x, cfg: ModelConfig,
                 state: dict) -> Tuple[jax.Array, dict]:
    b, _, d_model = x.shape
    nh, dh = 4, d_model // 4
    xz = x[:, 0] @ params["w_zifo"]
    st = _slstm_cell(params, xz, state, nh, dh)
    y = st["h"][:, None].astype(x.dtype)
    y = common.rms_norm(y, params["out_norm"], cfg.norm_eps)
    return y @ params["w_out"], st
