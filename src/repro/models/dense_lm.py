"""Decoder-only language models: dense GQA, dense MLA, MoE, VLM (M-RoPE).

Layer stacks are ``lax.scan`` over stacked per-layer params so the lowered
HLO is one layer body regardless of depth (94-layer MoE lowers as fast as a
2-layer smoke model).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import common, moe
from repro.models.config import ModelConfig, MOE, VLM
from repro.sharding import (ShardingCtx, constrain, constrain_layer_params,
                            seq_shard)


# ===========================================================================
# Per-layer init
# ===========================================================================
def layer_init(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    p = {
        "attn_norm": common.ones((cfg.d_model,), cfg.jnp_dtype),
        "attn": attn.attn_init(k1, cfg),
        "ffn_norm": common.ones((cfg.d_model,), cfg.jnp_dtype),
    }
    if cfg.family == MOE:
        p["moe"] = moe.moe_init(k2, cfg)
    else:
        p["mlp"] = common.mlp_init(k2, cfg.d_model, cfg.d_ff,
                                   cfg.jnp_dtype, gated=cfg.gated_mlp)
    return p


def init_params(key, cfg: ModelConfig) -> dict:
    ke, kl, kh = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.num_layers)
    layers = jax.vmap(lambda k: layer_init(k, cfg))(layer_keys)
    params = {
        "embed": common.embed_init(ke, cfg.padded_vocab, cfg.d_model,
                                   cfg.jnp_dtype),
        "layers": layers,
        "final_norm": common.ones((cfg.d_model,), cfg.jnp_dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = common.dense_init(
            kh, cfg.d_model, cfg.padded_vocab, cfg.jnp_dtype)
    if cfg.family == VLM:
        params["patch_proj"] = common.dense_init(
            jax.random.fold_in(kh, 1), cfg.d_model, cfg.d_model,
            cfg.jnp_dtype)
    return params


def lm_head_weight(params, cfg: ModelConfig):
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


# ===========================================================================
# Layer application (shared by train / prefill / decode)
# ===========================================================================
def _ffn(p, x, cfg: ModelConfig, ctx):
    h = common.rms_norm(x, p["ffn_norm"], cfg.norm_eps)
    if cfg.family == MOE:
        out, aux = moe.moe_apply(p["moe"], h, cfg, ctx)
        return x + out, aux
    return x + common.mlp_apply(p["mlp"], h), jnp.zeros((), jnp.float32)


def layer_prefill(p, x, cfg: ModelConfig, ctx, positions, *, make_cache,
                  mrope3=None):
    h = common.rms_norm(x, p["attn_norm"], cfg.norm_eps)
    if cfg.attention == "mla":
        a, cache = attn.mla_prefill(p["attn"], h, cfg, ctx, positions,
                                    make_cache=make_cache)
    elif mrope3 is not None:
        a, cache = attn.gqa_mrope_prefill(p["attn"], h, cfg, ctx, mrope3,
                                          make_cache=make_cache)
    else:
        a, cache = attn.gqa_prefill(p["attn"], h, cfg, ctx, positions,
                                    causal=cfg.causal,
                                    make_cache=make_cache)
    x = x + a
    x, aux = _ffn(p, x, cfg, ctx)
    return x, cache, aux


def layer_decode(p, x, cfg: ModelConfig, ctx, cache, pos, *, mrope3=None,
                 attn_impl=None):
    h = common.rms_norm(x, p["attn_norm"], cfg.norm_eps)
    if cfg.attention == "mla":
        a, cache = attn.mla_decode(p["attn"], h, cfg, ctx, cache, pos)
    else:
        a, cache = attn.gqa_decode(p["attn"], h, cfg, ctx, cache, pos,
                                   mrope_positions3=mrope3,
                                   attn_impl=attn_impl)
    x = x + a
    x, _ = _ffn(p, x, cfg, ctx)
    return x, cache


def layer_decode_paged(p, x, cfg: ModelConfig, pools, tables, pos, *,
                       attn_impl=None):
    """GQA decode against PAGED cache pools (core/kv_pages.py) — the
    paged sibling of ``layer_decode``, kept adjacent so decode-body
    changes land in both.  Single-device, full causal attention only
    (MLA / windowed / mrope configs take the gather-based generic path
    in core/modules.py, which reuses ``layer_decode`` itself)."""
    h = common.rms_norm(x, p["attn_norm"], cfg.norm_eps)
    a, pools = attn.gqa_decode_paged(p["attn"], h, cfg, pools, tables,
                                     pos, attn_impl=attn_impl)
    x = x + a
    x, _ = _ffn(p, x, cfg, None)
    return x, pools


def layer_verify_paged(p, x, cfg: ModelConfig, pools, tables, pos, *,
                       attn_impl=None):
    """Speculative-verify layer over PAGED pools: ``x`` stacks W
    consecutive tokens per row (B, W, D), each attending causally up to
    its own slot — one weight stream scores a whole draft window (the
    W>1 sibling of ``layer_decode_paged``)."""
    h = common.rms_norm(x, p["attn_norm"], cfg.norm_eps)
    a, pools = attn.gqa_verify_paged(p["attn"], h, cfg, pools, tables,
                                     pos, attn_impl=attn_impl)
    x = x + a
    x, _ = _ffn(p, x, cfg, None)
    return x, pools


# ===========================================================================
# VLM helpers
# ===========================================================================
def mrope_positions_prefill(cfg: ModelConfig, batch: int, n_patch: int,
                            s_text: int) -> jax.Array:
    """(3, B, n_patch + s_text) t/h/w position ids, Qwen2-VL style."""
    g = max(int(round(n_patch ** 0.5)), 1)
    pid = jnp.arange(n_patch)
    t_p = jnp.zeros((n_patch,), jnp.int32)
    h_p = (pid // g).astype(jnp.int32)
    w_p = (pid % g).astype(jnp.int32)
    base = jnp.maximum(g, 1)
    tid = base + jnp.arange(s_text, dtype=jnp.int32)
    pos3 = jnp.stack([
        jnp.concatenate([t_p, tid]),
        jnp.concatenate([h_p, tid]),
        jnp.concatenate([w_p, tid]),
    ])                                                        # (3, S)
    return jnp.broadcast_to(pos3[:, None], (3, batch, n_patch + s_text))


def mrope_positions_decode(cfg: ModelConfig, batch: int, pos) -> jax.Array:
    """Text-token M-RoPE id for global cache position ``pos``.

    The patch block compresses rope ids: text ids start at ``grid`` (not at
    ``num_patches``), so decode ids carry a static delta of
    ``grid - num_patches`` relative to the cache position (vLLM's
    mrope-delta, static here because the patch count is a config constant).
    """
    g = max(int(round(cfg.num_patches ** 0.5)), 1)
    p = jnp.full((batch, 1), pos - cfg.num_patches + g, jnp.int32)
    return jnp.stack([p, p, p])                               # (3, B, 1)


# ===========================================================================
# Full-model passes
# ===========================================================================
def _embed_inputs(params, cfg: ModelConfig, batch: dict, ctx):
    """Returns (x (B,S,D), positions or mrope3, text_offset)."""
    tokens = batch["tokens"]
    b, s_text = tokens.shape
    x = params["embed"][tokens]                               # (B,S,D) gather
    if cfg.family == VLM:
        patches = batch["patches"].astype(cfg.jnp_dtype)
        vis = patches @ params["patch_proj"]
        x = jnp.concatenate([vis, x], axis=1)
        n_patch = patches.shape[1]
        mrope3 = mrope_positions_prefill(cfg, b, n_patch, s_text)
        return x, mrope3, n_patch
    positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None],
                                 (b, x.shape[1]))
    return x, positions, 0


def _stack_scan(cfg: ModelConfig, body, x, layers, *extra):
    """Scan ``body`` over stacked layer params (+ optional stacked extras)."""
    if cfg.remat:
        body = jax.checkpoint(body)

    def f(carry, xs):
        return body(carry, xs)

    return jax.lax.scan(f, x, (layers,) + extra)


def loss_fn(params, batch: dict, cfg: ModelConfig,
            ctx: Optional[ShardingCtx]) -> Tuple[jax.Array, dict]:
    x, pos_or3, text_off = _embed_inputs(params, cfg, batch, ctx)
    x = constrain(ctx, x, ctx.batch_spec if ctx else None)
    is_vlm = cfg.family == VLM

    def body(h, xs):
        (p,) = xs
        p = constrain_layer_params(ctx, p)
        h, _, aux = layer_prefill(
            p, h, cfg, ctx,
            None if is_vlm else pos_or3, make_cache=False,
            mrope3=pos_or3 if is_vlm else None)
        return seq_shard(ctx, h), aux

    x, auxs = _stack_scan(cfg, body, x, params["layers"])
    x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if is_vlm:
        x = x[:, text_off:]
    head = lm_head_weight(params, cfg)
    loss = common.chunked_softmax_xent(x, head, batch["labels"])
    aux = jnp.sum(auxs)
    metrics = {"xent": loss, "aux": aux}
    return loss + 0.01 * aux, metrics


def prefill_fn(params, batch: dict, cfg: ModelConfig,
               ctx: Optional[ShardingCtx]) -> Tuple[jax.Array, dict]:
    """Returns (last-token logits (B, V), stacked cache)."""
    x, pos_or3, text_off = _embed_inputs(params, cfg, batch, ctx)
    is_vlm = cfg.family == VLM

    def body(h, xs):
        (p,) = xs
        h, cache, _ = layer_prefill(
            p, h, cfg, ctx,
            None if is_vlm else pos_or3, make_cache=True,
            mrope3=pos_or3 if is_vlm else None)
        return h, cache

    x, caches = _stack_scan(cfg, body, x, params["layers"])
    x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x[:, -1] @ lm_head_weight(params, cfg)
    return logits.astype(jnp.float32), caches


def decode_fn(params, tokens, cache, pos, cfg: ModelConfig,
              ctx: Optional[ShardingCtx]) -> Tuple[jax.Array, dict]:
    """tokens: (B, 1); pos: scalar index of the new token.

    The cache rides in the scan CARRY (slice layer in, write layer back)
    rather than as xs->ys: while-loop state buffers alias in place, so the
    multi-TB cache exists ONCE instead of as separate input/output/ys
    buffers — the difference between fitting and not fitting 16 GiB/chip
    on the 32k-decode shape.
    """
    b = tokens.shape[0]
    x = params["embed"][tokens]
    mrope3 = (mrope_positions_decode(cfg, b, pos)
              if cfg.family == VLM else None)

    def body(carry, xs):
        h, cache_all = carry
        p, li = xs
        c = jax.tree.map(
            lambda a: jax.lax.dynamic_index_in_dim(a, li, 0,
                                                   keepdims=False),
            cache_all)
        h, c_new = layer_decode(p, h, cfg, ctx, c, pos, mrope3=mrope3)
        cache_all = jax.tree.map(
            lambda a, n: jax.lax.dynamic_update_index_in_dim(
                a, n.astype(a.dtype), li, 0),
            cache_all, c_new)
        return (h, cache_all), None

    (x, new_cache), _ = jax.lax.scan(
        body, (x, cache),
        (params["layers"], jnp.arange(cfg.num_layers)))
    x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x[:, -1] @ lm_head_weight(params, cfg)
    return logits.astype(jnp.float32), new_cache


def empty_cache(cfg: ModelConfig, batch: int, seq: int, dtype=None) -> dict:
    l = cfg.num_layers
    if cfg.attention == "mla":
        one = attn.mla_empty_cache(cfg, batch, seq, dtype)
    else:
        one = attn.gqa_empty_cache(cfg, batch, seq, dtype)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (l,) + a.shape), one)
