"""Encoder-decoder backbone (seamless-m4t-medium language/decoder side).

The audio frontend is a stub per the assignment carve-out: ``batch["frames"]``
carries precomputed frame embeddings (B, S_enc, D); a learned projection makes
the stub non-trivial.  Decoder = self-attn (causal) + cross-attn + SwiGLU.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import common
from repro.models.config import ModelConfig
from repro.sharding import ShardingCtx, seq_shard


def _enc_layer_init(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": common.ones((cfg.d_model,), cfg.jnp_dtype),
        "attn": attn.gqa_init(k1, cfg),
        "ffn_norm": common.ones((cfg.d_model,), cfg.jnp_dtype),
        "mlp": common.mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.jnp_dtype),
    }


def _dec_layer_init(key, cfg: ModelConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "self_norm": common.ones((cfg.d_model,), cfg.jnp_dtype),
        "self_attn": attn.gqa_init(k1, cfg),
        "cross_norm": common.ones((cfg.d_model,), cfg.jnp_dtype),
        "cross_attn": attn.gqa_init(k2, cfg),
        "ffn_norm": common.ones((cfg.d_model,), cfg.jnp_dtype),
        "mlp": common.mlp_init(k3, cfg.d_model, cfg.d_ff, cfg.jnp_dtype),
    }


def init_params(key, cfg: ModelConfig) -> dict:
    kf, ke, kd, kt, kh = jax.random.split(key, 5)
    enc_keys = jax.random.split(ke, cfg.enc_layers)
    dec_keys = jax.random.split(kd, cfg.num_layers)
    return {
        "frame_proj": common.dense_init(kf, cfg.d_model, cfg.d_model,
                                        cfg.jnp_dtype),
        "enc_layers": jax.vmap(lambda k: _enc_layer_init(k, cfg))(enc_keys),
        "enc_norm": common.ones((cfg.d_model,), cfg.jnp_dtype),
        "embed": common.embed_init(kt, cfg.padded_vocab, cfg.d_model,
                                   cfg.jnp_dtype),
        "dec_layers": jax.vmap(lambda k: _dec_layer_init(k, cfg))(dec_keys),
        "final_norm": common.ones((cfg.d_model,), cfg.jnp_dtype),
        "lm_head": common.dense_init(kh, cfg.d_model, cfg.padded_vocab,
                                     cfg.jnp_dtype),
    }


def encode(params, frames, cfg: ModelConfig, ctx) -> jax.Array:
    b, s_enc, _ = frames.shape
    x = frames.astype(cfg.jnp_dtype) @ params["frame_proj"]
    positions = jnp.broadcast_to(jnp.arange(s_enc)[None], (b, s_enc))

    def body(h, xs):
        (p,) = xs
        a = common.rms_norm(h, p["attn_norm"], cfg.norm_eps)
        a, _ = attn.gqa_prefill(p["attn"], a, cfg, ctx, positions,
                                causal=False, make_cache=False)
        h = h + a
        f = common.rms_norm(h, p["ffn_norm"], cfg.norm_eps)
        return seq_shard(ctx, h + common.mlp_apply(p["mlp"], f)), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, (params["enc_layers"],))
    return common.rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _dec_layer_prefill(p, h, enc_out, cfg, ctx, positions, *, make_cache):
    a = common.rms_norm(h, p["self_norm"], cfg.norm_eps)
    a, self_cache = attn.gqa_prefill(p["self_attn"], a, cfg, ctx, positions,
                                     causal=True, make_cache=make_cache)
    h = h + a
    c = common.rms_norm(h, p["cross_norm"], cfg.norm_eps)
    cross_kv = attn.cross_attn_prefill_kv(p["cross_attn"], enc_out, cfg, ctx)
    h = h + attn.cross_attn_apply(p["cross_attn"], c, cross_kv, cfg, ctx)
    f = common.rms_norm(h, p["ffn_norm"], cfg.norm_eps)
    h = h + common.mlp_apply(p["mlp"], f)
    return h, self_cache, cross_kv


def loss_fn(params, batch: dict, cfg: ModelConfig,
            ctx: Optional[ShardingCtx]) -> Tuple[jax.Array, dict]:
    enc_out = encode(params, batch["frames"], cfg, ctx)
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def body(h, xs):
        (p,) = xs
        h, _, _ = _dec_layer_prefill(p, h, enc_out, cfg, ctx, positions,
                                     make_cache=False)
        return seq_shard(ctx, h), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, (params["dec_layers"],))
    x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
    loss = common.chunked_softmax_xent(x, params["lm_head"], batch["labels"])
    return loss, {"xent": loss}


def prefill_fn(params, batch: dict, cfg: ModelConfig,
               ctx: Optional[ShardingCtx]) -> Tuple[jax.Array, dict]:
    enc_out = encode(params, batch["frames"], cfg, ctx)
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def body(h, xs):
        (p,) = xs
        h, self_cache, cross_kv = _dec_layer_prefill(
            p, h, enc_out, cfg, ctx, positions, make_cache=True)
        return h, {"self": self_cache, "cross": cross_kv}

    x, caches = jax.lax.scan(body, x, (params["dec_layers"],))
    x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x[:, -1] @ params["lm_head"]
    return logits.astype(jnp.float32), caches


def decode_fn(params, tokens, cache, pos, cfg: ModelConfig,
              ctx: Optional[ShardingCtx]) -> Tuple[jax.Array, dict]:
    x = params["embed"][tokens]

    def body(h, xs):
        p, c = xs
        a = common.rms_norm(h, p["self_norm"], cfg.norm_eps)
        a, self_c = attn.gqa_decode(p["self_attn"], a, cfg, ctx,
                                    c["self"], pos)
        h = h + a
        cc = common.rms_norm(h, p["cross_norm"], cfg.norm_eps)
        h = h + attn.cross_attn_apply(p["cross_attn"], cc, c["cross"],
                                      cfg, ctx)
        f = common.rms_norm(h, p["ffn_norm"], cfg.norm_eps)
        h = h + common.mlp_apply(p["mlp"], f)
        return h, {"self": self_c, "cross": c["cross"]}

    x, new_cache = jax.lax.scan(body, x, (params["dec_layers"], cache))
    x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x[:, -1] @ params["lm_head"]
    return logits.astype(jnp.float32), new_cache


def empty_cache(cfg: ModelConfig, batch: int, seq: int, dtype=None) -> dict:
    l = cfg.num_layers
    self_c = attn.gqa_empty_cache(cfg, batch, seq, dtype)
    cross_c = attn.gqa_empty_cache(cfg, batch, cfg.enc_seq_len, dtype)
    one = {"self": self_c, "cross": cross_c}
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (l,) + a.shape), one)
