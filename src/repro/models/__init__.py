from repro.models.api import ModelAPI, build_model, param_pspecs  # noqa: F401
from repro.models.config import ModelConfig  # noqa: F401
