"""Mixture-of-Experts FFN with capacity-based dispatch + expert parallelism.

Expert parallelism: experts are sharded across the ``model`` mesh axis while
activations stay replicated on it (they already are, between attention
blocks).  Each model shard dispatches tokens to its local experts only and
the per-shard partial outputs are combined with one ``psum`` — the same
collective a Megatron-style TP MLP needs, so MoE composes with the rest of
the sharding scheme with no all-to-all in the baseline.  (An all-to-all
dispatch variant is a recorded §Perf lever.)

Dispatch is sort-based (GShard-style capacity, token dropping) rather than
one-hot-einsum based: the (T, E, C) dispatch tensor is never materialised.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:
    from jax import shard_map
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map  # type: ignore

from repro.models import common
from repro.models.config import ModelConfig
from repro.sharding import ShardingCtx


def moe_init(key, cfg: ModelConfig) -> dict:
    e, d, f = cfg.n_experts, cfg.d_model, cfg.expert_d_ff
    ks = common.split_keys(key, 4)
    scale = 1.0 / math.sqrt(d)
    fscale = 1.0 / math.sqrt(f)
    p = {
        "router": common.dense_init(ks[0], d, e, jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e, d, f), jnp.float32) * scale
                   ).astype(cfg.jnp_dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, f), jnp.float32) * scale
                 ).astype(cfg.jnp_dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d), jnp.float32) * fscale
                   ).astype(cfg.jnp_dtype),
    }
    return p


def capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(math.ceil(n_tokens * cfg.top_k / cfg.n_experts
                      * cfg.capacity_factor))
    return max(8, -(-c // 8) * 8)  # round up to 8 for TPU-friendly shapes


def _dispatch_indices(expert_ids: jax.Array, top_k: int, n_experts: int,
                      cap: int, e0, e_local: int):
    """Pair -> local buffer slot (or OOB = dropped).

    expert_ids: (T, K) int32.  Returns slots (T, K) int32 into a local
    (e_local * cap) buffer; pairs routed to non-local experts or beyond
    capacity map to e_local*cap (out of bounds -> dropped by .at ops).
    """
    t = expert_ids.shape[0]
    flat_e = expert_ids.reshape(-1)                       # (T*K,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    # position of each pair within its expert group (deterministic, global)
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos_sorted = jnp.arange(t * top_k) - first
    pos = jnp.zeros_like(pos_sorted).at[order].set(pos_sorted)
    pos = pos.reshape(t, top_k)

    local_e = expert_ids - e0
    ok = ((local_e >= 0) & (local_e < e_local) & (pos < cap))
    slots = jnp.where(ok, local_e * cap + pos, e_local * cap)
    return slots.astype(jnp.int32)


def _expert_ffn(buf: jax.Array, w_gate, w_up, w_down) -> jax.Array:
    """buf: (E_loc, C, D) -> (E_loc, C, D) via per-expert SwiGLU."""
    gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate))
    up = jnp.einsum("ecd,edf->ecf", buf, w_up)
    return jnp.einsum("ecf,efd->ecd", gate * up, w_down)


def _moe_local(params, x_flat: jax.Array, cfg: ModelConfig, cap: int,
               e0, e_local: int) -> Tuple[jax.Array, jax.Array]:
    """Dispatch + expert compute for experts [e0, e0+e_local).

    x_flat: (T, D).  Returns (out (T, D) containing ONLY local experts'
    contributions, aux load-balance loss computed over all experts).
    """
    t, d = x_flat.shape
    k = cfg.top_k
    logits = (x_flat.astype(jnp.float32) @ params["router"])   # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_ids = jax.lax.top_k(probs, k)                   # (T, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balance statistics (combined into the aux loss by
    # the caller AFTER cross-shard averaging, so local and sharded paths
    # produce identical losses).
    e = cfg.n_experts
    frac = jnp.zeros((e,), jnp.float32).at[top_ids.reshape(-1)].add(1.0)
    frac = frac / (t * k)
    p_mean = probs.mean(0)
    zloss = jnp.mean(jax.nn.logsumexp(logits, -1) ** 2) * 1e-3
    stats = (frac, p_mean, zloss)

    slots = _dispatch_indices(top_ids, k, e, cap, e0, e_local)  # (T, K)
    buf = jnp.zeros((e_local * cap, d), x_flat.dtype)
    # scatter pairs into the capacity buffer (dropped pairs fall off the end)
    tok_rep = jnp.broadcast_to(jnp.arange(t)[:, None], (t, k)).reshape(-1)
    buf = buf.at[slots.reshape(-1)].set(x_flat[tok_rep], mode="drop")
    buf = _expert_ffn(buf.reshape(e_local, cap, d),
                      params["w_gate"], params["w_up"], params["w_down"])
    buf = buf.reshape(e_local * cap, d)

    # combine: loop over K keeps the peak at (T, D)
    def body(acc, kk):
        contrib = buf.at[slots[:, kk]].get(mode="fill", fill_value=0.0)
        return acc + contrib * top_w[:, kk, None].astype(buf.dtype), None

    # carry derived from x_flat AND buf so its varying-axes type matches the
    # body output under shard_map (buf is model-varying via axis_index; a
    # fresh constant would be device-invariant and trip the VMA check)
    acc0 = (x_flat * 0).astype(buf.dtype) + buf[:1] * 0
    out, _ = jax.lax.scan(body, acc0, jnp.arange(k))
    return out, stats




def _aux_from_stats(cfg: ModelConfig, stats) -> jax.Array:
    frac, p_mean, zloss = stats
    return cfg.n_experts * jnp.sum(frac * p_mean) + zloss

def moe_apply(params, x: jax.Array, cfg: ModelConfig,
              ctx: Optional[ShardingCtx]) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out (B, S, D), aux scalar)."""
    b, s, d = x.shape

    if ctx is None:
        cap = capacity(cfg, b * s)
        out, stats = _moe_local(params, x.reshape(-1, d), cfg, cap,
                                jnp.int32(0), cfg.n_experts)
        return out.reshape(b, s, d), _aux_from_stats(cfg, stats)

    msize = ctx.model_size
    assert cfg.n_experts % msize == 0, (cfg.n_experts, msize)
    e_local = cfg.n_experts // msize
    t_local = b * s // (ctx.batch_size if ctx.shard_batch else 1)
    cap = capacity(cfg, t_local)
    bs, ax = ctx.batch_spec, ctx.model_axis

    def local(pp, xx):
        bl, sl, dl = xx.shape
        e0 = jax.lax.axis_index(ax) * e_local
        out, stats = _moe_local(pp, xx.reshape(-1, dl), cfg, cap, e0, e_local)
        out = jax.lax.psum(out, ax)
        if ctx.shard_batch:
            # average the per-shard routing statistics BEFORE forming the
            # product so the sharded loss equals the global-view loss
            stats = jax.tree.map(
                lambda a: jax.lax.pmean(a, ctx.batch_axes), stats)
        aux = _aux_from_stats(cfg, stats)
        # aux is computed from model-replicated inputs; make that explicit
        aux = jax.lax.pmean(aux, ax)
        return out.reshape(bl, sl, dl), aux

    param_specs = {
        "router": P(),                       # replicated
        "w_gate": P(ax, None, None),         # experts sharded on model
        "w_up": P(ax, None, None),
        "w_down": P(ax, None, None),
    }
    return shard_map(
        local, mesh=ctx.mesh,
        in_specs=(param_specs, P(bs, None, None)),
        out_specs=(P(bs, None, None), P()))(params, x)
