"""Causal flash attention (prefill hot-spot) as a Pallas TPU kernel.

Grid (batch*kv_heads*q_groups, Sq/bq, Sk/bk): the innermost grid dim streams
K/V blocks HBM -> VMEM while the MXU works on the previous block (PIPELOAD's
overlap at the attention level).  Online-softmax running stats (m, l) and
the f32 output accumulator live in VMEM scratch across the Sk dimension.

Layout: q (BH, Sq, dh), k/v (BH, Sk, dh) — callers fold batch/head dims.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  n_k: int, block_q: int, block_k: int, scale: float,
                  causal: bool, window: Optional[int]):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_ids = pl.program_id(1) * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_ids = kk * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    s = jnp.dot(q_ref[0] * scale, k_ref[0].T,
                preferred_element_type=jnp.float32)      # (bq, bk)
    if causal:
        s = jnp.where(k_ids <= q_ids, s, NEG_INF)
    if window is not None:
        s = jnp.where(k_ids > q_ids - window, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = (acc_ref[...] * corr
                    + jnp.dot(p.astype(v_ref.dtype), v_ref[0],
                              preferred_element_type=jnp.float32))

    @pl.when(kk == n_k - 1)
    def _flush():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    block_q: int = 256, block_k: int = 256,
                    interpret: bool = False) -> jax.Array:
    """q: (BH, Sq, dh); k, v: (BH, Sk, dh) -> (BH, Sq, dh)."""
    bh, sq, dh = q.shape
    sk = k.shape[1]
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    assert sq % bq == 0 and sk % bk == 0, ((sq, sk), (bq, bk))
    n_k = sk // bk
    scale = 1.0 / (dh ** 0.5)

    kern = functools.partial(
        _flash_kernel, n_k=n_k, block_q=bq, block_k=bk, scale=scale,
        causal=causal, window=window)
    return pl.pallas_call(
        kern,
        grid=(bh, sq // bq, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, i, kk: (b, i, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, i, kk: (b, kk, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, i, kk: (b, kk, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda b, i, kk: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),     # running max
            pltpu.VMEM((bq, 1), jnp.float32),     # running sum
            pltpu.VMEM((bq, dh), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
