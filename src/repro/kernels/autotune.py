"""Per-device kernel autotune cache (tile / impl selection).

The Pallas kernels expose tuning knobs — ``streamed_matmul`` /
``quantized_matmul`` take ``(block_m, block_n, block_k)`` tiles, and the
paged-decode path can run either the block-table Pallas kernel or the
jnp gather reference — whose best settings depend on the device, the
shard dtype and the KV page size.  The profiler already measures what
the knobs trade off (``t_load`` vs ``t_comp``) but nothing cached the
choice, so every process re-ran with the built-in defaults.

This module searches a small feasible candidate space, times each
candidate on the live device, and caches the winner to disk keyed by
``(kernel, arch, dtype, page_size)`` — repeat runs skip the search
entirely.  Measured profiler aggregates ride along as ``seed`` metadata
so a stale cache (profile changed underneath it) can be detected and
re-tuned with ``force=True``.

Cache file (JSON, ``REPRO_AUTOTUNE_CACHE`` overrides the location)::

    {"version": 1,
     "entries": {
       "matmul|cpu|float32|page=-":      {"block_m": 256, "block_n": 256,
                                          "block_k": 256, "t_us": 812.4,
                                          "shape": [256, 768, 3072]},
       "quant_matmul8|cpu|int8|page=-":  {...},
       "paged_decode|cpu|float32|page=4": {"impl": "reference",
                                           "t_us": 95.1}}}

Selections are *applied* through ``kernels.ops.set_tuned`` — the jitted
wrappers resolve their default tiles from the applied entry (falling
back whenever a tuned tile does not divide the call's shape), and
``core.modules.resolve_attn_impl`` consults the applied paged-decode
impl when asked for ``"auto"``.
"""
from __future__ import annotations

import json
import os
import time
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

CACHE_ENV = "REPRO_AUTOTUNE_CACHE"
VERSION = 1

# candidate tile edges (fitted to each shape's divisors before timing)
_BM_CANDIDATES = (64, 128, 256)
_BN_CANDIDATES = (64, 128, 256)
_BK_CANDIDATES = (128, 256, 512)


def default_cache_path() -> Path:
    env = os.environ.get(CACHE_ENV)
    if env:
        return Path(env)
    return Path.home() / ".cache" / "repro" / "autotune.json"


def device_arch() -> str:
    """Stable per-device key: the accelerator kind on real hardware,
    the JAX backend name otherwise."""
    try:
        d = jax.devices()[0]
        kind = getattr(d, "device_kind", "") or ""
        kind = kind.strip().lower().replace(" ", "-")
        return kind or jax.default_backend()
    except Exception:  # noqa: BLE001 — no backend: still a usable key
        return jax.default_backend()


class AutotuneCache:
    """Disk-backed map of ``(kernel, arch, dtype, page_size)`` -> choice."""

    def __init__(self, path=None):
        self.path = Path(path) if path is not None else default_cache_path()
        self.entries: Dict[str, dict] = {}
        if self.path.exists():
            try:
                blob = json.loads(self.path.read_text())
                if blob.get("version") == VERSION:
                    self.entries = dict(blob.get("entries", {}))
            except (OSError, ValueError):
                self.entries = {}

    @staticmethod
    def key(kernel: str, *, arch: str, dtype: str,
            page_size: Optional[int] = None) -> str:
        page = "-" if not page_size else str(int(page_size))
        return f"{kernel}|{arch}|{dtype}|page={page}"

    def get(self, kernel: str, *, arch: str, dtype: str,
            page_size: Optional[int] = None) -> Optional[dict]:
        return self.entries.get(self.key(kernel, arch=arch, dtype=dtype,
                                         page_size=page_size))

    def put(self, kernel: str, entry: dict, *, arch: str, dtype: str,
            page_size: Optional[int] = None):
        self.entries[self.key(kernel, arch=arch, dtype=dtype,
                              page_size=page_size)] = entry

    def save(self):
        self.path.parent.mkdir(parents=True, exist_ok=True)
        tmp = self.path.with_suffix(".tmp")
        tmp.write_text(json.dumps({"version": VERSION,
                                   "entries": self.entries}, indent=1))
        tmp.replace(self.path)


def _fit(block: int, dim: int) -> int:
    """Largest tile <= ``block`` that divides ``dim`` (the kernels
    require divisible tiling after clamping)."""
    b = min(block, dim)
    while dim % b:
        b -= 1
    return b


def _median_time(fn, reps: int = 3) -> float:
    fn()                                      # warmup / compile
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _tile_candidates(m: int, k: int, n: int,
                     bits: Optional[int]) -> List[Tuple[int, int, int]]:
    cands = []
    for bm in _BM_CANDIDATES:
        for bn in _BN_CANDIDATES:
            for bk in _BK_CANDIDATES:
                t = (_fit(bm, m), _fit(bn, n), _fit(bk, k))
                if bits == 4 and t[2] % 2:
                    continue              # int4 packs two rows per byte
                if t not in cands:
                    cands.append(t)
    return cands


def tune_matmul(m: int, k: int, n: int, *, dtype: str = "float32",
                bits: Optional[int] = None,
                cache: Optional[AutotuneCache] = None,
                arch: Optional[str] = None, reps: int = 3,
                force: bool = False) -> dict:
    """Search ``(block_m, block_n, block_k)`` for ``streamed_matmul``
    (``bits=None``) or ``quantized_matmul`` at the given shape; the
    winner is cached per ``(arch, dtype)`` so repeat runs skip the
    timing sweep."""
    cache = cache if cache is not None else AutotuneCache()
    arch = arch or device_arch()
    kernel = "matmul" if bits is None else f"quant_matmul{bits}"
    hit = cache.get(kernel, arch=arch, dtype=dtype)
    if hit is not None and not force:
        return hit
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    if bits is None:
        w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
        run = lambda t: ops.matmul(x, w, block_m=t[0], block_n=t[1],  # noqa: E731,E501
                                   block_k=t[2]).block_until_ready()
    else:
        iinfo_max = 127 if bits == 8 else 7
        kw = k if bits == 8 else k // 2
        w_q = jnp.asarray(rng.integers(-iinfo_max, iinfo_max, (kw, n)),
                          jnp.int8)
        scale = jnp.asarray(rng.uniform(0.5, 1.5, (n,)), jnp.float32)
        run = lambda t: ops.quant_matmul(x, w_q, scale, bits=bits,  # noqa: E731,E501
                                         block_m=t[0], block_n=t[1],
                                         block_k=t[2]).block_until_ready()
    best, best_t = None, float("inf")
    for tile in _tile_candidates(m, k, n, bits):
        dt = _median_time(lambda: run(tile), reps=reps)
        if dt < best_t:
            best, best_t = tile, dt
    entry = {"block_m": best[0], "block_n": best[1], "block_k": best[2],
             "t_us": best_t * 1e6, "shape": [m, k, n]}
    cache.put(kernel, entry, arch=arch, dtype=dtype)
    cache.save()
    return entry


def tune_paged_decode(page_size: int, *, dtype: str = "float32",
                      kv_heads: int = 2, groups: int = 2,
                      head_dim: int = 64, pages_per_row: int = 4,
                      cache: Optional[AutotuneCache] = None,
                      arch: Optional[str] = None, reps: int = 3,
                      force: bool = False) -> dict:
    """Pick the paged-decode implementation — the block-table Pallas
    kernel vs the jnp gather reference — for this device and page size
    (the page IS the kernel's tile, so the choice is page-size-keyed)."""
    cache = cache if cache is not None else AutotuneCache()
    arch = arch or device_arch()
    hit = cache.get("paged_decode", arch=arch, dtype=dtype,
                    page_size=page_size)
    if hit is not None and not force:
        return hit
    rng = np.random.default_rng(0)
    b = 2
    pool = pages_per_row * b
    q = jnp.asarray(rng.standard_normal((b, kv_heads, groups, head_dim)),
                    jnp.float32)
    kp = jnp.asarray(rng.standard_normal((pool, page_size, kv_heads,
                                          head_dim)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal(kp.shape), jnp.float32)
    tables = jnp.asarray(
        rng.permutation(pool).reshape(b, pages_per_row), jnp.int32)
    lengths = jnp.full((b,), pages_per_row * page_size - 1, jnp.int32)
    ref_fn = jax.jit(ref.paged_decode_ref)
    timings = {
        "pallas": _median_time(
            lambda: ops.paged_decode(q, kp, vp, tables,
                                     lengths).block_until_ready(),
            reps=reps),
        "reference": _median_time(
            lambda: ref_fn(q, kp, vp, tables,
                           lengths).block_until_ready(),
            reps=reps),
    }
    impl = min(timings, key=timings.get)
    entry = {"impl": impl, "t_us": timings[impl] * 1e6,
             "t_us_other": max(timings.values()) * 1e6}
    cache.put("paged_decode", entry, arch=arch, dtype=dtype,
              page_size=page_size)
    cache.save()
    return entry


def tune_for_model(cfg, profile: Optional[dict] = None, *,
                   page_size: Optional[int] = None,
                   quant: Optional[str] = None,
                   cache_path=None, tokens: int = 256,
                   reps: int = 3, force: bool = False,
                   apply: bool = True) -> dict:
    """Model-shaped autotune pass, seeded by the Layer Profiler.

    The matmul sweep runs at the model's FFN shape (``tokens x d_model @
    d_model x d_ff`` — the streaming hot spot); the profile supplies the
    shard dtype and its measured ``layer_t_comp`` / ``layer_t_load``
    aggregates, which are stored as ``seed`` metadata on the entries.
    Returns the selections and (``apply=True``) installs them as the
    jitted wrappers' default tiles via ``kernels.ops.set_tuned``.
    """
    cache = AutotuneCache(cache_path)
    dtype = (profile or {}).get("ckpt_dtype") or getattr(cfg, "dtype",
                                                        "float32")
    quant = quant or (profile or {}).get("quant")
    bits = {"int8": 8, "int4": 4}.get(quant or "")
    m = max(8, int(tokens))
    k = int(cfg.d_model)
    n = int(getattr(cfg, "d_ff", 4 * cfg.d_model))
    seed = None
    if profile:
        seed = {"layer_t_comp": profile.get("layer_t_comp"),
                "layer_t_load": profile.get("layer_t_load")}
    out = {"arch": device_arch(), "dtype": dtype}
    mat = tune_matmul(m, k, n, dtype=dtype, cache=cache, reps=reps,
                      force=force)
    if seed and "seed" not in mat:
        mat["seed"] = seed
        cache.save()
    out["matmul"] = mat
    if bits is not None:
        out["quant_matmul"] = tune_matmul(m, k, n, dtype=quant, bits=bits,
                                          cache=cache, reps=reps,
                                          force=force)
    if page_size:
        head_dim = int(getattr(cfg, "head_dim", 64))
        kv = int(getattr(cfg, "n_kv_heads", None)
                 or getattr(cfg, "n_heads", 2))
        g = max(1, int(getattr(cfg, "n_heads", kv)) // max(kv, 1))
        out["paged_decode"] = tune_paged_decode(
            int(page_size), dtype=dtype, kv_heads=kv, groups=g,
            head_dim=head_dim, cache=cache, reps=reps, force=force)
    if apply:
        apply_tuning(out)
    return out


def apply_tuning(selection: dict):
    """Install a ``tune_for_model`` selection as process-wide defaults
    for the jitted kernel wrappers (and the auto attn-impl choice)."""
    ops.set_tuned(matmul=selection.get("matmul"),
                  quant_matmul=selection.get("quant_matmul"),
                  paged_impl=(selection.get("paged_decode") or {})
                  .get("impl"))
