"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU so the same call sites work in CPU
tests and on real hardware (the TARGET is TPU: compiled BlockSpec pipelines;
interpret=True executes the kernel bodies in Python for validation).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention as _flash_attn
from repro.kernels.flash_decode import (flash_decode as _flash_decode,
                                        flash_decode_partial as _fd_partial)
from repro.kernels.paged_decode import (paged_flash_decode as _paged_decode,
                                        paged_flash_verify as _paged_verify)
from repro.kernels.streamed_matmul import (quantized_matmul as _qmatmul,
                                           streamed_matmul as _matmul)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k"))
def matmul(x, w, *, block_m: int = 256, block_n: int = 256,
           block_k: int = 512):
    return _matmul(x, w, block_m=block_m, block_n=block_n, block_k=block_k,
                   interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("bits", "block_m", "block_n",
                                             "block_k"))
def quant_matmul(x, w_q, scale, *, bits: int = 8, block_m: int = 256,
                 block_n: int = 256, block_k: int = 512):
    """Fused dequant-matmul over int8/int4 per-channel-scaled weights."""
    return _qmatmul(x, w_q, scale, bits=bits, block_m=block_m,
                    block_n=block_n, block_k=block_k,
                    interpret=not _on_tpu())


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "block_q", "block_k"))
def attention(q, k, v, *, causal: bool = True,
              window: Optional[int] = None, block_q: int = 256,
              block_k: int = 256):
    return _flash_attn(q, k, v, causal=causal, window=window,
                       block_q=block_q, block_k=block_k,
                       interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("block_k",))
def decode(q, k, v, valid, *, block_k: int = 512):
    return _flash_decode(q, k, v, valid, block_k=block_k,
                         interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("block_k",))
def decode_partial(q, k, v, valid, *, block_k: int = 512):
    return _fd_partial(q, k, v, valid, block_k=block_k,
                       interpret=not _on_tpu())


@jax.jit
def paged_decode(q, k_pages, v_pages, tables, lengths):
    """Paged flash decode through per-row block tables, directly over
    the scheduler's (P, page, KV, dh) physical pool layout (tile size
    is the pool's page size; no relayout or densify)."""
    return _paged_decode(q, k_pages, v_pages, tables, lengths,
                         interpret=not _on_tpu())


@jax.jit
def paged_verify(q, k_pages, v_pages, tables, lengths):
    """Stacked multi-query paged decode (speculative verify): q is
    (B, W, KV, G, dh), query i of row b attends slots
    ``<= lengths[b] - W + i`` — one call scores a whole speculation
    window against the block-table pool."""
    return _paged_verify(q, k_pages, v_pages, tables, lengths,
                         interpret=not _on_tpu())
