"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU so the same call sites work in CPU
tests and on real hardware (the TARGET is TPU: compiled BlockSpec pipelines;
interpret=True executes the kernel bodies in Python for validation).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention as _flash_attn
from repro.kernels.flash_decode import (flash_decode as _flash_decode,
                                        flash_decode_partial as _fd_partial)
from repro.kernels.paged_decode import (paged_flash_decode as _paged_decode,
                                        paged_flash_verify as _paged_verify)
from repro.kernels.streamed_matmul import (quantized_matmul as _qmatmul,
                                           streamed_matmul as _matmul)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ---- autotuned defaults (kernels/autotune.py) ----------------------------
# ``set_tuned`` installs per-device tile selections; the wrappers resolve
# their default blocks from here, falling back to the built-ins whenever a
# tuned tile does not divide the call's shape (the kernels require
# divisible tiling after clamping).
_DEFAULT_TILES = {"block_m": 256, "block_n": 256, "block_k": 512}
_TUNED: dict = {"matmul": None, "quant_matmul": None, "paged_impl": None}


def set_tuned(*, matmul=None, quant_matmul=None,
              paged_impl: Optional[str] = None):
    """Install autotune selections as process-wide wrapper defaults
    (pass nothing to clear)."""
    _TUNED["matmul"] = dict(matmul) if matmul else None
    _TUNED["quant_matmul"] = dict(quant_matmul) if quant_matmul else None
    _TUNED["paged_impl"] = paged_impl


def tuned_paged_impl() -> Optional[str]:
    """The autotuned paged-decode impl choice ("pallas" / "reference"),
    or None when untuned — ``core.modules.resolve_attn_impl`` consults
    this for ``attn_impl="auto"``."""
    return _TUNED["paged_impl"]


def _divides(tile: dict, m: int, k: int, n: int) -> bool:
    bm = min(tile["block_m"], m)
    bn = min(tile["block_n"], n)
    bk = min(tile["block_k"], k)
    return m % bm == 0 and n % bn == 0 and k % bk == 0


def _resolve_tiles(kernel: str, m: int, k: int, n: int, block_m, block_n,
                   block_k) -> dict:
    tuned = _TUNED[kernel]
    base = (tuned if tuned is not None and _divides(tuned, m, k, n)
            else _DEFAULT_TILES)
    return {"block_m": block_m if block_m is not None else base["block_m"],
            "block_n": block_n if block_n is not None else base["block_n"],
            "block_k": block_k if block_k is not None else base["block_k"]}


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k"))
def _matmul_jit(x, w, *, block_m: int, block_n: int, block_k: int):
    return _matmul(x, w, block_m=block_m, block_n=block_n, block_k=block_k,
                   interpret=not _on_tpu())


def matmul(x, w, *, block_m: Optional[int] = None,
           block_n: Optional[int] = None, block_k: Optional[int] = None):
    tiles = _resolve_tiles("matmul", x.shape[0], x.shape[1], w.shape[1],
                           block_m, block_n, block_k)
    return _matmul_jit(x, w, **tiles)


@functools.partial(jax.jit, static_argnames=("bits", "block_m", "block_n",
                                             "block_k"))
def _quant_matmul_jit(x, w_q, scale, *, bits: int, block_m: int,
                      block_n: int, block_k: int):
    return _qmatmul(x, w_q, scale, bits=bits, block_m=block_m,
                    block_n=block_n, block_k=block_k,
                    interpret=not _on_tpu())


def quant_matmul(x, w_q, scale, *, bits: int = 8,
                 block_m: Optional[int] = None,
                 block_n: Optional[int] = None,
                 block_k: Optional[int] = None):
    """Fused dequant-matmul over int8/int4 per-channel-scaled weights."""
    k = x.shape[1]
    tiles = _resolve_tiles("quant_matmul", x.shape[0], k, w_q.shape[1],
                           block_m, block_n, block_k)
    if bits == 4 and min(tiles["block_k"], k) % 2:
        tiles["block_k"] = _DEFAULT_TILES["block_k"]
    return _quant_matmul_jit(x, w_q, scale, bits=bits, **tiles)


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "block_q", "block_k"))
def attention(q, k, v, *, causal: bool = True,
              window: Optional[int] = None, block_q: int = 256,
              block_k: int = 256):
    return _flash_attn(q, k, v, causal=causal, window=window,
                       block_q=block_q, block_k=block_k,
                       interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("block_k",))
def decode(q, k, v, valid, *, block_k: int = 512):
    return _flash_decode(q, k, v, valid, block_k=block_k,
                         interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("block_k",))
def decode_partial(q, k, v, valid, *, block_k: int = 512):
    return _fd_partial(q, k, v, valid, block_k=block_k,
                       interpret=not _on_tpu())


@jax.jit
def paged_decode(q, k_pages, v_pages, tables, lengths):
    """Paged flash decode through per-row block tables, directly over
    the scheduler's (P, page, KV, dh) physical pool layout (tile size
    is the pool's page size; no relayout or densify)."""
    return _paged_decode(q, k_pages, v_pages, tables, lengths,
                         interpret=not _on_tpu())


@jax.jit
def paged_verify(q, k_pages, v_pages, tables, lengths):
    """Stacked multi-query paged decode (speculative verify): q is
    (B, W, KV, G, dh), query i of row b attends slots
    ``<= lengths[b] - W + i`` — one call scores a whole speculation
    window against the block-table pool."""
    return _paged_verify(q, k_pages, v_pages, tables, lengths,
                         interpret=not _on_tpu())
