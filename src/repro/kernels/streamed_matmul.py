"""Streamed (weight-streaming) matmul Pallas kernel.

The VMEM tier of PIPELOAD: weight tiles stream HBM -> VMEM through
``pallas_call``'s grid pipeline — while tile (i, j, k) is in the MXU, tile
(i, j, k+1) is being DMA'd.  This is the paper's loading-agent/inference-
agent overlap at VMEM granularity (the pipeline's in-flight buffer count is
the analogue of the agent count), and the "destroy after use" policy is the
pipeline's automatic tile recycling.

Grid (M/bm, N/bn, K/bk); f32 VMEM scratch accumulator; MXU-aligned
(128-multiple) tile defaults.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def streamed_matmul(x: jax.Array, w: jax.Array, *, block_m: int = 256,
                    block_n: int = 256, block_k: int = 512,
                    interpret: bool = False) -> jax.Array:
    """x: (M, K) @ w: (K, N) -> (M, N).  Requires divisible tiling."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        (m, n, k), (bm, bn, bk))
    n_k = k // bk

    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)
