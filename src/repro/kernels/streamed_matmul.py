"""Streamed (weight-streaming) matmul Pallas kernel.

The VMEM tier of PIPELOAD: weight tiles stream HBM -> VMEM through
``pallas_call``'s grid pipeline — while tile (i, j, k) is in the MXU, tile
(i, j, k+1) is being DMA'd.  This is the paper's loading-agent/inference-
agent overlap at VMEM granularity (the pipeline's in-flight buffer count is
the analogue of the agent count), and the "destroy after use" policy is the
pipeline's automatic tile recycling.

Grid (M/bm, N/bn, K/bk); f32 VMEM scratch accumulator; MXU-aligned
(128-multiple) tile defaults.

``quantized_matmul`` is the weight-streaming variant for int8/int4
PIPELOAD shards: the weight tile is DMA'd in its *quantized* form (1/4
or 1/8 the HBM->VMEM bytes of f32 — the same load-bandwidth win the
engine gets on the disk->memory tier) and dequantized in-kernel right
before the MXU dot, so the fp tile never exists outside VMEM scratch.
Scales are per-output-channel (`checkpoint/quant.py` scheme); int4
weights arrive nibble-packed along K and are unpacked in-kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.checkpoint import quant as qz


def _matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def streamed_matmul(x: jax.Array, w: jax.Array, *, block_m: int = 256,
                    block_n: int = 256, block_k: int = 512,
                    interpret: bool = False) -> jax.Array:
    """x: (M, K) @ w: (K, N) -> (M, N).  Requires divisible tiling."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        (m, n, k), (bm, bn, bk))
    n_k = k // bk

    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)


# ---------------------------------------------------------------------------
# Fused dequant-matmul (int8 / int4 weight streaming)
# ---------------------------------------------------------------------------
def _dequant_tile(w_ref, bits: int):
    """Quantized VMEM tile -> f32 at full K rows.  The int4 nibble
    layout has exactly one production implementation
    (checkpoint/quant.py::unpack_int4, pure jnp, Pallas-safe); the
    deliberately independent oracle copy lives in kernels/ref.py."""
    if bits == 8:
        return w_ref[...].astype(jnp.float32)
    return qz.unpack_int4(w_ref[...],
                          2 * w_ref.shape[0]).astype(jnp.float32)


def _quant_matmul_kernel(x_ref, w_ref, s_ref, o_ref, acc_ref, *,
                         n_k: int, bits: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _dequant_tile(w_ref, bits) * s_ref[...]   # (bk, bn) * (1, bn)
    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32), w,
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def quantized_matmul(x: jax.Array, w_q: jax.Array, scale: jax.Array, *,
                     bits: int = 8, block_m: int = 256, block_n: int = 256,
                     block_k: int = 512, interpret: bool = False
                     ) -> jax.Array:
    """x: (M, K) @ dequant(w_q, scale): (K, N) -> (M, N).

    ``w_q`` is int8 ``(K, N)`` for ``bits=8`` or nibble-packed uint8
    ``(K/2, N)`` for ``bits=4``; ``scale`` is f32 ``(N,)`` per-output-
    channel.  Requires divisible tiling, and even ``block_k`` rows per
    int4 tile (one packed byte row = two K rows)."""
    assert bits in (8, 4), bits
    m, k = x.shape
    kw = w_q.shape[0] * (2 if bits == 4 else 1)
    n = w_q.shape[1]
    assert k == kw, (x.shape, w_q.shape, bits)
    assert scale.shape == (n,), scale.shape
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        (m, n, k), (bm, bn, bk))
    assert bits == 8 or bk % 2 == 0, bk
    n_k = k // bk
    wrows = bk // 2 if bits == 4 else bk

    return pl.pallas_call(
        functools.partial(_quant_matmul_kernel, n_k=n_k, bits=bits),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((wrows, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w_q, scale.reshape(1, n))
