"""Flash-decoding Pallas kernel: one query token vs. a long KV cache.

This is the per-shard compute of the sequence-sharded decode path
(models/attention.flash_decode): the grid dim over cache blocks streams the
KV cache HBM -> VMEM (decode is memory-bound; the pipeline keeps the MXU/VPU
fed — PIPELOAD's overlap where it matters most).  Emits unnormalised
(o, m, l) partials so the cross-shard softmax combine (psum/pmax) can merge
shards exactly like the in-kernel running stats.

Layout: q (BH, dh); k/v (BH, S, dh); valid (BH, S) bool.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, m_out_ref,
                   l_out_ref, m_ref, l_ref, acc_ref, *, n_k: int,
                   scale: float):
    kk = pl.program_id(1)

    @pl.when(kk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...] * scale                                  # (1, dh)
    s = jnp.dot(q, k_ref[0].T,
                preferred_element_type=jnp.float32)         # (1, bk)
    s = jnp.where(valid_ref[...], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))   # (1, 1)
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = (acc_ref[...] * corr
                    + jnp.dot(p.astype(v_ref.dtype), v_ref[0],
                              preferred_element_type=jnp.float32))

    @pl.when(kk == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)       # unnormalised
        m_out_ref[...] = m_ref[...]
        l_out_ref[...] = l_ref[...]


def flash_decode_partial(q: jax.Array, k: jax.Array, v: jax.Array,
                         valid: jax.Array, *, block_k: int = 512,
                         interpret: bool = False):
    """Returns unnormalised (o (BH, dh) f32, m (BH, 1), l (BH, 1))."""
    bh, dh = q.shape
    s = k.shape[1]
    bk = min(block_k, s)
    assert s % bk == 0, (s, bk)
    n_k = s // bk
    scale = 1.0 / (dh ** 0.5)

    kern = functools.partial(_decode_kernel, n_k=n_k, scale=scale)
    o, m, l = pl.pallas_call(
        kern,
        grid=(bh, n_k),
        in_specs=[
            pl.BlockSpec((1, dh), lambda b, kk: (b, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, kk: (b, kk, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, kk: (b, kk, 0)),
            pl.BlockSpec((1, bk), lambda b, kk: (b, kk)),
        ],
        out_specs=[
            pl.BlockSpec((1, dh), lambda b, kk: (b, 0)),
            pl.BlockSpec((1, 1), lambda b, kk: (b, 0)),
            pl.BlockSpec((1, 1), lambda b, kk: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, dh), jnp.float32),
            jax.ShapeDtypeStruct((bh, 1), jnp.float32),
            jax.ShapeDtypeStruct((bh, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, valid)
    return o, m, l


def flash_decode(q, k, v, valid, *, block_k: int = 512,
                 interpret: bool = False):
    """Normalised single-shard decode: (BH, dh)."""
    o, m, l = flash_decode_partial(q, k, v, valid, block_k=block_k,
                                   interpret=interpret)
    return (o / jnp.maximum(l, 1e-30)).astype(v.dtype)
