"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.dot(x.astype(jnp.float32),
                   w.astype(jnp.float32)).astype(x.dtype)


def dequant_ref(w_q: jax.Array, scale: jax.Array, *,
                bits: int = 8) -> jax.Array:
    """Per-channel dequantization matching checkpoint/quant.py: int8
    (K, N) values, or int4 nibble-packed uint8 (K/2, N) with row 2i in
    the low nibble and 2i+1 in the high nibble."""
    if bits == 8:
        q = w_q.astype(jnp.float32)
    else:
        p = w_q.astype(jnp.uint8)
        lo = (p & 0xF).astype(jnp.int8)
        hi = ((p >> 4) & 0xF).astype(jnp.int8)
        lo = jnp.where(lo > 7, lo - 16, lo)
        hi = jnp.where(hi > 7, hi - 16, hi)
        q = jnp.stack([lo, hi], axis=1).reshape(
            (2 * p.shape[0],) + p.shape[1:]).astype(jnp.float32)
    return q * scale[None, :].astype(jnp.float32)


def quant_matmul_ref(x: jax.Array, w_q: jax.Array, scale: jax.Array, *,
                     bits: int = 8) -> jax.Array:
    return jnp.dot(x.astype(jnp.float32),
                   dequant_ref(w_q, scale, bits=bits)).astype(x.dtype)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True,
                  window: Optional[int] = None) -> jax.Array:
    """q: (BH, Sq, dh); k, v: (BH, Sk, dh)."""
    _, sq, dh = q.shape
    sk = k.shape[1]
    scale = 1.0 / (dh ** 0.5)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    q_ids = jnp.arange(sq)[:, None]
    k_ids = jnp.arange(sk)[None, :]
    if causal:
        s = jnp.where(k_ids <= q_ids, s, NEG_INF)
    if window is not None:
        s = jnp.where(k_ids > q_ids - window, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def decode_ref(q: jax.Array, k: jax.Array, v: jax.Array,
               valid: jax.Array) -> jax.Array:
    """q: (BH, dh); k, v: (BH, S, dh); valid: (BH, S)."""
    dh = q.shape[-1]
    scale = 1.0 / (dh ** 0.5)
    s = jnp.einsum("bd,bsd->bs", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bs,bsd->bd", p,
                      v.astype(jnp.float32)).astype(v.dtype)


def paged_decode_ref(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                     tables: jax.Array, lengths: jax.Array) -> jax.Array:
    """Paged decode oracle: densify the block-table gather, then run the
    masked grouped softmax.  q: (B, KV, G, dh); k_pages/v_pages:
    (P, page, KV, dh); tables: (B, NB) int32 page ids; lengths: (B,)
    live slots per row."""
    b, kv, g, dh = q.shape
    page = k_pages.shape[1]
    nb = tables.shape[1]
    s_tot = nb * page
    k = k_pages[tables].reshape(b, s_tot, kv, dh)
    v = v_pages[tables].reshape(b, s_tot, kv, dh)
    scale = 1.0 / (dh ** 0.5)
    s = jnp.einsum("bkgd,bskd->bkgs", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    valid = jnp.arange(s_tot)[None, :] < lengths[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgs,bskd->bkgd", p,
                      v.astype(jnp.float32)).astype(v.dtype)


def paged_verify_ref(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                     tables: jax.Array, lengths: jax.Array) -> jax.Array:
    """Multi-query paged oracle for speculative verification.

    ``q`` stacks W consecutive query tokens per row: (B, W, KV, G, dh).
    Query ``i`` sits at absolute slot ``lengths[b] - W + i`` (the caller
    already wrote its K/V into the pages and counted it in ``lengths``),
    so it attends causally to slots ``<= lengths[b] - W + i``.  W=1
    degenerates to ``paged_decode_ref`` exactly.  Returns
    (B, W, KV, G, dh) in ``v_pages``'s dtype.
    """
    b, w, kv, g, dh = q.shape
    page = k_pages.shape[1]
    s_tot = tables.shape[1] * page
    k = k_pages[tables].reshape(b, s_tot, kv, dh)
    v = v_pages[tables].reshape(b, s_tot, kv, dh)
    scale = 1.0 / (dh ** 0.5)
    s = jnp.einsum("bwkgd,bskd->bwkgs", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    qpos = lengths[:, None] - w + jnp.arange(w)[None, :]      # (B, W)
    valid = jnp.arange(s_tot)[None, None, :] <= qpos[:, :, None]
    s = jnp.where(valid[:, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bwkgs,bskd->bwkgd", p,
                      v.astype(jnp.float32)).astype(v.dtype)


def decode_partial_ref(q, k, v, valid):
    """Unnormalised (o, m, l) partials matching flash_decode_partial."""
    dh = q.shape[-1]
    scale = 1.0 / (dh ** 0.5)
    s = jnp.einsum("bd,bsd->bs", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    s = jnp.where(valid, s, NEG_INF)
    m = s.max(-1, keepdims=True)
    p = jnp.exp(s - m)
    l = p.sum(-1, keepdims=True)
    o = jnp.einsum("bs,bsd->bd", p, v.astype(jnp.float32))
    return o, m, l
