"""Paged flash-decoding Pallas kernel: block-table K/V gather.

The paged KV cache (core/kv_pages.py) stores K/V in fixed-size pages of
a physical pool; each request's logical sequence is a block table of
page ids.  This kernel runs one query token per (row, kv-head, q-group)
against that paged cache WITHOUT densifying or relayouting it: the
block table rides in as a scalar-prefetch operand, so the BlockSpec
index_map dereferences ``tables[b, j]`` to DMA exactly the j-th logical
page's tile for one kv head HBM -> VMEM — the gather happens in the
grid pipeline, not as a jnp ``take`` (or transpose) that materialises a
copy of the pool.

Masking is positional: row ``b`` attends to global slots
``[0, lengths[b])``; slots past the length (the tail of the last mapped
page, and any padded table entries — callers pad short tables with page
0) contribute exact zeros, so the result is identical to a dense decode
over the logically contiguous cache.

Layout (the scheduler's native pool layout — no flattening):
q (B, KV, G, dh); k_pages/v_pages (P, page, KV, dh); tables (B, NB)
int32; lengths (B,) int32.  The grouped cache tile is read once per
(kv, g) grid step — the same G-fold read amplification as
flash_decode's flat layout, and the same price for its HBM -> VMEM
streaming pipeline.  The running-softmax body matches flash_decode.py
block for block — only the source of each K/V tile changed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(tab_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, n_b: int, page: int,
                  scale: float):
    b = pl.program_id(0)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0, 0, :][None] * scale                     # (1, dh)
    k = k_ref[0, :, 0, :]                                   # (page, dh)
    s = jnp.dot(q, k.T,
                preferred_element_type=jnp.float32)         # (1, page)
    slot = j * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
    s = jnp.where(slot < len_ref[b], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))   # (1, 1)
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = (acc_ref[...] * corr
                    + jnp.dot(p.astype(v_ref.dtype), v_ref[0, :, 0, :],
                              preferred_element_type=jnp.float32))

    @pl.when(j == n_b - 1)
    def _flush():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = out[None, None].astype(o_ref.dtype)


def _paged_verify_kernel(tab_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, n_b: int, page: int,
                         w: int, scale: float):
    b = pl.program_id(0)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0, 0, :] * scale                        # (W, dh)
    k = k_ref[0, :, 0, :]                                   # (page, dh)
    s = jnp.dot(q, k.T,
                preferred_element_type=jnp.float32)         # (W, page)
    # query i lives at absolute slot len-W+i and attends slots <= that:
    # the per-query causal frontier of the stacked verify window
    slot = j * page + jax.lax.broadcasted_iota(jnp.int32, (w, page), 1)
    qpos = (len_ref[b] - w
            + jax.lax.broadcasted_iota(jnp.int32, (w, page), 0))
    s = jnp.where(slot <= qpos, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))   # (W, 1)
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = (acc_ref[...] * corr
                    + jnp.dot(p.astype(v_ref.dtype), v_ref[0, :, 0, :],
                              preferred_element_type=jnp.float32))

    @pl.when(j == n_b - 1)
    def _flush():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = out[None, :, None, None, :].astype(o_ref.dtype)


def paged_flash_verify(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                       tables: jax.Array, lengths: jax.Array, *,
                       interpret: bool = False) -> jax.Array:
    """Stacked multi-query paged decode for speculative verification.

    ``q`` is (B, W, KV, G, dh): W consecutive query tokens per row, the
    last of which sits at slot ``lengths[b] - 1`` (K/V for all W already
    written into the pages).  Each query applies its own causal frontier
    ``slot <= lengths[b] - W + i``, so one kernel call scores a whole
    speculation window — same block-table gather and running softmax as
    ``paged_flash_decode``, with W rows of scratch instead of one.
    Returns (B, W, KV, G, dh) in ``v_pages``'s dtype.
    """
    b, w, kv, g, dh = q.shape
    page = k_pages.shape[1]
    nb = tables.shape[1]
    scale = 1.0 / (dh ** 0.5)

    kern = functools.partial(_paged_verify_kernel, n_b=nb, page=page,
                             w=w, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                  # tables, lengths
        grid=(b, kv, g, nb),
        in_specs=[
            pl.BlockSpec((1, w, 1, 1, dh),
                         lambda b, k, gg, j, tab, lens: (b, 0, k, gg, 0)),
            pl.BlockSpec((1, page, 1, dh),
                         lambda b, k, gg, j, tab, lens: (tab[b, j], 0, k,
                                                         0)),
            pl.BlockSpec((1, page, 1, dh),
                         lambda b, k, gg, j, tab, lens: (tab[b, j], 0, k,
                                                         0)),
        ],
        out_specs=pl.BlockSpec((1, w, 1, 1, dh),
                               lambda b, k, gg, j, tab, lens: (b, 0, k, gg,
                                                               0)),
        scratch_shapes=[
            pltpu.VMEM((w, 1), jnp.float32),
            pltpu.VMEM((w, 1), jnp.float32),
            pltpu.VMEM((w, dh), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, w, kv, g, dh), v_pages.dtype),
        interpret=interpret,
    )(tables, lengths, q, k_pages, v_pages)


def paged_flash_decode(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                       tables: jax.Array, lengths: jax.Array, *,
                       interpret: bool = False) -> jax.Array:
    """Normalised paged decode: (B, KV, G, dh), dtype of ``v_pages``.

    ``tables`` (B, NB) maps each row's logical block j to a physical
    page id; entries past ``ceil(lengths[b] / page)`` are padding (any
    valid page id — their slots are masked).  ``lengths`` (B,) is the
    number of live slots per row (current position + 1).
    """
    b, kv, g, dh = q.shape
    page = k_pages.shape[1]
    nb = tables.shape[1]
    scale = 1.0 / (dh ** 0.5)

    kern = functools.partial(_paged_kernel, n_b=nb, page=page, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                  # tables, lengths
        grid=(b, kv, g, nb),
        in_specs=[
            pl.BlockSpec((1, 1, 1, dh),
                         lambda b, k, gg, j, tab, lens: (b, k, gg, 0)),
            pl.BlockSpec((1, page, 1, dh),
                         lambda b, k, gg, j, tab, lens: (tab[b, j], 0, k,
                                                         0)),
            pl.BlockSpec((1, page, 1, dh),
                         lambda b, k, gg, j, tab, lens: (tab[b, j], 0, k,
                                                         0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, dh),
                               lambda b, k, gg, j, tab, lens: (b, k, gg,
                                                               0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, dh), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kv, g, dh), v_pages.dtype),
        interpret=interpret,
    )(tables, lengths, q, k_pages, v_pages)
