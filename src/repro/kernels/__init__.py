"""Pallas TPU kernels (compute hot-spots) + jit wrappers + jnp oracles.

  streamed_matmul  — grid-pipelined weight streaming (PIPELOAD @ VMEM tier)
  quantized_matmul — fused dequant-matmul over int8/int4 shard weights
  flash_attention  — causal/windowed online-softmax prefill attention
  flash_decode     — single-token decode over a long KV cache, emitting
                     unnormalised partials for the cross-shard combine
  paged_decode     — flash decode over the paged KV cache: block tables
                     ride in as scalar prefetch, so each K/V tile is
                     gathered by page id in the grid pipeline
"""
from repro.kernels import autotune, ops, ref  # noqa: F401
from repro.kernels.flash_attention import flash_attention  # noqa: F401
from repro.kernels.flash_decode import (flash_decode,  # noqa: F401
                                        flash_decode_partial)
from repro.kernels.paged_decode import paged_flash_decode  # noqa: F401
from repro.kernels.streamed_matmul import (quantized_matmul,  # noqa: F401
                                           streamed_matmul)
