"""Roofline terms from a compiled dry-run artifact.

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_wire_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from our trip-count-corrected HLO analyzer
(``analysis.hlo``) because ``cost_analysis()`` counts scan bodies once;
both numbers are per-device, so the "chips" division is already implicit.
MODEL_FLOPS is the analytic 6·N·T / 2·N·T convention (MoE: active params).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.models.config import (ENCDEC, MAMBA_HYBRID, MOE, VLM, XLSTM,
                                 ModelConfig)


def params_count(cfg: ModelConfig, params_shape) -> Dict[str, float]:
    """Exact param counts from the abstract param tree."""
    total = 0
    embed = 0
    expert = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_shape)[0]:
        names = [e.key for e in path
                 if isinstance(e, jax.tree_util.DictKey)]
        total += leaf.size
        if any(n in ("embed", "lm_head") for n in names):
            embed += leaf.size
        if "moe" in names and names[-1] in ("w_gate", "w_up", "w_down"):
            expert += leaf.size
    return {"total": float(total), "embed": float(embed),
            "expert": float(expert)}


def active_params(cfg: ModelConfig, counts: Dict[str, float]) -> float:
    """Non-embedding active params (MoE: top_k of n_experts active)."""
    body = counts["total"] - counts["embed"]
    if cfg.family == MOE and cfg.n_experts:
        body = body - counts["expert"] * (1 - cfg.top_k / cfg.n_experts)
    return body


def model_flops(cfg: ModelConfig, counts: Dict[str, float], kind: str,
                global_batch: int, seq_len: int) -> float:
    """Global analytic FLOPs per step (6NT train / 2NT forward)."""
    n_act = active_params(cfg, counts)
    if kind == "train":
        tokens = global_batch * seq_len
        return 6.0 * n_act * tokens
    if kind == "prefill":
        tokens = global_batch * seq_len
        return 2.0 * n_act * tokens
    # decode: one token per sequence; attention still reads the whole cache,
    # which is memory- not FLOP-dominated -> 2·N·B plus cache dot FLOPs.
    flops = 2.0 * n_act * global_batch
    if cfg.family not in (XLSTM,):
        # q.K + p.V over the cache for every layer
        width = cfg.kv_cache_dim * cfg.num_layers
        eff_len = seq_len
        if cfg.sliding_window is not None:
            eff_len = min(seq_len, cfg.sliding_window)
        heads_mult = (cfg.n_heads if cfg.attention == "mla" else
                      cfg.q_heads_per_kv)
        flops += 2.0 * global_batch * eff_len * width * heads_mult
    return flops


def roofline_terms(hlo_summary: Dict, *, n_chips: int) -> Dict[str, float]:
    """hlo_summary: output of analysis.hlo.analyze_hlo (per-device)."""
    compute_s = hlo_summary["dot_flops"] / PEAK_FLOPS_BF16
    memory_s = hlo_summary["hbm_bytes"] / HBM_BW
    collective_s = hlo_summary["collective_wire_bytes"] / ICI_BW
    dominant = max(
        ("compute", compute_s), ("memory", memory_s),
        ("collective", collective_s), key=lambda kv: kv[1])[0]
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "bound_s": max(compute_s, memory_s, collective_s),
    }
