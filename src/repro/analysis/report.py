"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the committed
dry-run artifacts (experiments/dryrun/*.json).

    PYTHONPATH=src python -m repro.analysis.report > /tmp/tables.md
"""
from __future__ import annotations

import json
from pathlib import Path

ROOT = Path(__file__).resolve().parents[3]
DRYRUN = ROOT / "experiments" / "dryrun"


def load_all():
    rows = []
    for f in sorted(DRYRUN.glob("*.json")):
        rows.append(json.loads(f.read_text()))
    return rows


def fmt_ms(x):
    return f"{x*1e3:.1f}"


def dryrun_table(rows):
    out = ["| arch | shape | mesh | chips | GiB/chip | fits | collectives "
           "(wire GiB/chip) | compile s |",
           "|---|---|---|---|---|---|---|---|"]
    for d in rows:
        if d.get("status") == "skipped":
            out.append(f"| {d['arch']} | {d['shape']} | {d['mesh']} | — | — "
                       f"| SKIP | {d['reason']} | — |")
            continue
        if d.get("status") != "ok":
            out.append(f"| {d['arch']} | {d['shape']} | {d['mesh']} | — | — "
                       f"| ERROR | {d.get('error','')[:60]} | — |")
            continue
        m = d["memory"]
        coll = d["hlo"]["collectives"]
        cstr = " ".join(f"{k}:{v['wire_bytes']/2**30:.2f}"
                        for k, v in sorted(coll.items()))
        out.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} | {d['n_chips']} "
            f"| {m['per_chip_bytes']/2**30:.2f} "
            f"| {'Y' if m['fits_hbm'] else 'N'} | {cstr or '—'} "
            f"| {d['compile_s']} |")
    return "\n".join(out)


def roofline_table(rows):
    out = ["| arch | shape | compute ms | memory ms | collective ms | "
           "dominant | MODEL_FLOPS/chip TF | useful ratio | note |",
           "|---|---|---|---|---|---|---|---|---|"]
    for d in rows:
        if d.get("mesh") != "pod" or d.get("status") != "ok":
            continue
        r = d["roofline"]
        mf = d["model_flops_global"] / d["n_chips"] / 1e12
        dom = r["dominant"]
        note = {
            "compute": "MXU-bound; overlap/fusion won't help much",
            "memory": "HBM-bound; cut bytes (dtype, fusion, layout)",
            "collective": "ICI-bound; reshard or overlap collectives",
        }[dom]
        out.append(
            f"| {d['arch']} | {d['shape']} | {fmt_ms(r['compute_s'])} "
            f"| {fmt_ms(r['memory_s'])} | {fmt_ms(r['collective_s'])} "
            f"| **{dom}** | {mf:.1f} | {d['useful_flops_ratio']:.2f} "
            f"| {note} |")
    return "\n".join(out)


def main():
    rows = load_all()
    ok = [d for d in rows if d.get("status") == "ok"]
    print("## §Dry-run (auto-generated; full artifacts in "
          "experiments/dryrun/)\n")
    print(dryrun_table(rows))
    print(f"\n{len(ok)} combinations compiled "
          f"({sum(1 for d in rows if d.get('status')=='skipped')} documented "
          "skips).\n")
    print("## §Roofline (single-pod mesh, 256 chips)\n")
    print(roofline_table(rows))


if __name__ == "__main__":
    main()
