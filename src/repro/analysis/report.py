"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the committed
dry-run artifacts (experiments/dryrun/*.json).

    PYTHONPATH=src python -m repro.analysis.report > /tmp/tables.md
"""
from __future__ import annotations

import json
from pathlib import Path

ROOT = Path(__file__).resolve().parents[3]
DRYRUN = ROOT / "experiments" / "dryrun"


def load_all():
    rows = []
    for f in sorted(DRYRUN.glob("*.json")):
        rows.append(json.loads(f.read_text()))
    return rows


def fmt_ms(x):
    return f"{x*1e3:.1f}"


def dryrun_table(rows):
    out = ["| arch | shape | mesh | chips | GiB/chip | fits | collectives "
           "(wire GiB/chip) | compile s |",
           "|---|---|---|---|---|---|---|---|"]
    for d in rows:
        if d.get("status") == "skipped":
            out.append(f"| {d['arch']} | {d['shape']} | {d['mesh']} | — | — "
                       f"| SKIP | {d['reason']} | — |")
            continue
        if d.get("status") != "ok":
            out.append(f"| {d['arch']} | {d['shape']} | {d['mesh']} | — | — "
                       f"| ERROR | {d.get('error','')[:60]} | — |")
            continue
        m = d["memory"]
        coll = d["hlo"]["collectives"]
        cstr = " ".join(f"{k}:{v['wire_bytes']/2**30:.2f}"
                        for k, v in sorted(coll.items()))
        out.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} | {d['n_chips']} "
            f"| {m['per_chip_bytes']/2**30:.2f} "
            f"| {'Y' if m['fits_hbm'] else 'N'} | {cstr or '—'} "
            f"| {d['compile_s']} |")
    return "\n".join(out)


def roofline_table(rows):
    out = ["| arch | shape | compute ms | memory ms | collective ms | "
           "dominant | MODEL_FLOPS/chip TF | useful ratio | note |",
           "|---|---|---|---|---|---|---|---|---|"]
    for d in rows:
        if d.get("mesh") != "pod" or d.get("status") != "ok":
            continue
        r = d["roofline"]
        mf = d["model_flops_global"] / d["n_chips"] / 1e12
        dom = r["dominant"]
        note = {
            "compute": "MXU-bound; overlap/fusion won't help much",
            "memory": "HBM-bound; cut bytes (dtype, fusion, layout)",
            "collective": "ICI-bound; reshard or overlap collectives",
        }[dom]
        out.append(
            f"| {d['arch']} | {d['shape']} | {fmt_ms(r['compute_s'])} "
            f"| {fmt_ms(r['memory_s'])} | {fmt_ms(r['collective_s'])} "
            f"| **{dom}** | {mf:.1f} | {d['useful_flops_ratio']:.2f} "
            f"| {note} |")
    return "\n".join(out)


# ===========================================================================
# Planner drift report (predicted vs measured serving outcomes)
# ===========================================================================
def drift_report(plan_entry, serve_stats) -> dict:
    """Compare the winning plan's predictions against a serve run's
    measurements — the feedback signal planner changes are judged by.

    Duck-typed on attribute names (a ``GenPlanEntry`` and a
    ``ServeStats``, but anything carrying the fields works), so this
    module stays import-light.  Returns ``{"rows": [...]}`` where each
    row has ``metric`` / ``predicted`` / ``measured`` / ``ratio``
    (measured ÷ predicted; None when the prediction is zero or absent:
    no drift is computable)."""
    pairs = [
        ("ttft_s", "predicted_ttft_s", "ttft_p50_s"),
        ("tpot_s", "predicted_tpot_s", "tpot_p50_s"),
        ("throughput_tps", "predicted_throughput_tps", "tokens_per_s"),
        ("peak_bytes", "predicted_peak_bytes", "peak_bytes"),
    ]
    rows = []
    for metric, p_attr, m_attr in pairs:
        pred = getattr(plan_entry, p_attr, None)
        meas = getattr(serve_stats, m_attr, None)
        ratio = (meas / pred) if pred and meas is not None else None
        rows.append({"metric": metric, "predicted": pred,
                     "measured": meas, "ratio": ratio})
    return {"rows": rows}


def format_drift(report: dict) -> str:
    """Aligned text table for a ``drift_report`` result (serve.py prints
    this at the end of a run)."""
    lines = ["planner drift (predicted vs measured, ratio = meas/pred):",
             f"  {'metric':<16} {'predicted':>12} {'measured':>12} "
             f"{'ratio':>7}"]
    for row in report["rows"]:
        def num(v):
            if v is None:
                return "—"
            return f"{v:,.0f}" if abs(v) >= 1000 else f"{v:.4g}"
        ratio = "—" if row["ratio"] is None else f"{row['ratio']:.2f}x"
        lines.append(f"  {row['metric']:<16} {num(row['predicted']):>12} "
                     f"{num(row['measured']):>12} {ratio:>7}")
    return "\n".join(lines)


# ===========================================================================
# Peak-breakdown attribution (per-owner byte shares at the ledger peak)
# ===========================================================================
def peak_breakdown_report(stats) -> dict:
    """Attribute the run's ledger peak to its resident tiers.

    Duck-typed like ``drift_report``: ``stats`` is anything carrying
    ``peak_bytes`` and a ``peak_breakdown`` dict (``RunStats`` or
    ``ServeStats``).  The breakdown is the by-owner snapshot taken under
    the ledger lock at the instant the peak was set, so the shares sum
    EXACTLY to ``peak_bytes`` — a mismatch means a ledger bug, and the
    report surfaces it as a non-empty ``unattributed`` row rather than
    hiding it.  Returns ``{"peak_bytes", "rows": [...], "unattributed"}``
    with rows sorted largest share first."""
    peak = getattr(stats, "peak_bytes", 0) or 0
    breakdown = dict(getattr(stats, "peak_breakdown", None) or {})
    rows = [{"owner": o, "bytes": b,
             "share": (b / peak) if peak else 0.0}
            for o, b in sorted(breakdown.items(),
                               key=lambda kv: (-kv[1], kv[0]))]
    return {"peak_bytes": peak, "rows": rows,
            "unattributed": peak - sum(breakdown.values())}


def format_peak_breakdown(report: dict) -> str:
    """Aligned text table for ``peak_breakdown_report`` (serve.py prints
    this under the end-of-run summary)."""
    peak = report["peak_bytes"]
    lines = [f"ledger peak attribution (peak = {peak:,} bytes):",
             f"  {'owner':<16} {'bytes':>14} {'share':>7}"]
    if not report["rows"]:
        lines.append("  (no ledger charges recorded)")
    for row in report["rows"]:
        lines.append(f"  {row['owner']:<16} {row['bytes']:>14,} "
                     f"{row['share']:>6.1%}")
    if report["unattributed"]:
        lines.append(f"  {'UNATTRIBUTED':<16} "
                     f"{report['unattributed']:>14,} "
                     f"{'!':>7}  (ledger bug: shares must sum to peak)")
    return "\n".join(lines)


def main():
    rows = load_all()
    ok = [d for d in rows if d.get("status") == "ok"]
    print("## §Dry-run (auto-generated; full artifacts in "
          "experiments/dryrun/)\n")
    print(dryrun_table(rows))
    print(f"\n{len(ok)} combinations compiled "
          f"({sum(1 for d in rows if d.get('status')=='skipped')} documented "
          "skips).\n")
    print("## §Roofline (single-pod mesh, 256 chips)\n")
    print(roofline_table(rows))


if __name__ == "__main__":
    main()
