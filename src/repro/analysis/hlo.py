"""HLO-text analysis for the roofline model.

``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any scan-based
model (all of ours: layer stacks are scans) is undercounted by the trip
count, and collective bytes are not exposed at all.  This module parses the
post-SPMD optimized HLO text into a computation call graph, multiplies each
computation's costs by its execution count (``known_trip_count`` for while
bodies, call-site count for fusions/calls), and accumulates per device:

  * dot FLOPs: 2 * result_elems * contracted_elems (trip-count corrected)
  * an HBM traffic model: every materializing op charges result + operand
    bytes, with slice-awareness — a fusion that internally dynamic-slices a
    parameter (the layer-scan weight read) charges only the slice, and a
    fused in-place dynamic-update-slice (the KV-cache write) charges only
    2x the update — matching XLA's aliasing behaviour instead of charging
    whole weight stacks / caches per layer step
  * collective wire bytes with ring-algorithm factors

Shapes in post-SPMD HLO are per-device shards, so everything here is a
per-device cost.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_NAME_EQ_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_SINGLE_SHAPE_RE = re.compile(r"([\w]+\[[\d,]*\](?:\{[^}]*\})?)")
_KIND_RE = re.compile(r"\s*([\w\-]+)\((.*)$")
_BLOCK_HDR_RE = re.compile(r"^(ENTRY\s+)?%([\w.\-]+)\s*\(")
_TRIP_RE = re.compile(r"known_trip_count[^\d]*(\d+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\{(.*?)\}\}")
_GROUPS_ONE_RE = re.compile(r"replica_groups=\{\{([\d,]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_FREE_OPS = {"get-tuple-element", "bitcast", "parameter", "tuple",
             "after-all", "constant", "iota", "partition-id", "replica-id",
             "opt-barrier", "reshape", "transpose"}
_COLLECTIVE_KINDS = {"all-gather", "all-reduce", "reduce-scatter",
                     "all-to-all", "collective-permute", "ragged-all-to-all"}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(shape_str: str) -> List[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class OpInfo:
    name: str
    shape: str
    kind: str
    rest: str

    _args: Optional[List[str]] = None

    def args(self) -> List[str]:
        """Top-level call-argument op names (paren-matched)."""
        if self._args is None:
            depth = 1
            end = len(self.rest)
            for i, ch in enumerate(self.rest):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            self._args = _OPERAND_RE.findall(self.rest[:end])
        return self._args


@dataclass
class Block:
    name: str
    ops: List[OpInfo] = field(default_factory=list)
    symbols: Dict[str, str] = field(default_factory=dict)


def _parse_opline(line: str) -> Optional[OpInfo]:
    m = _NAME_EQ_RE.match(line)
    if not m:
        return None
    name, rest = m.group(1), m.group(2)
    if rest.startswith("("):
        depth = 0
        end = -1
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        if end < 0:
            return None
        shape, rem = rest[:end + 1], rest[end + 1:]
    else:
        m2 = _SINGLE_SHAPE_RE.match(rest)
        if not m2:
            return None
        shape, rem = m2.group(1), rest[m2.end():]
    m3 = _KIND_RE.match(rem)
    if not m3:
        return None
    return OpInfo(name=name, shape=shape, kind=m3.group(1), rest=m3.group(2))


def parse_blocks(hlo_text: str) -> Dict[str, Block]:
    blocks: Dict[str, Block] = {}
    current: Optional[Block] = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" "):            # block header / close
            hdr = _BLOCK_HDR_RE.match(line)
            if hdr and line.endswith("{"):
                current = Block(name=hdr.group(2))
                blocks[current.name] = current
                if hdr.group(1):
                    blocks["__entry__"] = current
            elif line.strip() == "}":
                current = None
            continue
        if current is None:
            continue
        op = _parse_opline(line)
        if op is None:
            continue
        current.ops.append(op)
        current.symbols[op.name] = op.shape
    return blocks


@dataclass
class Costs:
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0
    collectives: Dict[str, Dict] = field(
        default_factory=lambda: defaultdict(
            lambda: {"count": 0.0, "payload_bytes": 0.0, "wire_bytes": 0.0}))

    def add(self, other: "Costs", mult: float = 1.0):
        self.dot_flops += other.dot_flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        for k, v in other.collectives.items():
            s = self.collectives[k]
            s["count"] += v["count"] * mult
            s["payload_bytes"] += v["payload_bytes"] * mult
            s["wire_bytes"] += v["wire_bytes"] * mult


def _group_size(rest: str, total_devices: int) -> int:
    m = _GROUPS_V2_RE.search(rest)
    if m:
        return int(m.group(2))
    m = _GROUPS_ONE_RE.search(rest)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip()]
        return max(len(ids), 1)
    return total_devices


def _dot_flops(op: OpInfo, block: Block) -> float:
    result_elems = 1
    for d in _shape_dims(op.shape):
        result_elems *= d
    contract_elems = 1
    cm = _CONTRACT_RE.search(op.rest)
    if cm:
        operands = op.args()
        if operands:
            dims = _shape_dims(block.symbols.get(operands[0], ""))
            for idx_str in cm.group(1).split(","):
                if idx_str and int(idx_str) < len(dims):
                    contract_elems *= dims[int(idx_str)]
    return 2.0 * result_elems * contract_elems


def _operand_bytes(op: OpInfo, block: Block) -> float:
    return float(sum(_shape_bytes(block.symbols.get(a, ""))
                     for a in op.args()))


def _collective(op: OpInfo, total_devices: int) -> Optional[Tuple[str, Dict]]:
    kind = op.kind.replace("-start", "")
    if kind not in _COLLECTIVE_KINDS:
        return None
    payload = _shape_bytes(op.shape)
    if payload == 0:
        return None
    n = _group_size(op.rest, total_devices)
    if n <= 1:
        return None
    frac = (n - 1) / n
    if kind == "all-gather":
        wire = frac * payload
    elif kind == "reduce-scatter":
        wire = (n - 1) * payload
    elif kind == "all-reduce":
        wire = 2 * frac * payload
    elif kind in ("all-to-all", "ragged-all-to-all"):
        wire = frac * payload
    else:
        wire = payload
    return kind, {"count": 1.0, "payload_bytes": float(payload),
                  "wire_bytes": float(wire)}


_ALIAS_OPS = {"bitcast", "copy", "convert", "reshape", "transpose",
              "get-tuple-element", "broadcast"}


def _fusion_bytes(op: OpInfo, block: Block,
                  blocks: Dict[str, Block]) -> float:
    """Slice-aware byte accounting for one fusion call site.

    A fusion that only dynamic-slices a parameter reads the SLICE, not the
    whole buffer (the layer-scan weight/cache read); a fused in-place
    dynamic-update-slice writes only the update.  Alias-style ops (bitcast/
    copy/convert/reshape/transpose) are followed so a `ds(convert(param))`
    chain still counts as a sliced read — without this, decode steps get
    billed the whole KV-cache stack per layer (~80x overcount).
    """
    result_bytes = float(_shape_bytes(op.shape))
    callee_m = _CALLS_RE.search(op.rest)
    callee = blocks.get(callee_m.group(1)) if callee_m else None
    if callee is None:
        return result_bytes + _operand_bytes(op, block)

    param_shape: Dict[str, str] = {}
    for iop in callee.ops:
        if iop.kind == "parameter":
            param_shape[iop.name] = iop.shape

    # resolve alias chains: op name -> root param name (or None)
    root: Dict[str, Optional[str]] = {p: p for p in param_shape}

    def resolve(name: str) -> Optional[str]:
        seen = set()
        while name not in root:
            if name in seen:
                return None
            seen.add(name)
            found = None
            for iop in callee.ops:
                if iop.name == name:
                    if iop.kind in _ALIAS_OPS and iop.args():
                        found = iop.args()[0]
                    break
            if found is None:
                return None
            name = found
        return root[name]

    sliced_read: Dict[str, float] = {}
    dus_aliased: Dict[str, float] = {}
    consumed_whole: Dict[str, bool] = {p: False for p in param_shape}
    for iop in callee.ops:
        a = iop.args()
        if not a:
            continue
        if iop.kind in ("dynamic-slice", "slice"):
            p = resolve(a[0])
            if p is not None:
                sliced_read[p] = sliced_read.get(p, 0.0) + float(
                    _shape_bytes(iop.shape))
                continue
        if iop.kind == "dynamic-update-slice":
            p = resolve(a[0])
            if p is not None:
                upd = float(_shape_bytes(callee.symbols.get(a[1], "")))
                dus_aliased[p] = dus_aliased.get(p, 0.0) + upd
        # any other consumer that references a param directly (not through
        # a slice) reads it whole.  A dynamic-update-slice's TARGET operand
        # is written in place, not read — only its update/index operands
        # count as reads.
        if iop.kind in ("dynamic-slice", "slice", "parameter"):
            continue
        reads = a[1:] if iop.kind == "dynamic-update-slice" else a
        if iop.kind not in _ALIAS_OPS:
            for operand in reads:
                p = resolve(operand)
                if p is not None:
                    consumed_whole[p] = True

    total = 0.0
    aliased_result = False
    for pname, pshape in param_shape.items():
        if pname in dus_aliased and not consumed_whole.get(pname):
            total += 2.0 * dus_aliased[pname]  # read+write the update slot
            aliased_result = True
        elif pname in sliced_read and not consumed_whole.get(pname):
            total += sliced_read[pname]
        else:
            total += float(_shape_bytes(pshape))
    if not aliased_result:
        total += result_bytes
    return total


def analyze_block(block: Block, blocks: Dict[str, Block],
                  total_devices: int, memo: Dict[str, Costs],
                  stack=()) -> Costs:
    if block.name in memo:
        return memo[block.name]
    if block.name in stack:
        return Costs()
    costs = Costs()
    stack = stack + (block.name,)
    for op in block.ops:
        coll = _collective(op, total_devices)
        if coll is not None:
            kind, stats = coll
            s = costs.collectives[kind]
            for k, v in stats.items():
                s[k] += v
            continue
        if op.kind == "while":
            trip = 1
            tm = _TRIP_RE.search(op.rest)
            if tm:
                trip = int(tm.group(1))
            bm, cm = _BODY_RE.search(op.rest), _COND_RE.search(op.rest)
            if bm and bm.group(1) in blocks:
                costs.add(analyze_block(blocks[bm.group(1)], blocks,
                                        total_devices, memo, stack), trip)
            if cm and cm.group(1) in blocks:
                costs.add(analyze_block(blocks[cm.group(1)], blocks,
                                        total_devices, memo, stack),
                          trip + 1)
            continue  # loop state is aliased; no per-call bytes
        if op.kind == "fusion":
            costs.hbm_bytes += _fusion_bytes(op, block, blocks)
            cm = _CALLS_RE.search(op.rest)
            if cm and cm.group(1) in blocks:
                sub = analyze_block(blocks[cm.group(1)], blocks,
                                    total_devices, memo, stack)
                costs.dot_flops += sub.dot_flops
                for k, v in sub.collectives.items():
                    s = costs.collectives[k]
                    for kk in ("count", "payload_bytes", "wire_bytes"):
                        s[kk] += v[kk]
            continue
        if op.kind in ("call", "conditional", "async-start"):
            for callee in (_CALLS_RE.findall(op.rest)
                           + _BODY_RE.findall(op.rest)):
                if callee in blocks:
                    costs.add(analyze_block(blocks[callee], blocks,
                                            total_devices, memo, stack))
            continue
        if op.kind == "dot":
            costs.dot_flops += _dot_flops(op, block)
            costs.hbm_bytes += (_shape_bytes(op.shape)
                                + _operand_bytes(op, block))
            continue
        if op.kind == "dynamic-update-slice":
            a = op.args()
            upd = _shape_bytes(block.symbols.get(a[1], "")) if len(a) > 1 \
                else 0
            costs.hbm_bytes += 2.0 * upd
            continue
        if op.kind in ("dynamic-slice", "slice", "copy"):
            costs.hbm_bytes += 2.0 * _shape_bytes(op.shape)
            continue
        if op.kind in _FREE_OPS or op.kind.startswith("async"):
            continue
        # Bare elementwise / convert / broadcast / reduce ops: charge the
        # RESULT only.  The CPU backend fuses far less than the TPU backend;
        # charging operands too would bill every intermediate twice where
        # TPU XLA would have fused the chain (documented estimate policy).
        costs.hbm_bytes += _shape_bytes(op.shape)
    memo[block.name] = costs
    return costs


def analyze_hlo(hlo_text: str, total_devices: int) -> Dict:
    """Full-module per-device cost summary (trip-count corrected)."""
    blocks = parse_blocks(hlo_text)
    entry = blocks.get("__entry__")
    if entry is None:
        raise ValueError("no ENTRY computation found")
    memo: Dict[str, Costs] = {}
    costs = analyze_block(entry, blocks, total_devices, memo)
    coll = {k: dict(v) for k, v in costs.collectives.items()}
    return {
        "dot_flops": costs.dot_flops,
        "hbm_bytes": costs.hbm_bytes,
        "collectives": coll,
        "collective_wire_bytes": sum(v["wire_bytes"] for v in coll.values()),
        "collective_count": sum(v["count"] for v in coll.values()),
    }
