"""Continuous-batching scheduler: one weight stream serves every request.

PIPELOAD's dominant cost is streaming layer weights through the Loading
Agents, paid once per pipeline round — yet the single-request engine
spends each round on ONE sequence, so serving N users costs N full weight
streams per generated token.  The scheduler amortises the stream: each
round, layer ``k`` is loaded once, applied to the stacked single-token
hidden states of ALL in-flight requests (ragged positions — every request
sits at its own cache slot) and to the cache-capturing prefill of
requests admitted at this round boundary, then destroyed (``S_dest``).
Aggregate throughput scales with the in-flight count while the per-round
cost stays one weight stream.

Lifecycle (all transitions happen at round boundaries, except retirement
detection, which happens the instant a request's last token is sampled):

    submit() -> QUEUED -> [admission] -> PREFILLING -> DECODING -> DONE
                  ^                                       |
                  |            cache pages released       |
                  +------- (reusable at the SAME boundary)+

Memory protocol: every request's KV pages are charged to the engine's
``_Ledger`` — the same budget the streamed weights draw from.  Admission
is FIFO and blocks (requests wait in the queue) whenever the
post-admission decode floor

    other_bytes + pinned + all in-flight cache pages + one streaming layer

would exceed the budget, or the in-flight count would exceed
``max_inflight``.  Retirement is the cache analogue of ``S_dest``: the
round a request finishes, its pages are released immediately, so a queued
request can be admitted with the freed bytes at the very same boundary.

All caches are padded to ``max_total_len`` slots so stacked decode reuses
one jitted executable per batch size (padding past a request's position
is exactly masked out — softmax contributions are exact zeros — so
batched decoding is token-for-token identical to sequential runs).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import PipeloadEngine, _Ledger


@dataclasses.dataclass
class Request:
    """One generation request; scheduler-owned fields below ``rid``."""
    rid: int
    prompt: np.ndarray            # (S,) int token ids
    max_new_tokens: int
    arrival_round: int = 0        # earliest boundary it may be admitted at
    # -- scheduler state ------------------------------------------------
    tokens: List[int] = dataclasses.field(default_factory=list)
    generated: int = 0
    admitted_round: int = -1
    finished_round: int = -1
    cache_bytes: int = 0          # ledger reservation while in flight

    @property
    def done(self) -> bool:
        return self.generated >= self.max_new_tokens

    @property
    def pos(self) -> int:
        """Cache slot of the token about to be fed (current length - 1)."""
        return len(self.tokens) - 1


@dataclasses.dataclass
class ServeStats:
    rounds: int
    latency_s: float
    peak_bytes: int
    loads: int
    streamed_bytes: int
    new_tokens: int
    requests: int
    max_inflight_seen: int
    cache_bytes_peak: int
    events: List[Tuple[float, str, str]]
    # expert-streaming extras (0 for dense / whole-layer MoE serving)
    expert_hits: int = 0
    expert_misses: int = 0
    expert_evictions: int = 0
    expert_cache_bytes: int = 0
    unique_experts_per_round: float = 0.0

    @property
    def tokens_per_s(self) -> float:
        return self.new_tokens / self.latency_s if self.latency_s else 0.0

    @property
    def expert_hit_rate(self) -> float:
        """Fraction of expert activations served from the ExpertCache."""
        total = self.expert_hits + self.expert_misses
        return self.expert_hits / total if total else 0.0

    def event_log(self, kinds=None):
        return [e for e in self.events if kinds is None or e[1] in kinds]


class BatchScheduler:
    """Round-boundary continuous batching over a ``PipeloadEngine``.

    ``max_total_len`` bounds every request's prompt + generation length;
    it fixes the padded cache shape so batched rounds compile once per
    batch size.  ``max_inflight`` caps concurrency; the budget caps it
    further through admission control (capacity-first: the planner's
    ``plan_generate(..., max_inflight=...)`` picks the triple).
    """

    def __init__(self, engine: PipeloadEngine, *, max_inflight: int = 4,
                 max_total_len: int = 128):
        if engine.mode == "baseline":
            raise ValueError("continuous batching needs a pipelined mode "
                             "(pipeload / pipeswitch)")
        self.engine = engine
        self.max_inflight = max(1, max_inflight)
        self.max_total_len = max_total_len
        self.queue: List[Request] = []      # FIFO by (arrival_round, rid)
        self.inflight: List[Request] = []
        self.done: Dict[int, Request] = {}
        self.round = 0
        self._next_rid = 0
        # per-request-row stacked state (rows parallel to self.inflight)
        self._caches: Optional[Dict[str, dict]] = None   # leaves (R, T, ...)
        # serving-session accounting: ONE ledger across all rounds, so
        # weights, caches and the pinned window share a single budget
        self.ledger = _Ledger(engine.budget)
        self.events: List[Tuple[float, str, str]] = []
        self._t0 = time.perf_counter()
        self._cache_resident = 0
        self._cache_peak = 0
        self._max_seen = 0
        self._per_req_cache = (len(engine.layer_names)
                               * engine.cfg.cache_bytes(1, max_total_len))
        self._expert_snap = (engine.expert.snapshot()
                             if engine.expert is not None else None)
        # the widest fetch this workload can lock (a max-length prompt's
        # prefill): admission may shrink the ExpertCache to this, never
        # below, and submit-time feasibility reasons from it
        self._expert_floor = (
            engine.expert.working_set_bytes(max_total_len)
            if engine.expert is not None else None)

    # ------------------------------------------------------------------
    def submit(self, prompt, max_new_tokens: int,
               arrival_round: int = 0) -> int:
        """Queue a request; returns its id.

        Raises if the request could NEVER be admitted — a prompt +
        generation length beyond ``max_total_len``, or a cache
        reservation that exceeds the budget floor even with zero other
        requests in flight (admission would otherwise deadlock the FIFO
        queue head forever)."""
        prompt = np.asarray(prompt).reshape(-1)
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if len(prompt) + max_new_tokens > self.max_total_len:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new_tokens "
                f"({max_new_tokens}) exceeds max_total_len "
                f"{self.max_total_len}")
        self.engine._check_kv_budget(self._per_req_cache, inflight=1,
                                     expert_floor=self._expert_floor)
        req = Request(self._next_rid, prompt, max_new_tokens,
                      arrival_round=max(arrival_round, 0),
                      cache_bytes=self._per_req_cache)
        self._next_rid += 1
        self.queue.append(req)
        self.queue.sort(key=lambda r: (r.arrival_round, r.rid))
        return req.rid

    # ------------------------------------------------------------------
    def _fits(self, extra_cache: int) -> bool:
        """Would the decode floor still clear the budget after granting
        ``extra_cache`` more page bytes?  When the floor misses only
        because the ExpertCache holds the headroom, the cache shrinks
        first (LRU eviction releasing ledger bytes — the cache-side
        ``S_dest``), so a queued request's pages win over cold experts."""
        eng = self.engine
        if eng.budget is None:
            return True
        # until the expert engine is bound to THIS session's ledger the
        # live-reservation term does not exist (a binding left over from
        # an earlier run charged a dead ledger): reason from the
        # workload's expert floor so early admissions leave room for the
        # cache's minimum working set
        pre_bind = (eng.expert is not None
                    and not eng.expert.bound_to(self.ledger))
        kw = {"expert_floor": self._expert_floor} if pre_bind else {}
        floor = eng._kv_floor(self._cache_resident + extra_cache, **kw)
        if floor <= eng.budget:
            return True
        if eng.expert is not None and not pre_bind:
            if eng.expert.release_headroom(floor - eng.budget,
                                           floor=self._expert_floor):
                floor = eng._kv_floor(self._cache_resident + extra_cache)
                return floor <= eng.budget
        return False

    def _admit(self) -> List[Request]:
        """FIFO admission at the current boundary.  Strict head-of-line:
        all requests reserve the same padded cache size, so skipping the
        head could never help; blocking keeps arrival order fair and is
        deadlock-free (submit() rejected anything that can't fit alone,
        and in-flight requests always retire in finite rounds)."""
        admitted: List[Request] = []
        while (self.queue
               and self.queue[0].arrival_round <= self.round
               and len(self.inflight) + len(admitted) < self.max_inflight
               and self._fits(self.queue[0].cache_bytes)):
            req = self.queue.pop(0)
            # reserve the request's pages for its whole lifetime (never
            # blocks: _fits checked the floor, and at a boundary nothing
            # is streaming)
            self.ledger.acquire(req.cache_bytes, lambda: False)
            self._cache_resident += req.cache_bytes
            self._cache_peak = max(self._cache_peak, self._cache_resident)
            req.admitted_round = self.round
            req.tokens = list(map(int, req.prompt))
            self.events.append((time.perf_counter() - self._t0,
                                "admit", f"req{req.rid}"))
            admitted.append(req)
        return admitted

    def _retire(self, finished: List[Request]):
        """S_dest for cache pages: release the ledger bytes the moment a
        request completes so the next boundary can re-grant them."""
        for req in finished:
            self.ledger.release(req.cache_bytes)
            self._cache_resident -= req.cache_bytes
            req.finished_round = self.round
            self.done[req.rid] = req
            self.events.append((time.perf_counter() - self._t0,
                                "retire", f"req{req.rid}"))

    def _drop_rows(self, keep: List[int]):
        if self._caches is None:
            return
        if not keep:
            self._caches = None
            return
        idx = np.asarray(keep)
        self._caches = {name: jax.tree.map(lambda a: a[idx], c)
                        for name, c in self._caches.items()}

    def _append_rows(self, new_caches: List[Dict[str, dict]]):
        stacks = ([self._caches] if self._caches is not None else []) \
            + new_caches
        if not stacks:
            return
        if len(stacks) == 1:
            self._caches = stacks[0]
            return
        self._caches = {
            name: jax.tree.map(lambda *xs: jnp.concatenate(xs),
                               *(s[name] for s in stacks))
            for name in stacks[0]}

    # ------------------------------------------------------------------
    def step(self) -> bool:
        """One round boundary + (if there is work) one pipeline round.
        Returns False once every submitted request has retired."""
        eng = self.engine
        admitted = self._admit()
        if not self.inflight and not admitted:
            if not self.queue:
                return False
            # idle gap: fast-forward to the next arrival (no weight stream)
            self.round = max(self.round + 1,
                             min(r.arrival_round for r in self.queue))
            return True

        fns, t0 = eng.fns, self._t0
        self.events.append((time.perf_counter() - t0, "round",
                            str(self.round)))
        # ---- build the decode batch (stacked last tokens, ragged pos)
        dec_x = dec_pos = None
        if self.inflight:
            last = np.asarray([[r.tokens[-1]] for r in self.inflight],
                              np.int32)
            emb = eng._resident.get("embed")
            if emb is None:
                eng._ensure_aux(self.ledger, self.events, t0)
                emb = eng._resident["embed"]
            dec_x = fns["embed"](emb, jnp.asarray(last))
            dec_pos = jnp.asarray([r.pos for r in self.inflight], jnp.int32)
        # ---- build prefill jobs for this boundary's admissions
        pre_xs = []
        if admitted:
            eng._ensure_aux(self.ledger, self.events, t0)
            emb = eng._resident["embed"]
            for req in admitted:
                toks = jnp.asarray(np.asarray(req.tokens, np.int32)[None])
                pre_xs.append(fns["embed"](emb, toks))

        dec_x, caches, pre_outs, pre_caches = eng.run_batch_round(
            self.ledger, self.events, t0,
            decode_x=dec_x,
            decode_caches=self._caches,
            decode_pos=dec_pos,
            prefill_xs=pre_xs,
            prefill_total=self.max_total_len)
        self._caches = caches

        # ---- heads: one greedy token per request this round
        head = eng._resident["head"]
        if dec_x is not None:
            logits = fns["head"](head, dec_x)                  # (R, V)
            nxt = np.asarray(jnp.argmax(logits, -1))
            for row, req in enumerate(self.inflight):
                req.tokens.append(int(nxt[row]))
                req.generated += 1
        for i, req in enumerate(admitted):
            logits = fns["head"](head, pre_outs[i])            # (1, V)
            req.tokens.append(int(jnp.argmax(logits, -1)[0]))
            req.generated = 1

        # ---- merge admissions, then retire mid-stream finishers
        self._append_rows(pre_caches)
        self.inflight.extend(admitted)
        self._max_seen = max(self._max_seen, len(self.inflight))
        finished = [r for r in self.inflight if r.done]
        if finished:
            keep = [i for i, r in enumerate(self.inflight) if not r.done]
            self.inflight = [self.inflight[i] for i in keep]
            self._drop_rows(keep)
            self._retire(finished)
        self.round += 1
        return bool(self.inflight or self.queue)

    # ------------------------------------------------------------------
    def run(self) -> Tuple[Dict[int, np.ndarray], ServeStats]:
        """Drain the queue; returns ({rid: full token sequence}, stats)."""
        t_start = time.perf_counter()
        while self.step():
            pass
        lat = time.perf_counter() - t_start
        outs = {rid: np.asarray(r.tokens)
                for rid, r in sorted(self.done.items())}
        expert_kw = {}
        if self.engine.expert is not None:
            expert_kw = self.engine.expert.stats_since(self._expert_snap)
            self._expert_snap = self.engine.expert.snapshot()
        stats = ServeStats(
            rounds=self.round, latency_s=lat, peak_bytes=self.ledger.peak,
            loads=sum(1 for e in self.events if e[1] == "load_end"),
            streamed_bytes=self.engine._streamed(self.events),
            new_tokens=sum(r.generated for r in self.done.values()),
            requests=len(self.done), max_inflight_seen=self._max_seen,
            cache_bytes_peak=self._cache_peak, events=self.events,
            **expert_kw)
        return outs, stats

    # ------------------------------------------------------------------
    def warmup(self, prompt_lens=()) -> "BatchScheduler":
        """Pre-compile the serving executables: the batched decode fn for
        every batch size up to ``max_inflight`` (plus head/embed at those
        shapes) and the prefill fn per distinct prompt length — so the
        timed serving loop never stalls the Inference Agent on a jit
        compile while the Loading Agents race ahead."""
        eng = self.engine
        fns = eng.fns
        emb = eng._resident.get("embed") or eng._load("embed")
        head = eng._resident.get("head") or eng._load("head")
        w0 = eng._load(eng.layer_names[0])
        T = self.max_total_len
        for s in sorted(set(int(p) for p in prompt_lens)):
            x = fns["embed"](emb, jnp.zeros((1, s), jnp.int32))
            px, _ = eng._layer_cache(0, w0, x, T)
            fns["head"](head, px).block_until_ready()
        x1 = fns["embed"](emb, jnp.zeros((1, 1), jnp.int32))
        _, c1 = eng._layer_cache(0, w0, x1, T)
        for r in range(1, self.max_inflight + 1):
            cr = jax.tree.map(lambda a: jnp.concatenate([a] * r), c1)
            xr = fns["embed"](emb, jnp.zeros((r, 1), jnp.int32))
            dr, _ = eng._layer_decode(0, w0, xr, cr,
                                      jnp.zeros((r,), jnp.int32))
            fns["head"](head, dr).block_until_ready()
        del w0, emb, head
        if eng.expert is not None:
            # warmup's compile-time fetches are not serving traffic
            self._expert_snap = eng.expert.snapshot()
        self._t0 = time.perf_counter()
        return self
