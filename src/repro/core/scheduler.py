"""Continuous-batching scheduler: one weight stream serves every request.

PIPELOAD's dominant cost is streaming layer weights through the Loading
Agents, paid once per pipeline round — yet the single-request engine
spends each round on ONE sequence, so serving N users costs N full weight
streams per generated token.  The scheduler amortises the stream: each
round, layer ``k`` is loaded once, applied to the stacked single-token
hidden states of ALL in-flight requests (ragged positions — every request
sits at its own cache slot) and to the cache-capturing prefill of
requests admitted at this round boundary, then destroyed (``S_dest``).
Aggregate throughput scales with the in-flight count while the per-round
cost stays one weight stream.

Lifecycle (all transitions happen at round boundaries, except retirement
detection, which happens the instant a request's last token is sampled):

    submit() -> QUEUED -> [admission] -> PREFILLING -> DECODING -> DONE
                  ^                                       |
                  |            cache pages released       |
                  +------- (reusable at the SAME boundary)+

Memory protocol: every request's KV pages are charged to the engine's
``_Ledger`` — the same budget the streamed weights draw from.  Admission
is FIFO and blocks (requests wait in the queue) whenever the
post-admission decode floor

    other_bytes + pinned + all in-flight cache pages + one streaming layer

would exceed the budget, or the in-flight count would exceed
``max_inflight``.  Retirement is the cache analogue of ``S_dest``: the
round a request finishes, its pages are released immediately, so a queued
request can be admitted with the freed bytes at the very same boundary.

All caches are padded to ``max_total_len`` slots so stacked decode reuses
one jitted executable per batch size (padding past a request's position
is exactly masked out — softmax contributions are exact zeros — so
batched decoding is token-for-token identical to sequential runs).

**Paged mode** (``page_size`` set, core/kv_pages.py): instead of one
contiguous max-length reservation per request, the KV ledger bytes are
carved into fixed-size pages mapped through per-request block tables.

  * Admission charges only the request's PROMPT pages (pages a live
    sibling already mapped through the ``PrefixTree`` are refcount
    bumps — a fleet of requests behind one system prompt charges its
    prefix once), so the decode floor is pages-actually-mapped plus one
    page of headroom per in-flight request instead of
    ``inflight x max_total_len``.  A shared page holds K/V the
    sibling's prefill computed: bitwise what this request would have
    written when the prompts are the same LENGTH; a different length
    reuses values from a different prefill shape — equal up to float
    reassociation, so greedy can diverge at near-tie logits (the same
    caveat as preemption below).
  * Decode grows a request one page at a time as its position crosses a
    page boundary; writes into a shared page copy-on-write it first.
  * If growth cannot clear the floor, the YOUNGEST in-flight request is
    preempted — its pages are freed and it re-queues with its tokens so
    far (re-prefilled on re-admission); the oldest request always fits
    alone (submit() enforced it), so serving never deadlocks.  A
    re-prefill recomputes bit-identical K/V, but full-sequence prefill
    and incremental decode sum the softmax in different orders, so a
    preempted request's continuation can diverge from the sequential
    reference at float-tie tokens — preemption is a correctness-
    preserving overload valve, not part of the equivalence guarantee.
  * Retirement drops one reference per page: non-shared pages free (and
    re-enter the free list at the pool's high-water mark) the moment
    the request finishes; pages shared with a live sibling survive
    until the last sharer retires.

Physical page storage is one ``(rows, page_size, ...)`` array per layer
per cache leaf, sized once at construction (``max_inflight`` worst-case
tables + COW slack) so jitted decode shapes never change; the ledger
only ever charges MAPPED pages, and the decode attention gathers K/V
tiles through the block table (Pallas kernel under
``attn_impl="pallas"``, kernels/paged_decode.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import DraftModel, PipeloadEngine, _Ledger
from repro.core.kv_pages import BlockTable, PagePool, PrefixTree, pages_for


@dataclasses.dataclass
class Request:
    """One generation request; scheduler-owned fields below ``rid``."""
    rid: int
    prompt: np.ndarray            # (S,) int token ids
    max_new_tokens: int
    arrival_round: int = 0        # earliest boundary it may be admitted at
    # -- scheduler state ------------------------------------------------
    tokens: List[int] = dataclasses.field(default_factory=list)
    generated: int = 0
    admitted_round: int = -1
    finished_round: int = -1
    cache_bytes: int = 0          # ledger reservation while in flight
    table: Optional[BlockTable] = None   # paged mode: page ids + n_shared
    draft_pos: int = 0            # speculative: draft cache slots valid

    @property
    def done(self) -> bool:
        return self.generated >= self.max_new_tokens

    @property
    def pos(self) -> int:
        """Cache slot of the token about to be fed (current length - 1)."""
        return len(self.tokens) - 1


@dataclasses.dataclass
class ServeStats:
    rounds: int
    latency_s: float
    peak_bytes: int
    loads: int
    streamed_bytes: int
    new_tokens: int
    requests: int
    max_inflight_seen: int
    cache_bytes_peak: int
    events: List[Tuple[float, str, str]]
    # expert-streaming extras (0 for dense / whole-layer MoE serving)
    expert_hits: int = 0
    expert_misses: int = 0
    expert_evictions: int = 0
    expert_cache_bytes: int = 0
    unique_experts_per_round: float = 0.0
    # reproducibility: the RNG seed the serving trace was generated with
    # (None when the caller did not thread one)
    seed: Optional[int] = None
    # paged-KV extras (0 / dense defaults when page_size is unset)
    page_size: int = 0
    pages_allocated: int = 0       # pool allocs (fresh + free-list reuse)
    page_reuses: int = 0           # allocs served from the free list
    prefix_hit_pages: int = 0      # prompt pages shared via the PrefixTree
    cow_copies: int = 0            # copy-on-write page swaps
    preemptions: int = 0           # requests bounced back to the queue
    pool_pages_peak: int = 0       # high-water MAPPED page count
    # speculative-decoding extras (0 when spec_depth is unset)
    spec_depth: int = 0            # draft tokens proposed per round
    spec_rounds: int = 0           # verify rounds executed
    draft_tokens: int = 0          # proposals the draft emitted
    accepted_tokens: int = 0       # proposals the target committed

    @property
    def tokens_per_s(self) -> float:
        return self.new_tokens / self.latency_s if self.latency_s else 0.0

    @property
    def expert_hit_rate(self) -> float:
        """Fraction of expert activations served from the ExpertCache."""
        total = self.expert_hits + self.expert_misses
        return self.expert_hits / total if total else 0.0

    @property
    def acceptance_rate(self) -> float:
        """Fraction of draft proposals the target accepted."""
        return (self.accepted_tokens / self.draft_tokens
                if self.draft_tokens else 0.0)

    def event_log(self, kinds=None):
        return [e for e in self.events if kinds is None or e[1] in kinds]


class BatchScheduler:
    """Round-boundary continuous batching over a ``PipeloadEngine``.

    ``max_total_len`` bounds every request's prompt + generation length;
    it fixes the padded cache shape so batched rounds compile once per
    batch size.  ``max_inflight`` caps concurrency; the budget caps it
    further through admission control (capacity-first: the planner's
    ``plan_generate(..., max_inflight=...)`` picks the triple).
    """

    def __init__(self, engine: PipeloadEngine, *, max_inflight: int = 4,
                 max_total_len: int = 128,
                 page_size: Optional[int] = None,
                 prefix_cache: bool = True,
                 seed: Optional[int] = None,
                 draft: Optional[DraftModel] = None,
                 spec_depth: int = 0):
        if engine.mode == "baseline":
            raise ValueError("continuous batching needs a pipelined mode "
                             "(pipeload / pipeswitch)")
        self.engine = engine
        self.max_inflight = max(1, max_inflight)
        self.max_total_len = max_total_len
        # paged KV mode: explicit page_size wins, else inherit the
        # engine's (the planner threads its page-size pick through the
        # engine); 0/None = dense per-request reservations
        if page_size is None:
            page_size = engine.page_size
        self.page_size = page_size if page_size and page_size > 0 else None
        # speculative serving: a pinned draft proposes spec_depth tokens
        # per round per request and one stacked verify round scores them
        self.spec_depth = max(0, spec_depth) if draft is not None else 0
        self.draft = draft if self.spec_depth else None
        if self.spec_depth:
            if not self.page_size:
                raise ValueError(
                    "speculative serving needs paged KV (the verify "
                    "window rides the block tables); set page_size")
            if "layer_verify_paged" not in engine.fns:
                raise ValueError(
                    "engine's model fns lack layer_verify_paged "
                    "(speculative verify); architecture unsupported")
        self.seed = seed
        self.queue: List[Request] = []      # FIFO by (arrival_round, rid)
        self.inflight: List[Request] = []
        self.done: Dict[int, Request] = {}
        self.round = 0
        self._next_rid = 0
        # per-request-row stacked state (rows parallel to self.inflight)
        self._caches: Optional[Dict[str, dict]] = None   # leaves (R, T, ...)
        # serving-session accounting: ONE ledger across all rounds, so
        # weights, caches and the pinned window share a single budget
        self.ledger = _Ledger(engine.budget)
        self.events: List[Tuple[float, str, str]] = []
        self._t0 = time.perf_counter()
        self._cache_resident = 0
        self._cache_peak = 0
        self._max_seen = 0
        self._per_req_cache = (len(engine.layer_names)
                               * engine.cfg.cache_bytes(1, max_total_len))
        # ---- paged-KV state (None/unused in dense mode) ----
        self.pool: Optional[PagePool] = None
        self.tree: Optional[PrefixTree] = None
        self._pools: Optional[Dict[str, dict]] = None  # layer -> (P, ps, ..)
        self.preemptions = 0
        if self.page_size:
            if engine.expert is not None:
                raise ValueError(
                    "paged KV serving is not supported with expert-split "
                    "MoE checkpoints yet; repartition whole-layer or drop "
                    "page_size")
            ps = self.page_size
            # speculative verify writes K/V for the whole window
            # [pos, pos + depth]; the last round's window can run past
            # max_total_len, so tables carry the overhang slots (the
            # extra K/V is masked garbage, freed at retirement)
            self._nb = pages_for(max_total_len + self.spec_depth, ps)
            self._page_bytes = (len(engine.layer_names)
                                * engine.cfg.cache_bytes(1, ps))
            self.pool = PagePool(ps, self._page_bytes, self.ledger)
            self.tree = PrefixTree(ps) if prefix_cache else None
            # fixed physical pool rows: worst-case tables + COW slack,
            # sized ONCE so jitted decode shapes never change (the
            # ledger charges only MAPPED pages; these rows are buffer)
            self._pool_rows = self.max_inflight * self._nb + 2
        # ---- speculative state (draft pinned for the whole session) ----
        self._draft_caches: Optional[Dict[str, dict]] = None  # (R, T, ...)
        self._spec_rounds = 0
        self._draft_tokens = 0
        self._accepted_tokens = 0
        if self.spec_depth:
            # per-request growth headroom: a verify round can map up to
            # a full window of fresh pages at once
            self._req_headroom = pages_for(self.spec_depth + 1,
                                           self.page_size)
            self._draft_total = max_total_len + self.spec_depth
            self._draft_cache_bytes = self.draft.cache_bytes(
                1, self._draft_total)
            self.draft.pin(self.ledger)   # resident for the session
        else:
            self._req_headroom = 1 if self.page_size else 0
        self._draft_pinned = self.spec_depth > 0
        self._expert_snap = (engine.expert.snapshot()
                             if engine.expert is not None else None)
        # the widest fetch this workload can lock (a max-length prompt's
        # prefill): admission may shrink the ExpertCache to this, never
        # below, and submit-time feasibility reasons from it
        self._expert_floor = (
            engine.expert.working_set_bytes(max_total_len)
            if engine.expert is not None else None)

    # ------------------------------------------------------------------
    def close(self):
        """End the serving session: unpin the draft's ledger bytes and
        tear down the engine's prefetch runtime (worker + drainer
        threads).  Idempotent."""
        if self.draft is not None and self._draft_pinned:
            self.draft.unpin(self.ledger)
            self._draft_pinned = False
        self.engine.close()

    def __enter__(self) -> "BatchScheduler":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------------
    def submit(self, prompt, max_new_tokens: int,
               arrival_round: int = 0) -> int:
        """Queue a request; returns its id.

        Raises if the request could NEVER be admitted — a prompt +
        generation length beyond ``max_total_len``, or a cache
        reservation that exceeds the budget floor even with zero other
        requests in flight (admission would otherwise deadlock the FIFO
        queue head forever)."""
        prompt = np.asarray(prompt).reshape(-1)
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if len(prompt) + max_new_tokens > self.max_total_len:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new_tokens "
                f"({max_new_tokens}) exceeds max_total_len "
                f"{self.max_total_len}")
        if self.page_size:
            # worst case = every page of its final length, unshared,
            # PLUS the admission headroom (_fits_paged charges it per
            # in-flight request — without it a request whose total fits
            # the budget exactly would be accepted here yet never
            # admitted, spinning run() forever).  This is the guarantee
            # growth-with-preemption leans on: a request ALONE can
            # always map its next page (its whole verify window, in
            # speculative mode — where the draft and its cache row are
            # charged as extra residents too).
            worst = ((pages_for(len(prompt) + max_new_tokens
                                + self.spec_depth, self.page_size)
                      + self._req_headroom) * self._page_bytes)
            self.engine._check_kv_budget(
                worst, inflight=1, extra_resident=self._spec_resident(1))
            per_req = worst
        else:
            self.engine._check_kv_budget(self._per_req_cache, inflight=1,
                                         expert_floor=self._expert_floor)
            per_req = self._per_req_cache
        req = Request(self._next_rid, prompt, max_new_tokens,
                      arrival_round=max(arrival_round, 0),
                      cache_bytes=per_req)
        self._next_rid += 1
        self.queue.append(req)
        self.queue.sort(key=lambda r: (r.arrival_round, r.rid))
        return req.rid

    # ------------------------------------------------------------------
    def _fits(self, extra_cache: int) -> bool:
        """Would the decode floor still clear the budget after granting
        ``extra_cache`` more page bytes?  When the floor misses only
        because the ExpertCache holds the headroom, the cache shrinks
        first (LRU eviction releasing ledger bytes — the cache-side
        ``S_dest``), so a queued request's pages win over cold experts."""
        eng = self.engine
        if eng.budget is None:
            return True
        # until the expert engine is bound to THIS session's ledger the
        # live-reservation term does not exist (a binding left over from
        # an earlier run charged a dead ledger): reason from the
        # workload's expert floor so early admissions leave room for the
        # cache's minimum working set
        pre_bind = (eng.expert is not None
                    and not eng.expert.bound_to(self.ledger))
        kw = {"expert_floor": self._expert_floor} if pre_bind else {}
        floor = eng._kv_floor(self._cache_resident + extra_cache, **kw)
        if floor <= eng.budget:
            return True
        if eng.expert is not None and not pre_bind:
            if eng.expert.release_headroom(floor - eng.budget,
                                           floor=self._expert_floor):
                floor = eng._kv_floor(self._cache_resident + extra_cache)
                return floor <= eng.budget
        return False

    # ---- paged-mode admission / growth / preemption ------------------
    def _spec_resident(self, inflight: int) -> int:
        """Speculative mode's extra resident bytes: the pinned draft
        plus one dense draft-cache row per in-flight request."""
        if not self.spec_depth:
            return 0
        return (self.draft.total_bytes
                + inflight * self._draft_cache_bytes)

    def _fits_paged(self, extra_pages: int, inflight_after: int) -> bool:
        """Paged decode floor: pages actually mapped, plus the new pages,
        plus growth headroom per in-flight request (one page — a whole
        verify window of pages in speculative mode, where the pinned
        draft and its cache rows are charged as extra residents too)."""
        eng = self.engine
        if eng.budget is None:
            return True
        cache = ((self.pool.mapped_pages + extra_pages
                  + inflight_after * self._req_headroom)
                 * self._page_bytes)
        return (eng._kv_floor(
            cache, extra_resident=self._spec_resident(inflight_after))
            <= eng.budget)

    def _admit_one_paged(self, req: Request, inflight_after: int) -> bool:
        """Map the request's prompt pages (prefix-tree hits are refcount
        bumps, charged once across the fleet); False = does not fit at
        this boundary."""
        toks = req.tokens or [int(t) for t in req.prompt]
        n_pages = pages_for(len(toks), self.page_size)
        walk = self.tree.walk(toks) if self.tree is not None else None
        shared = len(walk[0]) if walk is not None else 0
        if not self._fits_paged(n_pages - shared, inflight_after):
            return False
        if self.tree is not None:
            pids, n_shared = self.tree.insert(toks, self.pool, walk=walk)
        else:
            pids, n_shared = [self.pool.alloc()
                              for _ in range(n_pages)], 0
        req.table = BlockTable(pids, n_shared)
        req.tokens = toks
        if self.spec_depth:
            # the request's dense draft-cache row lives as long as the
            # request is in flight (never blocks: _fits_paged charged it
            # via _spec_resident, and at a boundary nothing streams)
            self.ledger.acquire(self._draft_cache_bytes, lambda: False)
        return True

    def _preempt(self, victim: Request) -> None:
        """Bounce ``victim`` back to the queue, freeing its non-shared
        pages; it re-prefills from its tokens so far on re-admission."""
        victim.table.release_all(self.pool, self.tree)
        if self.spec_depth:
            idx = self.inflight.index(victim)
            self._draft_caches = self._rows_keep(
                self._draft_caches,
                [i for i in range(len(self.inflight)) if i != idx])
            self.ledger.release(self._draft_cache_bytes)
        self.inflight.remove(victim)
        victim.admitted_round = -1
        victim.arrival_round = self.round
        self.queue.append(victim)
        self.queue.sort(key=lambda r: (r.arrival_round, r.rid))
        self.preemptions += 1
        self.events.append((time.perf_counter() - self._t0,
                            "preempt", f"req{victim.rid}"))

    def _alloc_with_preemption(self, req: Request) -> Optional[int]:
        """Map one more page for ``req``, preempting the YOUNGEST
        in-flight request — possibly ``req`` itself — while the floor
        would not clear (strict age order: an older request's progress
        is never sacrificed for a younger grower).  Returns None when
        ``req`` was the victim; otherwise always succeeds — once ``req``
        is alone, submit() guaranteed its worst case fits."""
        while not self._fits_paged(1, 0) and len(self.inflight) > 1:
            victim = self.inflight[-1]        # admission-ordered: youngest
            self._preempt(victim)
            if victim is req:
                return None
        pid = self.pool.alloc()
        if pid >= self._pool_rows:
            raise RuntimeError(
                f"page pool overflow: page {pid} >= {self._pool_rows} "
                f"physical rows (max_inflight x table width + COW slack)"
            )   # unreachable: admission + growth bound live pages
        return pid

    def _grow_pages(self):
        """Round boundary, before admission: map each in-flight
        request's WRITE RANGE — the one page its next token lands in,
        or, in speculative mode, every page the verify window
        [pos, pos + depth] touches — growing across page boundaries and
        copy-on-writing shared pages before their first divergent
        write."""
        if not self.inflight:
            return
        cow: List[Tuple[Request, int, int]] = []
        for req in list(self.inflight):
            if req not in self.inflight:    # preempted by an earlier grower
                continue
            t = req.table
            lo = req.pos // self.page_size
            hi = (req.pos + self.spec_depth) // self.page_size
            while len(t.pages) <= hi:
                pid = self._alloc_with_preemption(req)
                if pid is None:             # req itself was the victim
                    break
                t.pages.append(pid)
            for pidx in range(lo, hi + 1):
                if req not in self.inflight:
                    break
                pid = t.pages[pidx]
                if not self.pool.is_shared(pid):
                    continue
                new = self._alloc_with_preemption(req)
                if new is None:             # req preempted: refs already
                    break                   # dropped by release_all
                cow.append((req, pid, new))
                # usually the sibling keeps the old page — but if the
                # COW alloc preempted that sibling, this drop is the
                # LAST reference and the tree node must go with it
                if self.pool.release(pid) and self.tree is not None:
                    self.tree.forget(pid)
                t.pages[pidx] = new
        # drop copies whose OWNER was preempted after queuing them (its
        # freed target id may already be re-mapped by a later grower —
        # a stale entry would make the batched scatter write the same
        # destination twice), then copy page contents old -> new in one
        # batched update per leaf
        cow = [(o, n) for r, o, n in cow if r in self.inflight]
        self.pool.stats.cow_copies += len(cow)   # copies actually made
        if cow:
            old = jnp.asarray([o for o, _ in cow], jnp.int32)
            new = jnp.asarray([n for _, n in cow], jnp.int32)
            self._pools = {
                name: jax.tree.map(lambda a: a.at[new].set(a[old]), c)
                for name, c in self._pools.items()}

    def _pool_like(self, cache):
        """Zeroed physical page array(s) shaped for ``cache`` leaves:
        (B, T, ...) -> (pool_rows, page_size, ...).  The ONE place the
        pool layout is defined — warmup compiles against arrays built
        here, so serving shapes always match the warmed executables."""
        return jax.tree.map(
            lambda a: jnp.zeros(
                (self._pool_rows, self.page_size) + a.shape[2:], a.dtype),
            cache)

    def _ensure_pool_arrays(self, template: Dict[str, dict]):
        """Create the physical page arrays from the first prefill's
        cache shapes: one (rows, page_size, ...) array per layer per
        cache leaf, sized once (see class docstring)."""
        if self._pools is not None:
            return
        self._pools = {name: self._pool_like(c)
                       for name, c in template.items()}

    def _scatter_prefills(self, reqs: List[Request],
                          caches: List[Dict[str, dict]]):
        """Write the boundary's captured prefill caches into each
        request's OWNED pages — ONE batched scatter per layer per cache
        leaf (a per-request loop would copy the whole physical pool
        once per update).  Shared prefix pages are skipped: a sibling
        already wrote identical K/V, and a shared partial page may hold
        the sibling's generated tokens past this prompt (masked by the
        valid-length mask, clobbered by nothing)."""
        ps = self.page_size
        owned = [(r, c) for r, c in zip(reqs, caches)
                 if len(r.table.pages) > r.table.n_shared]
        if not owned:
            return
        self._ensure_pool_arrays(owned[0][1])
        pids = jnp.asarray([pid for r, _ in owned
                            for pid in r.table.pages[r.table.n_shared:]],
                           jnp.int32)

        def rows(a, t):
            lo, hi = t.n_shared * ps, len(t.pages) * ps
            return a[0, lo:hi].reshape((len(t.pages) - t.n_shared, ps)
                                       + a.shape[2:])

        for name in self._pools:
            blocks = [jax.tree.map(lambda a, t=r.table: rows(a, t), c[name])
                      for r, c in owned]
            stacked = (blocks[0] if len(blocks) == 1 else jax.tree.map(
                lambda *xs: jnp.concatenate(xs), *blocks))
            self._pools[name] = jax.tree.map(
                lambda leaf, rr: leaf.at[pids].set(rr.astype(leaf.dtype)),
                self._pools[name], stacked)

    def _admit(self) -> List[Request]:
        """FIFO admission at the current boundary.  Strict head-of-line:
        skipping the head could never help (dense mode reserves one
        padded size for everyone; paged mode's head is also the next to
        shrink via sharing); blocking keeps arrival order fair and is
        deadlock-free (submit() rejected anything that can't fit alone,
        and in-flight requests always retire in finite rounds)."""
        admitted: List[Request] = []
        while (self.queue
               and self.queue[0].arrival_round <= self.round
               and len(self.inflight) + len(admitted) < self.max_inflight):
            req = self.queue[0]
            if self.page_size:
                if not self._admit_one_paged(
                        req, len(self.inflight) + len(admitted) + 1):
                    break
            else:
                if not self._fits(req.cache_bytes):
                    break
                # reserve the request's pages for its whole lifetime
                # (never blocks: _fits checked the floor, and at a
                # boundary nothing is streaming)
                self.ledger.acquire(req.cache_bytes, lambda: False)
                self._cache_resident += req.cache_bytes
                self._cache_peak = max(self._cache_peak,
                                       self._cache_resident)
                req.tokens = list(map(int, req.prompt))
            self.queue.pop(0)
            req.admitted_round = self.round
            self.events.append((time.perf_counter() - self._t0,
                                "admit", f"req{req.rid}"))
            admitted.append(req)
        return admitted

    def _retire(self, finished: List[Request]):
        """S_dest for cache pages: release the ledger bytes the moment a
        request completes so the next boundary can re-grant them.  Paged
        mode drops one reference per page — pages shared with a live
        sibling survive until the LAST sharer retires (exact-drain at
        page granularity)."""
        for req in finished:
            if self.page_size:
                req.table.release_all(self.pool, self.tree)
                if self.spec_depth:
                    self.ledger.release(self._draft_cache_bytes)
            else:
                self.ledger.release(req.cache_bytes)
                self._cache_resident -= req.cache_bytes
            req.finished_round = self.round
            self.done[req.rid] = req
            self.events.append((time.perf_counter() - self._t0,
                                "retire", f"req{req.rid}"))

    def _drop_rows(self, keep: List[int]):
        if self._caches is None:
            return
        if not keep:
            self._caches = None
            return
        idx = np.asarray(keep)
        self._caches = {name: jax.tree.map(lambda a: a[idx], c)
                        for name, c in self._caches.items()}

    def _append_rows(self, new_caches: List[Dict[str, dict]]):
        stacks = ([self._caches] if self._caches is not None else []) \
            + new_caches
        if not stacks:
            return
        if len(stacks) == 1:
            self._caches = stacks[0]
            return
        self._caches = {
            name: jax.tree.map(lambda *xs: jnp.concatenate(xs),
                               *(s[name] for s in stacks))
            for name in stacks[0]}

    # ---- speculative drafting (rows parallel to self.inflight) -------
    @staticmethod
    def _rows_keep(stack, keep: List[int]):
        """Row-filter a stacked cache dict (leaves (R, T, ...))."""
        if stack is None or not keep:
            return None
        idx = np.asarray(keep)
        return {name: jax.tree.map(lambda a: a[idx], c)
                for name, c in stack.items()}

    @staticmethod
    def _rows_concat(stacks):
        """Concatenate stacked cache dicts along the row dim."""
        stacks = [s for s in stacks if s is not None]
        if not stacks:
            return None
        if len(stacks) == 1:
            return stacks[0]
        return {name: jax.tree.map(lambda *xs: jnp.concatenate(xs),
                                   *(s[name] for s in stacks))
                for name in stacks[0]}

    def _draft_propose(self) -> List[List[int]]:
        """One stacked draft pass over every in-flight request: catch the
        draft cache up to the committed tokens, then chain ``spec_depth``
        greedy proposals per row.

        Rows may need different catch-up counts (1 after a partial
        accept, 2 after a full accept — the bonus token was never drafted).
        The batch feeds every row its last ``C = max(gap)`` committed
        tokens at their own slots: rows with a smaller gap RE-feed tokens
        already in their draft cache, overwriting those slots with
        bitwise-identical K/V (K/V depend only on token and position), so
        one jitted executable serves the ragged batch."""
        reqs = self.inflight
        c = max(len(r.tokens) - r.draft_pos for r in reqs)
        logits = None
        for i in range(c):
            toks = np.asarray([[r.tokens[len(r.tokens) - c + i]]
                               for r in reqs], np.int32)
            pos = np.asarray([len(r.tokens) - c + i for r in reqs],
                             np.int32)
            logits, self._draft_caches = self.draft.decode_batch(
                toks, self._draft_caches, pos)
        for r in reqs:
            r.draft_pos = len(r.tokens)
        props: List[List[int]] = [[] for _ in reqs]
        cur = np.asarray(jnp.argmax(logits, -1), np.int32)      # (R,)
        for j in range(self.spec_depth):
            for i in range(len(reqs)):
                props[i].append(int(cur[i]))
            if j < self.spec_depth - 1:
                pos = np.asarray([len(r.tokens) + j for r in reqs],
                                 np.int32)
                logits, self._draft_caches = self.draft.decode_batch(
                    cur[:, None], self._draft_caches, pos)
                cur = np.asarray(jnp.argmax(logits, -1), np.int32)
        return props

    # ------------------------------------------------------------------
    def step(self) -> bool:
        """One round boundary + (if there is work) one pipeline round.
        Returns False once every submitted request has retired."""
        eng = self.engine
        if self.page_size:
            # map every decoder's write page first (may preempt), THEN
            # admit into whatever room is left
            self._grow_pages()
        admitted = self._admit()
        if not self.inflight and not admitted:
            if not self.queue:
                return False
            # idle gap: fast-forward to the next arrival (no weight stream)
            self.round = max(self.round + 1,
                             min(r.arrival_round for r in self.queue))
            return True

        fns, t0 = eng.fns, self._t0
        self.events.append((time.perf_counter() - t0, "round",
                            str(self.round)))
        # ---- build the decode batch (stacked last tokens, ragged pos;
        # speculative mode widens each row to its verify window
        # [last committed token, draft proposals...])
        dec_x = dec_pos = props = None
        if self.inflight:
            emb = eng._resident.get("embed")
            if emb is None:
                eng._ensure_aux(self.ledger, self.events, t0)
                emb = eng._resident["embed"]
            if self.spec_depth:
                props = self._draft_propose()
                last = np.asarray(
                    [[r.tokens[-1]] + props[i]
                     for i, r in enumerate(self.inflight)], np.int32)
            else:
                last = np.asarray([[r.tokens[-1]] for r in self.inflight],
                                  np.int32)
            dec_x = fns["embed"](emb, jnp.asarray(last))
            dec_pos = jnp.asarray([r.pos for r in self.inflight], jnp.int32)
        # ---- build prefill jobs for this boundary's admissions
        pre_xs = []
        if admitted:
            eng._ensure_aux(self.ledger, self.events, t0)
            emb = eng._resident["embed"]
            for req in admitted:
                toks = jnp.asarray(np.asarray(req.tokens, np.int32)[None])
                pre_xs.append(fns["embed"](emb, toks))

        if self.page_size:
            # stacked block tables, padded with page 0 (masked slots)
            dec_tables = None
            if dec_x is not None:
                tb = np.zeros((len(self.inflight), self._nb), np.int32)
                for i, r in enumerate(self.inflight):
                    tb[i, :len(r.table.pages)] = r.table.pages
                dec_tables = jnp.asarray(tb)
            dec_x, pools, pre_outs, pre_caches = eng.run_batch_round(
                self.ledger, self.events, t0,
                decode_x=dec_x,
                decode_pos=dec_pos,
                prefill_xs=pre_xs,
                prefill_total=self._nb * self.page_size,
                paged_pools=(self._pools if dec_x is not None else None),
                decode_tables=dec_tables)
            if dec_x is not None:
                self._pools = pools
            self._scatter_prefills(admitted, pre_caches)
        else:
            dec_x, caches, pre_outs, pre_caches = eng.run_batch_round(
                self.ledger, self.events, t0,
                decode_x=dec_x,
                decode_caches=self._caches,
                decode_pos=dec_pos,
                prefill_xs=pre_xs,
                prefill_total=self.max_total_len)
            self._caches = caches

        # ---- heads: one greedy token per request this round — or, in
        # speculative mode, the accepted proposal prefix plus the
        # target's bonus token
        head = eng._resident["head"]
        if dec_x is not None and self.spec_depth:
            logits = fns["head_all"](head, dec_x)              # (R, W, V)
            greedy = np.asarray(jnp.argmax(logits, -1))        # (R, W)
            self._spec_rounds += 1
            for row, req in enumerate(self.inflight):
                prop = props[row]
                a = 0
                while a < len(prop) and prop[a] == int(greedy[row, a]):
                    a += 1
                # accepted prefix + the target's token after it, clamped
                # to the request's remaining token allowance (any prefix
                # of the commit list is the exact greedy continuation)
                remaining = req.max_new_tokens - req.generated
                commit = (prop[:a] + [int(greedy[row, a])])[:remaining]
                old_len = len(req.tokens)
                req.tokens.extend(commit)
                req.generated += len(commit)
                # draft slots old_len..old_len+depth-2 hold the proposal
                # K/V; they stay valid while the proposal matched the
                # committed token
                req.draft_pos = old_len + max(
                    0, min(a, self.spec_depth - 1, len(commit)))
                # count only proposals that could possibly commit — the
                # window always spans the full depth (uniform jitted
                # shapes), but near max_new_tokens the tail is clamped
                # away and should not read as rejections
                self._draft_tokens += min(len(prop), remaining)
                self._accepted_tokens += min(a, remaining)
        elif dec_x is not None:
            logits = fns["head"](head, dec_x)                  # (R, V)
            nxt = np.asarray(jnp.argmax(logits, -1))
            for row, req in enumerate(self.inflight):
                req.tokens.append(int(nxt[row]))
                req.generated += 1
        for i, req in enumerate(admitted):
            logits = fns["head"](head, pre_outs[i])            # (1, V)
            req.tokens.append(int(jnp.argmax(logits, -1)[0]))
            req.generated += 1           # re-prefills resume, not reset
        if self.spec_depth and admitted:
            # seed each admission's draft-cache row from its own prompt
            # prefill (the generated first token is caught up next round)
            rows = []
            for req in admitted:
                toks = jnp.asarray(np.asarray(req.tokens[:-1],
                                              np.int32)[None])
                _, dc = self.draft.prefill(toks, self._draft_total)
                req.draft_pos = len(req.tokens) - 1
                rows.append(dc)
            self._draft_caches = self._rows_concat(
                [self._draft_caches] + rows)

        # ---- merge admissions, then retire mid-stream finishers
        if not self.page_size:
            self._append_rows(pre_caches)
        self.inflight.extend(admitted)
        self._max_seen = max(self._max_seen, len(self.inflight))
        finished = [r for r in self.inflight if r.done]
        if finished:
            keep = [i for i, r in enumerate(self.inflight) if not r.done]
            self.inflight = [self.inflight[i] for i in keep]
            if not self.page_size:       # paged rows live in the pool
                self._drop_rows(keep)
            elif self.spec_depth:
                self._draft_caches = self._rows_keep(self._draft_caches,
                                                     keep)
            self._retire(finished)
        self.round += 1
        return bool(self.inflight or self.queue)

    # ------------------------------------------------------------------
    def run(self) -> Tuple[Dict[int, np.ndarray], ServeStats]:
        """Drain the queue; returns ({rid: full token sequence}, stats)."""
        t_start = time.perf_counter()
        while self.step():
            pass
        lat = time.perf_counter() - t_start
        outs = {rid: np.asarray(r.tokens)
                for rid, r in sorted(self.done.items())}
        expert_kw = {}
        if self.engine.expert is not None:
            expert_kw = self.engine.expert.stats_since(self._expert_snap)
            self._expert_snap = self.engine.expert.snapshot()
        paged_kw = {}
        if self.page_size:
            paged_kw = dict(
                page_size=self.page_size,
                pages_allocated=self.pool.stats.allocs,
                page_reuses=self.pool.stats.reuses,
                prefix_hit_pages=self.tree.hits if self.tree else 0,
                cow_copies=self.pool.stats.cow_copies,
                preemptions=self.preemptions,
                pool_pages_peak=self.pool.mapped_peak)
        spec_kw = {}
        if self.spec_depth:
            spec_kw = dict(spec_depth=self.spec_depth,
                           spec_rounds=self._spec_rounds,
                           draft_tokens=self._draft_tokens,
                           accepted_tokens=self._accepted_tokens)
        # paged mode: the pool records the true mapped high-water on
        # every alloc (an end-of-boundary sample would miss pages a
        # mid-loop preemption freed again)
        cache_peak = (self.pool.mapped_peak_bytes if self.page_size
                      else self._cache_peak)
        stats = ServeStats(
            rounds=self.round, latency_s=lat, peak_bytes=self.ledger.peak,
            loads=sum(1 for e in self.events if e[1] == "load_end"),
            streamed_bytes=self.engine._streamed(self.events),
            new_tokens=sum(r.generated for r in self.done.values()),
            requests=len(self.done), max_inflight_seen=self._max_seen,
            cache_bytes_peak=cache_peak, events=self.events,
            seed=self.seed, **paged_kw, **expert_kw, **spec_kw)
        return outs, stats

    # ------------------------------------------------------------------
    def warmup(self, prompt_lens=()) -> "BatchScheduler":
        """Pre-compile the serving executables: the batched decode fn for
        every batch size up to ``max_inflight`` (plus head/embed at those
        shapes) and the prefill fn per distinct prompt length — so the
        timed serving loop never stalls the Inference Agent on a jit
        compile while the Loading Agents race ahead."""
        eng = self.engine
        fns = eng.fns
        emb = eng._resident.get("embed") or eng._load("embed")
        head = eng._resident.get("head") or eng._load("head")
        w0 = eng._load(eng.layer_names[0])
        T = (self._nb * self.page_size if self.page_size
             else self.max_total_len)
        for s in sorted(set(int(p) for p in prompt_lens)):
            x = fns["embed"](emb, jnp.zeros((1, s), jnp.int32))
            px, _ = eng._layer_cache(0, w0, x, T)
            fns["head"](head, px).block_until_ready()
        x1 = fns["embed"](emb, jnp.zeros((1, 1), jnp.int32))
        _, c1 = eng._layer_cache(0, w0, x1, T)
        if self.page_size:
            # one fixed-size pool per leaf: compile the paged decode at
            # every batch size (the pool rows never change, so these are
            # the serving executables).  Speculative serving decodes
            # exclusively through W-wide verify windows, so it warms
            # those shapes instead — plus the draft's own executables.
            pool1 = self._pool_like(c1)
            w = self.spec_depth + 1
            for r in range(1, self.max_inflight + 1):
                tbr = jnp.zeros((r, self._nb), jnp.int32)
                if self.spec_depth:
                    xr = fns["embed"](emb, jnp.zeros((r, w), jnp.int32))
                    dr, _ = fns["layer_verify_paged"](
                        w0, xr, pool1, tbr, jnp.zeros((r,), jnp.int32))
                    fns["head_all"](head, dr).block_until_ready()
                else:
                    xr = fns["embed"](emb, jnp.zeros((r, 1), jnp.int32))
                    dr, _ = fns["layer_decode_paged"](
                        w0, xr, pool1, tbr, jnp.zeros((r,), jnp.int32))
                    fns["head"](head, dr).block_until_ready()
            if self.spec_depth:
                for s in sorted(set(int(p) for p in prompt_lens)):
                    self.draft.prefill(jnp.zeros((1, s), jnp.int32),
                                       self._draft_total)
                _, dc1 = self.draft.prefill(jnp.zeros((1, 1), jnp.int32),
                                            self._draft_total)
                for r in range(1, self.max_inflight + 1):
                    dcr = {name: jax.tree.map(
                        lambda a: jnp.concatenate([a] * r), c)
                        for name, c in dc1.items()}
                    self.draft.decode_batch(
                        jnp.zeros((r, 1), jnp.int32), dcr,
                        jnp.zeros((r,), jnp.int32))
        else:
            for r in range(1, self.max_inflight + 1):
                cr = jax.tree.map(lambda a: jnp.concatenate([a] * r), c1)
                xr = fns["embed"](emb, jnp.zeros((r, 1), jnp.int32))
                dr, _ = eng._layer_decode(0, w0, xr, cr,
                                          jnp.zeros((r,), jnp.int32))
                fns["head"](head, dr).block_until_ready()
        del w0, emb, head
        if eng.expert is not None:
            # warmup's compile-time fetches are not serving traffic
            self._expert_snap = eng.expert.snapshot()
        self._t0 = time.perf_counter()
        return self
