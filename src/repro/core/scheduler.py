"""Continuous-batching scheduler: one weight stream serves every request.

PIPELOAD's dominant cost is streaming layer weights through the Loading
Agents, paid once per pipeline round — yet the single-request engine
spends each round on ONE sequence, so serving N users costs N full weight
streams per generated token.  The scheduler amortises the stream: each
round, layer ``k`` is loaded once, applied to the stacked single-token
hidden states of ALL in-flight requests (ragged positions — every request
sits at its own cache slot) and to the cache-capturing prefill of
requests admitted at this round boundary, then destroyed (``S_dest``).
Aggregate throughput scales with the in-flight count while the per-round
cost stays one weight stream.

Lifecycle (all transitions happen at round boundaries, except retirement
detection, which happens the instant a request's last token is sampled):

    submit() -> QUEUED -> [admission] -> PREFILLING -> DECODING -> DONE
                  ^                                       |
                  |            cache pages released       |
                  +------- (reusable at the SAME boundary)+

Memory protocol: every request's KV pages are charged to the engine's
``_Ledger`` — the same budget the streamed weights draw from.  Admission
is FIFO and blocks (requests wait in the queue) whenever the
post-admission decode floor

    other_bytes + pinned + all in-flight cache pages + one streaming layer

would exceed the budget, or the in-flight count would exceed
``max_inflight``.  Retirement is the cache analogue of ``S_dest``: the
round a request finishes, its pages are released immediately, so a queued
request can be admitted with the freed bytes at the very same boundary.

All caches are padded to ``max_total_len`` slots so stacked decode reuses
one jitted executable per batch size (padding past a request's position
is exactly masked out — softmax contributions are exact zeros — so
batched decoding is token-for-token identical to sequential runs).

**Paged mode** (``page_size`` set, core/kv_pages.py): instead of one
contiguous max-length reservation per request, the KV ledger bytes are
carved into fixed-size pages mapped through per-request block tables.

  * Admission charges only the request's PROMPT pages (pages a live
    sibling already mapped through the ``PrefixTree`` are refcount
    bumps — a fleet of requests behind one system prompt charges its
    prefix once), so the decode floor is pages-actually-mapped plus one
    page of headroom per in-flight request instead of
    ``inflight x max_total_len``.  A shared page holds K/V the
    sibling's prefill computed: bitwise what this request would have
    written when the prompts are the same LENGTH; a different length
    reuses values from a different prefill shape — equal up to float
    reassociation, so greedy can diverge at near-tie logits (the same
    caveat as preemption below).
  * Decode grows a request one page at a time as its position crosses a
    page boundary; writes into a shared page copy-on-write it first.
  * If growth cannot clear the floor, the YOUNGEST in-flight request is
    preempted — its pages are freed and it re-queues with its tokens so
    far (re-prefilled on re-admission); the oldest request always fits
    alone (submit() enforced it), so serving never deadlocks.  A
    re-prefill recomputes bit-identical K/V, but full-sequence prefill
    and incremental decode sum the softmax in different orders, so a
    preempted request's continuation can diverge from the sequential
    reference at float-tie tokens — preemption is a correctness-
    preserving overload valve, not part of the equivalence guarantee.
  * Retirement drops one reference per page: non-shared pages free (and
    re-enter the free list at the pool's high-water mark) the moment
    the request finishes; pages shared with a live sibling survive
    until the last sharer retires.

Physical page storage is one ``(rows, page_size, ...)`` array per layer
per cache leaf, sized once at construction (``max_inflight`` worst-case
tables + COW slack) so jitted decode shapes never change; the ledger
only ever charges MAPPED pages, and the decode attention gathers K/V
tiles through the block table (Pallas kernel under
``attn_impl="pallas"``, kernels/paged_decode.py).

**Serving tier** (multi-tenant SLO serving on top of the mechanisms
above):

  * *Priority classes with preemption.*  ``submit(..., priority=p)``
    orders the queue by ``(-priority, arrival_round, rid)`` and admission
    may BOUNCE an in-flight request back to the queue to make room for a
    strictly-higher-priority arrival — the victim is always the
    lowest-priority, youngest-admitted in-flight request, the same
    ordering growth-preemption uses, so the no-deadlock argument is
    unchanged: ``submit()`` proved every request fits alone, victims
    release their ledger bytes exactly (``release_all``), and a bounced
    request re-prefills from its tokens-so-far on re-admission.
    Preemption only ever flows downhill (never equal or higher
    priority), so a boundary's admission loop terminates and a
    bounded-priority trace cannot starve: high classes drain in finite
    rounds, then the bounced request is the queue head again.
  * *Chunked prefill* (``chunk_prefill=C``, paged mode, page-aligned:
    ``C`` rounds up to a page multiple).  A prompt longer than ``C``
    joins decode rounds as a sequence of C-token chunk jobs riding the
    stacked ``layer_verify_paged`` window (the speculative-verify
    module): each round streams the layers ONCE and applies them to the
    decode batch AND every in-flight chunk, writing the chunk's K/V
    straight into the request's pages in-kernel — so a long prompt costs
    ``ceil(L/C)`` decode-shaped rounds instead of stalling every
    in-flight decode behind one monolithic prefill round.  The final
    chunk is padded to width ``C`` by RE-feeding the preceding tokens at
    their own positions (bitwise-identical K/V rewrites — the draft
    catch-up trick), and its last column feeds the head for the first
    generated token.  The jnp verify path reuses the decode attention
    exactly, so chunked prefill is token-identical to unchunked serving
    up to the usual prefill-vs-decode float-reassociation caveat.
  * *Per-tenant prefix namespaces.*  ``submit(..., tenant=t)`` keys the
    radix prefix index by tenant (``PrefixNamespaces``): system prompts
    share pages WITHIN a tenant, never across — isolation is structural,
    and retirement in one tenant can never free another's pages.
  * *SLO accounting + shedding.*  ``slo=SLO(...)`` sets TTFT/TPOT
    targets in ROUNDS (the deterministic clock — a replayed trace meets
    or misses them identically on any machine); ``ServeStats`` reports
    p50/p99 TTFT and TPOT in rounds and seconds, preemption counts and
    goodput-under-SLO, and ``SLO.shed=True`` rejects queued requests
    whose TTFT target is already unattainable at admission time instead
    of burning rounds on doomed work.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import telemetry as _tele
from repro.core.engine import DraftModel, PipeloadEngine, _Ledger
from repro.core.kv_pages import (BlockTable, PagePool, PrefixNamespaces,
                                 pages_for)


@dataclasses.dataclass(frozen=True)
class SLO:
    """Service-level objectives in ROUNDS — the scheduler's deterministic
    clock, so a replayed trace attains or misses them identically on any
    machine (wall-clock percentiles are reported alongside, but policy
    decisions never read the wall clock).

    ``ttft_rounds``: a request attains its TTFT target when its first
    token lands within that many rounds of arrival (inclusive — 1 means
    "the arrival round itself").  ``tpot_rounds``: average rounds per
    subsequent token (1.0 = a token every round; only speculative
    serving goes below 1).  ``shed=True`` additionally REJECTS a queued
    request at admission time once its TTFT target is provably
    unattainable (queueing delay alone already exceeds it) — shedding
    doomed work is how goodput-under-SLO beats raw throughput under
    overload."""
    ttft_rounds: Optional[int] = None
    tpot_rounds: Optional[float] = None
    shed: bool = False


@dataclasses.dataclass
class Request:
    """One generation request; scheduler-owned fields below ``rid``."""
    rid: int
    prompt: np.ndarray            # (S,) int token ids
    max_new_tokens: int
    arrival_round: int = 0        # earliest boundary it may be admitted at
    priority: int = 0             # higher = admitted (and kept) first
    tenant: str = "default"       # prefix-namespace key
    # -- scheduler state ------------------------------------------------
    tokens: List[int] = dataclasses.field(default_factory=list)
    generated: int = 0
    admitted_round: int = -1
    finished_round: int = -1
    cache_bytes: int = 0          # ledger reservation while in flight
    table: Optional[BlockTable] = None   # paged mode: page ids + n_shared
    draft_pos: int = 0            # speculative: draft cache slots valid
    # -- chunked prefill ------------------------------------------------
    prefilling: bool = False      # True while chunks are still feeding
    prefill_pos: int = 0          # tokens whose K/V is already paged in
    # -- SLO accounting -------------------------------------------------
    born_round: int = 0           # original arrival (preemption re-queues
                                  # mutate arrival_round; TTFT uses this)
    first_token_round: int = -1
    rejected: bool = False        # shed by SLO admission control
    t_arrival: float = -1.0       # wall-clock marks (observability only)
    t_first: float = -1.0
    t_done: float = -1.0

    @property
    def done(self) -> bool:
        return self.generated >= self.max_new_tokens

    @property
    def pos(self) -> int:
        """Cache slot of the token about to be fed (current length - 1)."""
        return len(self.tokens) - 1


@dataclasses.dataclass
class ServeStats:
    rounds: int
    latency_s: float
    peak_bytes: int
    loads: int
    streamed_bytes: int
    new_tokens: int
    requests: int
    max_inflight_seen: int
    cache_bytes_peak: int
    events: List[Tuple[float, str, str]]
    # expert-streaming extras (0 for dense / whole-layer MoE serving)
    expert_hits: int = 0
    expert_misses: int = 0
    expert_evictions: int = 0
    expert_cache_bytes: int = 0
    unique_experts_per_round: float = 0.0
    # reproducibility: the RNG seed the serving trace was generated with
    # (None when the caller did not thread one)
    seed: Optional[int] = None
    # paged-KV extras (0 / dense defaults when page_size is unset)
    page_size: int = 0
    pages_allocated: int = 0       # pool allocs (fresh + free-list reuse)
    page_reuses: int = 0           # allocs served from the free list
    prefix_hit_pages: int = 0      # prompt pages shared via the PrefixTree
    cow_copies: int = 0            # copy-on-write page swaps
    preemptions: int = 0           # requests bounced back to the queue
    pool_pages_peak: int = 0       # high-water MAPPED page count
    # speculative-decoding extras (0 when spec_depth is unset)
    spec_depth: int = 0            # draft tokens proposed per round
    spec_rounds: int = 0           # verify rounds executed
    draft_tokens: int = 0          # proposals the draft emitted
    accepted_tokens: int = 0       # proposals the target committed
    # serving-tier extras (SLO / multi-tenant / chunked prefill)
    tenants: int = 0               # distinct tenant namespaces served
    chunk_size: int = 0            # chunked-prefill chunk tokens (0 = off)
    chunk_jobs: int = 0            # prefill chunks joined into rounds
    ttft_p50_rounds: float = 0.0   # rounds from arrival to first token
    ttft_p99_rounds: float = 0.0
    tpot_p50_rounds: float = 0.0   # rounds per subsequent token
    tpot_p99_rounds: float = 0.0
    ttft_p50_s: float = 0.0        # wall-clock mirrors (observability)
    ttft_p99_s: float = 0.0
    tpot_p50_s: float = 0.0
    tpot_p99_s: float = 0.0
    slo_attained: float = 1.0      # fraction of requests meeting the SLO
    goodput_tokens: int = 0        # tokens from requests meeting the SLO
    slo_rejections: int = 0        # requests shed at admission
    # policy trace for golden-file regression tests:
    # (kind, rid, round, t_wall) for every admit / preempt / retire /
    # reject decision, in order.  The first three fields are
    # deterministic under a fixed trace (no wall-clock terms — golden
    # tests pin only those); t_wall is the decision's wall-clock second
    # since the session's _t0, the same timeline as ``events`` and the
    # Request t_arrival/t_first/t_done marks, so policy decisions line
    # up with trace spans (observability only)
    policy: List[Tuple[str, int, int, float]] = dataclasses.field(
        default_factory=list)
    # prefetch fault-injection outcomes (REPRO_PREFETCH_FAULT_RATE),
    # wired from the telemetry metrics registry as per-session deltas
    retries: int = 0
    faults_absorbed: int = 0
    # per-owner byte shares at the session ledger's peak (sums exactly
    # to peak_bytes; additive — golden traces pin only `policy`)
    peak_breakdown: Dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def tokens_per_s(self) -> float:
        return self.new_tokens / self.latency_s if self.latency_s else 0.0

    @property
    def goodput_tokens_per_s(self) -> float:
        """Goodput-under-SLO: only tokens from requests that met every
        SLO target count (the serving-tier objective — raw tokens/s
        rewards work the user already gave up on)."""
        return (self.goodput_tokens / self.latency_s
                if self.latency_s else 0.0)

    @property
    def expert_hit_rate(self) -> float:
        """Fraction of expert activations served from the ExpertCache."""
        total = self.expert_hits + self.expert_misses
        return self.expert_hits / total if total else 0.0

    @property
    def acceptance_rate(self) -> float:
        """Fraction of draft proposals the target accepted."""
        return (self.accepted_tokens / self.draft_tokens
                if self.draft_tokens else 0.0)

    def event_log(self, kinds=None):
        return [e for e in self.events if kinds is None or e[1] in kinds]


class BatchScheduler:
    """Round-boundary continuous batching over a ``PipeloadEngine``.

    ``max_total_len`` bounds every request's prompt + generation length;
    it fixes the padded cache shape so batched rounds compile once per
    batch size.  ``max_inflight`` caps concurrency; the budget caps it
    further through admission control (capacity-first: the planner's
    ``plan_generate(..., max_inflight=...)`` picks the triple).
    """

    def __init__(self, engine: PipeloadEngine, *, max_inflight: int = 4,
                 max_total_len: int = 128,
                 page_size: Optional[int] = None,
                 prefix_cache: bool = True,
                 seed: Optional[int] = None,
                 draft: Optional[DraftModel] = None,
                 spec_depth: int = 0,
                 chunk_prefill: int = 0,
                 slo: Optional[SLO] = None):
        if engine.mode == "baseline":
            raise ValueError("continuous batching needs a pipelined mode "
                             "(pipeload / pipeswitch)")
        self.engine = engine
        self.max_inflight = max(1, max_inflight)
        self.max_total_len = max_total_len
        # paged KV mode: explicit page_size wins, else inherit the
        # engine's (the planner threads its page-size pick through the
        # engine); 0/None = dense per-request reservations
        if page_size is None:
            page_size = engine.page_size
        self.page_size = page_size if page_size and page_size > 0 else None
        # speculative serving: a pinned draft proposes spec_depth tokens
        # per round per request and one stacked verify round scores them
        self.spec_depth = max(0, spec_depth) if draft is not None else 0
        self.draft = draft if self.spec_depth else None
        if self.spec_depth:
            if not self.page_size:
                raise ValueError(
                    "speculative serving needs paged KV (the verify "
                    "window rides the block tables); set page_size")
            if "layer_verify_paged" not in engine.fns:
                raise ValueError(
                    "engine's model fns lack layer_verify_paged "
                    "(speculative verify); architecture unsupported")
        # chunked prefill (serving tier): prompts longer than ``chunk``
        # tokens prefill C tokens per round through the stacked verify
        # window instead of one monolithic prefill round
        self.chunk = 0
        if chunk_prefill and chunk_prefill > 0:
            if not self.page_size:
                raise ValueError(
                    "chunked prefill needs paged KV (chunks write K/V "
                    "through the block tables); set page_size")
            if self.spec_depth:
                raise ValueError(
                    "chunked prefill and speculative serving are "
                    "mutually exclusive (both reshape the round); pick "
                    "one")
            if "layer_verify_paged" not in engine.fns:
                raise ValueError(
                    "engine's model fns lack layer_verify_paged (the "
                    "chunk window); architecture unsupported for "
                    "chunked prefill")
            # page-aligned chunks: non-final chunk boundaries land on
            # page boundaries, so a chunk never splits a page's writes
            # across rounds
            ps = self.page_size
            self.chunk = -(-int(chunk_prefill) // ps) * ps
        self.slo = slo
        self.slo_rejections = 0
        # (kind, rid, round, t_wall) policy decisions — the golden-trace
        # log (golden tests pin the first three, deterministic fields;
        # t_wall aligns each decision with the span-trace timeline)
        self.policy_log: List[Tuple[str, int, int, float]] = []
        self._chunk_jobs = 0
        # telemetry: registry counters cached once (reset() zeroes them
        # in place) + the session baseline for the fault-counter deltas
        m = _tele.metrics()
        self._m_admits = m.counter("sched.admits")
        self._m_preempts = m.counter("sched.preemptions")
        self._m_retires = m.counter("sched.retires")
        self._m_rejects = m.counter("sched.rejections")
        self._fault_base = _tele.counter_values("prefetch.retries",
                                                "prefetch.faults_absorbed")
        self.seed = seed
        self.queue: List[Request] = []   # by (-priority, arrival, rid)
        self.inflight: List[Request] = []
        self.done: Dict[int, Request] = {}
        self.round = 0
        self._next_rid = 0
        # per-request-row stacked state (rows parallel to self.inflight)
        self._caches: Optional[Dict[str, dict]] = None   # leaves (R, T, ...)
        # serving-session accounting: ONE ledger across all rounds, so
        # weights, caches and the pinned window share a single budget
        self.ledger = _Ledger(engine.budget)
        self.events: List[Tuple[float, str, str]] = []
        self._t0 = time.perf_counter()
        self._cache_resident = 0
        self._cache_peak = 0
        self._max_seen = 0
        self._per_req_cache = (len(engine.layer_names)
                               * engine.cfg.cache_bytes(1, max_total_len))
        # ---- paged-KV state (None/unused in dense mode) ----
        self.pool: Optional[PagePool] = None
        # per-tenant radix indexes over ONE shared pool: prefix pages
        # share within a tenant, never across (kv_pages.PrefixNamespaces)
        self.tree: Optional[PrefixNamespaces] = None
        self._pools: Optional[Dict[str, dict]] = None  # layer -> (P, ps, ..)
        self.preemptions = 0
        if self.page_size:
            if engine.expert is not None:
                raise ValueError(
                    "paged KV serving is not supported with expert-split "
                    "MoE checkpoints yet; repartition whole-layer or drop "
                    "page_size")
            ps = self.page_size
            # speculative verify writes K/V for the whole window
            # [pos, pos + depth]; the last round's window can run past
            # max_total_len, so tables carry the overhang slots (the
            # extra K/V is masked garbage, freed at retirement)
            self._nb = pages_for(max_total_len + self.spec_depth, ps)
            self._page_bytes = (len(engine.layer_names)
                                * engine.cfg.cache_bytes(1, ps))
            self.pool = PagePool(ps, self._page_bytes, self.ledger)
            self.tree = PrefixNamespaces(ps) if prefix_cache else None
            # fixed physical pool rows: worst-case tables + COW slack,
            # sized ONCE so jitted decode shapes never change (the
            # ledger charges only MAPPED pages; these rows are buffer)
            self._pool_rows = self.max_inflight * self._nb + 2
        # ---- speculative state (draft pinned for the whole session) ----
        self._draft_caches: Optional[Dict[str, dict]] = None  # (R, T, ...)
        self._spec_rounds = 0
        self._draft_tokens = 0
        self._accepted_tokens = 0
        if self.spec_depth:
            # per-request growth headroom: a verify round can map up to
            # a full window of fresh pages at once
            self._req_headroom = pages_for(self.spec_depth + 1,
                                           self.page_size)
            self._draft_total = max_total_len + self.spec_depth
            self._draft_cache_bytes = self.draft.cache_bytes(
                1, self._draft_total)
            self.draft.pin(self.ledger)   # resident for the session
        else:
            self._req_headroom = 1 if self.page_size else 0
        self._draft_pinned = self.spec_depth > 0
        self._expert_snap = (engine.expert.snapshot()
                             if engine.expert is not None else None)
        # the widest fetch this workload can lock (a max-length prompt's
        # prefill): admission may shrink the ExpertCache to this, never
        # below, and submit-time feasibility reasons from it
        self._expert_floor = (
            engine.expert.working_set_bytes(max_total_len)
            if engine.expert is not None else None)

    # ------------------------------------------------------------------
    def close(self):
        """End the serving session: unpin the draft's ledger bytes and
        tear down the engine's prefetch runtime (worker + drainer
        threads).  Idempotent."""
        if self.draft is not None and self._draft_pinned:
            self.draft.unpin(self.ledger)
            self._draft_pinned = False
        self.engine.close()

    def __enter__(self) -> "BatchScheduler":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------------
    def submit(self, prompt, max_new_tokens: int,
               arrival_round: int = 0, *, priority: int = 0,
               tenant: str = "default") -> int:
        """Queue a request; returns its id.

        ``priority`` orders admission (higher first; ties FIFO) and a
        strictly-higher-priority arrival may preempt the lowest-priority
        youngest in-flight request to get in.  ``tenant`` names the
        prefix namespace its prompt pages may share within.

        Raises if the request could NEVER be admitted — a prompt +
        generation length beyond ``max_total_len``, or a cache
        reservation that exceeds the budget floor even with zero other
        requests in flight (admission would otherwise deadlock the
        queue head forever)."""
        prompt = np.asarray(prompt).reshape(-1)
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if len(prompt) + max_new_tokens > self.max_total_len:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new_tokens "
                f"({max_new_tokens}) exceeds max_total_len "
                f"{self.max_total_len}")
        if self.page_size:
            # worst case = every page of its final length, unshared,
            # PLUS the admission headroom (_fits_paged charges it per
            # in-flight request — without it a request whose total fits
            # the budget exactly would be accepted here yet never
            # admitted, spinning run() forever).  This is the guarantee
            # growth-with-preemption leans on: a request ALONE can
            # always map its next page (its whole verify window, in
            # speculative mode — where the draft and its cache row are
            # charged as extra residents too).
            worst = ((pages_for(len(prompt) + max_new_tokens
                                + self.spec_depth, self.page_size)
                      + self._req_headroom) * self._page_bytes)
            self.engine._check_kv_budget(
                worst, inflight=1, extra_resident=self._spec_resident(1))
            per_req = worst
        else:
            self.engine._check_kv_budget(self._per_req_cache, inflight=1,
                                         expert_floor=self._expert_floor)
            per_req = self._per_req_cache
        req = Request(self._next_rid, prompt, max_new_tokens,
                      arrival_round=max(arrival_round, 0),
                      priority=int(priority), tenant=str(tenant),
                      cache_bytes=per_req,
                      born_round=max(arrival_round, 0))
        self._next_rid += 1
        self.queue.append(req)
        self._sort_queue()
        return req.rid

    def _sort_queue(self) -> None:
        """Priority lattice: higher classes first, FIFO within a class
        (a preempted request re-enters with arrival_round = now, so it
        queues behind its class's newest arrivals — bounded classes
        cannot starve it)."""
        self.queue.sort(key=lambda r: (-r.priority, r.arrival_round,
                                       r.rid))

    def _tree(self, req: Request):
        """The request's tenant-namespace radix tree (None when prefix
        caching is off)."""
        return self.tree.tree(req.tenant) if self.tree is not None else None

    # ------------------------------------------------------------------
    def _fits(self, extra_cache: int) -> bool:
        """Would the decode floor still clear the budget after granting
        ``extra_cache`` more page bytes?  When the floor misses only
        because the ExpertCache holds the headroom, the cache shrinks
        first (LRU eviction releasing ledger bytes — the cache-side
        ``S_dest``), so a queued request's pages win over cold experts."""
        eng = self.engine
        if eng.budget is None:
            return True
        # until the expert engine is bound to THIS session's ledger the
        # live-reservation term does not exist (a binding left over from
        # an earlier run charged a dead ledger): reason from the
        # workload's expert floor so early admissions leave room for the
        # cache's minimum working set
        pre_bind = (eng.expert is not None
                    and not eng.expert.bound_to(self.ledger))
        kw = {"expert_floor": self._expert_floor} if pre_bind else {}
        floor = eng._kv_floor(self._cache_resident + extra_cache, **kw)
        if floor <= eng.budget:
            return True
        if eng.expert is not None and not pre_bind:
            if eng.expert.release_headroom(floor - eng.budget,
                                           floor=self._expert_floor):
                floor = eng._kv_floor(self._cache_resident + extra_cache)
                return floor <= eng.budget
        return False

    # ---- paged-mode admission / growth / preemption ------------------
    def _spec_resident(self, inflight: int) -> int:
        """Speculative mode's extra resident bytes: the pinned draft
        plus one dense draft-cache row per in-flight request."""
        if not self.spec_depth:
            return 0
        return (self.draft.total_bytes
                + inflight * self._draft_cache_bytes)

    def _fits_paged(self, extra_pages: int, inflight_after: int) -> bool:
        """Paged decode floor: pages actually mapped, plus the new pages,
        plus growth headroom per in-flight request (one page — a whole
        verify window of pages in speculative mode, where the pinned
        draft and its cache rows are charged as extra residents too)."""
        eng = self.engine
        if eng.budget is None:
            return True
        cache = ((self.pool.mapped_pages + extra_pages
                  + inflight_after * self._req_headroom)
                 * self._page_bytes)
        return (eng._kv_floor(
            cache, extra_resident=self._spec_resident(inflight_after))
            <= eng.budget)

    def _admit_one_paged(self, req: Request, inflight_after: int) -> bool:
        """Map the request's prompt pages (prefix-tree hits are refcount
        bumps, charged once across the fleet); False = does not fit at
        this boundary."""
        toks = req.tokens or [int(t) for t in req.prompt]
        n_pages = pages_for(len(toks), self.page_size)
        tree = self._tree(req)      # tenant namespace: within-tenant hits
        walk = tree.walk(toks) if tree is not None else None
        shared = len(walk[0]) if walk is not None else 0
        if not self._fits_paged(n_pages - shared, inflight_after):
            return False
        self.pool.detail = f"req{req.rid}"
        if tree is not None:
            pids, n_shared = tree.insert(toks, self.pool, walk=walk)
        else:
            pids, n_shared = [self.pool.alloc()
                              for _ in range(n_pages)], 0
        self.pool.detail = None
        req.table = BlockTable(pids, n_shared)
        req.tokens = toks
        if self.chunk and len(toks) > self.chunk:
            # long prompt: feed it C tokens per round as chunk jobs
            # (pages are all mapped already; chunking spreads the
            # COMPUTE, not the reservation)
            req.prefilling = True
            req.prefill_pos = 0
        if self.spec_depth:
            # the request's dense draft-cache row lives as long as the
            # request is in flight (never blocks: _fits_paged charged it
            # via _spec_resident, and at a boundary nothing streams)
            self.ledger.acquire(self._draft_cache_bytes,
                                owner="spec_headroom",
                                detail=f"req{req.rid}")
        return True

    def _preempt(self, victim: Request) -> None:
        """Bounce ``victim`` back to the queue, releasing its ledger
        bytes exactly (non-shared pages in paged mode, the whole dense
        reservation otherwise); it re-prefills from its tokens so far on
        re-admission."""
        idx = self.inflight.index(victim)
        if self.page_size:
            self.pool.detail = f"req{victim.rid}"
            victim.table.release_all(self.pool, self._tree(victim))
            self.pool.detail = None
            if self.spec_depth:
                self._draft_caches = self._rows_keep(
                    self._draft_caches,
                    [i for i in range(len(self.inflight)) if i != idx])
                self.ledger.release(self._draft_cache_bytes,
                                    owner="spec_headroom",
                                    detail=f"req{victim.rid}")
        else:
            self.ledger.release(victim.cache_bytes, owner="kv_pages",
                                detail=f"req{victim.rid}")
            self._cache_resident -= victim.cache_bytes
            self._drop_rows([i for i in range(len(self.inflight))
                             if i != idx])
        self.inflight.pop(idx)
        victim.admitted_round = -1
        victim.arrival_round = self.round
        victim.prefilling = False
        victim.prefill_pos = 0
        self.queue.append(victim)
        self._sort_queue()
        self.preemptions += 1
        now = time.perf_counter() - self._t0
        self.events.append((now, "preempt", f"req{victim.rid}"))
        self.policy_log.append(("preempt", victim.rid, self.round, now))
        self._m_preempts.inc()
        tr = _tele.get_tracer()
        if tr.enabled:
            tr.instant("preempt", rid=victim.rid, round=self.round)

    def _victim(self, below: Optional[int] = None) -> Optional[Request]:
        """The preemption victim: lowest priority first, youngest
        admission within a class — the generalisation of the original
        youngest-first order (all priorities equal reduces to it).
        ``below`` restricts to strictly-lower-priority victims (admission
        preemption only flows downhill; growth passes None and may pick
        any request, including the grower itself)."""
        cands = [r for r in self.inflight
                 if below is None or r.priority < below]
        if not cands:
            return None
        order = {id(r): i for i, r in enumerate(self.inflight)}
        return min(cands, key=lambda r: (r.priority, -order[id(r)]))

    def _alloc_with_preemption(self, req: Request) -> Optional[int]:
        """Map one more page for ``req``, preempting the lowest-priority
        YOUNGEST in-flight request — possibly ``req`` itself — while the
        floor would not clear (within a priority class this is the
        original strict age order: an older request's progress is never
        sacrificed for a younger grower).  Returns None when ``req`` was
        the victim; otherwise always succeeds — once ``req`` is alone,
        submit() guaranteed its worst case fits."""
        while not self._fits_paged(1, 0) and len(self.inflight) > 1:
            victim = self._victim()
            self._preempt(victim)
            if victim is req:
                return None
        self.pool.detail = f"req{req.rid}"   # _preempt cleared it
        pid = self.pool.alloc()
        self.pool.detail = None
        if pid >= self._pool_rows:
            raise RuntimeError(
                f"page pool overflow: page {pid} >= {self._pool_rows} "
                f"physical rows (max_inflight x table width + COW slack)"
            )   # unreachable: admission + growth bound live pages
        return pid

    def _grow_pages(self):
        """Round boundary, before admission: map each in-flight
        request's WRITE RANGE — the one page its next token lands in,
        or, in speculative mode, every page the verify window
        [pos, pos + depth] touches — growing across page boundaries and
        copy-on-writing shared pages before their first divergent
        write."""
        if not self.inflight:
            return
        cow: List[Tuple[Request, int, int]] = []
        for req in list(self.inflight):
            if req not in self.inflight:    # preempted by an earlier grower
                continue
            if req.prefilling:
                # chunked prefill: every page was mapped at admission and
                # chunk writes land in the request's own prompt pages (or
                # rewrite shared pages with bitwise-identical K/V), so a
                # prefilling request neither grows nor copy-on-writes
                continue
            t = req.table
            lo = req.pos // self.page_size
            hi = (req.pos + self.spec_depth) // self.page_size
            while len(t.pages) <= hi:
                pid = self._alloc_with_preemption(req)
                if pid is None:             # req itself was the victim
                    break
                t.pages.append(pid)
            for pidx in range(lo, hi + 1):
                if req not in self.inflight:
                    break
                pid = t.pages[pidx]
                if not self.pool.is_shared(pid):
                    continue
                new = self._alloc_with_preemption(req)
                if new is None:             # req preempted: refs already
                    break                   # dropped by release_all
                cow.append((req, pid, new))
                # usually the sibling keeps the old page — but if the
                # COW alloc preempted that sibling, this drop is the
                # LAST reference and the tree node must go with it
                # (prefix pages only ever index the OWNER's tenant tree)
                tree = self._tree(req)
                if self.pool.release(pid) and tree is not None:
                    tree.forget(pid)
                t.pages[pidx] = new
        # drop copies whose OWNER was preempted after queuing them (its
        # freed target id may already be re-mapped by a later grower —
        # a stale entry would make the batched scatter write the same
        # destination twice), then copy page contents old -> new in one
        # batched update per leaf
        cow = [(o, n) for r, o, n in cow if r in self.inflight]
        self.pool.stats.cow_copies += len(cow)   # copies actually made
        self.pool._m_cow.inc(len(cow))
        tr = _tele.get_tracer()
        if tr.enabled and cow:
            tr.instant("page_cow", copies=len(cow), round=self.round)
        if cow:
            old = jnp.asarray([o for o, _ in cow], jnp.int32)
            new = jnp.asarray([n for _, n in cow], jnp.int32)
            self._pools = {
                name: jax.tree.map(lambda a: a.at[new].set(a[old]), c)
                for name, c in self._pools.items()}

    def _pool_like(self, cache):
        """Zeroed physical page array(s) shaped for ``cache`` leaves:
        (B, T, ...) -> (pool_rows, page_size, ...).  The ONE place the
        pool layout is defined — warmup compiles against arrays built
        here, so serving shapes always match the warmed executables."""
        return jax.tree.map(
            lambda a: jnp.zeros(
                (self._pool_rows, self.page_size) + a.shape[2:], a.dtype),
            cache)

    def _ensure_pool_arrays(self, template: Dict[str, dict]):
        """Create the physical page arrays from the first prefill's
        cache shapes: one (rows, page_size, ...) array per layer per
        cache leaf, sized once (see class docstring)."""
        if self._pools is not None:
            return
        self._pools = {name: self._pool_like(c)
                       for name, c in template.items()}

    def _scatter_prefills(self, reqs: List[Request],
                          caches: List[Dict[str, dict]]):
        """Write the boundary's captured prefill caches into each
        request's OWNED pages — ONE batched scatter per layer per cache
        leaf (a per-request loop would copy the whole physical pool
        once per update).  Shared prefix pages are skipped: a sibling
        already wrote identical K/V, and a shared partial page may hold
        the sibling's generated tokens past this prompt (masked by the
        valid-length mask, clobbered by nothing)."""
        ps = self.page_size
        owned = [(r, c) for r, c in zip(reqs, caches)
                 if len(r.table.pages) > r.table.n_shared]
        if not owned:
            return
        self._ensure_pool_arrays(owned[0][1])
        pids = jnp.asarray([pid for r, _ in owned
                            for pid in r.table.pages[r.table.n_shared:]],
                           jnp.int32)

        def rows(a, t):
            lo, hi = t.n_shared * ps, len(t.pages) * ps
            return a[0, lo:hi].reshape((len(t.pages) - t.n_shared, ps)
                                       + a.shape[2:])

        for name in self._pools:
            blocks = [jax.tree.map(lambda a, t=r.table: rows(a, t), c[name])
                      for r, c in owned]
            stacked = (blocks[0] if len(blocks) == 1 else jax.tree.map(
                lambda *xs: jnp.concatenate(xs), *blocks))
            self._pools[name] = jax.tree.map(
                lambda leaf, rr: leaf.at[pids].set(rr.astype(leaf.dtype)),
                self._pools[name], stacked)

    def _chunk_rounds(self, req: Request) -> int:
        """Rounds this request's prefill will take once admitted (1 for
        the monolithic path; ``ceil(L / C)`` chunk rounds otherwise)."""
        n = len(req.tokens) or len(req.prompt)
        if not (self.chunk and self.page_size and n > self.chunk):
            return 1
        return -(-n // self.chunk)

    def _shed(self, req: Request) -> bool:
        """SLO admission control: reject a request whose TTFT target is
        already unattainable — even admitted THIS boundary, its first
        token cannot land inside the target (queueing delay + its own
        prefill rounds already exceed it).  Burning rounds on it would
        only push other requests past their targets too."""
        if (self.slo is None or not self.slo.shed
                or self.slo.ttft_rounds is None):
            return False
        if req.first_token_round >= 0:
            # a preempted request already delivered its first token; its
            # TTFT is decided — bouncing it again cannot be shed
            return False
        best_ttft = (self.round - req.born_round) + self._chunk_rounds(req)
        if best_ttft <= self.slo.ttft_rounds:
            return False
        self.queue.remove(req)
        req.rejected = True
        req.finished_round = self.round
        self.done[req.rid] = req
        self.slo_rejections += 1
        now = time.perf_counter() - self._t0
        self.events.append((now, "reject", f"req{req.rid}"))
        self.policy_log.append(("reject", req.rid, self.round, now))
        self._m_rejects.inc()
        tr = _tele.get_tracer()
        if tr.enabled:
            tr.instant("reject", rid=req.rid, round=self.round)
        return True

    def _reserve(self, req: Request, inflight_after: int) -> bool:
        """Try to reserve the request's cache at this boundary (maps
        pages / acquires the dense reservation on success)."""
        if self.page_size:
            return self._admit_one_paged(req, inflight_after)
        if not self._fits(req.cache_bytes):
            return False
        # reserve the request's pages for its whole lifetime (never
        # blocks: _fits checked the floor, and at a boundary nothing is
        # streaming)
        self.ledger.acquire(req.cache_bytes, owner="kv_pages",
                            detail=f"req{req.rid}")
        self._cache_resident += req.cache_bytes
        self._cache_peak = max(self._cache_peak, self._cache_resident)
        # a preempted request resumes from its tokens so far (re-prefill),
        # a fresh one starts from its prompt
        req.tokens = req.tokens or list(map(int, req.prompt))
        return True

    def _admit(self) -> List[Request]:
        """Priority-ordered admission at the current boundary.  Strict
        head-of-line WITHIN the eligible queue (sorted by priority class,
        FIFO inside a class): skipping the head could never help (dense
        mode reserves one padded size for everyone; paged mode's head is
        also the next to shrink via sharing); blocking keeps the order
        fair and is deadlock-free (submit() rejected anything that can't
        fit alone, and in-flight requests always retire in finite
        rounds).  A head that does not fit may PREEMPT strictly-lower-
        priority in-flight requests (lowest class, youngest first) for
        both a concurrency slot and cache room — preemption only flows
        downhill, so a boundary's loop terminates: each bounced request
        re-queues behind its own class and can only displace still-lower
        ones."""
        admitted: List[Request] = []
        while self.queue:
            eligible = [r for r in self.queue
                        if r.arrival_round <= self.round]
            if not eligible:
                break
            req = eligible[0]           # queue order: priority, then FIFO
            if self._shed(req):
                continue
            # concurrency slot: bounce a strictly-lower-priority victim
            if len(self.inflight) + len(admitted) >= self.max_inflight:
                victim = self._victim(below=req.priority)
                if victim is None:
                    break
                self._preempt(victim)
                continue                # victim re-queued; re-evaluate
            ok = self._reserve(req, len(self.inflight) + len(admitted) + 1)
            while not ok:
                victim = self._victim(below=req.priority)
                if victim is None:
                    break
                self._preempt(victim)
                ok = self._reserve(req,
                                   len(self.inflight) + len(admitted) + 1)
            if not ok:
                break
            self.queue.remove(req)
            req.admitted_round = self.round
            now = time.perf_counter() - self._t0
            self.events.append((now, "admit", f"req{req.rid}"))
            self.policy_log.append(("admit", req.rid, self.round, now))
            self._m_admits.inc()
            tr = _tele.get_tracer()
            if tr.enabled:
                tr.instant("admit", rid=req.rid, round=self.round)
            admitted.append(req)
        return admitted

    def _retire(self, finished: List[Request]):
        """S_dest for cache pages: release the ledger bytes the moment a
        request completes so the next boundary can re-grant them.  Paged
        mode drops one reference per page — pages shared with a live
        sibling survive until the LAST sharer retires (exact-drain at
        page granularity)."""
        for req in finished:
            if self.page_size:
                self.pool.detail = f"req{req.rid}"
                req.table.release_all(self.pool, self._tree(req))
                self.pool.detail = None
                if self.spec_depth:
                    self.ledger.release(self._draft_cache_bytes,
                                        owner="spec_headroom",
                                        detail=f"req{req.rid}")
            else:
                self.ledger.release(req.cache_bytes, owner="kv_pages",
                                    detail=f"req{req.rid}")
                self._cache_resident -= req.cache_bytes
            req.finished_round = self.round
            req.t_done = time.perf_counter() - self._t0
            self.done[req.rid] = req
            self.events.append((req.t_done, "retire", f"req{req.rid}"))
            # t_wall reuses the retirement mark already stamped on the
            # Request, so the policy trace and t_done agree exactly
            self.policy_log.append(("retire", req.rid, self.round,
                                    req.t_done))
            self._m_retires.inc()
            tr = _tele.get_tracer()
            if tr.enabled:
                tr.instant("retire", rid=req.rid, round=self.round)

    def _drop_rows(self, keep: List[int]):
        if self._caches is None:
            return
        if not keep:
            self._caches = None
            return
        idx = np.asarray(keep)
        self._caches = {name: jax.tree.map(lambda a: a[idx], c)
                        for name, c in self._caches.items()}

    def _append_rows(self, new_caches: List[Dict[str, dict]]):
        stacks = ([self._caches] if self._caches is not None else []) \
            + new_caches
        if not stacks:
            return
        if len(stacks) == 1:
            self._caches = stacks[0]
            return
        self._caches = {
            name: jax.tree.map(lambda *xs: jnp.concatenate(xs),
                               *(s[name] for s in stacks))
            for name in stacks[0]}

    # ---- speculative drafting (rows parallel to self.inflight) -------
    @staticmethod
    def _rows_keep(stack, keep: List[int]):
        """Row-filter a stacked cache dict (leaves (R, T, ...))."""
        if stack is None or not keep:
            return None
        idx = np.asarray(keep)
        return {name: jax.tree.map(lambda a: a[idx], c)
                for name, c in stack.items()}

    @staticmethod
    def _rows_concat(stacks):
        """Concatenate stacked cache dicts along the row dim."""
        stacks = [s for s in stacks if s is not None]
        if not stacks:
            return None
        if len(stacks) == 1:
            return stacks[0]
        return {name: jax.tree.map(lambda *xs: jnp.concatenate(xs),
                                   *(s[name] for s in stacks))
                for name in stacks[0]}

    def _draft_propose(self) -> List[List[int]]:
        tr = _tele.get_tracer()
        if not tr.enabled:
            return self._draft_propose_inner()
        with tr.span("draft_propose", rows=len(self.inflight),
                     depth=self.spec_depth):
            return self._draft_propose_inner()

    def _draft_propose_inner(self) -> List[List[int]]:
        """One stacked draft pass over every in-flight request: catch the
        draft cache up to the committed tokens, then chain ``spec_depth``
        greedy proposals per row.

        Rows may need different catch-up counts (1 after a partial
        accept, 2 after a full accept — the bonus token was never drafted).
        The batch feeds every row its last ``C = max(gap)`` committed
        tokens at their own slots: rows with a smaller gap RE-feed tokens
        already in their draft cache, overwriting those slots with
        bitwise-identical K/V (K/V depend only on token and position), so
        one jitted executable serves the ragged batch."""
        reqs = self.inflight
        c = max(len(r.tokens) - r.draft_pos for r in reqs)
        logits = None
        for i in range(c):
            toks = np.asarray([[r.tokens[len(r.tokens) - c + i]]
                               for r in reqs], np.int32)
            pos = np.asarray([len(r.tokens) - c + i for r in reqs],
                             np.int32)
            logits, self._draft_caches = self.draft.decode_batch(
                toks, self._draft_caches, pos)
        for r in reqs:
            r.draft_pos = len(r.tokens)
        props: List[List[int]] = [[] for _ in reqs]
        cur = np.asarray(jnp.argmax(logits, -1), np.int32)      # (R,)
        for j in range(self.spec_depth):
            for i in range(len(reqs)):
                props[i].append(int(cur[i]))
            if j < self.spec_depth - 1:
                pos = np.asarray([len(r.tokens) + j for r in reqs],
                                 np.int32)
                logits, self._draft_caches = self.draft.decode_batch(
                    cur[:, None], self._draft_caches, pos)
                cur = np.asarray(jnp.argmax(logits, -1), np.int32)
        return props

    # ------------------------------------------------------------------
    def _first_token(self, req: Request) -> None:
        """TTFT bookkeeping: called right before a request's FIRST
        generated token is appended (re-admitted preempted requests have
        generated > 0 and keep their original mark)."""
        if req.generated == 0 and req.first_token_round < 0:
            req.first_token_round = self.round
            req.t_first = time.perf_counter() - self._t0

    def _ensure_chunk_pools(self) -> None:
        """Chunk jobs write K/V straight into the physical pools, so the
        pool arrays must exist before the first chunk round — even when
        no monolithic prefill ever captured a cache template.  Builds the
        template from one transient layer load (warmup does this ahead
        of time; this is the cold-start fallback)."""
        if self._pools is not None:
            return
        eng = self.engine
        eng._ensure_aux(self.ledger, self.events, self._t0)
        emb = eng._resident["embed"]
        w0 = eng._load(eng.layer_names[0])
        x1 = eng.fns["embed"](emb, jnp.zeros((1, 1), jnp.int32))
        _, c1 = eng._layer_cache(0, w0, x1, self._nb * self.page_size)
        del w0
        self._ensure_pool_arrays({name: c1 for name in eng.layer_names})

    def step(self) -> bool:
        """One round boundary + (if there is work) one pipeline round.
        Returns False once every submitted request has retired."""
        eng = self.engine
        now = time.perf_counter() - self._t0
        for r in self.queue:
            # wall-clock arrival mark: the first boundary at/after the
            # request's arrival round (rounds are the policy clock; the
            # wall marks only feed observability percentiles)
            if r.arrival_round <= self.round and r.t_arrival < 0:
                r.t_arrival = now
        if self.page_size:
            # map every decoder's write page first (may preempt), THEN
            # admit into whatever room is left
            self._grow_pages()
        admitted = self._admit()
        if not self.inflight and not admitted:
            if not self.queue:
                return False
            # idle gap: fast-forward to the next arrival (no weight stream)
            self.round = max(self.round + 1,
                             min(r.arrival_round for r in self.queue))
            return True

        fns, t0 = eng.fns, self._t0
        self.events.append((time.perf_counter() - t0, "round",
                            str(self.round)))
        tr = _tele.get_tracer()
        if tr.enabled:
            tr.instant("serve_round", round=self.round,
                       inflight=len(self.inflight) + len(admitted))
        # serving-tier round shape: DECODERS advance one token through
        # the stacked decode batch; CHUNKERS (mid-chunked-prefill, plus
        # this boundary's long-prompt admissions) feed one C-token chunk
        # each through the stacked verify window; unchunked admissions
        # run the monolithic cache-capturing prefill
        decoders = [r for r in self.inflight if not r.prefilling]
        chunkers = ([r for r in self.inflight if r.prefilling]
                    + [r for r in admitted if r.prefilling])
        pre_admits = [r for r in admitted if not r.prefilling]
        # ---- build the decode batch (stacked last tokens, ragged pos;
        # speculative mode widens each row to its verify window
        # [last committed token, draft proposals...])
        dec_x = dec_pos = props = None
        if decoders:
            emb = eng._resident.get("embed")
            if emb is None:
                eng._ensure_aux(self.ledger, self.events, t0)
                emb = eng._resident["embed"]
            if self.spec_depth:
                # spec mode never chunks (ctor enforces it), so the
                # decode rows stay parallel to self.inflight
                props = self._draft_propose()
                last = np.asarray(
                    [[r.tokens[-1]] + props[i]
                     for i, r in enumerate(decoders)], np.int32)
            else:
                last = np.asarray([[r.tokens[-1]] for r in decoders],
                                  np.int32)
            dec_x = fns["embed"](emb, jnp.asarray(last))
            dec_pos = jnp.asarray([r.pos for r in decoders], jnp.int32)
        # ---- build the stacked chunk batch: row i feeds C tokens at
        # positions [start, start + C); the FINAL chunk slides its
        # window back to [L - C, L) — the overlap RE-feeds tokens whose
        # K/V is already paged in, rewriting identical bytes (K/V depend
        # only on token and position), so one jitted (Bc, C) executable
        # serves every chunk round
        chunk_x = chunk_tables = chunk_pos = None
        chunk_meta: List[Tuple[Request, int]] = []   # (req, window_end)
        if chunkers:
            eng._ensure_aux(self.ledger, self.events, t0)
            emb = eng._resident["embed"]
            self._ensure_chunk_pools()
            c = self.chunk
            rows, starts = [], []
            for r in chunkers:
                n = len(r.tokens)
                w0 = min(r.prefill_pos, n - c)
                rows.append(r.tokens[w0:w0 + c])
                starts.append(w0)
                chunk_meta.append((r, w0 + c))
            chunk_x = fns["embed"](
                emb, jnp.asarray(np.asarray(rows, np.int32)))
            chunk_pos = jnp.asarray(starts, jnp.int32)
            tb = np.zeros((len(chunkers), self._nb), np.int32)
            for i, r in enumerate(chunkers):
                tb[i, :len(r.table.pages)] = r.table.pages
            chunk_tables = jnp.asarray(tb)
            self._chunk_jobs += len(chunkers)
            if tr.enabled:
                for (r, end) in chunk_meta:
                    tr.instant("chunk_job", rid=r.rid, round=self.round,
                               end=end)
        # ---- build prefill jobs for this boundary's admissions
        pre_xs = []
        if pre_admits:
            eng._ensure_aux(self.ledger, self.events, t0)
            emb = eng._resident["embed"]
            for req in pre_admits:
                toks = jnp.asarray(np.asarray(req.tokens, np.int32)[None])
                pre_xs.append(fns["embed"](emb, toks))

        chunk_out = None
        if self.page_size:
            # stacked block tables, padded with page 0 (masked slots)
            dec_tables = None
            if dec_x is not None:
                tb = np.zeros((len(decoders), self._nb), np.int32)
                for i, r in enumerate(decoders):
                    tb[i, :len(r.table.pages)] = r.table.pages
                dec_tables = jnp.asarray(tb)
            paged_work = dec_x is not None or chunk_x is not None
            dec_x, pools, pre_outs, pre_caches, chunk_out = \
                eng.run_batch_round(
                    self.ledger, self.events, t0,
                    decode_x=dec_x,
                    decode_pos=dec_pos,
                    prefill_xs=pre_xs,
                    prefill_total=self._nb * self.page_size,
                    paged_pools=(self._pools if paged_work else None),
                    decode_tables=dec_tables,
                    chunk_x=chunk_x,
                    chunk_tables=chunk_tables,
                    chunk_pos=chunk_pos)
            if paged_work:
                self._pools = pools
            self._scatter_prefills(pre_admits, pre_caches)
        else:
            dec_x, caches, pre_outs, pre_caches, _ = eng.run_batch_round(
                self.ledger, self.events, t0,
                decode_x=dec_x,
                decode_caches=self._caches,
                decode_pos=dec_pos,
                prefill_xs=pre_xs,
                prefill_total=self.max_total_len)
            self._caches = caches

        # ---- heads: one greedy token per request this round — or, in
        # speculative mode, the accepted proposal prefix plus the
        # target's bonus token
        head = eng._resident["head"]
        if dec_x is not None and self.spec_depth:
            logits = fns["head_all"](head, dec_x)              # (R, W, V)
            greedy = np.asarray(jnp.argmax(logits, -1))        # (R, W)
            self._spec_rounds += 1
            for row, req in enumerate(decoders):
                prop = props[row]
                a = 0
                while a < len(prop) and prop[a] == int(greedy[row, a]):
                    a += 1
                # accepted prefix + the target's token after it, clamped
                # to the request's remaining token allowance (any prefix
                # of the commit list is the exact greedy continuation)
                remaining = req.max_new_tokens - req.generated
                commit = (prop[:a] + [int(greedy[row, a])])[:remaining]
                old_len = len(req.tokens)
                req.tokens.extend(commit)
                req.generated += len(commit)
                # draft slots old_len..old_len+depth-2 hold the proposal
                # K/V; they stay valid while the proposal matched the
                # committed token
                req.draft_pos = old_len + max(
                    0, min(a, self.spec_depth - 1, len(commit)))
                # count only proposals that could possibly commit — the
                # window always spans the full depth (uniform jitted
                # shapes), but near max_new_tokens the tail is clamped
                # away and should not read as rejections
                self._draft_tokens += min(len(prop), remaining)
                self._accepted_tokens += min(a, remaining)
        elif dec_x is not None:
            logits = fns["head"](head, dec_x)                  # (R, V)
            nxt = np.asarray(jnp.argmax(logits, -1))
            for row, req in enumerate(decoders):
                req.tokens.append(int(nxt[row]))
                req.generated += 1
        if chunk_out is not None:
            # head reads the window's LAST column — only meaningful for
            # a FINAL chunk, whose last column sits at the prompt's last
            # token; non-final rows just advance their chunk cursor
            logits = fns["head"](head, chunk_out)              # (Bc, V)
            nxt = np.asarray(jnp.argmax(logits, -1))
            for i, (req, end) in enumerate(chunk_meta):
                if end >= len(req.tokens):       # final chunk: sample
                    req.prefilling = False
                    req.prefill_pos = len(req.tokens)
                    self._first_token(req)
                    req.tokens.append(int(nxt[i]))
                    req.generated += 1
                else:
                    req.prefill_pos = end
        for i, req in enumerate(pre_admits):
            logits = fns["head"](head, pre_outs[i])            # (1, V)
            self._first_token(req)
            req.tokens.append(int(jnp.argmax(logits, -1)[0]))
            req.generated += 1           # re-prefills resume, not reset
        if self.spec_depth and admitted:
            # seed each admission's draft-cache row from its own prompt
            # prefill (the generated first token is caught up next round)
            rows = []
            for req in admitted:
                toks = jnp.asarray(np.asarray(req.tokens[:-1],
                                              np.int32)[None])
                _, dc = self.draft.prefill(toks, self._draft_total)
                req.draft_pos = len(req.tokens) - 1
                rows.append(dc)
            self._draft_caches = self._rows_concat(
                [self._draft_caches] + rows)

        # ---- merge admissions, then retire mid-stream finishers
        if not self.page_size:
            self._append_rows(pre_caches)
        self.inflight.extend(admitted)
        self._max_seen = max(self._max_seen, len(self.inflight))
        finished = [r for r in self.inflight if r.done]
        if finished:
            keep = [i for i, r in enumerate(self.inflight) if not r.done]
            self.inflight = [self.inflight[i] for i in keep]
            if not self.page_size:       # paged rows live in the pool
                self._drop_rows(keep)
            elif self.spec_depth:
                self._draft_caches = self._rows_keep(self._draft_caches,
                                                     keep)
            self._retire(finished)
        self.round += 1
        return bool(self.inflight or self.queue)

    # ------------------------------------------------------------------
    def run(self) -> Tuple[Dict[int, np.ndarray], ServeStats]:
        """Drain the queue; returns ({rid: full token sequence}, stats)."""
        t_start = time.perf_counter()
        while self.step():
            pass
        lat = time.perf_counter() - t_start
        outs = {rid: np.asarray(r.tokens)
                for rid, r in sorted(self.done.items())}
        expert_kw = {}
        if self.engine.expert is not None:
            expert_kw = self.engine.expert.stats_since(self._expert_snap)
            self._expert_snap = self.engine.expert.snapshot()
        paged_kw = {}
        if self.page_size:
            paged_kw = dict(
                page_size=self.page_size,
                pages_allocated=self.pool.stats.allocs,
                page_reuses=self.pool.stats.reuses,
                prefix_hit_pages=self.tree.hits if self.tree else 0,
                cow_copies=self.pool.stats.cow_copies,
                preemptions=self.preemptions,
                pool_pages_peak=self.pool.mapped_peak)
        spec_kw = {}
        if self.spec_depth:
            spec_kw = dict(spec_depth=self.spec_depth,
                           spec_rounds=self._spec_rounds,
                           draft_tokens=self._draft_tokens,
                           accepted_tokens=self._accepted_tokens)
        # paged mode: the pool records the true mapped high-water on
        # every alloc (an end-of-boundary sample would miss pages a
        # mid-loop preemption freed again)
        cache_peak = (self.pool.mapped_peak_bytes if self.page_size
                      else self._cache_peak)
        faults = _tele.counter_values("prefetch.retries",
                                      "prefetch.faults_absorbed")
        # every request retired: the request-scoped tiers must have
        # drained exactly (audit mode raises naming the leaking owner;
        # the pinned window / draft / expert reservation legitimately
        # stay resident for the session)
        self.ledger.audit_check_drained("stream", "kv_pages",
                                        "spec_headroom")
        stats = ServeStats(
            rounds=self.round, latency_s=lat, peak_bytes=self.ledger.peak,
            loads=sum(1 for e in self.events if e[1] == "load_end"),
            streamed_bytes=self.engine._streamed(self.events),
            new_tokens=sum(r.generated for r in self.done.values()),
            requests=len(self.done), max_inflight_seen=self._max_seen,
            cache_bytes_peak=cache_peak, events=self.events,
            seed=self.seed, **paged_kw, **expert_kw, **spec_kw,
            retries=faults[0] - self._fault_base[0],
            faults_absorbed=faults[1] - self._fault_base[1],
            peak_breakdown=dict(self.ledger.peak_breakdown),
            **self._slo_stats())
        self._record_metrics(stats)
        return outs, stats

    def _record_metrics(self, stats: ServeStats) -> None:
        """Publish the session's headline stats into the process-wide
        metrics registry, so ``snapshot()`` (serve.py's summary table and
        ``--metrics-out``) sees serving outcomes next to the live
        counters the subsystems incremented along the way."""
        m = _tele.metrics()
        m.gauge("serve.rounds").set(stats.rounds)
        m.gauge("serve.requests").set(stats.requests)
        m.gauge("serve.new_tokens").set(stats.new_tokens)
        m.gauge("serve.tokens_per_s").set(stats.tokens_per_s)
        m.gauge("serve.streamed_bytes").set(stats.streamed_bytes)
        m.gauge("serve.ledger_peak_bytes").set(stats.peak_bytes)
        m.gauge("serve.cache_peak_bytes").set(stats.cache_bytes_peak)
        # per-owner shares at the ledger peak (exported via --metrics-out;
        # they sum exactly to serve.ledger_peak_bytes)
        for owner, nbytes in stats.peak_breakdown.items():
            m.gauge(f"ledger.peak.{owner}_bytes").set(nbytes)
        if stats.expert_hits or stats.expert_misses:
            m.gauge("serve.expert_hit_rate").set(stats.expert_hit_rate)
        if stats.draft_tokens:
            m.gauge("serve.acceptance_rate").set(stats.acceptance_rate)
        if stats.page_size:
            m.gauge("serve.prefix_hit_pages").set(stats.prefix_hit_pages)

    # ---- serving-tier accounting -------------------------------------
    def _req_slo(self, req: Request
                 ) -> Tuple[Optional[float], Optional[float], bool]:
        """(ttft_rounds, tpot_rounds, meets_slo) for one finished
        request.  TTFT counts from the ORIGINAL arrival (born_round —
        preemption re-queues mutate arrival_round); TPOT averages the
        rounds per token after the first."""
        if req.rejected or req.first_token_round < 0:
            return None, None, False
        ttft = float(req.first_token_round - req.born_round + 1)
        tpot = (float(req.finished_round - req.first_token_round)
                / (req.generated - 1) if req.generated > 1 else 0.0)
        ok = True
        if self.slo is not None:
            if (self.slo.ttft_rounds is not None
                    and ttft > self.slo.ttft_rounds):
                ok = False
            if (self.slo.tpot_rounds is not None
                    and tpot > self.slo.tpot_rounds):
                ok = False
        return ttft, tpot, ok

    def _slo_stats(self) -> Dict:
        """Serving-tier ServeStats fields: round-based TTFT/TPOT
        percentiles (deterministic under a fixed trace), their
        wall-clock mirrors, and goodput-under-SLO."""
        reqs = list(self.done.values())
        ttfts, tpots, good_tokens, attained = [], [], 0, 0
        ttfts_s, tpots_s = [], []
        for r in reqs:
            ttft, tpot, ok = self._req_slo(r)
            if ttft is not None:
                ttfts.append(ttft)
                tpots.append(tpot)
                if r.t_first >= 0 and r.t_arrival >= 0:
                    ttfts_s.append(r.t_first - r.t_arrival)
                if r.generated > 1 and r.t_done >= 0 and r.t_first >= 0:
                    tpots_s.append((r.t_done - r.t_first)
                                   / (r.generated - 1))
            if ok:
                attained += 1
                good_tokens += r.generated

        def pct(xs, q):
            return float(np.percentile(np.asarray(xs), q)) if xs else 0.0

        # wall-clock latency histograms for the registry snapshot (the
        # drift report and --metrics-out read these)
        m = _tele.metrics()
        for v in ttfts_s:
            m.histogram("serve.ttft_s").observe(v)
        for v in tpots_s:
            m.histogram("serve.tpot_s").observe(v)

        return dict(
            tenants=len({r.tenant for r in reqs}) if reqs else 0,
            chunk_size=self.chunk,
            chunk_jobs=self._chunk_jobs,
            ttft_p50_rounds=pct(ttfts, 50),
            ttft_p99_rounds=pct(ttfts, 99),
            tpot_p50_rounds=pct(tpots, 50),
            tpot_p99_rounds=pct(tpots, 99),
            ttft_p50_s=pct(ttfts_s, 50), ttft_p99_s=pct(ttfts_s, 99),
            tpot_p50_s=pct(tpots_s, 50), tpot_p99_s=pct(tpots_s, 99),
            slo_attained=(attained / len(reqs)) if reqs else 1.0,
            goodput_tokens=good_tokens,
            slo_rejections=self.slo_rejections,
            policy=list(self.policy_log))

    # ------------------------------------------------------------------
    def warmup(self, prompt_lens=()) -> "BatchScheduler":
        """Pre-compile the serving executables: the batched decode fn for
        every batch size up to ``max_inflight`` (plus head/embed at those
        shapes) and the prefill fn per distinct prompt length — so the
        timed serving loop never stalls the Inference Agent on a jit
        compile while the Loading Agents race ahead."""
        eng = self.engine
        fns = eng.fns
        emb = eng._resident.get("embed") or eng._load("embed")
        head = eng._resident.get("head") or eng._load("head")
        w0 = eng._load(eng.layer_names[0])
        T = (self._nb * self.page_size if self.page_size
             else self.max_total_len)
        for s in sorted(set(int(p) for p in prompt_lens)):
            x = fns["embed"](emb, jnp.zeros((1, s), jnp.int32))
            px, _ = eng._layer_cache(0, w0, x, T)
            fns["head"](head, px).block_until_ready()
        x1 = fns["embed"](emb, jnp.zeros((1, 1), jnp.int32))
        _, c1 = eng._layer_cache(0, w0, x1, T)
        if self.page_size:
            # one fixed-size pool per leaf: compile the paged decode at
            # every batch size (the pool rows never change, so these are
            # the serving executables).  Speculative serving decodes
            # exclusively through W-wide verify windows, so it warms
            # those shapes instead — plus the draft's own executables.
            pool1 = self._pool_like(c1)
            w = self.spec_depth + 1
            for r in range(1, self.max_inflight + 1):
                tbr = jnp.zeros((r, self._nb), jnp.int32)
                if self.spec_depth:
                    xr = fns["embed"](emb, jnp.zeros((r, w), jnp.int32))
                    dr, _ = fns["layer_verify_paged"](
                        w0, xr, pool1, tbr, jnp.zeros((r,), jnp.int32))
                    fns["head_all"](head, dr).block_until_ready()
                else:
                    xr = fns["embed"](emb, jnp.zeros((r, 1), jnp.int32))
                    dr, _ = fns["layer_decode_paged"](
                        w0, xr, pool1, tbr, jnp.zeros((r,), jnp.int32))
                    fns["head"](head, dr).block_until_ready()
                if self.chunk:
                    # chunked prefill rides (r, C) verify windows
                    xc = fns["embed"](emb,
                                      jnp.zeros((r, self.chunk), jnp.int32))
                    dc, _ = fns["layer_verify_paged"](
                        w0, xc, pool1, tbr, jnp.zeros((r,), jnp.int32))
                    fns["head"](head, dc).block_until_ready()
            if self.chunk:
                # chunk rounds write straight into the pools — create
                # them now so a cold chunked admission needs no extra
                # layer load (see _ensure_chunk_pools)
                self._ensure_pool_arrays(
                    {name: c1 for name in eng.layer_names})
            if self.spec_depth:
                for s in sorted(set(int(p) for p in prompt_lens)):
                    self.draft.prefill(jnp.zeros((1, s), jnp.int32),
                                       self._draft_total)
                _, dc1 = self.draft.prefill(jnp.zeros((1, 1), jnp.int32),
                                            self._draft_total)
                for r in range(1, self.max_inflight + 1):
                    dcr = {name: jax.tree.map(
                        lambda a: jnp.concatenate([a] * r), c)
                        for name, c in dc1.items()}
                    self.draft.decode_batch(
                        jnp.zeros((r, 1), jnp.int32), dcr,
                        jnp.zeros((r,), jnp.int32))
        else:
            for r in range(1, self.max_inflight + 1):
                cr = jax.tree.map(lambda a: jnp.concatenate([a] * r), c1)
                xr = fns["embed"](emb, jnp.zeros((r, 1), jnp.int32))
                dr, _ = eng._layer_decode(0, w0, xr, cr,
                                          jnp.zeros((r,), jnp.int32))
                fns["head"](head, dr).block_until_ready()
        del w0, emb, head
        if eng.expert is not None:
            # warmup's compile-time fetches are not serving traffic
            self._expert_snap = eng.expert.snapshot()
        self._t0 = time.perf_counter()
        return self
