"""PIPELOAD — the paper's primary contribution.

Execution engine (loading/inference/daemon agents + signals), layer
profiler, pipeline planner and the Hermes facade tying them together.
"""
from repro.core.engine import MODES, PipeloadEngine, RunStats  # noqa: F401
from repro.core.hermes import Hermes  # noqa: F401
from repro.core.planner import (PlanEntry, analytic_latency, plan,  # noqa: F401
                                simulate)
from repro.core.profiler import profile_model  # noqa: F401
