"""PIPELOAD — the paper's primary contribution.

Execution engine (loading/inference/daemon agents + signals), layer
profiler, pipeline planner and the Hermes facade tying them together.
"""
from repro.core.engine import MODES, PipeloadEngine, RunStats  # noqa: F401
from repro.core.expert_stream import (ExpertCache,  # noqa: F401
                                      ExpertStreamEngine)
from repro.core.hermes import Hermes  # noqa: F401
from repro.core.kv_pages import (BlockTable, PagePool,  # noqa: F401
                                 PrefixNamespaces, PrefixTree, pages_for)
from repro.core.planner import (GenPlanEntry, PlanEntry,  # noqa: F401
                                analytic_latency, expected_unique_experts,
                                plan, plan_generate, simulate)
from repro.core.prefetch import (PrefetchFault,  # noqa: F401
                                 PrefetchRuntime, PrefetchStream)
from repro.core.profiler import profile_model  # noqa: F401
from repro.core.scheduler import (SLO, BatchScheduler,  # noqa: F401
                                  Request, ServeStats)
# NOTE: the telemetry() accessor is deliberately NOT re-exported — it
# would shadow the repro.core.telemetry SUBMODULE attribute and break
# ``from repro.core import telemetry``; reach it via Hermes.telemetry()
# or repro.core.telemetry.telemetry()
from repro.core.telemetry import (MetricsRegistry, Telemetry,  # noqa: F401
                                  Tracer, export_chrome_trace, get_tracer,
                                  metrics)
