"""PIPELOAD Execution Engine (Hermes paper §III).

Three worker roles communicate through an explicit signalling mechanism:

  * ``m`` **Loading Agents** (threads): agent *i* loads shard stripe
    ``L_{i+jm}`` (paper's round-robin assignment) from the layer-partitioned
    on-disk checkpoint, then raises ``S_comp(k)`` (computation-ready).
  * one **Inference Agent** (caller thread): maintains the inference queue —
    layer *k* computes only after *k-1* — and raises ``S_dest(k)`` (memory
    destruction) as soon as layer *k*'s forward pass finishes.
  * one **Daemon Agent** (thread): maintains the resident-bytes ledger,
    frees destroyed layers, and enforces the memory budget: a loader asking
    to exceed the budget blocks (the paper's ``S_stop``) until the daemon
    frees enough space and wakes it.

Engine modes:
  * ``baseline``   — load the whole model, then infer (no pipeline).
  * ``pipeswitch`` — standard pipeline: ONE loading agent, no destruction
    (PipeSwitch-style; peak memory == whole model).
  * ``pipeload``   — the paper's mechanism with ``num_agents`` loaders.

``pin_window > 0`` implements the paper's future-work item (beyond-paper):
the first ``pin_window`` layers stay resident across GPT token iterations,
skipping their reload in later pipeline rounds while still honouring the
budget (the Pipeline Planner picks the window from the schedule).

Generation runs in one of two regimes:

  * ``run_generate(..., kv_cache=False)`` — the paper's engine: the full
    load+prefix pipeline re-runs for EVERY generated token (§V-B2).
  * ``run_generate(..., kv_cache=True)`` — beyond-paper incremental decode:
    ONE pipelined prefill captures a per-layer KV cache (charged to the
    ledger, so weights + cache share the budget), then each token is a
    single-token decode pass that still streams non-pinned layer weights
    through the Loading Agents but touches only O(1) new activations.

Multi-request serving amortises the weight stream further:
``run_batch_round`` runs ONE pipeline round whose Inference Agent step
applies each streamed layer to EVERY in-flight request (stacked decode
states with ragged positions + joining prefills) before destroying it —
the continuous-batching scheduler (core/scheduler.py) drives it.

Quantized checkpoints (int8/int4 shards, checkpoint/quant.py) flow
through unchanged: ``load_shard`` hands back ``QuantizedTensor`` leaves,
the manifest ``bytes`` every ledger acquire/release uses are the
*quantized* sizes (so ``S_stop`` gates, the KV decode floor and the
batch-round admission maths all shrink with the shards), and the module
fns dequantize in-jit at compute time.  The per-layer fp copy is a
transient XLA temporary — like activations, it is not a resident tier
the ledger tracks.

Expert-split MoE checkpoints (manifest ``expert_split``,
core/expert_stream.py) change WHAT a pipeline stage is, not how it
flows: the Loading Agents stripe the per-layer attention+router shards
exactly as above, and the Inference Agent's per-layer step becomes
router-then-demand-load — run the attention+router module, read back the
batch's top-k expert ids, fetch only that union (LRU ExpertCache hits
skip the disk; misses stream on a worker pool), then run the combine
module over the streamed experts.  The cache's capacity is reserved
through the ledger up front for budgeted runs (the KV-page protocol:
the Inference Agent raises ``S_dest`` and must never park on ``S_stop``
itself) and shrinks under admission pressure via LRU eviction.
"""
from __future__ import annotations

import dataclasses
import os
import sys
import threading
import time
from pathlib import Path
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.partition import load_manifest, load_shard
from repro.core import telemetry as _tele
from repro.core.kv_pages import pages_for
from repro.core.modules import build_module_fns
from repro.core.prefetch import PrefetchRuntime
from repro.models.config import ModelConfig

MODES = ("baseline", "pipeswitch", "pipeload")


@dataclasses.dataclass
class RunStats:
    mode: str
    num_agents: int
    latency_s: float
    peak_bytes: int
    events: List[Tuple[float, str, str]]
    loads: int = 0
    streamed_bytes: int = 0   # disk bytes read (quantized shards shrink it)
    # generation extras (0 for single-pass runs)
    new_tokens: int = 0
    prefill_s: float = 0.0
    decode_s: float = 0.0
    cache_bytes: int = 0
    kv_cache: bool = False
    # expert-streaming extras (0 for dense / whole-layer MoE runs)
    expert_hits: int = 0
    expert_misses: int = 0
    expert_evictions: int = 0
    expert_cache_bytes: int = 0
    unique_experts_per_round: float = 0.0
    # speculative-decoding extras (0 for non-speculative runs)
    spec_depth: int = 0
    spec_rounds: int = 0           # draft-propose / verify rounds run
    draft_tokens: int = 0          # tokens the draft proposed
    accepted_tokens: int = 0       # proposals the target confirmed
    # prefetch fault-injection outcomes (REPRO_PREFETCH_FAULT_RATE),
    # wired from the telemetry metrics registry as per-run deltas
    retries: int = 0               # transient load failures retried
    faults_absorbed: int = 0       # injected faults hidden by retries
    # per-owner byte shares at the ledger peak (sums exactly to
    # peak_bytes; empty for runs that never charged the ledger)
    peak_breakdown: Dict[str, int] = dataclasses.field(default_factory=dict)

    def event_log(self, kinds=None):
        return [e for e in self.events if kinds is None or e[1] in kinds]

    @property
    def per_token_s(self) -> float:
        """Mean latency per generated token (whole run / tokens)."""
        return self.latency_s / self.new_tokens if self.new_tokens else 0.0

    @property
    def expert_hit_rate(self) -> float:
        """Fraction of expert activations served from the ExpertCache."""
        total = self.expert_hits + self.expert_misses
        return self.expert_hits / total if total else 0.0

    @property
    def acceptance_rate(self) -> float:
        """Fraction of draft proposals the target accepted."""
        return (self.accepted_tokens / self.draft_tokens
                if self.draft_tokens else 0.0)


#: Resident-tier owner taxonomy (docs/observability.md §Memory
#: attribution).  Every acquire/release names one of these (or a new
#: tag, which just works — the taxonomy is advisory, not an enum):
#:   pin            pinned window + embed/head aux + baseline weights
#:   stream         in-flight streamed layer shards (PrefetchStream)
#:   expert_cache   ExpertCache reservation / resident experts
#:   kv_pages       KV cache bytes, dense reservations and mapped pages
#:   draft          the pinned speculative-draft model's weights
#:   spec_headroom  per-request draft dense-cache rows
LEDGER_OWNERS = ("pin", "stream", "expert_cache", "kv_pages", "draft",
                 "spec_headroom")

_AUDIT_ENV = "REPRO_LEDGER_AUDIT"


class LedgerAuditError(AssertionError):
    """A memory-accounting invariant broke under ``REPRO_LEDGER_AUDIT=1``:
    a per-owner balance went negative (double release / wrong owner tag)
    or an owner held bytes at a drain point (leak).  The message names
    the owner and the call sites involved."""


def _caller_site(depth: int) -> str:
    """``file.py:line`` of the frame ``depth`` levels up (audit only —
    never runs on the un-audited hot path)."""
    try:
        f = sys._getframe(depth)
        return f"{Path(f.f_code.co_filename).name}:{f.f_lineno}"
    except Exception:  # pragma: no cover - interpreter without _getframe
        return "<unknown>"


class _LedgerAudit:
    """Event recorder behind a ``_Ledger`` when ``REPRO_LEDGER_AUDIT=1``.

    Keeps the full event log, a per-owner stack of outstanding acquires
    (with the acquiring call site), and per-``(owner, detail)`` balances
    so leaks can be pinned to a request id.  All methods are called with
    the ledger's cond lock held."""

    def __init__(self):
        # (op, owner, detail, nbytes, site) in program order
        self.events: List[Tuple[str, str, Optional[str], int, str]] = []
        # owner -> [(nbytes_outstanding, site), ...] LIFO
        self.open: Dict[str, List[Tuple[int, str]]] = {}
        # (owner, detail) -> outstanding bytes
        self.balance: Dict[Tuple[str, Optional[str]], int] = {}

    def charge(self, owner, detail, nbytes, depth=3):
        site = _caller_site(depth)
        self.events.append(("acquire", owner, detail, nbytes, site))
        self.open.setdefault(owner, []).append((nbytes, site))
        key = (owner, detail)
        self.balance[key] = self.balance.get(key, 0) + nbytes

    def credit(self, owner, detail, nbytes, owner_resident, depth=3):
        site = _caller_site(depth)
        self.events.append(("release", owner, detail, nbytes, site))
        if owner_resident < 0:
            stack = self.open.get(owner, [])
            last = stack[-1][1] if stack else "<no outstanding acquires>"
            raise LedgerAuditError(
                f"ledger audit: owner '{owner}' balance went negative "
                f"({owner_resident} bytes) releasing {nbytes} at {site} "
                f"— double release or wrong owner tag; last outstanding "
                f"acquire: {last}")
        key = (owner, detail)
        self.balance[key] = self.balance.get(key, 0) - nbytes
        # unwind the outstanding-acquire stack LIFO (releases may split
        # or merge acquires byte-wise; only the byte totals must match)
        left = nbytes
        stack = self.open.get(owner, [])
        while left > 0 and stack:
            got, site0 = stack.pop()
            if got > left:
                stack.append((got - left, site0))
                left = 0
            else:
                left -= got

    def move(self, src, dst, nbytes, src_resident, detail, depth=3):
        site = _caller_site(depth)
        self.events.append(("transfer", f"{src}->{dst}", detail, nbytes,
                            site))
        if src_resident < 0:
            raise LedgerAuditError(
                f"ledger audit: transfer of {nbytes} bytes from '{src}' "
                f"to '{dst}' at {site} drove '{src}' negative "
                f"({src_resident} bytes)")
        left = nbytes
        stack = self.open.get(src, [])
        while left > 0 and stack:
            got, site0 = stack.pop()
            if got > left:
                stack.append((got - left, site0))
                left = 0
            else:
                left -= got
        self.open.setdefault(dst, []).append((nbytes, site))

    def check_drained(self, by_owner, owners):
        bad = []
        for o in owners:
            resid = by_owner.get(o, 0)
            if resid:
                sites = [s for _, s in self.open.get(o, [])]
                where = ", ".join(sites[-3:]) if sites else "<unknown site>"
                bad.append(f"owner '{o}' holds {resid} bytes "
                           f"(outstanding acquires: {where})")
        if bad:
            raise LedgerAuditError(
                "ledger audit: non-zero residue at drain point: "
                + "; ".join(bad))


class _Ledger:
    """Resident-bytes accounting + budget gate (Daemon Agent state).

    Every ``acquire``/``release`` carries an ``owner`` tag (one of
    ``LEDGER_OWNERS``) so the scalar total decomposes into per-tier
    balances (``by_owner``); at every new peak the full breakdown is
    snapshotted under the same lock (``peak_breakdown``), so its values
    sum EXACTLY to ``peak``.  ``transfer`` re-attributes bytes between
    owners without touching the total (kept stream shards becoming
    pinned-window bytes).

    Telemetry: every acquire/release samples the resident total into the
    ``ledger.resident_bytes`` gauge plus a per-owner
    ``ledger.<owner>.resident_bytes`` gauge (always on — a few attribute
    stores) and, when tracing is enabled, into the
    ``ledger_resident_bytes`` / ``ledger_resident_bytes.<owner>``
    counter tracks the Chrome-trace exporter renders as residency
    timelines.  The traced sites guard on ``tracer.enabled`` so the
    disabled path adds no allocation.

    Audit mode (``REPRO_LEDGER_AUDIT=1``, default-on under pytest via
    tests/conftest.py) records every event with its call site and raises
    ``LedgerAuditError`` on negative per-owner balances (double release)
    or on residue at ``audit_check_drained`` points; off, the hot path
    pays only the ``by_owner`` dict update."""

    def __init__(self, budget: Optional[int]):
        self.budget = budget
        self.resident = 0
        self.peak = 0
        self.by_owner: Dict[str, int] = {}
        self.peak_breakdown: Dict[str, int] = {}
        self.cond = threading.Condition()
        self._gauge = _tele.metrics().gauge("ledger.resident_bytes")
        self._owner_gauges: Dict[str, object] = {}
        self.audit = (_LedgerAudit()
                      if os.environ.get(_AUDIT_ENV) == "1" else None)

    def _sample(self, owner: str):
        self._gauge.set(self.resident)
        og = self._owner_gauges.get(owner)
        if og is None:
            og = self._owner_gauges[owner] = _tele.metrics().gauge(
                f"ledger.{owner}.resident_bytes")
        og.set(self.by_owner.get(owner, 0))
        tr = _tele.get_tracer()
        if tr.enabled:
            tr.counter("ledger_resident_bytes", self.resident)
            tr.counter(f"ledger_resident_bytes.{owner}",
                       self.by_owner.get(owner, 0))

    def acquire(self, nbytes: int, stop_flag=None, *,
                owner: str = "untagged", detail: Optional[str] = None):
        """Loader-side: blocks while the budget would be exceeded
        (paper's S_stop semantics).  ``owner`` attributes the bytes to a
        resident tier; ``detail`` is an audit-only sub-key (request id,
        shard name) for per-entity residue queries."""
        with self.cond:
            if self.budget is not None:
                while (self.resident + nbytes > self.budget
                       and self.resident > 0
                       and not (stop_flag() if stop_flag else False)):
                    self.cond.wait(timeout=0.1)
            self.resident += nbytes
            self.by_owner[owner] = self.by_owner.get(owner, 0) + nbytes
            if self.resident > self.peak:
                self.peak = self.resident
                self.peak_breakdown = {o: b for o, b in
                                       self.by_owner.items() if b}
            if self.audit is not None:
                self.audit.charge(owner, detail, nbytes)
            self._sample(owner)

    def release(self, nbytes: int, *, owner: str = "untagged",
                detail: Optional[str] = None):
        with self.cond:
            self.resident -= nbytes
            self.by_owner[owner] = self.by_owner.get(owner, 0) - nbytes
            if self.audit is not None:
                self.audit.credit(owner, detail, nbytes,
                                  self.by_owner[owner])
            self._sample(owner)
            self.cond.notify_all()

    def transfer(self, nbytes: int, src: str, dst: str, *,
                 detail: Optional[str] = None):
        """Re-attribute ``nbytes`` resident bytes from owner ``src`` to
        ``dst`` (total resident unchanged — no budget interaction)."""
        with self.cond:
            self.by_owner[src] = self.by_owner.get(src, 0) - nbytes
            self.by_owner[dst] = self.by_owner.get(dst, 0) + nbytes
            if self.audit is not None:
                self.audit.move(src, dst, nbytes, self.by_owner[src],
                                detail)
            self._sample(src)
            self._sample(dst)

    def audit_check_drained(self, *owners: str):
        """Raise ``LedgerAuditError`` if any named owner still holds
        bytes.  No-op when audit mode is off, so drain points call it
        unconditionally."""
        if self.audit is None:
            return
        with self.cond:
            self.audit.check_drained(self.by_owner, owners)

    def audit_residue(self, owner: str, detail: Optional[str] = None):
        """Outstanding bytes for ``(owner, detail)`` — audit mode only
        (returns None when off)."""
        if self.audit is None:
            return None
        with self.cond:
            return self.audit.balance.get((owner, detail), 0)


def _fault_snap() -> Tuple[int, ...]:
    """Baseline of the prefetch fault counters (registry values)."""
    return _tele.counter_values("prefetch.retries",
                                "prefetch.faults_absorbed")


def _fault_delta(snap: Tuple[int, ...]) -> dict:
    """RunStats kwargs for faults absorbed since ``snap``."""
    now = _tele.counter_values("prefetch.retries",
                               "prefetch.faults_absorbed")
    return {"retries": now[0] - snap[0],
            "faults_absorbed": now[1] - snap[1]}


class DraftModel:
    """A small model pinned WHOLE for speculative drafting.

    Unlike the target — whose layers stream through the Loading Agents —
    the draft is tiny enough to live resident under the budget, like the
    pin window: ``pin`` loads every shard once and charges the ledger;
    proposals are then plain jitted calls with no disk traffic.  The
    draft keeps an ordinary dense KV cache (one contiguous
    ``total_len`` block, charged as extra resident bytes) because its
    cache is orders of magnitude smaller than the target's.
    """

    def __init__(self, ckpt_dir, cfg: ModelConfig, *,
                 attn_impl: Optional[str] = "auto"):
        self.dir = Path(ckpt_dir)
        self.cfg = cfg
        self.manifest = load_manifest(ckpt_dir)
        self.fns = build_module_fns(cfg, attn_impl=attn_impl)
        self.shards = {s["name"]: s for s in self.manifest["shards"]}
        if self.manifest.get("expert_split"):
            raise ValueError("expert-split checkpoints cannot be draft "
                             "models (the draft must pin whole)")
        self.layer_names = [s["name"] for s in self.manifest["shards"]
                            if s["kind"] == "layer"]
        self.total_bytes = sum(s["bytes"] for s in self.shards.values())
        self.weights: Optional[Dict[str, dict]] = None

    def cache_bytes(self, batch: int, total_len: int) -> int:
        return (len(self.layer_names)
                * self.cfg.cache_bytes(batch, total_len))

    def pin(self, ledger: Optional[_Ledger] = None):
        """Load every shard resident; charges ``ledger`` for the lot."""
        if ledger is not None:
            ledger.acquire(self.total_bytes, owner="draft")
        if self.weights is None:
            self.weights = {
                name: jax.tree.map(jnp.asarray, load_shard(self.dir, name))
                for name in self.shards}
        return self

    def unpin(self, ledger: Optional[_Ledger] = None):
        """Return the draft's bytes to the budget (weights stay cached
        host-side for the next run; the LEDGER charge is what budgets)."""
        if ledger is not None:
            ledger.release(self.total_bytes, owner="draft")

    def prefill(self, tokens, total_len: int):
        """Prompt pass; returns (last-token logits (B, V), caches)."""
        assert self.weights is not None, "pin() the draft first"
        fns, w = self.fns, self.weights
        x = fns["embed"](w["embed"], jnp.asarray(tokens))
        caches: Dict[str, dict] = {}
        for name in self.layer_names:
            x, caches[name] = fns["layer_cache"](w[name], x, total_len)
        return fns["head"](w["head"], x), caches

    def decode(self, token: int, caches, pos: int):
        """Feed ``token`` at cache slot ``pos``; returns (logits (1, V),
        caches) — the draft's prediction for slot ``pos + 1``."""
        fns, w = self.fns, self.weights
        x = fns["embed"](w["embed"], jnp.full((1, 1), token, jnp.int32))
        for name in self.layer_names:
            x, caches[name] = fns["layer_decode"](
                w[name], x, caches[name], jnp.int32(pos))
        return fns["head"](w["head"], x), caches

    def decode_batch(self, tokens, caches, pos):
        """Stacked draft step for the serving scheduler: ``tokens``
        (R, 1) fed at ragged per-row slots ``pos`` (R,); returns
        (logits (R, V), caches with leading row dim R)."""
        fns, w = self.fns, self.weights
        x = fns["embed"](w["embed"], jnp.asarray(tokens, jnp.int32))
        pos = jnp.asarray(pos, jnp.int32)
        for name in self.layer_names:
            x, caches[name] = fns["layer_decode"](
                w[name], x, caches[name], pos)
        return fns["head"](w["head"], x), caches


@dataclasses.dataclass
class SpecConfig:
    """Speculative-decoding knobs for ``run_generate(speculative=...)``.

    ``depth`` draft tokens are proposed per round (the planner's
    ``spec_depth``); the draft checkpoint must fit resident next to the
    pinned window — the budget check charges it as extra resident
    bytes."""
    draft_dir: str
    draft_cfg: ModelConfig
    depth: int = 4


class PipeloadEngine:
    def __init__(self, ckpt_dir, cfg: ModelConfig, *,
                 mode: str = "pipeload", num_agents: int = 4,
                 budget_bytes: Optional[int] = None, pin_window: int = 0,
                 attn_impl: Optional[str] = "auto",
                 expert_cache_bytes: Optional[int] = None,
                 page_size: Optional[int] = None):
        assert mode in MODES, mode
        self.dir = Path(ckpt_dir)
        self.cfg = cfg
        self.mode = mode
        self.m = max(1, num_agents) if mode == "pipeload" else 1
        self.budget = budget_bytes
        self.pin = pin_window if mode == "pipeload" else 0
        # paged KV (core/kv_pages.py): cache ledger bytes are charged in
        # page_size-token pages as positions are reached, instead of one
        # max-length reservation up front.  None = dense reservation.
        self.page_size = page_size if page_size and page_size > 0 else None
        self.manifest = load_manifest(ckpt_dir)
        self.fns = build_module_fns(cfg, attn_impl=attn_impl)
        self.shards = {s["name"]: s for s in self.manifest["shards"]}
        self.layer_names = [s["name"] for s in self.manifest["shards"]
                            if s["kind"] == "layer"]
        # persistent across pipeline rounds (pinning / non-destroying modes)
        self._resident: Dict[str, dict] = {}
        # ONE async prefetch runtime for every byte mover: the PIPELOAD
        # Loading Agents stream shard rounds through it and the expert
        # engine demand-loads on the same pool (core/prefetch.py)
        self.runtime = PrefetchRuntime(workers=self.m, name="pipeload")
        # expert-split MoE checkpoints demand-load experts post-router
        self.expert = None
        self.expert_cache_bytes = expert_cache_bytes
        if self.manifest.get("expert_split"):
            from repro.core.expert_stream import ExpertStreamEngine
            self.expert = ExpertStreamEngine(
                self.dir, self.manifest, cfg, self.fns, workers=self.m,
                cache_bytes=expert_cache_bytes, runtime=self.runtime)

    def close(self):
        """Tear down the prefetch runtime (joins worker + drainer
        threads).  Idempotent; the engine stays usable for module-level
        math but cannot run further pipeline rounds."""
        if self.expert is not None:
            self.expert.close()
        self.runtime.close()

    def __enter__(self) -> "PipeloadEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------------
    def warmup(self, batch: int, seq: int, *, decode: bool = False,
               total_len: Optional[int] = None):
        """Compile the module fns ahead of the timed run (serving systems
        warm their executables; without this the first layer's jit compile
        stalls the Inference Agent while Loading Agents race ahead and the
        measured peak degenerates to the whole model).  ``decode=True``
        additionally compiles the KV-cache prefill/decode modules for the
        (batch, seq -> total_len) generation shape."""
        tokens = jnp.zeros((batch, seq), jnp.int32)
        emb = self._resident.get("embed") or self._load("embed")
        head = self._resident.get("head") or self._load("head")
        w0 = self._load(self.layer_names[0])
        x = self.fns["embed"](emb, tokens)
        if decode:
            total = total_len or (seq + 1)
            self.fns["embed"](emb, tokens[:, -1:])   # single-token shape
            _, cache = self._layer_cache(0, w0, x, total)
            x1, _ = self._layer_decode(0, w0, x[:, -1:], cache, seq)
            self.fns["head"](head, x1).block_until_ready()
        x = self._apply_layer(w0, x, k=0)
        self.fns["head"](head, x).block_until_ready()
        del w0, emb, head
        return self

    # ------------------------------------------------------------------
    def _load(self, name: str) -> dict:
        """Disk -> host -> device ("memory" tier)."""
        host = load_shard(self.dir, name)
        return jax.tree.map(jnp.asarray, host)

    # Per-layer apply paths.  Expert-split MoE checkpoints route through
    # the ExpertStreamEngine (router -> demand-load union -> combine);
    # everything else runs the whole-layer jitted module fns.
    def _apply_layer(self, weights, x, k: int = 0):
        if self.expert is not None:
            return self.expert.layer(self.layer_names[k], weights, x)
        y = self.fns["layer"](weights, x)
        y.block_until_ready()
        return y

    def _layer_cache(self, k: int, weights, x, total_len: int):
        if self.expert is not None:
            return self.expert.layer_cache(self.layer_names[k], weights, x,
                                           total_len)
        return self.fns["layer_cache"](weights, x, total_len)

    def _layer_decode(self, k: int, weights, x, cache, pos):
        if self.expert is not None:
            return self.expert.layer_decode(self.layer_names[k], weights, x,
                                            cache, pos)
        return self.fns["layer_decode"](weights, x, cache, pos)

    def _streamed(self, events) -> int:
        """Total shard bytes read from disk this run (manifest sizes, so
        quantized checkpoints stream ~4x/8x fewer bytes per load)."""
        return sum(self.shards[e[2]]["bytes"] for e in events
                   if e[1] == "load_end")

    # ------------------------------------------------------------------
    def _run_pipeline(self, x, ledger: _Ledger, events, t0,
                      destroy: bool,
                      apply_fn: Optional[Callable] = None) -> jnp.ndarray:
        """One pipelined pass over the layer stack (PIPELOAD §III-B).

        ``apply_fn(k, weights, x) -> x`` is the Inference Agent's per-layer
        step; the default is the full-sequence forward.  The KV decode path
        substitutes a cache-aware closure — the loading/destruction
        machinery (S_comp / S_dest / S_stop) is identical.
        """
        names = self.layer_names
        n = len(names)
        if self.expert is not None:
            self.expert.begin_round()
        if apply_fn is None:
            apply_fn = lambda k, w, h: self._apply_layer(w, h, k=k)  # noqa: E731,E501

        # One prefetch stream per round (core/prefetch.py): the Loading
        # Agents are the runtime's pool workers, the Daemon Agent is its
        # destroy drainer, and the in-order grant discipline lives there
        # as a runtime policy.  Pinned layers (beyond-paper resident
        # window) ride along uncharged as ``preloaded`` entries.
        preloaded = {k: self._resident[names[k]] for k in range(n)
                     if names[k] in self._resident}
        stream = self.runtime.stream(
            names, [self.shards[nm]["bytes"] for nm in names], self._load,
            ledger=ledger, preloaded=preloaded, events=events, t0=t0)

        # ---- Inference Agent (this thread): in-order inference queue
        tr = _tele.get_tracer()
        with stream, tr.span("stream_round", layers=n):
            for k in range(n):
                w = stream.wait(k)                   # S_comp(k)
                t = time.perf_counter()
                if tr.enabled:
                    with tr.span("compute", layer=names[k]):
                        x = apply_fn(k, w, x)
                else:
                    x = apply_fn(k, w, x)
                events.append((t - t0, "comp_start", names[k]))
                events.append((time.perf_counter() - t0, "comp_end",
                               names[k]))
                name = names[k]
                pinned = k < self.pin
                if pinned and name not in self._resident:
                    self._resident[name] = w
                if destroy and not pinned:
                    stream.destroy(k, w)             # S_dest(k)
                else:
                    # pin window / pipeswitch: the weights and their
                    # ledger charge leave the stream with us — pinned
                    # layers re-attribute to the pin window, pipeswitch
                    # keeps stay stream bytes until the end-of-pass swap
                    stream.keep(k, owner="pin" if pinned else None)
                del w
        if not destroy:
            # pipeswitch: the whole model was resident for the pass (peak ==
            # full model); it is swapped out when the pass ends (PipeSwitch
            # time-shares the device between tasks), so the ledger releases
            # every non-pinned layer here.
            for k in range(n):
                if names[k] not in self._resident:
                    ledger.release(self.shards[names[k]]["bytes"],
                                   owner="stream")
        return x

    # ------------------------------------------------------------------
    def _ensure_aux(self, ledger: _Ledger, events, t0):
        """embed + head are the paper's "other layers": loaded up front,
        resident for the whole run."""
        for aux in ("embed", "head"):
            if aux not in self._resident:
                ledger.acquire(self.shards[aux]["bytes"],
                               owner="pin", detail=aux)
                self._resident[aux] = self._load(aux)
                events.append((time.perf_counter() - t0, "load_end", aux))

    def _bind_expert(self, ledger: _Ledger, events, t0, *,
                     round_tokens: int = 1):
        """Reserve the ExpertCache's capacity on this run's ledger (no-op
        when already bound to it).  Called after the run's fixed
        reservations (aux shards, KV pages) so the auto capacity is the
        budget headroom left once the pinned window and one streaming
        layer are spoken for.  ``round_tokens`` is the widest batch this
        run's rounds feed the router (a prefill's batch*seq); the cache
        must fit that round's expert working set, or the run would wedge
        mid-pipeline with every fetched expert locked."""
        if self.expert is None or self.expert.bound_to(ledger):
            return
        cap = self.expert_cache_bytes
        need = self.expert.working_set_bytes(round_tokens)
        if self.budget is not None:
            sizes = [self.shards[nm]["bytes"] for nm in self.layer_names]
            pinned = sum(sizes[:self.pin])
            streaming = max(sizes[self.pin:], default=0)
            head = self.budget - ledger.resident - pinned - streaming
            if cap is None:
                cap = head
            elif min(cap, self.expert.total_bytes) > head:
                # reserving past the headroom would park the Inference
                # Agent on S_stop forever — fail loudly instead
                raise ValueError(
                    f"expert_cache_bytes={cap} does not fit budget "
                    f"{self.budget}: only {head} bytes of headroom remain "
                    f"after other shards, KV pages, the pinned window and "
                    f"one streaming layer")
        elif cap is None:
            cap = self.expert.total_bytes
        if min(cap, self.expert.total_bytes) < need:
            raise ValueError(
                f"expert cache too small for this workload: "
                f"{min(cap, self.expert.total_bytes)} bytes available but "
                f"a {round_tokens}-token round can lock "
                f"{need} bytes of experts (min(n_experts, tokens*top_k) "
                f"co-resident); raise the budget / expert_cache_bytes, or "
                f"let the generation-aware planner size the schedule")
        self.expert.reserve(ledger, cap, events, t0)

    def _forward_once(self, tokens, ledger, events, t0) -> jnp.ndarray:
        """embed -> pipelined layers -> head."""
        self._ensure_aux(ledger, events, t0)
        self._bind_expert(ledger, events, t0,
                          round_tokens=tokens.shape[0] * tokens.shape[1])
        x = self.fns["embed"](self._resident["embed"], tokens)

        if self.mode == "baseline":
            # load-all-then-infer
            if self.expert is not None:
                self.expert.begin_round()
            weights = {}
            for name in self.layer_names:
                ledger.acquire(self.shards[name]["bytes"],
                               owner="pin", detail=name)
                weights[name] = self._load(name)
                events.append((time.perf_counter() - t0, "load_end", name))
            for k, name in enumerate(self.layer_names):
                x = self._apply_layer(weights[name], x, k=k)
            self._baseline_weights = weights     # resident (no destruction)
        else:
            destroy = self.mode == "pipeload"
            x = self._run_pipeline(x, ledger, events, t0, destroy)

        return self.fns["head"](self._resident["head"], x)

    # ------------------------------------------------------------------
    def _expert_snap(self) -> Optional[dict]:
        return self.expert.snapshot() if self.expert is not None else None

    def _expert_stats(self, snap: Optional[dict]) -> dict:
        """RunStats expert-streaming fields accumulated since ``snap``."""
        if self.expert is None:
            return {}
        return self.expert.stats_since(snap)

    def run_single(self, tokens) -> Tuple[jnp.ndarray, RunStats]:
        """Single-pass inference (BERT / ViT workloads)."""
        events: List[Tuple[float, str, str]] = []
        ledger = _Ledger(self.budget)
        snap = self._expert_snap()
        fsnap = _fault_snap()
        t0 = time.perf_counter()
        logits = self._forward_once(jnp.asarray(tokens), ledger, events, t0)
        logits.block_until_ready()
        lat = time.perf_counter() - t0
        return logits, RunStats(self.mode, self.m, lat, ledger.peak, events,
                                loads=sum(1 for e in events
                                          if e[1] == "load_end"),
                                streamed_bytes=self._streamed(events),
                                peak_breakdown=dict(ledger.peak_breakdown),
                                **self._expert_stats(snap),
                                **_fault_delta(fsnap))

    def run_generate(self, tokens, new_tokens: int, *,
                     kv_cache: bool = False,
                     speculative: Optional[SpecConfig] = None
                     ) -> Tuple[jnp.ndarray, RunStats]:
        """GPT-style generation.

        ``kv_cache=False`` reproduces the paper's engine: re-run the full
        load+prefix pipeline for EVERY generated token (§V-B2).
        ``kv_cache=True`` prefills once, then decodes token-by-token against
        per-layer KV caches (see module docstring).
        ``speculative`` (a ``SpecConfig``; requires ``page_size``) runs
        the draft/verify loop: a pinned draft proposes ``depth`` tokens
        per round and ONE stacked weight-stream round verifies them all
        — greedy-token-identical to the non-speculative paths."""
        if speculative is not None:
            return self._generate_spec(tokens, new_tokens, speculative)
        if kv_cache:
            return self._generate_kv(tokens, new_tokens)
        events: List[Tuple[float, str, str]] = []
        ledger = _Ledger(self.budget)
        snap = self._expert_snap()
        fsnap = _fault_snap()
        toks = jnp.asarray(tokens)
        t0 = time.perf_counter()
        prefill_s = 0.0
        if self.expert is not None:
            # re-prefill rounds GROW with every generated token; bind the
            # expert cache against the widest (last) round up front so an
            # infeasible budget fails here, not mid-generation
            b, s0 = toks.shape
            self._ensure_aux(ledger, events, t0)
            self._bind_expert(ledger, events, t0,
                              round_tokens=b * (s0 + new_tokens - 1))
        for step in range(new_tokens):
            if self.mode == "baseline" and step > 0:
                # baseline keeps the model resident: only re-infer
                if self.expert is not None:
                    self.expert.begin_round()
                x = self.fns["embed"](self._resident["embed"], toks)
                for k, name in enumerate(self.layer_names):
                    x = self._apply_layer(self._baseline_weights[name], x,
                                          k=k)
                logits = self.fns["head"](self._resident["head"], x)
            else:
                logits = self._forward_once(toks, ledger, events, t0)
            nxt = jnp.argmax(logits, -1).astype(toks.dtype)[:, None]
            toks = jnp.concatenate([toks, nxt], axis=1)
            if step == 0:
                nxt.block_until_ready()
                prefill_s = time.perf_counter() - t0
        toks.block_until_ready()
        lat = time.perf_counter() - t0
        return toks, RunStats(self.mode, self.m, lat, ledger.peak, events,
                              loads=sum(1 for e in events
                                        if e[1] == "load_end"),
                              streamed_bytes=self._streamed(events),
                              new_tokens=new_tokens, prefill_s=prefill_s,
                              decode_s=lat - prefill_s,
                              peak_breakdown=dict(ledger.peak_breakdown),
                              **self._expert_stats(snap),
                              **_fault_delta(fsnap))

    # ------------------------------------------------------------------
    def _generate_kv(self, tokens, new_tokens: int
                     ) -> Tuple[jnp.ndarray, RunStats]:
        """Incremental decode: one cache-capturing prefill, then
        ``new_tokens - 1`` single-token passes over the same pipeline."""
        if new_tokens <= 0:   # match the kv_cache=False path: no-op run
            return jnp.asarray(tokens), RunStats(self.mode, self.m, 0.0, 0,
                                                 [], kv_cache=True)
        events: List[Tuple[float, str, str]] = []
        ledger = _Ledger(self.budget)
        snap = self._expert_snap()
        fsnap = _fault_snap()
        toks = jnp.asarray(tokens)
        b, s0 = toks.shape
        total = s0 + new_tokens
        names = self.layer_names
        n = len(names)
        expert_floor = (self.expert.working_set_bytes(b * s0)
                        if self.expert is not None else None)
        # Paged accounting (core/kv_pages.py): charge the ledger one
        # page at a time as decode reaches new positions, instead of
        # the whole max-length block up front — the ledger peak tracks
        # pages actually mapped.  Feasibility still checks the final
        # page count (a single request cannot be preempted
        # mid-generation; the scheduler path can).  Expert-split MoE
        # keeps the dense up-front reservation: _bind_expert sizes the
        # ExpertCache from the ledger headroom at bind time, so decode
        # pages mapped LATER would find their bytes already handed to
        # the cache and park ensure_slots on S_stop forever.
        paged = bool(self.page_size) and self.expert is None
        if paged:
            ps = self.page_size
            cache_total = (pages_for(total, ps)
                           * n * self.cfg.cache_bytes(b, ps))
        else:
            cache_total = n * self.cfg.cache_bytes(b, total)
        self._check_kv_budget(cache_total, expert_floor=expert_floor)

        caches: Dict[str, dict] = {}
        t0 = time.perf_counter()
        self._ensure_aux(ledger, events, t0)
        # Reserve the cache bytes the NEXT round needs before its
        # pipeline starts: the Inference Agent raises S_dest, so letting
        # it block on S_stop mid-pipeline would deadlock; the floor
        # check above guarantees these boundary acquires never wait, and
        # loaders then see the correct streaming headroom each round.
        # Dense reservations grab everything here; paged runs grow
        # page-by-page via ensure_slots().
        mapped = {"bytes": 0}

        def ensure_slots(slots: int):
            """Grow the charged reservation to cover ``slots`` cache
            positions (rounded up to pages when paged)."""
            if paged:
                need = (pages_for(slots, self.page_size)
                        * n * self.cfg.cache_bytes(b, self.page_size))
            else:
                need = cache_total
            if need > mapped["bytes"]:
                ledger.acquire(need - mapped["bytes"], owner="kv_pages")
                events.append((time.perf_counter() - t0, "cache_reserve",
                               str(need - mapped["bytes"])))
                mapped["bytes"] = need

        ensure_slots(s0 if paged else total)
        self._bind_expert(ledger, events, t0, round_tokens=b * s0)
        x = self.fns["embed"](self._resident["embed"], toks)

        # ---- prefill: pipelined pass that also captures per-layer caches
        def prefill_apply(k, w, h):
            h, cache = self._layer_cache(k, w, h, total)
            h.block_until_ready()
            caches[names[k]] = cache
            events.append((time.perf_counter() - t0, "cache_alloc",
                           names[k]))
            return h

        if self.mode == "baseline":
            if self.expert is not None:
                self.expert.begin_round()
            weights = getattr(self, "_baseline_weights", None)
            if weights is None:
                weights = {}
                for name in names:
                    ledger.acquire(self.shards[name]["bytes"],
                                   owner="pin", detail=name)
                    weights[name] = self._load(name)
                    events.append((time.perf_counter() - t0, "load_end",
                                   name))
                self._baseline_weights = weights
            else:
                for name in names:   # already resident from an earlier run
                    ledger.acquire(self.shards[name]["bytes"],
                                   owner="pin", detail=name)
            for k, name in enumerate(names):
                x = prefill_apply(k, weights[name], x)
        else:
            destroy = self.mode == "pipeload"
            x = self._run_pipeline(x, ledger, events, t0, destroy,
                                   apply_fn=prefill_apply)
        logits = self.fns["head"](self._resident["head"], x)
        nxt = jnp.argmax(logits, -1).astype(toks.dtype)[:, None]
        toks = jnp.concatenate([toks, nxt], axis=1)
        nxt.block_until_ready()
        prefill_s = time.perf_counter() - t0

        # ---- decode: one single-token pipeline round per remaining token
        def decode_apply(pos):
            def apply(k, w, h):
                h, caches[names[k]] = self._layer_decode(
                    k, w, h, caches[names[k]], pos)
                h.block_until_ready()
                return h
            return apply

        for step in range(1, new_tokens):
            pos = s0 + step - 1          # cache slot of the token we feed
            ensure_slots(pos + 1)        # paged: map the write page
            events.append((time.perf_counter() - t0, "token", str(step)))
            x = self.fns["embed"](self._resident["embed"], toks[:, -1:])
            if self.mode == "baseline":
                if self.expert is not None:
                    self.expert.begin_round()
                for k, name in enumerate(names):
                    x = decode_apply(pos)(k, self._baseline_weights[name], x)
            else:
                x = self._run_pipeline(x, ledger, events, t0,
                                       self.mode == "pipeload",
                                       apply_fn=decode_apply(pos))
            logits = self.fns["head"](self._resident["head"], x)
            nxt = jnp.argmax(logits, -1).astype(toks.dtype)[:, None]
            toks = jnp.concatenate([toks, nxt], axis=1)

        toks.block_until_ready()
        lat = time.perf_counter() - t0
        caches.clear()                    # free cache pages ...
        ledger.release(mapped["bytes"],   # ... and return them to the budget
                       owner="kv_pages")
        ledger.audit_check_drained("stream", "kv_pages")
        return toks, RunStats(self.mode, self.m, lat, ledger.peak, events,
                              loads=sum(1 for e in events
                                        if e[1] == "load_end"),
                              streamed_bytes=self._streamed(events),
                              new_tokens=new_tokens, prefill_s=prefill_s,
                              decode_s=lat - prefill_s,
                              cache_bytes=mapped["bytes"], kv_cache=True,
                              peak_breakdown=dict(ledger.peak_breakdown),
                              **self._expert_stats(snap),
                              **_fault_delta(fsnap))

    # ------------------------------------------------------------------
    def _draft_model(self, spec: SpecConfig) -> DraftModel:
        """One DraftModel per checkpoint dir, cached across runs (the
        benchmark calls run_generate repeatedly; re-reading the draft
        from disk each run would charge its load to the decode phase)."""
        cache = getattr(self, "_drafts", None)
        if cache is None:
            cache = self._drafts = {}
        key = str(spec.draft_dir)
        if key not in cache:
            cache[key] = DraftModel(spec.draft_dir, spec.draft_cfg)
        return cache[key]

    def _generate_spec(self, tokens, new_tokens: int, spec: SpecConfig
                       ) -> Tuple[jnp.ndarray, RunStats]:
        """Speculative draft/verify generation over the PAGED cache.

        Each round: the pinned draft proposes up to ``spec.depth``
        tokens (plain resident-model decodes, no weight stream), the
        target scores the whole window — last committed token + all
        proposals — in ONE stacked pipeline round
        (``layer_verify_paged``), and the longest agreeing prefix plus
        the target's own next pick commits.  Draft writes land on a
        copy-on-write BRANCH of the block table, so a rejected suffix
        rolls back by dropping page refcounts (O(pages), never a copy).
        Greedy outputs are token-identical to the non-speculative paths:
        every committed token is the argmax of target logits over an
        exactly-equal attention mask, regardless of what the draft
        proposed."""
        from repro.core.kv_pages import BlockTable, PagePool

        if not self.page_size:
            raise ValueError("speculative decoding needs the paged KV "
                             "cache: construct the engine with page_size")
        if "layer_verify_paged" not in self.fns:
            raise ValueError(
                "speculative decoding needs the stacked GQA verify path; "
                f"config {self.cfg.name} (attention={self.cfg.attention}, "
                f"sliding_window={self.cfg.sliding_window}) only supports "
                "the generic gather path")
        if new_tokens <= 0:
            return jnp.asarray(tokens), RunStats(self.mode, self.m, 0.0, 0,
                                                 [], kv_cache=True)
        toks_in = jnp.asarray(tokens)
        b, s0 = toks_in.shape
        if b != 1:
            raise ValueError("run_generate(speculative=...) is the "
                             "single-request path; use the scheduler's "
                             "spec_depth for batched serving")
        depth = max(1, int(spec.depth))
        w_max = depth + 1
        ps = self.page_size
        names = self.layer_names
        n = len(names)
        total = s0 + new_tokens
        nb = pages_for(total, ps)
        page_bytes = n * self.cfg.cache_bytes(1, ps)
        draft = self._draft_model(spec)
        draft_cache_bytes = draft.cache_bytes(1, total)
        extra = draft.total_bytes + draft_cache_bytes
        # feasibility at the WORST mapped-page count: the full-length
        # table plus one COW copy of the window's write page (branch
        # growth past the committed length is new pages the rollback
        # returns, but they are live during the verify round)
        cache_total = (nb + pages_for(w_max, ps) + 1) * page_bytes
        self._check_kv_budget(cache_total, extra_resident=extra)

        events: List[Tuple[float, str, str]] = []
        ledger = _Ledger(self.budget)
        fsnap = _fault_snap()
        t0 = time.perf_counter()
        self._ensure_aux(ledger, events, t0)
        draft.pin(ledger)
        events.append((time.perf_counter() - t0, "draft_pin",
                       str(draft.total_bytes)))
        ledger.acquire(draft_cache_bytes, owner="spec_headroom")

        toks: List[int] = [int(t) for t in np.asarray(toks_in).reshape(-1)]
        pool = PagePool(ps, page_bytes, ledger)
        pool_rows = nb + pages_for(w_max, ps) + 2
        table = BlockTable([pool.alloc() for _ in range(pages_for(s0, ps))])

        # ---- draft prefill (resident; overlaps nothing — it is cheap)
        _, dcaches = draft.prefill(toks_in, total)
        draft_pos = s0                   # draft-cache slots that match toks

        # ---- target prefill: pipelined cache capture, scattered into
        # the page pool (pad to the page boundary so rows split evenly)
        pad_len = pages_for(s0, ps) * ps
        caches: Dict[str, dict] = {}

        def prefill_apply(k, w, h):
            h, cache = self._layer_cache(k, w, h, pad_len)
            h.block_until_ready()
            caches[names[k]] = cache
            events.append((time.perf_counter() - t0, "cache_alloc",
                           names[k]))
            return h

        x = self.fns["embed"](self._resident["embed"], toks_in)
        if self.mode == "baseline":
            raise ValueError("speculative decoding needs a pipelined mode")
        x = self._run_pipeline(x, ledger, events, t0,
                               self.mode == "pipeload",
                               apply_fn=prefill_apply)
        logits = self.fns["head"](self._resident["head"], x)
        toks.append(int(jnp.argmax(logits, -1)[0]))
        generated = 1

        # physical pools: (pool_rows, ps, ...) per layer leaf, prefill
        # rows scattered into this request's own pages
        pids = jnp.asarray(table.pages, jnp.int32)
        pools: Dict[str, dict] = {}
        for name in names:
            pools[name] = jax.tree.map(
                lambda a: jnp.zeros((pool_rows, ps) + a.shape[2:],
                                    a.dtype).at[pids].set(
                    a[0].reshape((len(table.pages), ps) + a.shape[2:])),
                caches[name])
        caches.clear()
        prefill_s = time.perf_counter() - t0

        # ---- draft/verify rounds
        tr = _tele.get_tracer()
        spec_rounds = draft_tokens = accepted = 0
        while generated < new_tokens:
            k_prop = min(depth, new_tokens - generated - 1)
            # 1. draft proposes: catch up on committed tokens it has not
            # seen (<= 2 feeds after the first round), then chain k_prop
            # proposals off its own greedy picks
            logits_d = None
            props: List[int] = []
            with tr.span("draft_propose", depth=k_prop):
                for t in toks[draft_pos:]:
                    logits_d, dcaches = draft.decode(t, dcaches, draft_pos)
                    draft_pos += 1
                for j in range(k_prop):
                    nxt = int(jnp.argmax(logits_d, -1)[0])
                    props.append(nxt)
                    if j < k_prop - 1:
                        logits_d, dcaches = draft.decode(nxt, dcaches,
                                                         draft_pos)
                        draft_pos += 1
            # 2. branch the block table copy-on-write and map the verify
            # window's write range [pos0, pos0 + w_r)
            pos0 = len(toks) - 1         # slot of the last committed token
            w_r = k_prop + 1
            br = table.branch(pool)
            while len(br.pages) < pages_for(pos0 + w_r, ps):
                br.pages.append(pool.alloc())
            cow: List[Tuple[int, int]] = []
            for pidx in range(pos0 // ps,
                              pages_for(pos0 + w_r, ps)):
                swap = br.cow(pidx, pool)
                if swap is not None:
                    cow.append(swap)
            if cow:
                old = jnp.asarray([o for o, _ in cow], jnp.int32)
                new = jnp.asarray([nn for _, nn in cow], jnp.int32)
                pools = {name: jax.tree.map(
                    lambda a: a.at[new].set(a[old]), c)
                    for name, c in pools.items()}
            # 3. ONE stacked weight-stream round scores the window
            tab = np.zeros((1, nb), np.int32)
            tab[0, :len(br.pages)] = br.pages
            tab_j = jnp.asarray(tab)
            pos_j = jnp.asarray([pos0], jnp.int32)
            window = jnp.asarray([[toks[-1]] + props], jnp.int32)
            x = self.fns["embed"](self._resident["embed"], window)

            def verify_apply(k, w, h):
                h, pools[names[k]] = self.fns["layer_verify_paged"](
                    w, h, pools[names[k]], tab_j, pos_j)
                h.block_until_ready()
                return h

            events.append((time.perf_counter() - t0, "spec_round",
                           f"w={w_r}"))
            if tr.enabled:
                tr.instant("spec_verify", window=w_r)
            x = self._run_pipeline(x, ledger, events, t0,
                                   self.mode == "pipeload",
                                   apply_fn=verify_apply)
            logits = self.fns["head_all"](self._resident["head"], x)
            greedy = np.asarray(jnp.argmax(logits[0], -1))       # (w_r,)
            # 4. accept the longest agreeing prefix + the target's own
            # next pick (the "bonus" token — always correct: its context
            # is fully committed)
            a = 0
            while a < k_prop and props[a] == int(greedy[a]):
                a += 1
            old_len = len(toks)
            toks.extend(props[:a])
            toks.append(int(greedy[a]))
            generated += a + 1
            # 5. rollback: drop refcounts past the committed length —
            # rejected suffix pages unmap without copies — then commit
            # the branch as the new table
            br.rollback(pool, pages_for(pos0 + a + 1, ps))
            if tr.enabled:
                tr.instant("spec_rollback", accepted=a, proposed=k_prop)
            table.release_all(pool)
            table = br
            # draft-cache slots still agreeing with toks: everything it
            # had, minus proposals past the accepted prefix
            draft_pos = old_len + max(0, min(a, k_prop - 1))
            spec_rounds += 1
            draft_tokens += k_prop
            accepted += a

        out = jnp.asarray(np.asarray(toks)[None]).astype(toks_in.dtype)
        out.block_until_ready()
        lat = time.perf_counter() - t0
        table.release_all(pool)
        ledger.release(draft_cache_bytes, owner="spec_headroom")
        draft.unpin(ledger)
        ledger.audit_check_drained("stream", "kv_pages", "draft",
                                   "spec_headroom")
        return out, RunStats(self.mode, self.m, lat, ledger.peak, events,
                             loads=sum(1 for e in events
                                       if e[1] == "load_end"),
                             streamed_bytes=self._streamed(events),
                             new_tokens=new_tokens, prefill_s=prefill_s,
                             decode_s=lat - prefill_s,
                             cache_bytes=pool.mapped_peak_bytes,
                             kv_cache=True, spec_depth=depth,
                             spec_rounds=spec_rounds,
                             draft_tokens=draft_tokens,
                             accepted_tokens=accepted,
                             peak_breakdown=dict(ledger.peak_breakdown),
                             **_fault_delta(fsnap))

    # ------------------------------------------------------------------
    # Continuous-batching rounds (core/scheduler.py drives these)
    # ------------------------------------------------------------------
    def run_batch_round(self, ledger: _Ledger, events, t0, *,
                        decode_x=None, decode_caches: Optional[Dict] = None,
                        decode_pos=None, prefill_xs=(),
                        prefill_total: int = 0,
                        paged_pools: Optional[Dict] = None,
                        decode_tables=None,
                        chunk_x=None, chunk_tables=None, chunk_pos=None):
        """ONE pipeline round shared by every in-flight request.

        The §III machinery (loading agents, S_comp/S_dest/S_stop, in-order
        ledger grants) is untouched; only the Inference Agent's per-layer
        step changes: layer ``k`` streams through memory ONCE and is
        applied to

          * the stacked single-token states of all decoding requests
            (``decode_x`` (R, 1, D), per-layer caches with leading row
            dim R, RAGGED ``decode_pos`` (R,) — each request sits at its
            own cache slot), and
          * each joining request's cache-capturing prefill
            (``prefill_xs``: full-sequence states, caches padded to
            ``prefill_total`` slots),

        then destroyed.  This is the whole point of continuous batching:
        the dominant weight-stream cost is paid once per ROUND, not once
        per request.  The caller owns ``ledger``/``events``/``t0`` so
        accounting spans the serving session, not a single call.

        Paged serving (core/kv_pages.py) passes ``paged_pools`` — per
        layer, cache dicts with ``(P, page, ...)`` leaves — plus the
        stacked ``decode_tables`` (R, NB) block tables; the decode step
        then runs ``layer_decode_paged`` (Pallas block-table gather
        under ``attn_impl="pallas"``) and the pools are returned in the
        caches slot.  Prefill jobs are unchanged either way: the caller
        scatters their captured caches into pages at the boundary.

        Chunked prefill (the serving tier's long-prompt path) passes
        ``chunk_x`` (Bc, C, D) stacked C-token windows with their own
        ``chunk_tables`` (Bc, NB) / ``chunk_pos`` (Bc,): each streamed
        layer additionally applies ``layer_verify_paged`` to the chunk
        batch, writing the chunks' K/V straight into their requests'
        pages in-kernel — a long prompt joins decode rounds one chunk at
        a time instead of stalling them behind a monolithic prefill.

        Returns ``(decode_x', decode_caches', prefill_outs,
        prefill_caches, chunk_x')`` — the advanced decode states, per
        prefill job its final hidden states and captured per-layer
        caches, and the chunk batch's final hidden states (None when no
        chunks ran).
        """
        if self.mode == "baseline":
            raise ValueError(
                "run_batch_round needs a pipelined mode (pipeload / "
                "pipeswitch); baseline keeps the model resident and has "
                "no round to amortise")
        if paged_pools is not None and self.expert is not None:
            raise ValueError(
                "paged KV serving is not supported with expert-split "
                "MoE checkpoints yet; repartition whole-layer or drop "
                "page_size")
        names = self.layer_names
        prefill_caches: List[Dict[str, dict]] = [{} for _ in prefill_xs]

        if (decode_x is not None and decode_x.shape[1] > 1
                and paged_pools is None):
            raise ValueError(
                "stacked multi-token decode (speculative verify) needs "
                "paged pools; dense decode_caches take one token per "
                "round")
        if chunk_x is not None and paged_pools is None:
            raise ValueError(
                "chunked prefill needs paged pools (chunks write K/V "
                "through the block tables)")

        def apply_fn(k, w, state):
            dx, cx, pxs = state
            if dx is not None and paged_pools is not None:
                # W>1 stacked states = a speculative verify round: each
                # request's window [pos, pos+W) scores in one pass
                fn = (self.fns["layer_verify_paged"] if dx.shape[1] > 1
                      else self.fns["layer_decode_paged"])
                dx, paged_pools[names[k]] = fn(
                    w, dx, paged_pools[names[k]], decode_tables,
                    decode_pos)
                dx.block_until_ready()
            elif dx is not None:
                dx, decode_caches[names[k]] = self._layer_decode(
                    k, w, dx, decode_caches[names[k]], decode_pos)
                dx.block_until_ready()
            if cx is not None:
                # chunk windows ride the same verify module at width C,
                # against their OWN tables/positions (disjoint writes:
                # chunk slots are prompt positions in the chunkers'
                # pages; any shared page gets bitwise-identical bytes)
                cx, paged_pools[names[k]] = self.fns["layer_verify_paged"](
                    w, cx, paged_pools[names[k]], chunk_tables, chunk_pos)
                cx.block_until_ready()
            nxt = []
            for i, px in enumerate(pxs):
                px, cache = self._layer_cache(k, w, px, prefill_total)
                px.block_until_ready()
                prefill_caches[i][names[k]] = cache
                nxt.append(px)
            return dx, cx, nxt

        self._ensure_aux(ledger, events, t0)
        widest = [px.shape[0] * px.shape[1] for px in prefill_xs]
        if decode_x is not None:
            widest.append(decode_x.shape[0])
        if chunk_x is not None:
            widest.append(chunk_x.shape[0] * chunk_x.shape[1])
        self._bind_expert(ledger, events, t0,
                          round_tokens=max(widest, default=1))
        state = (decode_x, chunk_x, list(prefill_xs))
        dx, cx, pxs = self._run_pipeline(state, ledger, events, t0,
                                         destroy=self.mode == "pipeload",
                                         apply_fn=apply_fn)
        caches_out = paged_pools if paged_pools is not None else decode_caches
        return dx, caches_out, pxs, prefill_caches, cx

    def _kv_floor(self, cache_total: int, *,
                  expert_floor: Optional[int] = None,
                  extra_resident: int = 0) -> int:
        """Smallest budget that cannot deadlock a KV decode round holding
        ``cache_total`` bytes of cache pages: other layers + all pages +
        the pinned window + one streaming layer.  Non-destroying modes
        (baseline / pipeswitch) keep the WHOLE model resident for a round,
        so their floor is the full model + cache.  ``cache_total`` is the
        TOTAL reservation — for continuous batching, the sum over every
        in-flight request — which is what the scheduler's admission
        control feeds back in before granting a new request its pages.
        ``extra_resident`` adds run-scoped residents outside the four
        standard tiers — the speculative path's pinned draft model and
        its dense cache."""
        other = sum(s["bytes"] for s in self.shards.values()
                    if s["kind"] not in ("layer", "expert"))
        layer_sizes = [self.shards[nm]["bytes"] for nm in self.layer_names]
        if self.mode == "pipeload":
            pinned = sum(layer_sizes[:self.pin])
            streaming = max(layer_sizes[self.pin:], default=0)
        else:
            pinned, streaming = sum(layer_sizes), 0
        expert = 0
        if self.expert is not None:
            # ``expert_floor`` = the workload's shrinkable minimum (the
            # scheduler's feasibility checks pass it — admission can
            # evict the cache down to it); otherwise bound sessions hold
            # the live reservation and pre-run checks use the smallest
            # cache a single-token round can run with
            if expert_floor is not None:
                expert = expert_floor
            else:
                expert = (self.expert.reserved if self.expert.bound
                          else self.expert.min_ws)
        return (other + cache_total + pinned + streaming + expert
                + extra_resident)

    def _check_kv_budget(self, cache_total: int, *, inflight: int = 1,
                         expert_floor: Optional[int] = None,
                         extra_resident: int = 0):
        """Raise unless the budget clears the decode floor for the full
        multi-request reservation (``cache_total`` bytes across
        ``inflight`` concurrent requests); below it the pipeline deadlocks
        with every loader parked on S_stop.  ``expert_floor`` overrides
        the expert-cache term with the workload's shrinkable minimum
        (see ``_kv_floor``); ``extra_resident`` adds the speculative
        draft's pinned bytes."""
        if self.budget is None:
            return
        floor = self._kv_floor(cache_total, expert_floor=expert_floor,
                               extra_resident=extra_resident)
        if self.budget < floor:
            per_req = cache_total // max(inflight, 1)
            raise ValueError(
                f"budget {self.budget} below the KV decode floor {floor} "
                f"for {inflight} in-flight request(s) "
                f"(cache={cache_total} = {inflight} x {per_req} "
                f"cache-page bytes, plus other layers, the pinned window, "
                f"one streaming layer and — for expert-split MoE — the "
                f"expert cache); use the generation-aware "
                f"planner (Hermes.plan_generate) to pick a feasible "
                f"(num_agents, pin_window, max_inflight), or let the "
                f"scheduler queue the request until pages free up")
