"""PIPELOAD Execution Engine (Hermes paper §III).

Three worker roles communicate through an explicit signalling mechanism:

  * ``m`` **Loading Agents** (threads): agent *i* loads shard stripe
    ``L_{i+jm}`` (paper's round-robin assignment) from the layer-partitioned
    on-disk checkpoint, then raises ``S_comp(k)`` (computation-ready).
  * one **Inference Agent** (caller thread): maintains the inference queue —
    layer *k* computes only after *k-1* — and raises ``S_dest(k)`` (memory
    destruction) as soon as layer *k*'s forward pass finishes.
  * one **Daemon Agent** (thread): maintains the resident-bytes ledger,
    frees destroyed layers, and enforces the memory budget: a loader asking
    to exceed the budget blocks (the paper's ``S_stop``) until the daemon
    frees enough space and wakes it.

Engine modes:
  * ``baseline``   — load the whole model, then infer (no pipeline).
  * ``pipeswitch`` — standard pipeline: ONE loading agent, no destruction
    (PipeSwitch-style; peak memory == whole model).
  * ``pipeload``   — the paper's mechanism with ``num_agents`` loaders.

``pin_window > 0`` implements the paper's future-work item (beyond-paper):
the first ``pin_window`` layers stay resident across GPT token iterations,
skipping their reload in later pipeline rounds while still honouring the
budget (the Pipeline Planner picks the window from the schedule).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.partition import load_manifest, load_shard
from repro.core.modules import build_module_fns
from repro.models.config import ModelConfig

MODES = ("baseline", "pipeswitch", "pipeload")


@dataclasses.dataclass
class RunStats:
    mode: str
    num_agents: int
    latency_s: float
    peak_bytes: int
    events: List[Tuple[float, str, str]]
    loads: int = 0

    def event_log(self, kinds=None):
        return [e for e in self.events if kinds is None or e[1] in kinds]


class _Ledger:
    """Resident-bytes accounting + budget gate (Daemon Agent state)."""

    def __init__(self, budget: Optional[int]):
        self.budget = budget
        self.resident = 0
        self.peak = 0
        self.cond = threading.Condition()

    def acquire(self, nbytes: int, stop_flag):
        """Loader-side: blocks while the budget would be exceeded
        (paper's S_stop semantics)."""
        with self.cond:
            if self.budget is not None:
                while (self.resident + nbytes > self.budget
                       and self.resident > 0 and not stop_flag()):
                    self.cond.wait(timeout=0.1)
            self.resident += nbytes
            self.peak = max(self.peak, self.resident)

    def release(self, nbytes: int):
        with self.cond:
            self.resident -= nbytes
            self.cond.notify_all()


class PipeloadEngine:
    def __init__(self, ckpt_dir, cfg: ModelConfig, *,
                 mode: str = "pipeload", num_agents: int = 4,
                 budget_bytes: Optional[int] = None, pin_window: int = 0):
        assert mode in MODES, mode
        self.dir = Path(ckpt_dir)
        self.cfg = cfg
        self.mode = mode
        self.m = max(1, num_agents) if mode == "pipeload" else 1
        self.budget = budget_bytes
        self.pin = pin_window if mode == "pipeload" else 0
        self.manifest = load_manifest(ckpt_dir)
        self.fns = build_module_fns(cfg)
        self.shards = {s["name"]: s for s in self.manifest["shards"]}
        self.layer_names = [s["name"] for s in self.manifest["shards"]
                            if s["kind"] == "layer"]
        # persistent across pipeline rounds (pinning / non-destroying modes)
        self._resident: Dict[str, dict] = {}

    # ------------------------------------------------------------------
    def warmup(self, batch: int, seq: int):
        """Compile the module fns ahead of the timed run (serving systems
        warm their executables; without this the first layer's jit compile
        stalls the Inference Agent while Loading Agents race ahead and the
        measured peak degenerates to the whole model)."""
        tokens = jnp.zeros((batch, seq), jnp.int32)
        emb = self._resident.get("embed") or self._load("embed")
        head = self._resident.get("head") or self._load("head")
        w0 = self._load(self.layer_names[0])
        x = self.fns["embed"](emb, tokens)
        x = self.fns["layer"](w0, x)
        self.fns["head"](head, x).block_until_ready()
        del w0, emb, head
        return self

    # ------------------------------------------------------------------
    def _load(self, name: str) -> dict:
        """Disk -> host -> device ("memory" tier)."""
        host = load_shard(self.dir, name)
        return jax.tree.map(jnp.asarray, host)

    def _apply_layer(self, weights, x):
        y = self.fns["layer"](weights, x)
        y.block_until_ready()
        return y

    # ------------------------------------------------------------------
    def _run_pipeline(self, x, ledger: _Ledger, events, t0,
                      destroy: bool) -> jnp.ndarray:
        """One pipelined pass over the layer stack (PIPELOAD §III-B)."""
        names = self.layer_names
        n = len(names)
        ready: Dict[int, dict] = {}
        ready_cond = threading.Condition()   # carries S_comp signals
        destroy_q: List[Tuple[int, dict]] = []
        destroy_cond = threading.Condition()  # carries S_dest signals
        done = threading.Event()
        err: List[BaseException] = []

        # Pinned layers (beyond-paper resident window) skip the disk load.
        def loader(agent_idx: int):
            try:
                for k in range(agent_idx, n, self.m):
                    name = names[k]
                    if name in self._resident:
                        with ready_cond:
                            ready[k] = self._resident[name]
                            ready_cond.notify_all()  # S_comp(k)
                        continue
                    nbytes = self.shards[name]["bytes"]
                    ledger.acquire(nbytes, done.is_set)  # may block: S_stop
                    if done.is_set():
                        ledger.release(nbytes)
                        return
                    t = time.perf_counter()
                    w = self._load(name)
                    events.append((t - t0, "load_start", name))
                    events.append((time.perf_counter() - t0, "load_end",
                                   name))
                    with ready_cond:
                        ready[k] = w
                        ready_cond.notify_all()          # S_comp(k)
            except BaseException as e:  # noqa: BLE001
                err.append(e)
                done.set()
                with ready_cond:
                    ready_cond.notify_all()

        def daemon():
            """Frees destroyed layers; wakes blocked loaders."""
            freed = 0
            while freed < n and not done.is_set():
                with destroy_cond:
                    while not destroy_q and not done.is_set():
                        destroy_cond.wait(timeout=0.05)
                    if not destroy_q:
                        continue
                    k, w = destroy_q.pop(0)
                name = names[k]
                nbytes = self.shards[name]["bytes"]
                del w                                    # free device memory
                ledger.release(nbytes)
                events.append((time.perf_counter() - t0, "destroy", name))
                freed += 1

        threads = [threading.Thread(target=loader, args=(i,), daemon=True)
                   for i in range(self.m)]
        dt = threading.Thread(target=daemon, daemon=True) if destroy else None
        for t in threads:
            t.start()
        if dt:
            dt.start()

        # ---- Inference Agent (this thread): in-order inference queue
        keep: List[dict] = []   # pipeswitch: layers stay alive for the pass
        try:
            for k in range(n):
                with ready_cond:
                    while k not in ready and not err:
                        ready_cond.wait(timeout=0.1)
                    if err:
                        raise err[0]
                    w = ready[k]
                t = time.perf_counter()
                x = self._apply_layer(w, x)
                events.append((t - t0, "comp_start", names[k]))
                events.append((time.perf_counter() - t0, "comp_end",
                               names[k]))
                name = names[k]
                pinned = k < self.pin
                if pinned and name not in self._resident:
                    self._resident[name] = w
                del ready[k]
                if destroy and not pinned:
                    with destroy_cond:
                        destroy_q.append((k, w))
                        destroy_cond.notify_all()        # S_dest(k)
                elif not destroy:
                    keep.append(w)
                del w
        finally:
            done.set()
            with destroy_cond:
                destroy_cond.notify_all()
            for t in threads:
                t.join(timeout=5)
            if dt:
                dt.join(timeout=5)
        if not destroy:
            # pipeswitch: the whole model was resident for the pass (peak ==
            # full model); it is swapped out when the pass ends (PipeSwitch
            # time-shares the device between tasks), so the ledger releases
            # every non-pinned layer here.
            for k in range(n):
                if names[k] not in self._resident:
                    ledger.release(self.shards[names[k]]["bytes"])
        return x

    # ------------------------------------------------------------------
    def _forward_once(self, tokens, ledger, events, t0) -> jnp.ndarray:
        """embed -> pipelined layers -> head."""
        # embed + head are the paper's "other layers": loaded up front,
        # resident for the whole run.
        for aux in ("embed", "head"):
            if aux not in self._resident:
                ledger.acquire(self.shards[aux]["bytes"], lambda: False)
                self._resident[aux] = self._load(aux)
                events.append((time.perf_counter() - t0, "load_end", aux))

        x = self.fns["embed"](self._resident["embed"], tokens)

        if self.mode == "baseline":
            # load-all-then-infer
            weights = {}
            for name in self.layer_names:
                ledger.acquire(self.shards[name]["bytes"], lambda: False)
                weights[name] = self._load(name)
                events.append((time.perf_counter() - t0, "load_end", name))
            for name in self.layer_names:
                x = self._apply_layer(weights[name], x)
            self._baseline_weights = weights     # resident (no destruction)
        else:
            destroy = self.mode == "pipeload"
            x = self._run_pipeline(x, ledger, events, t0, destroy)

        return self.fns["head"](self._resident["head"], x)

    # ------------------------------------------------------------------
    def run_single(self, tokens) -> Tuple[jnp.ndarray, RunStats]:
        """Single-pass inference (BERT / ViT workloads)."""
        events: List[Tuple[float, str, str]] = []
        ledger = _Ledger(self.budget)
        t0 = time.perf_counter()
        logits = self._forward_once(jnp.asarray(tokens), ledger, events, t0)
        logits.block_until_ready()
        lat = time.perf_counter() - t0
        return logits, RunStats(self.mode, self.m, lat, ledger.peak, events,
                                loads=sum(1 for e in events
                                          if e[1] == "load_end"))

    def run_generate(self, tokens, new_tokens: int
                     ) -> Tuple[jnp.ndarray, RunStats]:
        """GPT-style generation: the paper's engine re-runs the pipeline
        (load + prefix re-inference) for EVERY generated token (§V-B2)."""
        events: List[Tuple[float, str, str]] = []
        ledger = _Ledger(self.budget)
        toks = jnp.asarray(tokens)
        t0 = time.perf_counter()
        for step in range(new_tokens):
            if self.mode == "baseline" and step > 0:
                # baseline keeps the model resident: only re-infer
                x = self.fns["embed"](self._resident["embed"], toks)
                for name in self.layer_names:
                    x = self._apply_layer(self._baseline_weights[name], x)
                logits = self.fns["head"](self._resident["head"], x)
            else:
                logits = self._forward_once(toks, ledger, events, t0)
            nxt = jnp.argmax(logits, -1).astype(toks.dtype)[:, None]
            toks = jnp.concatenate([toks, nxt], axis=1)
        toks.block_until_ready()
        lat = time.perf_counter() - t0
        return toks, RunStats(self.mode, self.m, lat, ledger.peak, events,
                              loads=sum(1 for e in events
                                        if e[1] == "load_end"))
