"""Hermes framework facade (paper §IV): Layer Profiler -> Pipeline Planner
-> Execution Engine, wired together.

    hermes = Hermes(ckpt_dir, cfg)
    profile = hermes.profile()                  # §IV-1
    schedule = hermes.plan([b1, b2, None])      # §IV-2
    logits, stats = hermes.execute(tokens, budget_bytes=b1)   # §IV-3

Generation workloads get the generation-aware tier:

    gplan = hermes.plan_generate([b1], prompt_len=128, new_tokens=32)[0]
    stats = hermes.execute(tokens, generate=32, kv_cache=True,
                           budget_bytes=b1)     # picks (m, pin) jointly

Quantized weight streaming threads through the same facade:

    h8 = hermes.quantized("int8")      # sibling int8 checkpoint (cached)
    g = hermes.plan_generate([b1], quants=("fp32", "int8", "int4"),
                             prompt_len=128, new_tokens=32)[0]
    engine = hermes.quantized(g.dtype).engine(...)   # g.dtype = winner
"""
from __future__ import annotations

from pathlib import Path
from typing import Dict, List, Optional, Sequence

from repro.checkpoint.partition import ensure_quantized
from repro.core.engine import DraftModel, PipeloadEngine, RunStats
from repro.core.planner import GenPlanEntry, PlanEntry, plan, plan_generate
from repro.core.profiler import load_profile, profile_model, save_profile
from repro.models.config import ModelConfig

# planner label for "no quantization: stream shards at the ckpt dtype"
FP_LABEL = "fp32"


class Hermes:
    def __init__(self, ckpt_dir, cfg: ModelConfig):
        self.dir = Path(ckpt_dir)
        self.cfg = cfg
        self._profile: Optional[Dict] = None
        self._variants: Dict[str, "Hermes"] = {}

    # ---- Telemetry (core/telemetry.py) ---------------------------------
    def telemetry(self):
        """The process-wide telemetry handle: ``.enable()`` turns on span
        tracing across every PIPELOAD subsystem, ``.metrics`` is the
        always-on registry, ``.export_chrome_trace(path)`` writes a
        Perfetto-loadable timeline of the runs since enable()."""
        from repro.core.telemetry import telemetry
        return telemetry()

    # ---- Layer Profiler ------------------------------------------------
    def profile(self, *, batch: int = 1, seq: int = 128,
                force: bool = False) -> Dict:
        cache = self.dir / "profile.json"
        if not force and self._profile is not None:
            return self._profile
        if not force and cache.exists():
            self._profile = load_profile(cache)
            return self._profile
        self._profile = profile_model(self.dir, self.cfg, batch=batch,
                                      seq=seq)
        save_profile(self._profile, cache)
        return self._profile

    # ---- Kernel autotune (kernels/autotune.py) -------------------------
    def autotune(self, *, page_size: Optional[int] = None,
                 quant: Optional[str] = None, tokens: int = 256,
                 force: bool = False, cache_path=None) -> Dict:
        """Per-device kernel tile / impl selection, seeded by this
        checkpoint's Layer Profiler run and cached to disk (repeat runs
        skip the timing sweep).  Applies the winners as the jitted
        kernel wrappers' process-wide defaults and returns them."""
        from repro.kernels.autotune import tune_for_model
        host = self.quantized(quant) if quant else self
        return tune_for_model(self.cfg, host.profile(),
                              page_size=page_size, quant=quant,
                              tokens=tokens, force=force,
                              cache_path=cache_path)

    # ---- Quantized checkpoint variants ---------------------------------
    def quantized(self, quant: Optional[str]) -> "Hermes":
        """Hermes over the ``quant`` variant of this checkpoint.  The
        sibling directory ``<dir>-<quant>`` is transcoded once (no model
        init) and reused — including its own cached profile.json — and
        re-transcoded automatically if the source checkpoint changed
        underneath it (``checkpoint.ensure_quantized``)."""
        if quant in (None, FP_LABEL):
            return self
        if quant not in self._variants:
            dst = self.dir.parent / f"{self.dir.name}-{quant}"
            ensure_quantized(self.dir, dst, quant)
            self._variants[quant] = Hermes(dst, self.cfg)
        return self._variants[quant]

    def _quant_profiles(self, quants: Sequence[Optional[str]],
                        **profile_kw) -> Dict[str, Dict]:
        """One Layer Profiler run per requested shard dtype."""
        labels = [q or FP_LABEL for q in quants]
        return {lb: self.quantized(lb).profile(**profile_kw)
                for lb in labels}

    # ---- Pipeline Planner ----------------------------------------------
    def plan(self, budgets: List[Optional[int]],
             max_agents: Optional[int] = None,
             quants: Optional[Sequence[Optional[str]]] = None
             ) -> List[PlanEntry]:
        """Schedule per budget; ``quants`` (e.g. ``("fp32", "int8")``)
        widens the search over shard dtype — the winning entry's
        ``dtype`` says which variant to execute."""
        prof = (self.profile() if quants is None
                else self._quant_profiles(quants))
        return plan(prof, budgets, max_agents)

    def best_agents(self, budget_bytes: Optional[int]) -> int:
        return self.plan([budget_bytes])[0].num_agents

    def plan_generate(self, budgets: List[Optional[int]], *,
                      batch: int = 1, prompt_len: int = 128,
                      new_tokens: int = 32,
                      max_agents: Optional[int] = None,
                      max_pin: Optional[int] = None,
                      max_inflight: int = 1,
                      quants: Optional[Sequence[Optional[str]]] = None,
                      page_sizes: Sequence[int] = (),
                      shared_prefix_len: int = 0,
                      spec_depths: Sequence[int] = (),
                      spec_draft: Optional[Dict] = None,
                      slo_ttft_s: Optional[float] = None,
                      slo_tpot_s: Optional[float] = None,
                      chunk_prefill: int = 0
                      ) -> List[GenPlanEntry]:
        """Generation-aware schedule: joint (num_agents, pin_window) with
        KV-cache bytes charged against the budget.  ``max_inflight > 1``
        additionally searches the continuous-batching in-flight count
        (capacity-first; see ``planner.plan_generate``); ``quants``
        widens the search over shard dtype (KV pages keep the model
        dtype, so ``cache_bytes_per_layer`` is shared); ``page_sizes``
        widens it over PAGED KV reservations (core/kv_pages.py) —
        ``shared_prefix_len`` tells the model how many leading prompt
        tokens the workload's requests share, whose full pages are
        charged once across the batch; ``spec_depths`` + ``spec_draft``
        widen it over SPECULATIVE verify depths (a pinned draft's bytes,
        cache row and acceptance rate — see ``planner.plan_generate``);
        ``slo_ttft_s``/``slo_tpot_s`` gate the capacity-first search on
        predicted TTFT/TPOT (``chunk_prefill`` models chunk-joined
        prefill rounds — see the planner's SLO dimension)."""
        cb = self.cfg.cache_bytes(batch, prompt_len + new_tokens)
        prof = (self.profile() if quants is None
                else self._quant_profiles(quants, batch=1, seq=prompt_len))
        return plan_generate(prof, budgets, new_tokens=new_tokens,
                             cache_bytes_per_layer=cb, max_agents=max_agents,
                             max_pin=max_pin, max_inflight=max_inflight,
                             page_sizes=tuple(page_sizes),
                             total_len=prompt_len + new_tokens,
                             shared_prefix_len=shared_prefix_len,
                             spec_depths=tuple(spec_depths),
                             spec_draft=spec_draft,
                             slo_ttft_s=slo_ttft_s, slo_tpot_s=slo_tpot_s,
                             chunk_prefill=chunk_prefill)

    # ---- Execution Engine ----------------------------------------------
    def engine(self, *, mode: str = "pipeload",
               budget_bytes: Optional[int] = None,
               num_agents: Optional[int] = None,
               pin_window: int = 0,
               expert_cache_bytes: Optional[int] = None,
               page_size: Optional[int] = None) -> PipeloadEngine:
        if num_agents is None and mode == "pipeload":
            num_agents = self.best_agents(budget_bytes)
        return PipeloadEngine(self.dir, self.cfg, mode=mode,
                              num_agents=num_agents or 1,
                              budget_bytes=budget_bytes,
                              pin_window=pin_window,
                              expert_cache_bytes=expert_cache_bytes,
                              page_size=page_size)

    def scheduler(self, *, budget_bytes: Optional[int] = None,
                  max_inflight: int = 4, prompt_len: int = 128,
                  new_tokens: int = 32,
                  num_agents: Optional[int] = None,
                  pin_window: Optional[int] = None,
                  max_total_len: Optional[int] = None,
                  quants: Optional[Sequence[Optional[str]]] = None,
                  page_sizes: Sequence[int] = (),
                  shared_prefix_len: int = 0,
                  prefix_cache: bool = True,
                  seed: Optional[int] = None,
                  draft: Optional["DraftModel"] = None,
                  spec_depth: Optional[int] = None,
                  draft_acceptance: float = 0.8,
                  autotune: bool = False,
                  chunk_prefill: int = 0,
                  slo: Optional["SLO"] = None,
                  slo_ttft_s: Optional[float] = None,
                  slo_tpot_s: Optional[float] = None
                  ) -> "BatchScheduler":
        """Continuous-batching serving facade: plan the
        (num_agents, pin_window, inflight) triple for the budget, build
        the engine, and wrap it in a ``BatchScheduler`` ready for
        ``submit()``/``run()``.  ``prompt_len``/``new_tokens`` describe
        the TYPICAL request (they size the padded cache reservation);
        per-request lengths may vary below ``max_total_len``.
        ``quants`` widens the plan over shard dtype and ``page_sizes``
        over paged KV reservations (``shared_prefix_len`` models the
        workload's common prompt prefix); the engine is built on the
        winning checkpoint variant with the winning page size.  A
        ``draft`` model adds the SPECULATIVE dimension: ``spec_depth``
        fixes the verify depth (None = search {1, 2, 4} jointly at the
        modelled ``draft_acceptance``), and the winning depth — 0 when
        speculation does not pay at this budget — drives the
        scheduler's draft-and-verify rounds.

        The SERVING-TIER knobs: ``chunk_prefill`` (tokens per prefill
        chunk; needs ``page_sizes``, incompatible with ``draft``) joins
        long prompts into decode rounds; ``slo`` (a rounds-based
        ``scheduler.SLO``) arms admission-time shedding; and
        ``slo_ttft_s``/``slo_tpot_s`` gate the planner's capacity-first
        search — when only the seconds targets are given, the winning
        schedule's predicted round latency converts them into the
        rounds-based ``SLO`` handed to the scheduler."""
        from repro.core.scheduler import SLO, BatchScheduler
        if chunk_prefill and draft is not None:
            raise ValueError("chunk_prefill is incompatible with a draft "
                             "model (speculative rounds own the verify "
                             "window)")
        if chunk_prefill and not page_sizes:
            raise ValueError("chunk_prefill requires page_sizes (chunk "
                             "rounds write through the paged KV kernel)")
        spec_kw = {}
        if draft is not None:
            depths = ((spec_depth,) if spec_depth else (1, 2, 4))
            total = max_total_len or prompt_len + new_tokens
            spec_kw = dict(
                spec_depths=tuple(d for d in depths if d and d > 0),
                spec_draft=dict(
                    bytes=draft.total_bytes,
                    cache_bytes=draft.cache_bytes(1, total + max(depths)),
                    acceptance=draft_acceptance))
        g = self.plan_generate([budget_bytes], prompt_len=prompt_len,
                               new_tokens=new_tokens,
                               max_inflight=max_inflight, quants=quants,
                               page_sizes=page_sizes,
                               # sharing off -> every page is private;
                               # the plan must not assume prefix hits
                               shared_prefix_len=(shared_prefix_len
                                                  if prefix_cache
                                                  else 0),
                               slo_ttft_s=slo_ttft_s,
                               slo_tpot_s=slo_tpot_s,
                               chunk_prefill=chunk_prefill,
                               **spec_kw)[0]
        if not g.feasible:
            raise ValueError(
                f"no feasible serving schedule for budget {budget_bytes}: "
                f"best candidate predicts peak {g.predicted_peak_bytes} "
                f"bytes ({g.cache_bytes} of KV cache at inflight="
                f"{g.inflight}); raise the budget or shrink "
                f"prompt/new_tokens")
        host = self.quantized(g.dtype) if quants is not None else self
        if autotune:
            # tune AFTER planning: the planner's winning (dtype,
            # page_size) pair keys the autotune cache lookup, so the
            # kernels are tuned for the configuration that will serve
            self.autotune(page_size=(g.page_size or None),
                          quant=(g.dtype if quants is not None
                                 and g.dtype != FP_LABEL else None))
        eng = host.engine(mode="pipeload", budget_bytes=budget_bytes,
                          num_agents=(num_agents if num_agents is not None
                                      else g.num_agents),
                          pin_window=(pin_window if pin_window is not None
                                      else g.pin_window),
                          expert_cache_bytes=(g.expert_cache_bytes or None),
                          page_size=(g.page_size or None))
        if slo is None and (slo_ttft_s or slo_tpot_s):
            # convert the seconds targets into the scheduler's rounds
            # clock via the winning schedule's predicted round latency
            rl = g.predicted_per_token_s
            if rl and rl > 0:
                slo = SLO(
                    ttft_rounds=(max(int(slo_ttft_s / rl), 1)
                                 if slo_ttft_s else None),
                    tpot_rounds=((slo_tpot_s / rl)
                                 if slo_tpot_s else None))
        return BatchScheduler(eng, max_inflight=g.inflight,
                              max_total_len=(max_total_len
                                             or prompt_len + new_tokens),
                              prefix_cache=prefix_cache, seed=seed,
                              draft=(draft if g.spec_depth else None),
                              spec_depth=g.spec_depth,
                              chunk_prefill=(chunk_prefill
                                             if g.page_size else 0),
                              slo=slo)

    def execute(self, tokens, *, generate: int = 0, mode: str = "pipeload",
                budget_bytes: Optional[int] = None,
                num_agents: Optional[int] = None,
                pin_window: Optional[int] = None,
                kv_cache: bool = False) -> RunStats:
        expert_cache = None
        if (kv_cache and generate and mode == "pipeload"
                and (num_agents is None or pin_window is None)):
            # generation-aware tier picks (num_agents, pin_window) jointly
            b, s0 = tokens.shape
            g = self.plan_generate([budget_bytes], batch=b, prompt_len=s0,
                                   new_tokens=generate)[0]
            if not g.feasible:
                raise ValueError(
                    f"no feasible generation schedule for budget "
                    f"{budget_bytes}: best candidate predicts peak "
                    f"{g.predicted_peak_bytes} bytes ({g.cache_bytes} of "
                    f"KV cache); raise the budget or shrink "
                    f"batch/prompt/new_tokens")
            num_agents = g.num_agents if num_agents is None else num_agents
            pin_window = g.pin_window if pin_window is None else pin_window
            expert_cache = g.expert_cache_bytes or None
        eng = self.engine(mode=mode, budget_bytes=budget_bytes,
                          num_agents=num_agents,
                          pin_window=pin_window or 0,
                          expert_cache_bytes=expert_cache)
        if generate:
            _, stats = eng.run_generate(tokens, generate, kv_cache=kv_cache)
        else:
            _, stats = eng.run_single(tokens)
        return stats
