"""Hermes framework facade (paper §IV): Layer Profiler -> Pipeline Planner
-> Execution Engine, wired together.

    hermes = Hermes(ckpt_dir, cfg)
    profile = hermes.profile()                  # §IV-1
    schedule = hermes.plan([b1, b2, None])      # §IV-2
    logits, stats = hermes.execute(tokens, budget_bytes=b1)   # §IV-3
"""
from __future__ import annotations

from pathlib import Path
from typing import Dict, List, Optional

from repro.core.engine import PipeloadEngine, RunStats
from repro.core.planner import PlanEntry, plan
from repro.core.profiler import load_profile, profile_model, save_profile
from repro.models.config import ModelConfig


class Hermes:
    def __init__(self, ckpt_dir, cfg: ModelConfig):
        self.dir = Path(ckpt_dir)
        self.cfg = cfg
        self._profile: Optional[Dict] = None

    # ---- Layer Profiler ------------------------------------------------
    def profile(self, *, batch: int = 1, seq: int = 128,
                force: bool = False) -> Dict:
        cache = self.dir / "profile.json"
        if not force and self._profile is not None:
            return self._profile
        if not force and cache.exists():
            self._profile = load_profile(cache)
            return self._profile
        self._profile = profile_model(self.dir, self.cfg, batch=batch,
                                      seq=seq)
        save_profile(self._profile, cache)
        return self._profile

    # ---- Pipeline Planner ----------------------------------------------
    def plan(self, budgets: List[Optional[int]],
             max_agents: Optional[int] = None) -> List[PlanEntry]:
        return plan(self.profile(), budgets, max_agents)

    def best_agents(self, budget_bytes: Optional[int]) -> int:
        return self.plan([budget_bytes])[0].num_agents

    # ---- Execution Engine ----------------------------------------------
    def engine(self, *, mode: str = "pipeload",
               budget_bytes: Optional[int] = None,
               num_agents: Optional[int] = None,
               pin_window: int = 0) -> PipeloadEngine:
        if num_agents is None and mode == "pipeload":
            num_agents = self.best_agents(budget_bytes)
        return PipeloadEngine(self.dir, self.cfg, mode=mode,
                              num_agents=num_agents or 1,
                              budget_bytes=budget_bytes,
                              pin_window=pin_window)

    def execute(self, tokens, *, generate: int = 0, mode: str = "pipeload",
                budget_bytes: Optional[int] = None,
                num_agents: Optional[int] = None,
                pin_window: int = 0) -> RunStats:
        eng = self.engine(mode=mode, budget_bytes=budget_bytes,
                          num_agents=num_agents, pin_window=pin_window)
        if generate:
            _, stats = eng.run_generate(tokens, generate)
        else:
            _, stats = eng.run_single(tokens)
        return stats
