"""Unified async prefetch runtime (the shared I/O engine under PIPELOAD).

Every byte-moving subsystem in the repo used to run its own hand-rolled
prefetch loop: the per-round Loading Agent threads in ``core/engine.py``,
the expert-fetch ``ThreadPoolExecutor`` in ``core/expert_stream.py`` and
the profiler's synchronous load-timing loops.  This module replaces all
three with ONE runtime — a bounded worker pool plus a destroy drainer —
and one explicit shard lifecycle::

    acquire ──> load ──> publish ──> consume ──┬─> destroy
      (S_stop)   (disk)    (S_comp)            └─> keep
         │          │          │
         └──────────┴──────────┴──── any failure / cancellation
                                      └─> release (ledger drains exact)

The load-bearing invariant: **bytes charged to a ``_Ledger`` are released
on every exit path** — load exceptions, consumer exceptions, round
cancellation, weights published but never consumed, weights consumed but
never destroyed.  A serving session shares one ledger across every round,
so any leaked charge permanently eats streaming headroom; ``PrefetchStream``
tracks a per-job charge flag and its ``close()`` sweeps whatever the happy
path did not hand off.

In-order grant policy (kept from the original inline thread code, now a
runtime policy): budgeted runs grant ledger bytes in JOB order.  Without
this, a worker loading shard k+1 can win the race for the last slot of
headroom while shard k's worker parks on S_stop — the in-order consumer
then never computes k, nothing is destroyed, and the pipeline deadlocks
even above the budget floor.  Granting in order makes the lowest unloaded
shard the next byte consumer, so the floor (other + cache + pinned + one
streaming shard) really does guarantee progress.

Fault injection (CI's prefetch-fault-smoke): ``REPRO_PREFETCH_FAULT_RATE``
makes stream loads raise a deterministic ``PrefetchFault`` with that
probability and ``REPRO_PREFETCH_RETRIES`` retries transient failures, so
a serve run with an artificially flaky loader still completes — and the
fault-injection tests assert the ledger stays byte-exact either way.
"""
from __future__ import annotations

import os
import random
import threading
import time
from collections import deque
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Dict, List, Optional, Sequence

from repro.core import telemetry as _tele

FAULT_RATE_ENV = "REPRO_PREFETCH_FAULT_RATE"
FAULT_SEED_ENV = "REPRO_PREFETCH_FAULT_SEED"
RETRIES_ENV = "REPRO_PREFETCH_RETRIES"

# job lifecycle states
PENDING = "pending"        # submitted, nothing charged yet
CHARGED = "charged"        # ledger bytes acquired, load in flight
READY = "ready"            # published, waiting for the consumer (S_comp)
CONSUMED = "consumed"      # handed to the consumer, still charged
KEPT = "kept"              # ownership left the stream (pin / pipeswitch)
DESTROYED = "destroyed"    # freed by the drainer, bytes released (S_dest)
RELEASED = "released"      # failure path: charge returned, weights dropped
SKIPPED = "skipped"        # already resident: published without a charge


class PrefetchFault(IOError):
    """Injected transient load failure (fault-injection hooks)."""


class _Job:
    __slots__ = ("index", "key", "nbytes", "state", "charged")

    def __init__(self, index: int, key: str, nbytes: int):
        self.index = index
        self.key = key
        self.nbytes = int(nbytes)
        self.state = PENDING
        self.charged = False


class PrefetchStream:
    """One round's ordered shard loads, lifecycle-managed.

    Built by ``PrefetchRuntime.stream``; the consumer drives it strictly
    in order — ``wait(k)`` blocks on S_comp, then either ``destroy(k, w)``
    (queue the bytes for the drainer, the S_dest path) or ``keep(k)``
    (ownership transfers out: pinned windows and pipeswitch passes, where
    the caller owns the eventual release).  Always ``close()`` (or use as
    a context manager): close aborts outstanding work, drains queued
    destroys, and releases every charge the consumer did not take over.
    """

    def __init__(self, runtime: "PrefetchRuntime", keys: Sequence[str],
                 sizes: Sequence[int], load_fn: Callable[[str], dict], *,
                 ledger=None, preloaded: Optional[Dict[int, dict]] = None,
                 events: Optional[list] = None, t0: float = 0.0,
                 retries: Optional[int] = None, owner: str = "stream"):
        assert len(keys) == len(sizes)
        self._runtime = runtime
        self._load_fn = load_fn
        self._ledger = ledger
        self._owner = owner
        self._events = events
        self._t0 = t0
        self._retries = runtime.retries if retries is None else int(retries)
        self._jobs = [_Job(i, k, b) for i, (k, b) in
                      enumerate(zip(keys, sizes))]
        self._ready: Dict[int, dict] = {}
        self._cond = threading.Condition()        # carries S_comp signals
        self._done = threading.Event()
        self._err: List[BaseException] = []
        # in-order grant policy state (see module docstring): the order
        # is the non-preloaded jobs, lowest index first
        preloaded = preloaded or {}
        self._order = [j.index for j in self._jobs
                       if j.index not in preloaded]
        self._grant = {"pos": 0}
        self._grant_cond = threading.Condition()
        # destroys queued on the runtime drainer but not yet finalized
        self._pending_destroy = 0
        self._destroy_cond = threading.Condition()
        self._futures: List[Future] = []
        for idx, w in preloaded.items():
            job = self._jobs[idx]
            job.state = SKIPPED
            self._ready[idx] = w                  # uncharged publish
        for job in self._jobs:
            if job.state is not SKIPPED:
                self._futures.append(runtime._submit_stream(self._work, job))

    # -- lifecycle: acquire ------------------------------------------------
    def _acquire(self, job: _Job) -> bool:
        """Reserve ``job.nbytes`` under the in-order grant policy; False =
        round aborted (nothing left charged)."""
        if self._ledger is None:
            return not self._done.is_set()
        if self._ledger.budget is not None:
            with self._grant_cond:
                while (not self._done.is_set()
                       and self._grant["pos"] < len(self._order)
                       and self._order[self._grant["pos"]] != job.index):
                    self._grant_cond.wait(timeout=0.1)
            if self._done.is_set():
                return False
        self._ledger.acquire(job.nbytes, self._done.is_set,  # may park: S_stop
                             owner=self._owner, detail=job.key)
        job.charged = True
        job.state = CHARGED
        if self._ledger.budget is not None:
            with self._grant_cond:
                self._grant["pos"] += 1
                self._grant_cond.notify_all()
        if self._done.is_set():
            self._release_job(job)
            return False
        return True

    def _release_job(self, job: _Job):
        """Return a job's charge to the ledger exactly once."""
        with self._cond:
            charged, job.charged = job.charged, False
            job.state = RELEASED
        if charged and self._ledger is not None:
            self._ledger.release(job.nbytes, owner=self._owner,
                                 detail=job.key)

    def _fail(self, e: BaseException):
        self._err.append(e)
        self._done.set()
        with self._cond:
            self._cond.notify_all()
        with self._grant_cond:
            self._grant_cond.notify_all()

    def _event(self, kind: str, key: str, t: float):
        if self._events is not None:
            self._events.append((t - self._t0, kind, key))

    # -- lifecycle: load + publish (worker side) ---------------------------
    def _work(self, job: _Job):
        tr = _tele.get_tracer()
        try:
            if self._done.is_set():
                return
            if tr.enabled:
                with tr.span("shard_acquire", key=job.key,
                             bytes=job.nbytes):
                    ok = self._acquire(job)
            else:
                ok = self._acquire(job)
            if not ok:
                return
            w = None
            t_start = time.perf_counter()
            absorbed = 0
            for attempt in range(self._retries + 1):
                try:
                    self._runtime._maybe_fault(job.key)
                    t_start = time.perf_counter()
                    if tr.enabled:
                        with tr.span("shard_load", key=job.key,
                                     bytes=job.nbytes):
                            w = self._load_fn(job.key)
                    else:
                        w = self._load_fn(job.key)
                    break
                except Exception as e:  # noqa: BLE001 — transient I/O retry
                    if attempt < self._retries and not self._done.is_set():
                        absorbed += 1
                        self._runtime._m_retries.inc()
                        continue
                    self._release_job(job)
                    self._fail(e)
                    return
            if absorbed:
                self._runtime._m_faults.inc(absorbed)
            self._event("load_start", job.key, t_start)
            self._event("load_end", job.key, time.perf_counter())
            if tr.enabled:
                tr.instant("shard_publish", key=job.key, bytes=job.nbytes)
            with self._cond:
                if self._done.is_set():
                    abort = True
                else:
                    abort = False
                    job.state = READY
                    self._ready[job.index] = w
                    self._cond.notify_all()              # S_comp(k)
            if abort:
                self._release_job(job)
        except BaseException as e:  # noqa: BLE001 — never die silently
            self._release_job(job)
            self._fail(e)

    # -- lifecycle: consume ------------------------------------------------
    def wait(self, k: int) -> dict:
        """Block until job ``k`` is published; raises the first worker
        error if the round failed.  The returned weights stay charged —
        finish the lifecycle with ``destroy`` or ``keep``."""
        with self._cond:
            while k not in self._ready and not self._err:
                self._cond.wait(timeout=0.1)
            if self._err:
                raise self._err[0]
            w = self._ready.pop(k)
            job = self._jobs[k]
            if job.state is READY:
                job.state = CONSUMED
        return w

    # -- lifecycle: destroy / keep -----------------------------------------
    def destroy(self, k: int, weights):
        """Queue job ``k``'s weights for the drainer (S_dest): the bytes
        are released off the consumer's critical path."""
        with self._destroy_cond:
            self._pending_destroy += 1
        self._runtime._enqueue_destroy(self, self._jobs[k], weights)

    def keep(self, k: int, owner: Optional[str] = None):
        """Transfer ownership out of the stream: the caller now owns the
        weights AND the ledger charge (pinned windows keep both; the
        pipeswitch pass releases at end-of-pass).  ``owner`` re-attributes
        the charge to that tier (pinned layers become ``pin`` bytes);
        None leaves it on the stream's own tag."""
        with self._cond:
            job = self._jobs[k]
            job.state = KEPT
            charged = job.charged
        if (owner is not None and owner != self._owner and charged
                and self._ledger is not None):
            self._ledger.transfer(job.nbytes, self._owner, owner,
                                  detail=job.key)

    def _finalize_destroy(self, job: _Job, weights):
        """Drainer-side: free the weights and return the charge."""
        tr = _tele.get_tracer()
        if tr.enabled:
            with tr.span("shard_destroy", key=job.key, bytes=job.nbytes):
                self._finalize_destroy_inner(job, weights)
        else:
            self._finalize_destroy_inner(job, weights)

    def _finalize_destroy_inner(self, job: _Job, weights):
        del weights                                  # free device memory
        with self._cond:
            charged, job.charged = job.charged, False
            job.state = DESTROYED
        if charged and self._ledger is not None:
            self._ledger.release(job.nbytes, owner=self._owner,
                                 detail=job.key)
        self._event("destroy", job.key, time.perf_counter())
        with self._destroy_cond:
            self._pending_destroy -= 1
            self._destroy_cond.notify_all()

    # -- lifecycle: close --------------------------------------------------
    def close(self):
        """Abort outstanding work and sweep every remaining charge.

        Safe on every path: workers that already handed off (READY /
        CONSUMED) are swept here; workers still in flight observe
        ``done`` and release their own charge on the way out; queued
        destroys are drained before the sweep so nothing is counted
        twice."""
        self._done.set()
        with self._cond:
            self._cond.notify_all()
        with self._grant_cond:
            self._grant_cond.notify_all()
        deadline = time.monotonic() + 10.0
        for f in self._futures:
            f.cancel()
            try:
                f.result(timeout=max(0.1, deadline - time.monotonic()))
            except BaseException:  # noqa: BLE001 — errors already in _err
                pass
        with self._destroy_cond:
            while self._pending_destroy > 0:
                self._destroy_cond.wait(timeout=0.1)
        for job in self._jobs:
            if job.charged and job.state in (READY, CONSUMED):
                self._ready.pop(job.index, None)
                with self._cond:
                    charged, job.charged = job.charged, False
                if charged and self._ledger is not None:
                    self._ledger.release(job.nbytes, owner=self._owner,
                                         detail=job.key)

    def __enter__(self) -> "PrefetchStream":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @property
    def error(self) -> Optional[BaseException]:
        return self._err[0] if self._err else None


class PrefetchRuntime:
    """Bounded worker pool + destroy drainer shared by every prefetch
    call site (PIPELOAD shard streams, expert demand-loads, profiler
    load timing).  Threads are created lazily on first use; ``close()``
    joins them (fixing the leaked expert-loader threads the old
    per-engine executor left behind)."""

    def __init__(self, workers: int = 4, *, name: str = "prefetch",
                 fault_rate: Optional[float] = None,
                 fault_seed: Optional[int] = None,
                 retries: Optional[int] = None):
        self.workers = max(1, int(workers))
        self.name = name
        self.fault_rate = (float(os.environ.get(FAULT_RATE_ENV, "0") or 0)
                           if fault_rate is None else float(fault_rate))
        seed = (os.environ.get(FAULT_SEED_ENV)
                if fault_seed is None else fault_seed)
        self._fault_rng = random.Random(int(seed) if seed is not None else 0)
        self.retries = (int(os.environ.get(RETRIES_ENV, "0") or 0)
                        if retries is None else int(retries))
        # registry instruments, cached once (reset() zeroes them in place,
        # so these stay wired across serve runs)
        m = _tele.metrics()
        self._m_retries = m.counter("prefetch.retries")
        self._m_faults = m.counter("prefetch.faults_absorbed")
        self._lock = threading.Lock()
        self._pool: Optional[ThreadPoolExecutor] = None
        self._demand: Optional[ThreadPoolExecutor] = None
        self._destroy_q: "deque" = deque()
        self._destroy_cond = threading.Condition()
        self._drainer: Optional[threading.Thread] = None
        self._shutdown = False

    # -- worker pools ------------------------------------------------------
    # Two pools, not one: stream workers can PARK — a budgeted loader
    # blocks on S_stop until the consumer destroys a layer.  Demand loads
    # (expert fetches, profiler timing) are issued BY that consumer
    # mid-layer, so queueing them behind parked stream workers would
    # deadlock the round: the parked loader waits for the consumer, the
    # consumer waits for its demand load, the demand load waits for the
    # parked loader's pool slot.
    def _ensure_pool(self) -> ThreadPoolExecutor:
        with self._lock:
            if self._shutdown:
                raise RuntimeError(f"PrefetchRuntime '{self.name}' is closed")
            if self._pool is None:
                self._pool = ThreadPoolExecutor(
                    max_workers=self.workers,
                    thread_name_prefix=f"{self.name}-worker")
            return self._pool

    def _ensure_demand(self) -> ThreadPoolExecutor:
        with self._lock:
            if self._shutdown:
                raise RuntimeError(f"PrefetchRuntime '{self.name}' is closed")
            if self._demand is None:
                self._demand = ThreadPoolExecutor(
                    max_workers=self.workers,
                    thread_name_prefix=f"{self.name}-demand")
            return self._demand

    def submit(self, fn: Callable, *args, **kwargs) -> Future:
        """Demand-pool access (the expert-fetch Loading Agents): never
        queues behind stream workers parked on S_stop."""
        return self._ensure_demand().submit(fn, *args, **kwargs)

    def timed_load(self, fn: Callable, *args):
        """Run ``fn(*args)`` on a demand-pool worker and time it there
        (queueing excluded) — the profiler's load-timing path.  Returns
        ``(result, seconds)``."""
        def _run():
            t0 = time.perf_counter()
            out = fn(*args)
            return out, time.perf_counter() - t0
        return self._ensure_demand().submit(_run).result()

    def _submit_stream(self, fn: Callable, *args) -> Future:
        """Stream-pool access (PrefetchStream's per-job workers)."""
        return self._ensure_pool().submit(fn, *args)

    # -- fault injection ---------------------------------------------------
    def _maybe_fault(self, key: str):
        if self.fault_rate > 0:
            with self._lock:
                hit = self._fault_rng.random() < self.fault_rate
            if hit:
                raise PrefetchFault(f"injected load fault: {key}")

    # -- destroy drainer (the Daemon Agent) --------------------------------
    def _ensure_drainer(self):
        with self._lock:
            if self._drainer is None and not self._shutdown:
                self._drainer = threading.Thread(
                    target=self._drain_loop, daemon=True,
                    name=f"{self.name}-drainer")
                self._drainer.start()

    def _enqueue_destroy(self, stream: PrefetchStream, job: _Job, weights):
        self._ensure_drainer()
        with self._destroy_cond:
            self._destroy_q.append((stream, job, weights))
            self._destroy_cond.notify_all()          # S_dest(k)

    def _drain_loop(self):
        while True:
            with self._destroy_cond:
                while not self._destroy_q and not self._shutdown:
                    self._destroy_cond.wait(timeout=0.05)
                if not self._destroy_q:
                    if self._shutdown:
                        return
                    continue
                stream, job, weights = self._destroy_q.popleft()
            stream._finalize_destroy(job, weights)
            del weights

    # -- stream construction -----------------------------------------------
    def stream(self, keys: Sequence[str], sizes: Sequence[int],
               load_fn: Callable[[str], dict], *, ledger=None,
               preloaded: Optional[Dict[int, dict]] = None,
               events: Optional[list] = None, t0: float = 0.0,
               retries: Optional[int] = None,
               owner: str = "stream") -> PrefetchStream:
        """One round's ordered prefetch over ``keys`` (``preloaded`` maps
        already-resident indices to their weights: published immediately,
        never charged).  ``owner`` tags every ledger charge the stream
        makes (see engine.LEDGER_OWNERS)."""
        return PrefetchStream(self, keys, sizes, load_fn, ledger=ledger,
                              preloaded=preloaded, events=events, t0=t0,
                              retries=retries, owner=owner)

    # -- teardown ----------------------------------------------------------
    @property
    def closed(self) -> bool:
        return self._shutdown

    def close(self, wait: bool = True):
        with self._lock:
            if self._shutdown:
                return
            self._shutdown = True
            pool, self._pool = self._pool, None
            demand, self._demand = self._demand, None
            drainer, self._drainer = self._drainer, None
        with self._destroy_cond:
            self._destroy_cond.notify_all()
        if pool is not None:
            pool.shutdown(wait=wait)
        if demand is not None:
            demand.shutdown(wait=wait)
        if drainer is not None and wait:
            drainer.join(timeout=5)

    def __enter__(self) -> "PrefetchRuntime":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):  # best-effort: don't leak pool threads
        try:
            self.close(wait=False)
        except BaseException:  # noqa: BLE001 — interpreter teardown
            pass
