"""Paged KV-cache subsystem: PagePool + radix-tree prefix sharing.

The dense KV path reserves one contiguous ``max_total_len`` cache block
per request for its whole lifetime, so the decode floor scales with
``inflight x max_seq`` even when requests share long system prompts or
retire early.  This module brings PIPELOAD's "memory as a budgeted,
dynamically managed resource" discipline to the KV side:

  * ``PagePool`` carves the ledger's KV reservation into fixed-size
    pages of ``page_size`` token slots (page size chosen by the Pipeline
    Planner).  A page's bytes are charged to the engine's ``_Ledger``
    exactly once, when the page is first mapped, and released the moment
    its last reference drops — the cache analogue of ``S_dest``.  Freed
    page ids go on a free list and are reused before the pool grows, so
    the physical pool plateaus at its high-water mark instead of growing
    with cumulative traffic.

  * ``PrefixTree`` is a radix tree over token ids at page granularity:
    requests whose prompts share a prefix map the SAME physical pages
    (refcounted), so a fleet of requests behind one system prompt
    charges its pages once.  Full pages are shared on a per-chunk match;
    the trailing partial page is shared only on an exact match (its
    remaining slots will be written by decode, so it must be
    copy-on-write — see below).  Nodes are pruned when their page's last
    reference drops, which keeps the drain-to-zero ledger invariant: no
    page outlives the requests that reference it.

  * Copy-on-write append: writes into a shared page (refcount > 1) must
    first copy it to a fresh private page and swap the request's block
    table entry — ``PagePool.is_shared`` + ``alloc``/``release`` give
    the scheduler the primitives; the jnp row copy happens at the round
    boundary where the tables are rebuilt.

Physical storage is owned by the caller (the scheduler keeps one
``(num_pages, page_size, ...)`` jnp array per layer per cache leaf);
this module is the bookkeeping layer — page ids, refcounts, ledger
bytes, and the prefix index.  ``kernels/paged_decode.py`` is the compute
side: a Pallas kernel that gathers K/V tiles through the block table.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import telemetry as _tele


def pages_for(tokens: int, page_size: int) -> int:
    """Number of pages covering ``tokens`` token slots."""
    if tokens <= 0:
        return 0
    return -(-tokens // page_size)


@dataclasses.dataclass
class PoolStats:
    allocs: int = 0            # pages handed out (fresh + reused)
    reuses: int = 0            # allocs served from the free list
    shares: int = 0            # refcount bumps (prefix hits)
    frees: int = 0             # pages whose last reference dropped
    cow_copies: int = 0        # copy-on-write page swaps


class PagePool:
    """Fixed-size KV pages charged against a byte ledger.

    ``page_bytes`` is what ONE page costs across every layer (the
    scheduler computes it as ``num_layers * cache_bytes(1, page_size)``);
    ``ledger`` (an engine ``_Ledger`` or None) is charged on first map
    and credited when the last reference drops.  ``alloc`` never blocks:
    callers check the decode floor first (the admission protocol), so
    the acquire is a plain reservation.
    """

    def __init__(self, page_size: int, page_bytes: int, ledger=None):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.page_size = page_size
        self.page_bytes = page_bytes
        self.ledger = ledger
        # ledger attribution: every page charge is `kv_pages`; `detail`
        # is an audit-only sub-key the scheduler sets around
        # request-scoped alloc/release batches (e.g. the request id)
        self.detail: Optional[str] = None
        self._ref: Dict[int, int] = {}      # live page id -> refcount
        self._free: List[int] = []          # recycled ids, LIFO
        self.capacity = 0                   # high-water page count
        self.mapped_peak = 0                # high-water LIVE page count
        self.stats = PoolStats()
        # registry counters cached once (reset() zeroes them in place)
        m = _tele.metrics()
        self._m_allocs = m.counter("pages.allocs")
        self._m_frees = m.counter("pages.frees")
        self._m_cow = m.counter("pages.cow_copies")

    def _sample(self) -> None:
        """Mapped-pages counter track (only when tracing is enabled)."""
        tr = _tele.get_tracer()
        if tr.enabled:
            tr.counter("kv_mapped_pages", len(self._ref))

    # -- introspection ---------------------------------------------------
    @property
    def mapped_pages(self) -> int:
        return len(self._ref)

    @property
    def mapped_bytes(self) -> int:
        return len(self._ref) * self.page_bytes

    @property
    def mapped_peak_bytes(self) -> int:
        return self.mapped_peak * self.page_bytes

    def refcount(self, pid: int) -> int:
        return self._ref.get(pid, 0)

    def is_shared(self, pid: int) -> bool:
        return self._ref.get(pid, 0) > 1

    # -- lifecycle -------------------------------------------------------
    def alloc(self) -> int:
        """Map a fresh private page (refcount 1); charges the ledger."""
        if self._free:
            pid = self._free.pop()
            self.stats.reuses += 1
        else:
            pid = self.capacity
            self.capacity += 1
        self._ref[pid] = 1
        self.stats.allocs += 1
        self._m_allocs.inc()
        self.mapped_peak = max(self.mapped_peak, len(self._ref))
        self._sample()
        if self.ledger is not None:
            self.ledger.acquire(self.page_bytes, owner="kv_pages",
                                detail=self.detail)
        return pid

    def share(self, pid: int) -> int:
        """Add a reference to an already-mapped page (no new bytes)."""
        if pid not in self._ref:
            raise KeyError(f"page {pid} is not mapped")
        self._ref[pid] += 1
        self.stats.shares += 1
        return pid

    def release(self, pid: int) -> bool:
        """Drop one reference; True when the page was actually freed
        (last reference — its bytes return to the ledger and the id to
        the free list)."""
        refs = self._ref.get(pid)
        if refs is None:
            raise KeyError(f"page {pid} is not mapped")
        if refs > 1:
            self._ref[pid] = refs - 1
            return False
        del self._ref[pid]
        self._free.append(pid)
        self.stats.frees += 1
        self._m_frees.inc()
        self._sample()
        if self.ledger is not None:
            self.ledger.release(self.page_bytes, owner="kv_pages",
                                detail=self.detail)
        return True


# ===========================================================================
# Radix-tree prefix index (page-granular)
# ===========================================================================
class _Node:
    __slots__ = ("pid", "children")

    def __init__(self, pid: int):
        self.pid = pid
        self.children: Dict[Tuple[int, ...], "_Node"] = {}


class PrefixTree:
    """Radix tree over token ids, one node per mapped prompt page.

    Children are keyed by the page's token tuple: a full ``page_size``
    chunk matches any request whose prompt continues with those exact
    tokens; a trailing PARTIAL chunk (the prompt's last, not-full page)
    is keyed by its shorter tuple, so it is shared only between prompts
    that end identically — the slots beyond it belong to each request's
    own generation and the scheduler copy-on-writes the page before the
    first divergent write.

    The tree only indexes LIVE pages: ``forget(pid)`` (called when a
    page's last reference drops) prunes the node, so sharing happens
    among concurrently-resident requests and the pool still drains to
    zero when everything retires.  A freed parent implies freed children
    (prefix refcounts are monotone down the path), so pruning a node
    never orphans a live descendant.
    """

    def __init__(self, page_size: int):
        self.page_size = page_size
        self.root = _Node(-1)
        self._where: Dict[int, Tuple[_Node, Tuple[int, ...]]] = {}
        self.hits = 0               # pages served by sharing
        self.misses = 0             # pages that had to be allocated

    def _chunks(self, tokens: Sequence[int]) -> List[Tuple[int, ...]]:
        ps = self.page_size
        toks = [int(t) for t in tokens]
        return [tuple(toks[i:i + ps]) for i in range(0, len(toks), ps)]

    def walk(self, tokens: Sequence[int]
             ) -> Tuple[List[_Node], List[Tuple[int, ...]]]:
        """One radix descent (no mutation): the matched node path for
        the longest shareable prefix, plus ALL page chunks of the
        prompt — reusable by ``insert`` so an admission attempt walks
        the tree once, not twice."""
        chunks = self._chunks(tokens)
        node, path = self.root, []
        for key in chunks:
            child = node.children.get(key)
            if child is None:
                break
            path.append(child)
            node = child
        return path, chunks

    def match(self, tokens: Sequence[int]) -> int:
        """Longest shareable prefix, in PAGES (no mutation)."""
        return len(self.walk(tokens)[0])

    def insert(self, tokens: Sequence[int], pool: PagePool, *,
               walk: Optional[Tuple[List[_Node],
                                    List[Tuple[int, ...]]]] = None
               ) -> Tuple[List[int], int]:
        """Map the prompt's pages: shared prefix pages are refcount
        bumps, the rest are fresh ``pool.alloc()`` calls registered
        under their token key.  ``walk`` (a ``self.walk(tokens)``
        result; must predate no tree mutation) skips the re-descent.
        Returns ``(page_ids, n_shared)`` — the first ``n_shared``
        entries need no K/V writes (a sibling already holds identical
        content)."""
        path, chunks = walk if walk is not None else self.walk(tokens)
        pids: List[int] = []
        for child in path:
            pool.share(child.pid)
            pids.append(child.pid)
            self.hits += 1
        node = path[-1] if path else self.root
        for key in chunks[len(path):]:
            pid = pool.alloc()
            child = _Node(pid)
            node.children[key] = child
            self._where[pid] = (node, key)
            self.misses += 1
            pids.append(pid)
            node = child
        return pids, len(path)

    def forget(self, pid: int) -> None:
        """Prune the node indexing a freed page (no-op for pages the
        tree never saw, e.g. decode-growth or COW pages)."""
        entry = self._where.pop(pid, None)
        if entry is None:
            return
        parent, key = entry
        child = parent.children.get(key)
        if child is not None and child.pid == pid:
            del parent.children[key]


# ===========================================================================
# Per-tenant prefix namespaces
# ===========================================================================
class PrefixNamespaces:
    """Tenant-keyed family of ``PrefixTree``s over ONE shared ``PagePool``.

    Multi-tenant serving must not leak one tenant's prompt content into
    another's cache reuse: a prefix hit proves the requester already
    knows the tokens, so cross-tenant sharing is a timing/content oracle.
    Namespacing the radix index by tenant id makes isolation structural —
    two tenants submitting byte-identical system prompts map DISJOINT
    physical pages, while requests within a tenant still share theirs.
    The physical pool stays shared (pages are just rows; isolation is an
    indexing property), so retirement in one tenant can never free
    another tenant's pages: their refcounts live on separate nodes.
    """

    def __init__(self, page_size: int):
        self.page_size = page_size
        self._trees: Dict[str, PrefixTree] = {}

    def tree(self, tenant: str) -> PrefixTree:
        """The tenant's own radix tree (created on first use)."""
        t = self._trees.get(tenant)
        if t is None:
            t = self._trees[tenant] = PrefixTree(self.page_size)
        return t

    @property
    def tenants(self) -> List[str]:
        return sorted(self._trees)

    @property
    def hits(self) -> int:
        """Prefix-hit pages, summed across tenants (each hit is by
        construction a WITHIN-tenant share)."""
        return sum(t.hits for t in self._trees.values())

    @property
    def misses(self) -> int:
        return sum(t.misses for t in self._trees.values())

    def hits_by_tenant(self) -> Dict[str, int]:
        return {k: t.hits for k, t in sorted(self._trees.items())}


# ===========================================================================
# Per-request block table
# ===========================================================================
@dataclasses.dataclass
class BlockTable:
    """One request's logical-page -> physical-page mapping.

    ``n_shared`` counts the leading prompt pages mapped through the
    prefix tree — their contents were written by a sibling request and
    must not be re-written by this request's prefill (a shared partial
    page may already hold the sibling's generated tokens past this
    request's prompt; they are masked out by the valid-length mask)."""
    pages: List[int] = dataclasses.field(default_factory=list)
    n_shared: int = 0

    def __len__(self) -> int:
        return len(self.pages)

    def release_all(self, pool: PagePool,
                    tree: Optional[PrefixTree] = None) -> int:
        """Retirement: drop this request's reference on every page;
        pages still referenced by a live sibling survive (the
        refcounted exact-drain property).  Returns pages freed."""
        freed = 0
        for pid in self.pages:
            if pool.release(pid):
                freed += 1
                if tree is not None:
                    tree.forget(pid)
        self.pages.clear()
        self.n_shared = 0
        return freed

    # -- speculative branching (copy-on-write off a committed table) ----
    def branch(self, pool: PagePool) -> "BlockTable":
        """Map a speculative branch: a new table sharing EVERY page of
        this one (refcount bumps, zero new bytes, zero copies).  The
        branch starts fully shared (``n_shared == len(pages)``); the
        speculator ``cow``s each page before its first write and
        ``rollback``s the suffix a failed verification leaves behind.
        Commit = ``release_all`` the parent, keep the branch."""
        for pid in self.pages:
            pool.share(pid)
        return BlockTable(list(self.pages), len(self.pages))

    def cow(self, idx: int, pool: PagePool) -> Optional[Tuple[int, int]]:
        """Make logical page ``idx`` privately writable.  Shared pages
        (a sibling or the committed parent holds them) are swapped for a
        fresh alloc — the caller must copy the page's contents
        ``old -> new`` in the physical pool; returns ``(old, new)`` to
        batch that copy.  Already-private pages return None (write in
        place)."""
        pid = self.pages[idx]
        if not pool.is_shared(pid):
            if idx < self.n_shared:
                self.n_shared = idx
            return None
        new = pool.alloc()
        pool.release(pid)        # sibling keeps it: never frees here
        pool.stats.cow_copies += 1
        pool._m_cow.inc()
        tr = _tele.get_tracer()
        if tr.enabled:
            tr.instant("page_cow", old=pid, new=new)
        self.pages[idx] = new
        if idx < self.n_shared:
            self.n_shared = idx
        return pid, new

    def rollback(self, pool: PagePool, keep_pages: int,
                 tree: Optional[PrefixTree] = None) -> int:
        """Drop every page past the first ``keep_pages`` — the O(pages)
        rejection path: a refused speculative suffix is unmapped by
        refcount drops alone, never a copy.  Returns pages freed."""
        freed = 0
        while len(self.pages) > max(keep_pages, 0):
            pid = self.pages.pop()
            if pool.release(pid):
                freed += 1
                if tree is not None:
                    tree.forget(pid)
        self.n_shared = min(self.n_shared, len(self.pages))
        return freed
