"""Per-layer executable modules for the PIPELOAD Execution Engine.

The engine operates at shard granularity: ``embed`` -> N x ``layer`` ->
``head``.  Each module is a jitted full-sequence forward (the paper's
engine re-runs the pipeline per generated token for GPT-style models, so
decode is prefix re-inference, matching §V-B2 semantics).
"""
from __future__ import annotations

import functools
from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.dense_lm import layer_prefill
from repro.models.config import ModelConfig


def build_module_fns(cfg: ModelConfig) -> Dict[str, Callable]:
    """Returns jitted {embed, layer, head} apply functions."""

    @jax.jit
    def embed_apply(weights, tokens):
        return weights["embed"][tokens]

    @jax.jit
    def layer_apply(weights, x):
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        out, _, _ = layer_prefill(weights, x, cfg, None, positions,
                                  make_cache=False)
        return out

    @jax.jit
    def head_apply(weights, x):
        h = common.rms_norm(x, weights["final_norm"], cfg.norm_eps)
        if "lm_head" in weights:
            return (h[:, -1] @ weights["lm_head"]).astype(jnp.float32)
        return h[:, -1].astype(jnp.float32)

    return {"embed": embed_apply, "layer": layer_apply, "head": head_apply}
