"""Per-layer executable modules for the PIPELOAD Execution Engine.

The engine operates at shard granularity: ``embed`` -> N x ``layer`` ->
``head``.  Two generation regimes are supported:

  * **re-prefill** (the paper's §V-B2 semantics): ``layer`` is a jitted
    full-sequence forward with ``make_cache=False``; GPT decode re-runs the
    whole prefix every token.
  * **KV-cache incremental decode** (beyond-paper): ``layer_cache`` is the
    prefill that ALSO emits the layer's KV cache, padded out to
    ``total_len`` so later single-token writes are in-place updates, and
    ``layer_decode`` advances one token against that cache.  The decode
    attention can run through the Pallas flash-decoding kernel
    (``attn_impl="pallas"``, kernels/flash_decode.py) — "auto" picks it on
    TPU, the jnp online softmax elsewhere.

Quantized checkpoints (``partition_and_save(..., quant="int8"|"int4")``)
arrive as weight trees whose 2-D matmul weights are ``QuantizedTensor``
leaves.  Every module fn dequantizes those leaves *inside* its jit — the
resident form the engine's ledger accounts stays quantized, and the fp
copy of (at most) the layer currently computing is a transient XLA
temporary, destroyed with the computation.  The embedding fn takes the
gather-then-scale fast path so the fp table is never materialised for
int8.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import quant as qz
from repro.models import common
from repro.models.dense_lm import layer_decode, layer_prefill
from repro.models.config import ModelConfig


def resolve_attn_impl(attn_impl: Optional[str]) -> Optional[str]:
    """"auto" -> Pallas kernel on TPU, jnp online softmax elsewhere
    (interpret-mode Pallas is a validation tool, not a fast path)."""
    if attn_impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else None
    return attn_impl


def _pad_seq(a: jax.Array, total_len: int) -> jax.Array:
    """Grow a cache leaf (B, S, ...) to (B, total_len, ...) in place-0."""
    if a.shape[1] >= total_len:
        return a
    out = jnp.zeros((a.shape[0], total_len) + a.shape[2:], a.dtype)
    return jax.lax.dynamic_update_slice_in_dim(out, a, 0, axis=1)


def build_module_fns(cfg: ModelConfig,
                     attn_impl: Optional[str] = "auto") -> Dict[str, Callable]:
    """Returns jitted {embed, layer, layer_cache, layer_decode, head}
    apply functions."""
    impl = resolve_attn_impl(attn_impl)

    @jax.jit
    def embed_apply(weights, tokens):
        emb = weights["embed"]
        if qz.is_quantized(emb):
            return emb.take_rows(tokens)
        return emb[tokens]

    @jax.jit
    def layer_apply(weights, x):
        weights = qz.dequant_tree(weights)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        out, _, _ = layer_prefill(weights, x, cfg, None, positions,
                                  make_cache=False)
        return out

    @functools.partial(jax.jit, static_argnums=(2,))
    def layer_cache_apply(weights, x, total_len: int):
        """Prefill one layer AND capture its KV cache, padded to
        ``total_len`` slots so decode steps write in place."""
        weights = qz.dequant_tree(weights)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        out, cache, _ = layer_prefill(weights, x, cfg, None, positions,
                                      make_cache=True)
        cache = jax.tree.map(lambda a: _pad_seq(a, total_len), cache)
        return out, cache

    @jax.jit
    def layer_decode_apply(weights, x, cache, pos):
        """One token per sequence (B, 1, D) against this layer's cache.
        ``pos`` is the global position of the new token — a scalar for the
        single-request path, or a RAGGED (B,) vector when the batch stacks
        concurrent requests whose sequences sit at different lengths (the
        continuous-batching scheduler).  Traced either way: no per-step
        recompile, and batched rounds reuse one executable per batch
        size."""
        weights = qz.dequant_tree(weights)
        out, new_cache = layer_decode(weights, x, cfg, None, cache, pos,
                                      attn_impl=impl)
        return out, new_cache

    @jax.jit
    def head_apply(weights, x):
        weights = qz.dequant_tree(weights)
        h = common.rms_norm(x, weights["final_norm"], cfg.norm_eps)
        if "lm_head" in weights:
            return (h[:, -1] @ weights["lm_head"]).astype(jnp.float32)
        return h[:, -1].astype(jnp.float32)

    return {"embed": embed_apply, "layer": layer_apply,
            "layer_cache": layer_cache_apply,
            "layer_decode": layer_decode_apply, "head": head_apply}
