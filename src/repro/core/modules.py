"""Per-layer executable modules for the PIPELOAD Execution Engine.

The engine operates at shard granularity: ``embed`` -> N x ``layer`` ->
``head``.  Two generation regimes are supported:

  * **re-prefill** (the paper's §V-B2 semantics): ``layer`` is a jitted
    full-sequence forward with ``make_cache=False``; GPT decode re-runs the
    whole prefix every token.
  * **KV-cache incremental decode** (beyond-paper): ``layer_cache`` is the
    prefill that ALSO emits the layer's KV cache, padded out to
    ``total_len`` so later single-token writes are in-place updates, and
    ``layer_decode`` advances one token against that cache.  The decode
    attention can run through the Pallas flash-decoding kernel
    (``attn_impl="pallas"``, kernels/flash_decode.py) — "auto" picks it on
    TPU, the jnp online softmax elsewhere.

Quantized checkpoints (``partition_and_save(..., quant="int8"|"int4")``)
arrive as weight trees whose 2-D matmul weights are ``QuantizedTensor``
leaves.  Every module fn dequantizes those leaves *inside* its jit — the
resident form the engine's ledger accounts stays quantized, and the fp
copy of (at most) the layer currently computing is a transient XLA
temporary, destroyed with the computation.  The embedding fn takes the
gather-then-scale fast path so the fp table is never materialised for
int8.

MoE-family configs additionally get the **expert-streaming split** of the
layer forward (core/expert_stream.py drives it):

  * ``moe_router`` / ``moe_router_cache`` / ``moe_router_decode`` — the
    attention block plus the router: everything the per-layer
    attention+router shard can compute on its own.  They return the
    post-attention residual, the normed FFN input and the batch's
    normalised top-k routing ``(top_w, top_ids)`` — the engine reads
    ``top_ids`` back and demand-loads exactly those experts.
  * ``moe_combine`` — capacity-based dispatch + expert FFN + combine over
    a *subset* of experts (the round's activated union, padded with
    zero-weight experts and ``sel=-1`` slots to a fixed bucket size).
    The math is ``models/moe.py``'s ``_moe_local`` restricted to the
    selected experts: every kept (token, expert) pair lands in the same
    buffer row with the same capacity-drop rule, and unselected experts'
    rows were all-zero in the oracle anyway — so streamed outputs match
    the in-memory oracle token-for-token.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import quant as qz
from repro.models import attention as attn
from repro.models import common, moe
from repro.models.dense_lm import (layer_decode, layer_decode_paged,
                                   layer_prefill, layer_verify_paged)
from repro.models.config import DENSE, MOE, VLM, ModelConfig

# Families the PIPELOAD engine can execute at shard granularity.  The
# recurrent / enc-dec families have layer semantics (states, cross
# attention) the per-layer module fns do not model yet.
ENGINE_FAMILIES = (DENSE, MOE, VLM)


def check_engine_family(cfg: ModelConfig, where: str = "the PIPELOAD "
                        "engine") -> None:
    """Raise a clear error for families the engine cannot stream, instead
    of a KeyError from deep inside module construction."""
    if cfg.family not in ENGINE_FAMILIES:
        raise ValueError(
            f"model family '{cfg.family}' ({cfg.name}) is not supported "
            f"by {where}; supported families: "
            f"{', '.join(ENGINE_FAMILIES)}")


def resolve_attn_impl(attn_impl: Optional[str]) -> Optional[str]:
    """"auto" -> the autotuned per-device choice when one is installed
    (kernels/autotune.py), else Pallas kernel on TPU and jnp online
    softmax elsewhere (interpret-mode Pallas is a validation tool, not a
    fast path)."""
    if attn_impl == "auto":
        from repro.kernels import ops
        tuned = ops.tuned_paged_impl()
        if tuned is not None:
            return "pallas" if tuned == "pallas" else None
        return "pallas" if jax.default_backend() == "tpu" else None
    return attn_impl


def _pad_seq(a: jax.Array, total_len: int) -> jax.Array:
    """Grow a cache leaf (B, S, ...) to (B, total_len, ...) in place-0."""
    if a.shape[1] >= total_len:
        return a
    out = jnp.zeros((a.shape[0], total_len) + a.shape[2:], a.dtype)
    return jax.lax.dynamic_update_slice_in_dim(out, a, 0, axis=1)


def build_module_fns(cfg: ModelConfig,
                     attn_impl: Optional[str] = "auto") -> Dict[str, Callable]:
    """Returns jitted {embed, layer, layer_cache, layer_decode, head}
    apply functions — plus the expert-streaming split
    (moe_router/moe_router_cache/moe_router_decode/moe_combine) for
    MoE-family configs."""
    check_engine_family(cfg)
    impl = resolve_attn_impl(attn_impl)

    @jax.jit
    def embed_apply(weights, tokens):
        emb = weights["embed"]
        if qz.is_quantized(emb):
            return emb.take_rows(tokens)
        return emb[tokens]

    @jax.jit
    def layer_apply(weights, x):
        weights = qz.dequant_tree(weights)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        out, _, _ = layer_prefill(weights, x, cfg, None, positions,
                                  make_cache=False)
        return out

    @functools.partial(jax.jit, static_argnums=(2,))
    def layer_cache_apply(weights, x, total_len: int):
        """Prefill one layer AND capture its KV cache, padded to
        ``total_len`` slots so decode steps write in place."""
        weights = qz.dequant_tree(weights)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        out, cache, _ = layer_prefill(weights, x, cfg, None, positions,
                                      make_cache=True)
        cache = jax.tree.map(lambda a: _pad_seq(a, total_len), cache)
        return out, cache

    @jax.jit
    def layer_decode_apply(weights, x, cache, pos):
        """One token per sequence (B, 1, D) against this layer's cache.
        ``pos`` is the global position of the new token — a scalar for the
        single-request path, or a RAGGED (B,) vector when the batch stacks
        concurrent requests whose sequences sit at different lengths (the
        continuous-batching scheduler).  Traced either way: no per-step
        recompile, and batched rounds reuse one executable per batch
        size."""
        weights = qz.dequant_tree(weights)
        out, new_cache = layer_decode(weights, x, cfg, None, cache, pos,
                                      attn_impl=impl)
        return out, new_cache

    # Paged KV decode (core/kv_pages.py): cache leaves live in fixed-size
    # page pools (P, page, ...) and each request's logical sequence is a
    # block table of page ids.  GQA without a sliding window takes the
    # dedicated path (Pallas block-table kernel under impl="pallas", no
    # densified gather); everything else (MLA, windows) gathers the
    # row's pages into the logically contiguous cache — bit-identical to
    # the dense decode over the same padded length — runs the ordinary
    # layer_decode, and scatters the one written row back into its page.
    gqa_paged = cfg.attention != "mla" and cfg.sliding_window is None

    @jax.jit
    def layer_decode_paged_apply(weights, x, pools, tables, pos):
        """One token per request against the paged cache.  ``pools`` is
        this layer's cache dict with (P, page, ...) leaves; ``tables``
        (B, NB) int32 block tables (pad short rows with page 0);
        ``pos`` (B,) ragged write positions.  The write page must be
        private (the scheduler copy-on-writes shared pages first)."""
        weights = qz.dequant_tree(weights)
        b, nb = tables.shape
        posv = jnp.asarray(pos, jnp.int32).reshape(b)
        if gqa_paged:
            return layer_decode_paged(weights, x, cfg, pools, tables,
                                      posv, attn_impl=impl)
        ps = next(iter(pools.values())).shape[1]
        cache = jax.tree.map(
            lambda a: a[tables].reshape((b, nb * ps) + a.shape[2:]), pools)
        out, new_cache = layer_decode(weights, x, cfg, None, cache, posv,
                                      attn_impl=impl)
        rows = jnp.arange(b)

        def scatter(pool_leaf, cache_leaf):
            val = cache_leaf[rows, posv]
            return pool_leaf.at[tables[rows, posv // ps],
                                posv % ps].set(val.astype(pool_leaf.dtype))

        pools = jax.tree.map(scatter, pools, new_cache)
        return out, pools

    @jax.jit
    def layer_verify_paged_apply(weights, x, pools, tables, pos):
        """Stacked W-token speculative verify against the paged cache:
        ``x`` (B, W, D) holds each request's last committed token plus
        its draft proposals, ``pos`` (B,) the cache slot of the FIRST
        stacked token.  One weight stream scores the whole window —
        query i attends slots <= pos + i, so the outputs match W
        sequential ``layer_decode_paged`` steps."""
        weights = qz.dequant_tree(weights)
        b = tables.shape[0]
        posv = jnp.asarray(pos, jnp.int32).reshape(b)
        return layer_verify_paged(weights, x, cfg, pools, tables, posv,
                                  attn_impl=impl)

    @jax.jit
    def head_apply(weights, x):
        weights = qz.dequant_tree(weights)
        h = common.rms_norm(x, weights["final_norm"], cfg.norm_eps)
        if "lm_head" in weights:
            return (h[:, -1] @ weights["lm_head"]).astype(jnp.float32)
        return h[:, -1].astype(jnp.float32)

    @jax.jit
    def head_all_apply(weights, x):
        """Full-width head: logits for EVERY stacked position (B, W, V)
        — the verify step needs the target's greedy pick at each slot
        of the speculation window, not just the last."""
        weights = qz.dequant_tree(weights)
        h = common.rms_norm(x, weights["final_norm"], cfg.norm_eps)
        if "lm_head" in weights:
            return (h @ weights["lm_head"]).astype(jnp.float32)
        return h.astype(jnp.float32)

    fns = {"embed": embed_apply, "layer": layer_apply,
           "layer_cache": layer_cache_apply,
           "layer_decode": layer_decode_apply,
           "layer_decode_paged": layer_decode_paged_apply,
           "head": head_apply, "head_all": head_all_apply}
    if gqa_paged:
        # the stacked verify path is GQA-only (no windowed/MLA variant);
        # gating the key lets callers feature-test speculation support
        fns["layer_verify_paged"] = layer_verify_paged_apply
    if cfg.family == MOE:
        fns.update(_build_moe_stream_fns(cfg, impl))
    return fns


# ===========================================================================
# Expert-streaming MoE split (core/expert_stream.py drives these)
# ===========================================================================
def _build_moe_stream_fns(cfg: ModelConfig,
                          impl: Optional[str]) -> Dict[str, Callable]:
    k, n_e = cfg.top_k, cfg.n_experts

    def _route(weights, x):
        """Post-attention residual ``x`` -> (flat FFN input, normalised
        top-k weights, expert ids) — byte-identical routing to
        ``models/moe._moe_local``."""
        h = common.rms_norm(x, weights["ffn_norm"], cfg.norm_eps)
        hf = h.reshape(-1, h.shape[-1])
        logits = hf.astype(jnp.float32) @ weights["moe"]["router"]
        probs = jax.nn.softmax(logits, axis=-1)
        top_w, top_ids = jax.lax.top_k(probs, k)
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
        return hf, top_w, top_ids

    def _attn_prefill(weights, x, *, make_cache):
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        h = common.rms_norm(x, weights["attn_norm"], cfg.norm_eps)
        if cfg.attention == "mla":
            a, cache = attn.mla_prefill(weights["attn"], h, cfg, None,
                                        positions, make_cache=make_cache)
        else:
            a, cache = attn.gqa_prefill(weights["attn"], h, cfg, None,
                                        positions, causal=cfg.causal,
                                        make_cache=make_cache)
        return x + a, cache

    @jax.jit
    def moe_router_apply(weights, x):
        """Full-sequence attention + router (no cache)."""
        weights = qz.dequant_tree(weights)
        xa, _ = _attn_prefill(weights, x, make_cache=False)
        hf, top_w, top_ids = _route(weights, xa)
        return xa, hf, top_w, top_ids

    @functools.partial(jax.jit, static_argnums=(2,))
    def moe_router_cache_apply(weights, x, total_len: int):
        """Cache-capturing prefill variant (pads like layer_cache)."""
        weights = qz.dequant_tree(weights)
        xa, cache = _attn_prefill(weights, x, make_cache=True)
        cache = jax.tree.map(lambda a: _pad_seq(a, total_len), cache)
        hf, top_w, top_ids = _route(weights, xa)
        return xa, cache, hf, top_w, top_ids

    @jax.jit
    def moe_router_decode_apply(weights, x, cache, pos):
        """Single-token attention against the layer cache + router.
        ``pos`` scalar or ragged (B,), as in layer_decode."""
        weights = qz.dequant_tree(weights)
        h = common.rms_norm(x, weights["attn_norm"], cfg.norm_eps)
        if cfg.attention == "mla":
            a, new_cache = attn.mla_decode(weights["attn"], h, cfg, None,
                                           cache, pos)
        else:
            a, new_cache = attn.gqa_decode(weights["attn"], h, cfg, None,
                                           cache, pos, attn_impl=impl)
        xa = x + a
        hf, top_w, top_ids = _route(weights, xa)
        return xa, new_cache, hf, top_w, top_ids

    @jax.jit
    def moe_combine_apply(experts, sel, xa, hf, top_w, top_ids):
        """Dispatch + expert FFN + combine over the round's streamed
        experts.

        ``experts`` is a tuple of per-expert weight dicts (zero-weight
        pads at the tail); ``sel`` (U,) maps each slot to its global
        expert id (-1 for pads).  The dispatch reuses the oracle's
        ``_dispatch_indices`` — same capacity, same drop rule — then
        remaps global expert rows onto the U-expert buffer."""
        ws = [qz.dequant_tree(e) for e in experts]
        wg = jnp.stack([w["w_gate"] for w in ws])
        wu = jnp.stack([w["w_up"] for w in ws])
        wd = jnp.stack([w["w_down"] for w in ws])
        u = len(ws)
        t, d = hf.shape
        cap = moe.capacity(cfg, t)
        slots = moe._dispatch_indices(top_ids, k, n_e, cap,
                                      jnp.int32(0), n_e)       # (T, K)
        # global expert id -> union slot; -1 = not streamed this round.
        # Pad sel entries scatter out of bounds (dropped), so inv[n_e]
        # — the bucket dropped pairs land in — stays -1.
        inv = jnp.full((n_e + 1,), -1, jnp.int32)
        inv = inv.at[jnp.where(sel >= 0, sel, n_e + 1)].set(
            jnp.arange(u, dtype=jnp.int32), mode="drop")
        g = jnp.minimum(slots // cap, n_e)                     # n_e = dropped
        pos_in = slots % cap
        uslot = inv[g]
        local = jnp.where((slots < n_e * cap) & (uslot >= 0),
                          uslot * cap + pos_in, u * cap)       # OOB = drop
        tok = jnp.broadcast_to(jnp.arange(t)[:, None], (t, k)).reshape(-1)
        buf = jnp.zeros((u * cap, d), hf.dtype)
        buf = buf.at[local.reshape(-1)].set(hf[tok], mode="drop")
        buf = moe._expert_ffn(buf.reshape(u, cap, d), wg, wu, wd)
        buf = buf.reshape(u * cap, d)

        def body(acc, kk):
            contrib = buf.at[local[:, kk]].get(mode="fill", fill_value=0.0)
            return acc + contrib * top_w[:, kk, None].astype(buf.dtype), None

        acc0 = (hf * 0).astype(buf.dtype) + buf[:1] * 0
        out, _ = jax.lax.scan(body, acc0, jnp.arange(k))
        return xa + out.reshape(xa.shape)

    return {"moe_router": moe_router_apply,
            "moe_router_cache": moe_router_cache_apply,
            "moe_router_decode": moe_router_decode_apply,
            "moe_combine": moe_combine_apply}
