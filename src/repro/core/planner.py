"""Pipeline Planner (Hermes §IV-2) + generation-aware tier (beyond-paper).

From the Layer Profiler's output it builds a PIPELOAD execution schedule:
for each memory constraint, the number of Loading Agents that minimises
latency while the predicted peak stays within budget.

Two prediction tiers, mirroring the paper's "reasonable range, then exact
pre-run":
  1. an analytic model for the feasible range of ``m``:
        T(m) ~ t_load + ceil(N/m - 1) * max(t_load, m*t_comp) + m*t_comp
        M(m) ~ (m + c) * layer_bytes + other_bytes
  2. a discrete-event simulation of the engine (the "pre-run") that
     replays the exact agent striping, in-order inference and destruction
     to get latency and true peak memory.

The generation-aware tier (``plan_generate``) plans KV-cache decode
workloads: it charges ``num_layers * cache_bytes`` of KV pages to the peak
model, amortises layer loads over ``new_tokens`` pipeline rounds, and
searches ``(num_agents, pin_window)`` JOINTLY — pinned layers trade budget
headroom (they stay resident) against reloads (they skip the disk in every
decode round).  With ``max_inflight > 1`` it also searches the
continuous-batching dimension: KV pages scale with the in-flight count
while the weight stream does not, so the optimal
``(num_agents, pin_window, inflight)`` triple changes with the budget.

Expert-split MoE profiles (``expert_split`` + per-expert byte/latency
figures from the Layer Profiler) add a third search dimension: the
**ExpertCache size**.  The round model is analytic-on-top-of-simulated:
``expected_unique_experts(n_experts, top_k, tokens)`` gives the expected
per-layer union a round demand-loads (exact under uniform independent
top-k routing: ``E * (1 - ((E-k)/E)^T)``), a first-order LRU model turns
cache bytes into a hit rate (the cached fraction of the ``L*E`` expert
pool), and the resulting expected miss-fetch time is folded into each
layer's compute time — expert fetches ride the Inference Agent's path,
after the router — before the discrete-event ``simulate`` replays the
round.  ``plan_generate`` then searches cache size jointly with
``(num_agents, pin_window, inflight, dtype)``; the winning entry's
``expert_cache_bytes`` sizes the engine's reservation.

Both ``plan`` and ``plan_generate`` also search over shard *dtype*: pass
``{"fp32": profile, "int8": profile, ...}`` (one Layer Profiler run per
quantized variant of the checkpoint — per-dtype ``t_load``/``bytes`` are
measured, not modelled) and every candidate grid is the union across
dtypes; the chosen entry's ``dtype`` field names the winner.  Quantized
shards carry ~4x/8x fewer bytes, so under tight budgets they admit more
loading agents, deeper pin windows and more in-flight requests — the
capacity-first search surfaces exactly that.  KV-cache pages keep the
model dtype (only weights are quantized), so ``cache_bytes_per_layer``
is dtype-independent.  Accuracy is the user's trade-off, not the
planner's: it never discounts a dtype for quantization error (see
docs/quantization.md for the measured tolerances).
"""
from __future__ import annotations

import copy
import dataclasses
import heapq
import math
from typing import Dict, List, Optional, Tuple

from repro.core.kv_pages import pages_for


@dataclasses.dataclass
class PlanEntry:
    budget_bytes: Optional[int]
    num_agents: int
    predicted_latency_s: float
    predicted_peak_bytes: int
    feasible: bool
    dtype: Optional[str] = None       # shard dtype when searching over quant


@dataclasses.dataclass
class GenPlanEntry:
    """A generation-aware schedule: joint (num_agents, pin_window) — and,
    for serving workloads, the in-flight request count the budget
    admits (``inflight``; 1 for plain single-request generation)."""
    budget_bytes: Optional[int]
    num_agents: int
    pin_window: int
    predicted_latency_s: float        # prefill + all decode rounds
    predicted_prefill_s: float
    predicted_per_token_s: float      # one decode ROUND (all requests)
    predicted_peak_bytes: int         # weights + KV cache
    cache_bytes: int                  # total KV pages (all in-flight reqs)
    feasible: bool
    inflight: int = 1                 # concurrent requests in the batch
    predicted_throughput_tps: float = 0.0  # inflight tokens / decode round
    dtype: Optional[str] = None       # shard dtype when searching over quant
    expert_cache_bytes: int = 0       # ExpertCache size (expert-split MoE)
    page_size: int = 0                # KV page size (0 = dense reservation)
    spec_depth: int = 0               # draft tokens per verify round
    draft_bytes: int = 0              # pinned draft + per-req cache rows
    predicted_ttft_s: float = 0.0     # queue-free time-to-first-token
    predicted_tpot_s: float = 0.0     # expected time per output token
    slo_ok: bool = True               # meets the requested TTFT/TPOT SLO
    chunk_prefill: int = 0            # prefill chunk tokens (0 = monolithic)


# ---------------------------------------------------------------------------
# Tier 1: analytic model
# ---------------------------------------------------------------------------
def analytic_latency(n_layers: int, m: int, t_load: float,
                     t_comp: float) -> float:
    """Pipeline makespan with m parallel loaders, striped L_{i+jm}."""
    waves = math.ceil(n_layers / m)
    stage = max(t_load, m * t_comp)
    return t_load + max(waves - 1, 0) * stage + min(m, n_layers) * t_comp


def analytic_peak(m: int, layer_bytes: int, other_bytes: int,
                  inflight: int = 2, cache_bytes: int = 0,
                  pin_window: int = 0,
                  n_layers: Optional[int] = None) -> int:
    """~(m + c) layers resident: m loading + c awaiting destruction.

    Generation-aware extras: ``cache_bytes`` (total KV pages, resident for
    the whole run) and ``pin_window`` pinned layers (resident across
    decode rounds on top of the streaming window).  With ``n_layers`` the
    streaming term is clamped to the layers that actually stream — a
    fully-pinned stack has NO streaming window, only the pinned bytes."""
    streaming = m + inflight
    if n_layers is not None:
        streaming = min(streaming, max(n_layers - pin_window, 0))
    return ((streaming + pin_window) * layer_bytes + other_bytes
            + cache_bytes)


# ---------------------------------------------------------------------------
# Tier 2: discrete-event simulation (the planner's "pre-run")
# ---------------------------------------------------------------------------
def simulate(profile: Dict, m: int,
             budget_bytes: Optional[int] = None, *,
             pin_window: int = 0, retain_window: int = 0,
             extra_resident_bytes: int = 0,
             t_comp_key: str = "t_comp",
             batch: int = 1) -> Tuple[float, int]:
    """Event-driven replay of PIPELOAD.  Returns (latency_s, peak_bytes).

    Models: m loaders (each strictly sequential over its stripe, reserving
    ledger bytes at load START), one inference agent (in-order), destruction
    at compute completion, loaders blocked while resident + next > budget
    (the paper's S_stop), woken at the next destruction.

    Generation-aware extras (all default to the paper's single-pass
    semantics): the first ``pin_window`` layers are already resident
    (their bytes are charged up front, their loads are free, they are
    never destroyed); the first ``retain_window`` layers load normally
    but are never destroyed (the engine's PREFILL round, where the
    pinned prefix becomes resident); ``extra_resident_bytes`` models
    KV-cache pages held for the whole round; ``t_comp_key`` selects
    which per-shard compute time drives the inference agent
    (``"t_decode"`` for one-token rounds, falling back to ``t_comp``
    when a profile predates decode timing); ``batch`` is the
    continuous-batching in-flight count — the Inference Agent applies
    each streamed layer to ``batch`` stacked requests, so compute times
    scale linearly (a pessimistic bound: batched GEMMs amortise) while
    load times do NOT — exactly the asymmetry the scheduler exploits.
    """
    layers = [s for s in profile["shards"] if s["kind"] == "layer"]
    n = len(layers)
    pin = min(max(pin_window, 0), n)
    keep = max(pin, min(max(retain_window, 0), n))   # never destroyed
    t_load = [s["t_load"] for s in layers]
    t_comp = [batch * s.get(t_comp_key, s["t_comp"]) for s in layers]
    nbytes = [s["bytes"] for s in layers]
    other = profile["other_bytes"] + extra_resident_bytes

    resident = other + sum(nbytes[:pin])
    peak = resident
    streaming = list(range(pin, n))      # layers that actually hit the disk
    stripes = [streaming[i::m] for i in range(m)]
    agent_pos = [0] * m
    ready_at = [math.inf] * n
    loaded_done = [False] * n
    for k in range(pin):                 # pinned: S_comp already raised
        ready_at[k], loaded_done[k] = 0.0, True
    next_inf = 0
    inf_free_at = 0.0
    latency = 0.0
    blocked: List[int] = []           # agent ids blocked on the budget

    # event heap: (time, seq, kind, payload)
    seq = 0
    events: List[Tuple[float, int, str, int]] = []

    def push(t, kind, payload):
        nonlocal seq
        heapq.heappush(events, (t, seq, kind, payload))
        seq += 1

    def try_start_load(a: int, now: float):
        nonlocal resident, peak
        if agent_pos[a] >= len(stripes[a]):
            return
        k = stripes[a][agent_pos[a]]
        if budget_bytes is not None and resident + nbytes[k] > budget_bytes \
                and resident > other:
            if a not in blocked:
                blocked.append(a)     # S_stop: wait for a destruction
            return
        resident += nbytes[k]         # ledger reserve at load start
        peak = max(peak, resident)
        agent_pos[a] += 1
        push(now + t_load[k], "load_done", (a << 20) | k)

    def advance_inference(now: float):
        nonlocal next_inf, inf_free_at
        while next_inf < n and loaded_done[next_inf]:
            start = max(ready_at[next_inf], inf_free_at)
            inf_free_at = start + t_comp[next_inf]
            push(inf_free_at, "inf_done", next_inf)
            next_inf += 1

    for a in range(m):
        try_start_load(a, 0.0)
    advance_inference(0.0)            # pinned prefix computes immediately
    if not events and n > 0:
        return math.inf, peak         # budget below a single layer

    guard = 0
    while events and guard < 20 * n + 100:
        guard += 1
        now, _, kind, payload = heapq.heappop(events)
        if kind == "load_done":
            a, k = payload >> 20, payload & ((1 << 20) - 1)
            ready_at[k] = now
            loaded_done[k] = True
            try_start_load(a, now)    # next stripe item (may block)
            # inference agent: start any now-unblocked in-order layers
            advance_inference(now)
        else:  # inf_done -> destruction (daemon) frees bytes, wakes loaders
            k = payload
            latency = max(latency, now)
            if k >= keep:             # pinned/retained: never destroyed
                resident -= nbytes[k]
                waiting, blocked[:] = list(blocked), []
                for a in waiting:
                    try_start_load(a, now)  # re-appends itself if blocked
    if next_inf < n:
        return math.inf, peak         # could not finish (budget deadlock)
    return latency, peak


# ---------------------------------------------------------------------------
# Planner
# ---------------------------------------------------------------------------
def _as_profiles(profile) -> List[Tuple[Optional[str], Dict]]:
    """Normalise the planner input: a single Layer Profiler output, or a
    ``{dtype_label: profile}`` dict to search shard dtype jointly."""
    if isinstance(profile, dict) and "shards" not in profile:
        return list(profile.items())
    return [(profile.get("quant"), profile)]


def _better(cand, best) -> bool:
    """Feasible beats infeasible; ties break on predicted latency."""
    return best is None or (cand.feasible and not best.feasible) or (
        cand.feasible == best.feasible
        and cand.predicted_latency_s < best.predicted_latency_s)


def _gen_better(cand: "GenPlanEntry", best: Optional["GenPlanEntry"]
                ) -> bool:
    """Generation-tier comparator: feasibility, then SLO attainment,
    then latency — but a LATENCY TIE goes to the deeper pin window.  When loads overlap
    compute completely (fast disk, warm page cache) the simulator
    predicts identical round latency for every pin that hides the first
    load, yet each unpinned layer still costs a real disk read per
    decode round; the simulator's objective is blind to that traffic, so
    the tie-break is where "stream as few bytes as possible" lives.  A
    remaining tie goes to the bigger expert cache — same argument, for
    demand-loaded expert shards."""
    if best is None:
        return True
    if cand.feasible != best.feasible:
        return cand.feasible
    if cand.slo_ok != best.slo_ok:
        return cand.slo_ok
    a, b = cand.predicted_latency_s, best.predicted_latency_s
    if not (math.isfinite(a) and math.isfinite(b)):
        return a < b
    tol = 1e-6 * max(a, b, 1e-12)
    if abs(a - b) > tol:
        return a < b
    if cand.pin_window != best.pin_window:
        return cand.pin_window > best.pin_window
    if cand.expert_cache_bytes != best.expert_cache_bytes:
        return cand.expert_cache_bytes > best.expert_cache_bytes
    # same latency, same pins: prefer the schedule holding FEWER cache
    # bytes — paged reservations with prefix sharing free real headroom
    # the simulator's objective is blind to
    return cand.cache_bytes < best.cache_bytes


def plan(profile, budgets: List[Optional[int]],
         max_agents: Optional[int] = None) -> List[PlanEntry]:
    """Single-pass schedule per budget.  ``profile`` may be one Layer
    Profiler output or ``{dtype: profile}`` (candidates union over
    dtypes; the winning entry's ``dtype`` names the shard precision)."""
    profiles = _as_profiles(profile)

    entries: List[PlanEntry] = []
    for budget in budgets:
        best: Optional[PlanEntry] = None
        for label, prof in profiles:
            n = prof["num_layers"]
            lb = prof["layer_bytes"]
            other = prof["other_bytes"]
            max_m = max_agents or min(n, 12)
            # tier 1: feasible range
            feasible_ms = [m for m in range(1, max_m + 1)
                           if budget is None
                           or analytic_peak(m, lb, other) <= budget]
            if not feasible_ms:
                feasible_ms = [1]
            # tier 2: exact pre-run on the feasible range
            for m in feasible_ms:
                lat, peak = simulate(prof, m, budget)
                ok = math.isfinite(lat) and (budget is None
                                             or peak <= budget)
                cand = PlanEntry(budget, m, lat, int(peak), ok,
                                 dtype=label)
                if _better(cand, best):
                    best = cand
        entries.append(best)
    return entries


# ---------------------------------------------------------------------------
# Expert-streaming round model (expert-split MoE profiles)
# ---------------------------------------------------------------------------
def expected_unique_experts(n_experts: int, top_k: int,
                            tokens: int) -> float:
    """Expected per-layer count of DISTINCT experts a round's batch
    activates.  Exact under uniform independent routing: each token
    picks a top-k set uniformly, so P(expert untouched by one token) =
    (E-k)/E and E[unique] = E * (1 - ((E-k)/E)^T)."""
    if n_experts <= 0 or top_k <= 0 or tokens <= 0:
        return 0.0
    return n_experts * (1.0 - ((n_experts - top_k) / n_experts) ** tokens)


def expert_hit_rate_model(cache_bytes: int, expert_bytes: int,
                          n_layers: int, n_experts: int) -> float:
    """First-order LRU hit model: under near-uniform routing the chance
    a needed expert is resident ≈ the cached fraction of the L*E expert
    pool (saturating at 1 when everything fits)."""
    pool = n_layers * n_experts * expert_bytes
    if pool <= 0 or cache_bytes <= 0:
        return 0.0
    return min(1.0, cache_bytes / pool)


def _slim_profile(prof: Dict) -> Dict:
    """Copy without the per-expert shard rows (simulate only reads layer
    rows; the expert aggregates stay at the top level)."""
    out = {k: v for k, v in prof.items() if k != "shards"}
    out["shards"] = [dict(s) for s in prof["shards"]
                     if s["kind"] != "expert"]
    return out


def _moe_stream_profile(slim: Dict, *, tokens: int, cache_bytes: int,
                        m: int, batch: int, key: str) -> Dict:
    """Derive a profile whose per-layer ``key`` time includes the round's
    expected expert demand-loads: ``unique * miss_rate`` shards fetched
    on ``m`` parallel workers, on the Inference Agent's path (after the
    router).  ``simulate`` scales compute by ``batch``, and the union is
    already a whole-round quantity, so the extra is pre-divided."""
    e, k = slim["n_experts"], slim["top_k"]
    u = expected_unique_experts(e, k, tokens)
    hit = expert_hit_rate_model(cache_bytes, slim["expert_bytes"],
                                slim["num_layers"], e)
    extra = (u * (1.0 - hit) * slim["expert_t_load"]
             / max(m, 1) / max(batch, 1))
    out = copy.deepcopy(slim)
    for s in out["shards"]:
        if s["kind"] == "layer":
            s[key] = s.get(key, s["t_comp"]) + extra
    return out


def _expert_cache_grid(slim: Dict, batch: int, seq: int) -> List[int]:
    """Candidate ExpertCache sizes: the worst-case per-layer union (the
    smallest cache a round can run with — prefill may touch every expert
    of a layer at once), doublings of it, and the whole expert pool."""
    e, k = slim["n_experts"], slim["top_k"]
    eb = slim["expert_bytes"]
    total = slim["num_layers"] * e * eb
    c = min(e, max(batch * seq, 1) * k) * eb
    grid = []
    while c < total:
        grid.append(int(c))
        c *= 2
    grid.append(int(total))
    return grid


# ---------------------------------------------------------------------------
# Generation-aware planner (KV-cache decode workloads)
# ---------------------------------------------------------------------------
def _with_decode_times(profile: Dict) -> Dict:
    """Fill per-shard ``t_decode`` when the profile predates decode timing:
    one-token compute scales ~linearly down from the profiled prefill seq."""
    if all("t_decode" in s for s in profile["shards"]
           if s["kind"] == "layer"):
        return profile
    prof = copy.deepcopy(profile)
    seq = max(int(prof.get("seq", 1)), 1)
    for s in prof["shards"]:
        if s["kind"] == "layer":
            s.setdefault("t_decode", s["t_comp"] / seq)
    return prof


def plan_generate(profile, budgets: List[Optional[int]], *,
                  new_tokens: int, cache_bytes_per_layer: int,
                  max_agents: Optional[int] = None,
                  max_pin: Optional[int] = None,
                  max_inflight: int = 1,
                  page_sizes: Tuple[int, ...] = (),
                  total_len: Optional[int] = None,
                  shared_prefix_len: int = 0,
                  spec_depths: Tuple[int, ...] = (),
                  spec_draft: Optional[Dict] = None,
                  slo_ttft_s: Optional[float] = None,
                  slo_tpot_s: Optional[float] = None,
                  chunk_prefill: int = 0
                  ) -> List[GenPlanEntry]:
    """Joint (num_agents, pin_window, inflight) schedule for KV-cache
    generation and continuous-batching serving — over one profile, or
    ``{dtype: profile}`` to search shard dtype jointly (module docs).

    Total latency model: one cache-capturing prefill round (full-sequence
    compute, every layer loaded) + ``new_tokens - 1`` decode rounds
    (one-token compute, only NON-pinned layers reloaded).  Loads amortise
    over rounds exactly as the engine replays them; KV pages are extra
    resident bytes in every round.  Feasibility = finite latency and peak
    (weights + cache) within budget in BOTH round shapes.

    The batch dimension (``max_inflight > 1``) models the scheduler:
    cache bytes scale linearly with the in-flight count and per-layer
    compute scales with the stacked batch, but the weight stream does
    NOT — one round serves everyone.  The search is CAPACITY-FIRST: it
    picks the largest in-flight count the budget admits (serving as many
    concurrent users as memory allows is the primary objective; per-round
    latency barely moves with batch in the load-bound regime, so the
    largest feasible batch is also throughput-optimal), then optimises
    ``(num_agents, pin_window)`` for round latency at that count.
    Capacity-first also makes the planner MONOTONE: a larger budget never
    shrinks ``inflight``, because feasibility of a count only ever grows
    with budget.

    The **page dimension** (``page_sizes`` non-empty, needs
    ``total_len``): each candidate page size charges the paged
    scheduler's admission model instead of the dense ``r x total_len``
    reservation — ``ceil(total_len / ps)`` pages per request, of which
    the ``shared_prefix_len // ps`` full pages under the workload's
    common prompt prefix are charged ONCE across all ``r`` requests (the
    expected prefix-hit bytes), plus one page of growth headroom per
    request.  Page size 0 (always searched) is the dense reservation, so
    paging wins only where sharing/rounding actually frees bytes; the
    winning entry's ``page_size`` feeds the engine and scheduler.

    The **speculative dimension** (``spec_depths`` non-empty, needs
    ``spec_draft`` and ``page_sizes``): each candidate depth ``k`` plays
    the scheduler's draft-and-verify protocol — a pinned draft
    (``spec_draft["bytes"]`` resident, plus one
    ``spec_draft["cache_bytes"]`` dense cache row per in-flight request)
    proposes ``k`` tokens per round and one stacked verify round scores
    the whole window, so a round commits
    ``E(k, a) = (1 - a^(k+1)) / (1 - a)`` tokens in expectation at
    acceptance rate ``a = spec_draft["acceptance"]``.  The verify round's
    compute scales by the window width (the weight stream does NOT — the
    same asymmetry continuous batching exploits, amortised ``E``-fold),
    the draft's serial chain adds ``k * spec_draft["t_token"]``, and the
    KV charge grows by the window-overhang pages.  Depth 0 (always
    searched) is plain decoding, so speculation wins only where the
    acceptance rate actually buys rounds; the winning entry's
    ``spec_depth``/``draft_bytes`` feed the scheduler.

    The **SLO dimension** (``slo_ttft_s`` / ``slo_tpot_s``): every
    candidate carries a queue-free TTFT prediction (the prefill-round
    latency — or, with ``chunk_prefill > 0``, ``ceil(prompt / chunk)``
    chunk-joined decode rounds, each simulated with the chunk's tokens
    stacked onto the decode batch) and a TPOT prediction (round latency
    over expected committed tokens).  ``slo_ok`` marks candidates whose
    predictions meet both targets; the comparator prefers SLO-meeting
    schedules right after feasibility, and the capacity-first loop
    breaks only on a feasible AND SLO-meeting count — admitting fewer
    concurrent requests to protect latency targets.  When NO feasible
    candidate attains the SLO at any count, the planner falls back to
    the best feasible schedule (serve degraded rather than not at all)
    with ``slo_ok=False`` so callers can surface the miss.
    """
    profiles = [(label, _with_decode_times(p))
                for label, p in _as_profiles(profile)]
    rounds = max(new_tokens - 1, 0)
    if page_sizes and not total_len:
        raise ValueError("page_sizes search requires total_len")
    if spec_depths and spec_draft is None:
        raise ValueError("spec_depths search requires spec_draft "
                         "(draft bytes / cache_bytes / acceptance)")
    if spec_depths and not page_sizes:
        raise ValueError("spec_depths search requires page_sizes (the "
                         "verify window rides the paged KV block tables)")
    if chunk_prefill and spec_depths:
        raise ValueError("chunk_prefill is incompatible with spec_depths "
                         "(the scheduler forbids chunked prefill in "
                         "speculative mode)")
    ps_grid = [0] + [int(p) for p in page_sizes if p and p > 0]
    depth_grid = [0] + [int(d) for d in spec_depths if d and d > 0]
    chunk = max(int(chunk_prefill), 0)
    if chunk:
        # chunked prefill writes through the paged KV kernel, so the
        # dense candidate cannot serve it — the paged grid is the grid
        if len(ps_grid) < 2:
            raise ValueError("chunk_prefill requires page_sizes")
        ps_grid = ps_grid[1:]
    accept = (min(max(float(spec_draft.get("acceptance", 0.8)), 0.0), 1.0)
              if spec_draft else 0.0)
    draft_t = float(spec_draft.get("t_token", 0.0)) if spec_draft else 0.0

    def kv_bytes(n_layers: int, r: int, ps: int, depth: int = 0) -> int:
        """Total KV reservation the scheduler will charge for ``r``
        in-flight requests at page size ``ps`` (0 = dense) and verify
        depth ``depth`` (window-overhang pages + per-request window
        growth headroom)."""
        if ps == 0:
            return n_layers * cache_bytes_per_layer * r
        tok = cache_bytes_per_layer // total_len      # exact: linear in S
        pages_per_req = pages_for(total_len + depth, ps)
        shared = min(shared_prefix_len // ps, pages_per_req)
        pages = (shared + r * (pages_per_req - shared)
                 + r * pages_for(depth + 1, ps))      # + headroom
        return n_layers * tok * ps * pages

    def expected_commit(depth: int) -> float:
        """Tokens one verify round commits in expectation: accepted
        prefix + the target's bonus token."""
        if depth == 0:
            return 1.0
        if accept >= 1.0:
            return depth + 1.0
        return (1.0 - accept ** (depth + 1)) / (1.0 - accept)

    def best_at(label, prof, budget, r: int) -> Optional[GenPlanEntry]:
        """Best (m, pin[, expert cache][, page size]) candidate with
        ``r`` requests in flight."""
        n = prof["num_layers"]
        lb = prof["layer_bytes"]
        other = prof["other_bytes"]
        max_m = max_agents or min(n, 12)
        pin_cap = n if max_pin is None else min(max_pin, n)
        moe = bool(prof.get("expert_split"))
        seq = max(int(prof.get("seq", 1)), 1)
        slim = _slim_profile(prof) if moe else prof
        cache_opts = (_expert_cache_grid(slim, r, seq) if moe else [0])
        # paged serving does not support expert-split MoE (the scheduler
        # rejects the combination), so MoE profiles search dense only;
        # speculative depths need the paged verify window, so depth > 0
        # pairs only with ps > 0
        pss = [0] if moe else ps_grid
        best: Optional[GenPlanEntry] = None
        grid = [(p, c, d) for p in pss for c in cache_opts
                for d in (depth_grid if p else [0])]
        for ps, cbytes, depth in grid:
            cache_total = kv_bytes(n, r, ps, depth)
            dbytes = ((spec_draft["bytes"]
                       + r * spec_draft["cache_bytes"]) if depth else 0)
            resident = cache_total + cbytes + dbytes
            derived = {}   # (pre_prof, dec_prof) per m — pin-independent
            for pin in range(pin_cap + 1):
                # tier 1: analytic feasibility prunes the (m, pin) grid
                ms = [m for m in range(1, max_m + 1)
                      if budget is None
                      or analytic_peak(m, lb, other, cache_bytes=resident,
                                       pin_window=pin, n_layers=n)
                      <= budget]
                if not ms:
                    # keep one fallback candidate per page size: the
                    # analytic peak overestimates (simulate's in-order
                    # grants are tighter), and page sizes differ in
                    # cache bytes, so pruning all of them here would
                    # hide feasible paged schedules
                    ms = ([1] if pin == 0 and cbytes == cache_opts[0]
                          else [])
                for m in ms:
                    # tier 2: pre-run both round shapes.  The prefill
                    # round loads every layer but RETAINS the pinned
                    # prefix (the engine never destroys it), so it is
                    # pin-dependent too.  Expert-split MoE rounds fold
                    # the expected demand-load time into compute —
                    # prefill runs cold (cache_bytes=0), decode at the
                    # candidate cache's modelled hit rate.
                    if moe:
                        if m not in derived:
                            derived[m] = (
                                _moe_stream_profile(
                                    slim, tokens=r * seq, cache_bytes=0,
                                    m=m, batch=r, key="t_comp"),
                                _moe_stream_profile(
                                    slim, tokens=r, cache_bytes=cbytes,
                                    m=m, batch=r, key="t_decode"))
                        pre_prof, dec_prof = derived[m]
                    else:
                        pre_prof = dec_prof = prof
                    pre_lat, pre_peak = simulate(
                        pre_prof, m, budget, retain_window=pin,
                        extra_resident_bytes=resident, batch=r)
                    # a verify round applies each streamed layer to the
                    # whole (depth + 1)-token window — compute scales,
                    # the weight stream does not
                    dec_lat, dec_peak = simulate(
                        dec_prof, m, budget, pin_window=pin,
                        extra_resident_bytes=resident,
                        t_comp_key="t_decode", batch=r * (depth + 1))
                    exp = expected_commit(depth)
                    n_rounds = math.ceil(rounds / exp) if rounds else 0
                    round_lat = dec_lat + depth * draft_t
                    prompt_len = (max(total_len - new_tokens, 1)
                                  if total_len else seq)
                    if chunk and prompt_len > chunk and ps:
                        # chunked prefill replaces the monolithic
                        # cache-capture round with ceil(Lp/C) decode-shaped
                        # rounds, each stacking C chunk tokens onto the
                        # decode batch — the weight stream is unchanged,
                        # compute scales with the joined width
                        n_chunks = math.ceil(prompt_len / chunk)
                        ch_lat, ch_peak = simulate(
                            dec_prof, m, budget, pin_window=pin,
                            extra_resident_bytes=resident,
                            t_comp_key="t_decode", batch=r + chunk)
                        ttft = n_chunks * ch_lat
                        total = ttft + n_rounds * round_lat
                        peak = max(ch_peak, dec_peak)
                        pre_lat = ttft
                    else:
                        ttft = pre_lat
                        total = pre_lat + n_rounds * round_lat
                        peak = max(pre_peak, dec_peak)
                    tpot = (round_lat / exp
                            if (round_lat and math.isfinite(round_lat))
                            else math.inf)
                    ok = math.isfinite(total) and (budget is None
                                                   or peak <= budget)
                    slo = ((slo_ttft_s is None
                            or (math.isfinite(ttft)
                                and ttft <= slo_ttft_s))
                           and (slo_tpot_s is None
                                or (math.isfinite(tpot)
                                    and tpot <= slo_tpot_s)))
                    tput = r * exp / round_lat \
                        if (round_lat and math.isfinite(round_lat)) \
                        else 0.0
                    cand = GenPlanEntry(budget, m, pin, total, pre_lat,
                                        round_lat, int(peak), cache_total,
                                        ok, inflight=r,
                                        predicted_throughput_tps=tput,
                                        dtype=label,
                                        expert_cache_bytes=cbytes,
                                        page_size=ps,
                                        spec_depth=depth,
                                        draft_bytes=dbytes,
                                        predicted_ttft_s=ttft,
                                        predicted_tpot_s=tpot,
                                        slo_ok=slo,
                                        chunk_prefill=(
                                            chunk if ps else 0))
                    if _gen_better(cand, best):
                        best = cand
        return best

    entries: List[GenPlanEntry] = []
    for budget in budgets:
        chosen: Optional[GenPlanEntry] = None
        fallback: Optional[GenPlanEntry] = None   # best feasible, SLO-miss
        for r in range(max(max_inflight, 1), 0, -1):   # capacity-first
            # candidates union over dtype: a dtype whose shards admit
            # this in-flight count wins over one that must shed requests
            cand: Optional[GenPlanEntry] = None
            for label, prof in profiles:
                c = best_at(label, prof, budget, r)
                if c is not None and _gen_better(c, cand):
                    cand = c
            if cand is not None and cand.feasible:
                if cand.slo_ok:        # feasible AND meets the SLO: done
                    chosen = cand
                    break
                if fallback is None:   # largest feasible count, kept in
                    fallback = cand    # case no count attains the SLO
            if r == 1 and chosen is None:
                # no feasible SLO-meeting schedule at any count: serve
                # degraded (best feasible, slo_ok=False) — or report the
                # least infeasible single-request schedule
                chosen = fallback if fallback is not None else cand
        entries.append(chosen)
    return entries
