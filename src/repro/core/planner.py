"""Pipeline Planner (Hermes §IV-2).

From the Layer Profiler's output it builds a PIPELOAD execution schedule:
for each memory constraint, the number of Loading Agents that minimises
latency while the predicted peak stays within budget.

Two prediction tiers, mirroring the paper's "reasonable range, then exact
pre-run":
  1. an analytic model for the feasible range of ``m``:
        T(m) ~ t_load + ceil(N/m - 1) * max(t_load, m*t_comp) + m*t_comp
        M(m) ~ (m + c) * layer_bytes + other_bytes
  2. a discrete-event simulation of the engine (the "pre-run") that
     replays the exact agent striping, in-order inference and destruction
     to get latency and true peak memory.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass
class PlanEntry:
    budget_bytes: Optional[int]
    num_agents: int
    predicted_latency_s: float
    predicted_peak_bytes: int
    feasible: bool


# ---------------------------------------------------------------------------
# Tier 1: analytic model
# ---------------------------------------------------------------------------
def analytic_latency(n_layers: int, m: int, t_load: float,
                     t_comp: float) -> float:
    """Pipeline makespan with m parallel loaders, striped L_{i+jm}."""
    waves = math.ceil(n_layers / m)
    stage = max(t_load, m * t_comp)
    return t_load + max(waves - 1, 0) * stage + min(m, n_layers) * t_comp


def analytic_peak(m: int, layer_bytes: int, other_bytes: int,
                  inflight: int = 2) -> int:
    """~(m + c) layers resident: m loading + c awaiting destruction."""
    return (m + inflight) * layer_bytes + other_bytes


# ---------------------------------------------------------------------------
# Tier 2: discrete-event simulation (the planner's "pre-run")
# ---------------------------------------------------------------------------
def simulate(profile: Dict, m: int,
             budget_bytes: Optional[int] = None) -> Tuple[float, int]:
    """Event-driven replay of PIPELOAD.  Returns (latency_s, peak_bytes).

    Models: m loaders (each strictly sequential over its stripe, reserving
    ledger bytes at load START), one inference agent (in-order), destruction
    at compute completion, loaders blocked while resident + next > budget
    (the paper's S_stop), woken at the next destruction.
    """
    layers = [s for s in profile["shards"] if s["kind"] == "layer"]
    n = len(layers)
    t_load = [s["t_load"] for s in layers]
    t_comp = [s["t_comp"] for s in layers]
    nbytes = [s["bytes"] for s in layers]
    other = profile["other_bytes"]

    resident = other
    peak = resident
    stripes = [list(range(i, n, m)) for i in range(m)]
    agent_pos = [0] * m
    ready_at = [math.inf] * n
    loaded_done = [False] * n
    next_inf = 0
    inf_free_at = 0.0
    latency = 0.0
    blocked: List[int] = []           # agent ids blocked on the budget

    # event heap: (time, seq, kind, payload)
    seq = 0
    events: List[Tuple[float, int, str, int]] = []

    def push(t, kind, payload):
        nonlocal seq
        heapq.heappush(events, (t, seq, kind, payload))
        seq += 1

    def try_start_load(a: int, now: float):
        nonlocal resident, peak
        if agent_pos[a] >= len(stripes[a]):
            return
        k = stripes[a][agent_pos[a]]
        if budget_bytes is not None and resident + nbytes[k] > budget_bytes \
                and resident > other:
            if a not in blocked:
                blocked.append(a)     # S_stop: wait for a destruction
            return
        resident += nbytes[k]         # ledger reserve at load start
        peak = max(peak, resident)
        agent_pos[a] += 1
        push(now + t_load[k], "load_done", (a << 20) | k)

    for a in range(m):
        try_start_load(a, 0.0)
    if not events and n > 0:
        return math.inf, peak         # budget below a single layer

    guard = 0
    while events and guard < 20 * n + 100:
        guard += 1
        now, _, kind, payload = heapq.heappop(events)
        if kind == "load_done":
            a, k = payload >> 20, payload & ((1 << 20) - 1)
            ready_at[k] = now
            loaded_done[k] = True
            try_start_load(a, now)    # next stripe item (may block)
            # inference agent: start any now-unblocked in-order layers
            while next_inf < n and loaded_done[next_inf]:
                start = max(ready_at[next_inf], inf_free_at)
                inf_free_at = start + t_comp[next_inf]
                push(inf_free_at, "inf_done", next_inf)
                next_inf += 1
        else:  # inf_done -> destruction (daemon) frees bytes, wakes loaders
            k = payload
            resident -= nbytes[k]
            latency = max(latency, now)
            waiting, blocked[:] = list(blocked), []
            for a in waiting:
                try_start_load(a, now)   # re-appends itself if still blocked
    if next_inf < n:
        return math.inf, peak         # could not finish (budget deadlock)
    return latency, peak


# ---------------------------------------------------------------------------
# Planner
# ---------------------------------------------------------------------------
def plan(profile: Dict, budgets: List[Optional[int]],
         max_agents: Optional[int] = None) -> List[PlanEntry]:
    n = profile["num_layers"]
    t_load = profile["layer_t_load"]
    t_comp = profile["layer_t_comp"]
    lb = profile["layer_bytes"]
    other = profile["other_bytes"]
    max_m = max_agents or min(n, 12)

    entries: List[PlanEntry] = []
    for budget in budgets:
        best: Optional[PlanEntry] = None
        # tier 1: feasible range
        feasible_ms = [m for m in range(1, max_m + 1)
                       if budget is None
                       or analytic_peak(m, lb, other) <= budget]
        if not feasible_ms:
            feasible_ms = [1]
        # tier 2: exact pre-run on the feasible range
        for m in feasible_ms:
            lat, peak = simulate(profile, m, budget)
            ok = math.isfinite(lat) and (budget is None or peak <= budget)
            cand = PlanEntry(budget, m, lat, int(peak), ok)
            if best is None or (cand.feasible and not best.feasible) or (
                    cand.feasible == best.feasible
                    and cand.predicted_latency_s < best.predicted_latency_s):
                best = cand
        entries.append(best)
    return entries
