"""Unified telemetry: span tracing, metrics registry, trace exporters.

Every subsystem that moves bytes or makes a policy decision emits
through this module — the PrefetchRuntime's acquire→load→publish→destroy
shard lifecycle, the engine's stream rounds and per-layer compute steps,
the scheduler's admit/preempt/retire/shed decisions and chunk-prefill
jobs, ExpertStreamEngine fetches, PagePool mapping and the spec-decode
draft/verify/rollback loop.  Three pieces:

  * **Span tracer** — ``get_tracer().span("shard_load", key=k, bytes=n)``
    context managers record ``(name, thread, t_start, t_end, args)``
    tuples; ``instant()`` records point events (policy decisions) and
    ``counter()`` records sampled time series (ledger resident bytes,
    mapped KV pages).  Process-wide and thread-safe: workers, the
    destroy drainer and the Inference Agent all write the same buffer,
    and the Chrome-trace exporter lays each thread out as its own track.
  * **Metrics registry** — named counters / gauges / histograms with a
    ``snapshot()`` dict.  Always on (an increment is an int add — there
    is nothing to disable); ``RunStats``/``ServeStats`` wire their
    ``retries``/``faults_absorbed`` fields from counter deltas.
  * **Exporters** — ``export_chrome_trace`` writes Chrome trace-event
    JSON (loadable in ``chrome://tracing`` / https://ui.perfetto.dev:
    one track per worker thread, "C" counter tracks, "i" policy
    instants) and ``summary_table`` renders a plain-text metric table.

Zero-cost when disabled: the module-level tracer defaults to
``NULL_TRACER``, whose ``span()`` returns the shared ``NULL_SPAN``
singleton — no span object, no buffer append.  Hot paths (per-layer
compute, every ledger acquire/release, page allocs) additionally guard
on ``tracer.enabled`` so the disabled path builds no argument dicts at
all; per-round and per-job call sites go through the no-op singleton
unconditionally.  Span names and argument keys are platform-stable
(like ``policy_log``), so the golden structural test can pin the trace
shape while timestamps stay free.
"""
from __future__ import annotations

import json
import threading
import time
from pathlib import Path
from typing import Dict, List, Mapping, Optional, Tuple

import numpy as np

__all__ = [
    "Tracer", "NullTracer", "NULL_TRACER", "NULL_SPAN",
    "get_tracer", "enable", "disable",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "metrics",
    "counter_values", "export_chrome_trace", "summary_table",
    "Telemetry", "telemetry",
]


# ===========================================================================
# Span tracer
# ===========================================================================
class _Span:
    """Live span: records on ``__exit__`` so nested spans order by end."""

    __slots__ = ("_tracer", "_name", "_args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, args: dict):
        self._tracer = tracer
        self._name = name
        self._args = args

    def __enter__(self) -> "_Span":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self._tracer._record_span(self._name, self._t0,
                                  time.perf_counter(), self._args)


class _NullSpan:
    """The do-nothing span: one shared instance, handed out for every
    ``NULL_TRACER.span()`` call (identity-checkable — the overhead-guard
    unit test asserts disabled tracing allocates nothing)."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


NULL_SPAN = _NullSpan()


class NullTracer:
    """Disabled tracing: every method is a no-op and ``span()`` returns
    the shared ``NULL_SPAN`` singleton."""

    enabled = False

    def span(self, name: str, **args) -> _NullSpan:
        return NULL_SPAN

    def instant(self, name: str, **args) -> None:
        pass

    def counter(self, name: str, value) -> None:
        pass


NULL_TRACER = NullTracer()


class Tracer:
    """Recording tracer: thread-safe append-only buffers.

    Spans carry the recording thread's name so the exporter can lay one
    track per worker (``pipeload-worker_0``, ``pipeload-drainer``, the
    Inference Agent's ``MainThread``); counters form their own "C"
    tracks keyed by counter name.
    """

    enabled = True

    def __init__(self, t0: Optional[float] = None):
        self.t0 = time.perf_counter() if t0 is None else t0
        self._lock = threading.Lock()
        # (name, thread, t_start, t_end, args)
        self.spans: List[Tuple[str, str, float, float, dict]] = []
        # (name, thread, t, args)
        self.instants: List[Tuple[str, str, float, dict]] = []
        # (name, t, value)
        self.counters: List[Tuple[str, float, float]] = []

    def span(self, name: str, **args) -> _Span:
        return _Span(self, name, args)

    def _record_span(self, name: str, t_start: float, t_end: float,
                     args: dict) -> None:
        tname = threading.current_thread().name
        with self._lock:
            self.spans.append((name, tname, t_start, t_end, args))

    def instant(self, name: str, **args) -> None:
        tname = threading.current_thread().name
        t = time.perf_counter()
        with self._lock:
            self.instants.append((name, tname, t, args))

    def counter(self, name: str, value) -> None:
        t = time.perf_counter()
        with self._lock:
            self.counters.append((name, t, float(value)))

    def clear(self) -> None:
        with self._lock:
            self.spans.clear()
            self.instants.clear()
            self.counters.clear()


_active: object = NULL_TRACER


def get_tracer():
    """The process-wide tracer (``NULL_TRACER`` unless ``enable()``d)."""
    return _active


def enable(tracer: Optional[Tracer] = None) -> Tracer:
    """Install (and return) a recording tracer as the process-wide one."""
    global _active
    _active = tracer if tracer is not None else Tracer()
    return _active


def disable() -> None:
    """Restore the no-op singleton (recorded events are dropped with the
    old tracer unless the caller kept a reference)."""
    global _active
    _active = NULL_TRACER


# ===========================================================================
# Metrics registry
# ===========================================================================
class Counter:
    """Monotonic counter (thread-safe increment)."""

    __slots__ = ("_lock", "value")

    def __init__(self):
        self._lock = threading.Lock()
        self.value = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self.value += n

    def _reset(self) -> None:
        with self._lock:
            self.value = 0


class Gauge:
    """Sampled last-value gauge with min/max/sample-count bookkeeping.
    ``set`` is lock-free (single attribute stores under the GIL) — it
    sits on the ledger acquire/release path."""

    __slots__ = ("last", "min", "max", "n")

    def __init__(self):
        self.last = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self.n = 0

    def set(self, value) -> None:
        v = float(value)
        self.last = v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        self.n += 1

    def _reset(self) -> None:
        self.last, self.min, self.max, self.n = 0.0, float("inf"), \
            float("-inf"), 0

    def as_dict(self) -> dict:
        if not self.n:
            return {"last": 0.0, "min": 0.0, "max": 0.0, "n": 0}
        return {"last": self.last, "min": self.min, "max": self.max,
                "n": self.n}


class Histogram:
    """Value-recording histogram; snapshot reports count/mean/p50/p99/max."""

    __slots__ = ("_lock", "values")

    def __init__(self):
        self._lock = threading.Lock()
        self.values: List[float] = []

    def observe(self, value) -> None:
        with self._lock:
            self.values.append(float(value))

    def _reset(self) -> None:
        with self._lock:
            self.values.clear()

    def as_dict(self) -> dict:
        with self._lock:
            vals = list(self.values)
        if not vals:
            return {"count": 0, "mean": 0.0, "p50": 0.0, "p99": 0.0,
                    "max": 0.0}
        arr = np.asarray(vals)
        return {"count": len(vals), "mean": float(arr.mean()),
                "p50": float(np.percentile(arr, 50)),
                "p99": float(np.percentile(arr, 99)),
                "max": float(arr.max())}


class MetricsRegistry:
    """Named instruments, created on first touch.  ``reset()`` zeroes
    instruments IN PLACE, so call sites that cached a Counter/Gauge at
    construction time (the ledger, the prefetch runtime) stay wired
    across serve runs."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._hists: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter()
            return c

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge()
            return g

    def histogram(self, name: str) -> Histogram:
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                h = self._hists[name] = Histogram()
            return h

    def reset(self) -> None:
        with self._lock:
            for c in self._counters.values():
                c._reset()
            for g in self._gauges.values():
                g._reset()
            for h in self._hists.values():
                h._reset()

    def snapshot(self) -> dict:
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            hists = dict(self._hists)
        return {
            "counters": {k: c.value for k, c in sorted(counters.items())},
            "gauges": {k: g.as_dict() for k, g in sorted(gauges.items())},
            "histograms": {k: h.as_dict()
                           for k, h in sorted(hists.items())},
        }


_metrics = MetricsRegistry()


def metrics() -> MetricsRegistry:
    """The process-wide metrics registry (always on)."""
    return _metrics


def counter_values(*names: str) -> Tuple[int, ...]:
    """Current values of the named counters (delta-snapshot helper for
    RunStats/ServeStats wiring)."""
    return tuple(_metrics.counter(n).value for n in names)


# ===========================================================================
# Exporters
# ===========================================================================
def _usec(t: float, t0: float) -> float:
    return max(t - t0, 0.0) * 1e6


def export_chrome_trace(path, tracer: Optional[Tracer] = None) -> dict:
    """Write the tracer's buffers as Chrome trace-event JSON.

    Layout: pid 1, one tid per recording thread ("M" thread_name
    metadata rows name the tracks), "X" complete events for spans, "i"
    thread-scoped instants for policy decisions, and "C" counter events
    (their own implicit tracks, keyed by counter name) for the sampled
    series.  Returns the trace dict (also written to ``path``)."""
    tracer = tracer if tracer is not None else get_tracer()
    if not getattr(tracer, "enabled", False):
        raise ValueError("no active tracer: call telemetry.enable() "
                         "before the run you want to export")
    t0 = tracer.t0
    with tracer._lock:
        spans = list(tracer.spans)
        instants = list(tracer.instants)
        counters = list(tracer.counters)
    events: List[dict] = []
    tids: Dict[str, int] = {}

    def tid(tname: str) -> int:
        t = tids.get(tname)
        if t is None:
            t = tids[tname] = len(tids) + 1
            events.append({"ph": "M", "name": "thread_name", "pid": 1,
                           "tid": t, "args": {"name": tname}})
        return t

    for name, tname, ts, te, args in spans:
        events.append({"ph": "X", "cat": "span", "name": name, "pid": 1,
                       "tid": tid(tname), "ts": _usec(ts, t0),
                       "dur": max(te - ts, 0.0) * 1e6, "args": args})
    for name, tname, ts, args in instants:
        events.append({"ph": "i", "cat": "instant", "name": name,
                       "pid": 1, "tid": tid(tname), "s": "t",
                       "ts": _usec(ts, t0), "args": args})
    for name, ts, value in counters:
        events.append({"ph": "C", "cat": "counter", "name": name,
                       "pid": 1, "tid": 0, "ts": _usec(ts, t0),
                       "args": {"value": value}})
    # metadata rows first, then everything else in timestamp order —
    # Perfetto tolerates any order, but a stable layout diffs cleanly
    meta = [e for e in events if e["ph"] == "M"]
    rest = sorted((e for e in events if e["ph"] != "M"),
                  key=lambda e: (e["ts"], e["ph"], e["name"]))
    trace = {"traceEvents": meta + rest, "displayTimeUnit": "ms"}
    if path is not None:
        Path(path).write_text(json.dumps(trace, indent=1))
    return trace


def summary_table(rows: Mapping[str, object], title: str = "metrics"
                  ) -> str:
    """Render ``{name: value}`` as an aligned two-column text table."""
    if not rows:
        return f"{title}: (empty)"
    width = max(len(str(k)) for k in rows)
    lines = [f"{title}:"]
    for k, v in rows.items():
        lines.append(f"  {str(k):<{width}}  {v}")
    return "\n".join(lines)


# ===========================================================================
# Facade handle (Hermes.telemetry())
# ===========================================================================
class Telemetry:
    """Thin handle over the process-wide tracer + registry — what
    ``Hermes.telemetry()`` returns."""

    @property
    def tracer(self):
        return get_tracer()

    @property
    def metrics(self) -> MetricsRegistry:
        return metrics()

    def enable(self, tracer: Optional[Tracer] = None) -> Tracer:
        return enable(tracer)

    def disable(self) -> None:
        disable()

    def export_chrome_trace(self, path) -> dict:
        return export_chrome_trace(path)

    def snapshot(self) -> dict:
        return metrics().snapshot()

    def summary(self, title: str = "metrics") -> str:
        snap = metrics().snapshot()
        rows: Dict[str, object] = {}
        rows.update(snap["counters"])
        rows.update({k: v["last"] for k, v in snap["gauges"].items()})
        rows.update({f"{k}.p50": v["p50"]
                     for k, v in snap["histograms"].items() if v["count"]})
        return summary_table(rows, title=title)


_HANDLE = Telemetry()


def telemetry() -> Telemetry:
    return _HANDLE
