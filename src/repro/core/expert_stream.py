"""Expert-streaming PIPELOAD: routing-aware MoE shard loading (beyond-paper).

Hermes streams whole layers because a dense layer is all-or-nothing: every
weight participates in every token.  A mixture-of-experts FFN is not —
with ``top_k`` of ``n_experts`` experts active per token, only
``~top_k/n_experts`` of the FFN bytes matter for any given round (6% for
the 128-expert top-8 configs in the zoo).  This module exploits that
routing sparsity *losslessly*: the checkpoint is partitioned into
per-layer attention+router shards plus ONE SHARD PER EXPERT
(``checkpoint/partition.py`` with ``expert_split=True``), the Loading
Agents stream attention+router eagerly exactly as before, and the
experts are fetched on demand — after the router runs, the engine loads
only the union of top-k experts activated by the round's batch.

Two pieces live here:

  * ``ExpertCache`` — LRU residency of hot experts.  Routing is heavily
    reused across decode rounds (the same few experts keep winning), so
    caching fetched experts converts repeat activations into disk-free
    hits.  The cache's bytes are charged to the engine's ``_Ledger``:
    for budgeted runs the engine reserves the cache capacity up front —
    the same protocol KV pages use, because the Inference Agent is the
    thread that raises ``S_dest`` and must never park on ``S_stop``
    itself — and under admission pressure the scheduler shrinks the
    reservation (``release_headroom``), evicting LRU experts and
    releasing their ledger bytes through the same path a destroyed
    layer's bytes take, so blocked loaders and waiting requests wake.
    Unbudgeted runs charge per-expert acquire/release instead, so
    ``peak_bytes`` stays a faithful account.
  * ``ExpertStreamEngine`` — the demand-loading logic the engine's
    Inference Agent calls per MoE layer: run the jitted attention+router
    module, read the batch's top-k expert ids back to the host, fetch
    the union (cache hits skip the disk; misses load in parallel on a
    worker pool — the expert-side Loading Agents), then run the jitted
    combine module over the streamed per-expert weights.  Fetched
    expert sets are padded to power-of-two buckets so the combine
    executable compiles once per bucket, not once per union size.

Streamed-MoE outputs are bit-compatible with the in-memory
``models/moe.py`` oracle: the router, capacity-based dispatch and
combine math are the same functions, evaluated over only the experts
that received tokens (the dropped rows were all-zero anyway) — see
``core/modules.py``.
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.partition import load_shard
from repro.core import telemetry as _tele
from repro.core.prefetch import PrefetchRuntime
from repro.models.config import ModelConfig

_Key = Tuple[str, int]   # (layer shard name, expert index)


class ExpertCache:
    """LRU map of (layer, expert) -> device weights, with byte accounting.

    Pure residency bookkeeping — ledger interaction lives in
    ``ExpertStreamEngine`` so the cache itself is trivially unit-testable.
    """

    def __init__(self):
        self._entries: "OrderedDict[_Key, Tuple[dict, int]]" = OrderedDict()
        self.resident = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: _Key) -> bool:
        return key in self._entries

    def get(self, key: _Key) -> Optional[dict]:
        """Hit -> weights (entry becomes most-recently-used); miss -> None."""
        entry = self._entries.get(key)
        if entry is None:
            self.misses += 1
            return None
        self._entries.move_to_end(key)
        self.hits += 1
        return entry[0]

    def peek(self, key: _Key) -> Optional[dict]:
        """Lookup without touching the hit/miss counters or LRU order
        (the fetch path's duplicate re-check)."""
        entry = self._entries.get(key)
        return None if entry is None else entry[0]

    def put(self, key: _Key, weights: dict, nbytes: int):
        old = self._entries.get(key)
        if old is not None:
            # replacing an entry must not double-count its bytes
            self.resident -= old[1]
        self._entries[key] = (weights, int(nbytes))
        self._entries.move_to_end(key)
        self.resident += int(nbytes)

    def evict_lru(self, exclude: frozenset = frozenset()
                  ) -> Optional[Tuple[_Key, int]]:
        """Drop the least-recently-used entry not in ``exclude``;
        returns (key, freed bytes) or None if nothing is evictable."""
        for key in self._entries:
            if key not in exclude:
                _, nbytes = self._entries.pop(key)
                self.resident -= nbytes
                self.evictions += 1
                return key, nbytes
        return None


class ExpertStreamEngine:
    """Demand-loading of per-expert shards for one partitioned checkpoint.

    Owned by ``PipeloadEngine`` when the manifest says ``expert_split``;
    the engine's per-layer apply paths route MoE layers through
    ``layer`` / ``layer_cache`` / ``layer_decode`` here instead of the
    whole-layer module fns.
    """

    def __init__(self, ckpt_dir, manifest: dict, cfg: ModelConfig, fns,
                 *, workers: int = 4, cache_bytes: Optional[int] = None,
                 runtime: Optional[PrefetchRuntime] = None):
        self.dir = Path(ckpt_dir)
        self.cfg = cfg
        self.fns = fns
        by_index = {s["index"]: s["name"] for s in manifest["shards"]
                    if s["kind"] == "layer"}
        self.rows: Dict[str, Dict[int, dict]] = {}
        for s in manifest["shards"]:
            if s["kind"] != "expert":
                continue
            layer_name = by_index[s["index"]]
            self.rows.setdefault(layer_name, {})[s["expert"]] = s
        if not self.rows:
            raise ValueError(
                f"manifest at {self.dir} is tagged expert_split but holds "
                f"no kind='expert' shards")
        all_rows = [r for per in self.rows.values() for r in per.values()]
        self.total_bytes = int(sum(r["bytes"] for r in all_rows))
        self.max_expert_bytes = int(max(r["bytes"] for r in all_rows))
        # smallest cache that cannot wedge a single-token decode round:
        # one layer's top_k activated experts must be co-resident
        self.min_ws = self.working_set_bytes(1)
        self.cache = ExpertCache()
        self.reserved = 0            # the cache's byte allotment
        self._reserved_mode = False  # True = capacity charged up front
        self._ledger = None
        self._events: List = []
        self._t0 = 0.0
        self._lock = threading.Lock()
        # the expert-side Loading Agents ride the unified prefetch
        # runtime: the owning PipeloadEngine shares its pool; standalone
        # users (the profiler, unit tests) get a private one that
        # ``close()`` tears down (the old per-engine ThreadPoolExecutor
        # was never shut down and leaked its worker threads)
        self._owns_runtime = runtime is None
        self._runtime = runtime if runtime is not None else PrefetchRuntime(
            workers=max(1, workers), name="expert-loader")
        self._zero_expert = None     # padding template (per-family shapes)
        # O(1) round bookkeeping: counters + the current round's set only
        self._rounds = 0
        self._unique_total = 0
        self._round_seen: set = set()

    def close(self):
        """Join the fetch pool's worker threads (only if this engine owns
        the runtime — a shared pool belongs to the PipeloadEngine)."""
        if self._owns_runtime:
            self._runtime.close()

    def __enter__(self) -> "ExpertStreamEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def working_set_bytes(self, tokens: int) -> int:
        """Bytes of the widest single fetch a round with ``tokens`` batch
        tokens can lock: min(E, tokens * top_k) experts co-resident.
        The cache's allotment must never drop below the workload's
        working set, or a round wedges with everything locked."""
        u = min(self.cfg.n_experts, max(int(tokens), 1) * self.cfg.top_k)
        return u * self.max_expert_bytes

    # -- ledger binding ------------------------------------------------
    @property
    def bound(self) -> bool:
        return self._ledger is not None

    def bound_to(self, ledger) -> bool:
        return self._ledger is ledger

    def reserve(self, ledger, capacity: int, events, t0: float):
        """Bind this run's ledger and charge the cache's capacity to it
        (budgeted ledgers reserve up front — the KV-page protocol; see
        module docstring).  Re-binding the same ledger is a no-op, so a
        serving session reserves once."""
        if ledger is self._ledger:
            return
        capacity = int(min(capacity, self.total_bytes))
        with self._lock:
            # a tighter run than the last one: shed residency first (the
            # old ledger is gone with its run, nothing to release there)
            while self.cache.resident > capacity:
                self.cache.evict_lru()
            self._ledger = ledger
            self._events = events
            self._t0 = t0
            self._reserved_mode = ledger.budget is not None
            if self._reserved_mode:
                self.reserved = max(capacity, self.cache.resident)
                ledger.acquire(self.reserved, owner="expert_cache")
            else:
                self.reserved = capacity
                if self.cache.resident:
                    ledger.acquire(self.cache.resident, owner="expert_cache")
            events.append((time.perf_counter() - t0, "expert_reserve",
                           str(self.reserved)))

    def release_headroom(self, nbytes: int,
                         floor: Optional[int] = None) -> int:
        """Shrink the budgeted reservation by up to ``nbytes`` (never
        below ``floor`` — the caller's workload working set, defaulting
        to ``min_ws``), evicting LRU experts and releasing their ledger
        bytes — the cache-side ``S_dest`` path the scheduler's admission
        control pulls on when a queued request needs pages."""
        if not self._reserved_mode or self._ledger is None:
            return 0
        with self._lock:
            target = max(self.min_ws, floor or 0,
                         self.reserved - int(nbytes))
            if target >= self.reserved:
                return 0
            while self.cache.resident > target:
                evicted = self.cache.evict_lru()
                if evicted is None:
                    break
                self._event("expert_evict", f"{evicted[0][0]}#{evicted[0][1]}")
            target = max(target, self.cache.resident)
            freed = self.reserved - target
            self.reserved = target
        if freed:
            self._ledger.release(freed, owner="expert_cache")
        return freed

    def clear(self):
        """Drop every cached expert (releasing per-expert ledger charges
        when unreserved).  Standalone users with no byte cap — the
        profiler times layer after layer with a warm cache — call this
        between layers so residency stays one layer's union, not the
        model's whole expert pool."""
        with self._lock:
            while True:
                evicted = self.cache.evict_lru()
                if evicted is None:
                    return
                if self._ledger is not None and not self._reserved_mode:
                    self._ledger.release(evicted[1], owner="expert_cache")

    # -- round bookkeeping ---------------------------------------------
    def begin_round(self):
        self._rounds += 1
        self._round_seen = set()

    def _event(self, kind: str, payload: str):
        self._events.append((time.perf_counter() - self._t0, kind, payload))

    def snapshot(self) -> dict:
        c = self.cache
        return {"hits": c.hits, "misses": c.misses,
                "evictions": c.evictions, "rounds": self._rounds,
                "unique": self._unique_total}

    def stats_since(self, snap: dict) -> dict:
        """RunStats/ServeStats field values accumulated since ``snap``."""
        now = self.snapshot()
        rounds = max(now["rounds"] - snap["rounds"], 1)
        return {
            "expert_hits": now["hits"] - snap["hits"],
            "expert_misses": now["misses"] - snap["misses"],
            "expert_evictions": now["evictions"] - snap["evictions"],
            "expert_cache_bytes": (self.reserved if self._reserved_mode
                                   else self.cache.resident),
            "unique_experts_per_round":
                (now["unique"] - snap["unique"]) / rounds,
        }

    # -- demand loading -------------------------------------------------
    def _load_one(self, row: dict) -> dict:
        name = row["name"]
        t = time.perf_counter() - self._t0
        host = load_shard(self.dir, name)
        w = jax.tree.map(jnp.asarray, host)
        self._events.append((t, "load_start", name))
        self._event("load_end", name)
        return w

    def fetch(self, layer_name: str, ids: Sequence[int]) -> List[dict]:
        """Resolve the round's activated experts for one layer: cache
        hits skip the disk, misses stream in parallel on the worker
        pool.  Returns weight dicts aligned with ``ids``."""
        tr = _tele.get_tracer()
        rows = self.rows[layer_name]
        locked = frozenset((layer_name, int(e)) for e in ids)
        out: Dict[int, dict] = {}
        missing: List[int] = []
        with self._lock:
            for e in ids:
                w = self.cache.get((layer_name, e))
                if w is None:
                    missing.append(e)
                else:
                    out[e] = w
            if missing:
                need = sum(rows[e]["bytes"] for e in missing)
                self._make_room(need, locked)
        m = _tele.metrics()
        m.counter("expert.hits").inc(len(ids) - len(missing))
        m.counter("expert.misses").inc(len(missing))
        if missing and tr.enabled:
            with tr.span("expert_fetch", layer=layer_name,
                         misses=len(missing)):
                self._fetch_missing(layer_name, rows, missing, out)
        elif missing:
            self._fetch_missing(layer_name, rows, missing, out)
        if self._rounds:
            self._unique_total += len(locked - self._round_seen)
            self._round_seen |= locked
        return [out[int(e)] for e in ids]

    def _fetch_missing(self, layer_name: str, rows, missing: List[int],
                       out: Dict[int, dict]) -> None:
        if missing:
            futures = [(e, self._runtime.submit(self._load_one, rows[e]))
                       for e in missing]
            for e, fut in futures:
                w = fut.result()
                nbytes = rows[e]["bytes"]
                charge = (self._ledger is not None
                          and not self._reserved_mode)
                if charge:
                    # unreserved acquire never parks (no budget gate), so
                    # charging before the dup re-check below cannot wedge
                    self._ledger.acquire(nbytes, owner="expert_cache")
                with self._lock:
                    # re-check under the lock: a concurrent fetch that
                    # missed on the same (layer, expert) while we held no
                    # lock may have put it already — overwriting would
                    # strand its ledger charge (double-charge bug)
                    cached = self.cache.peek((layer_name, e))
                    duplicate = cached is not None
                    if duplicate:
                        out[e] = cached
                    else:
                        self.cache.put((layer_name, e), w, nbytes)
                        out[e] = w
                if duplicate and charge:
                    self._ledger.release(nbytes, owner="expert_cache")  # drop our copy's charge
                del w

    def _make_room(self, need: int, locked: frozenset):
        """Evict LRU entries until ``need`` more bytes fit the cache's
        allotment (``reserved`` — the up-front ledger reservation for
        budgeted runs, the engine-chosen capacity otherwise; an unbound
        engine — standalone use, e.g. the profiler — is uncapped)."""
        if self._ledger is None:
            return
        cap = self.reserved
        while self.cache.resident + need > cap:
            evicted = self.cache.evict_lru(exclude=locked)
            if evicted is None:
                locked_bytes = self.cache.resident
                raise ValueError(
                    f"expert cache too small for this round's working "
                    f"set on {next(iter(locked))[0]}: needs "
                    f"{locked_bytes + need} bytes co-resident but the "
                    f"cache reservation is {cap}; raise the budget / "
                    f"expert_cache_bytes, or let the generation-aware "
                    f"planner size the cache")
            key, nbytes = evicted
            if self._ledger is not None and not self._reserved_mode:
                self._ledger.release(nbytes, owner="expert_cache")
            self._event("expert_evict", f"{key[0]}#{key[1]}")
            _tele.metrics().counter("expert.evictions").inc()

    # -- union + padding -------------------------------------------------
    def _union(self, top_ids) -> List[int]:
        return [int(e) for e in np.unique(np.asarray(top_ids))]

    def _bucket(self, u: int) -> int:
        """Pad union sizes to powers of two (>= top_k) so the combine
        module compiles once per bucket instead of once per union size."""
        b = max(self.cfg.top_k, 1)
        while b < u:
            b *= 2
        return min(b, self.cfg.n_experts)   # the union never exceeds E

    def _gather(self, layer_name: str, ids: List[int]):
        ws = self.fetch(layer_name, ids)
        u = self._bucket(len(ids))
        if self._zero_expert is None:
            self._zero_expert = jax.tree.map(jnp.zeros_like, ws[0])
        experts = tuple(ws) + (self._zero_expert,) * (u - len(ids))
        sel = np.full((u,), -1, np.int32)
        sel[:len(ids)] = ids
        return experts, jnp.asarray(sel)

    # -- per-layer apply paths (Inference Agent steps) -------------------
    def layer(self, layer_name: str, weights, x):
        xa, hf, top_w, top_ids = self.fns["moe_router"](weights, x)
        experts, sel = self._gather(layer_name, self._union(top_ids))
        out = self.fns["moe_combine"](experts, sel, xa, hf, top_w, top_ids)
        out.block_until_ready()
        return out

    def layer_cache(self, layer_name: str, weights, x, total_len: int):
        xa, cache, hf, top_w, top_ids = self.fns["moe_router_cache"](
            weights, x, total_len)
        experts, sel = self._gather(layer_name, self._union(top_ids))
        out = self.fns["moe_combine"](experts, sel, xa, hf, top_w, top_ids)
        out.block_until_ready()
        return out, cache

    def layer_decode(self, layer_name: str, weights, x, cache, pos):
        xa, new_cache, hf, top_w, top_ids = self.fns["moe_router_decode"](
            weights, x, cache, pos)
        experts, sel = self._gather(layer_name, self._union(top_ids))
        out = self.fns["moe_combine"](experts, sel, xa, hf, top_w, top_ids)
        out.block_until_ready()
        return out, new_cache
