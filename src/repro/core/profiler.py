"""Layer Profiler (Hermes §IV-1).

Measures, per shard of a partitioned checkpoint: load time (real disk ->
host -> device), compute time (jitted forward after warmup), one-token
decode time against a KV cache (feeds the generation-aware planner) and
byte size.  The profile feeds the Pipeline Planner.

Quantized checkpoints profile exactly like full-precision ones — the
shards ARE smaller on disk and the module fns DO pay the in-jit dequant
— so ``t_load``/``t_comp``/``t_decode`` are honest per-dtype
measurements, not scaled estimates.  The profile carries the manifest's
``quant``/``dtype`` tags so the Pipeline Planner can search shard dtype
jointly with the schedule (pass one profile per quantized variant as
``{dtype: profile}``).

Expert-split MoE checkpoints additionally get per-expert byte/latency
rows: every kind-``expert`` shard lands in the profile with its manifest
bytes, ``t_load`` is measured on a per-layer sample of expert shards
(disk behaviour is uniform across a layer's experts — they are
identically-shaped files) and the median fills the rest; layer
``t_comp``/``t_decode`` are measured through the expert-streamed apply
path (router -> fetch -> combine) with a warm ExpertCache, so compute
and demand-load costs stay separable for the planner.  Top-level
aggregates (``expert_bytes``, ``expert_t_load``, ``n_experts``,
``top_k``) feed ``planner.expected_unique_experts`` and the cache-size
search.
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.partition import load_manifest, load_shard
from repro.core import telemetry as _tele
from repro.core.modules import build_module_fns
from repro.core.prefetch import PrefetchRuntime
from repro.models.config import ModelConfig


def _timed_device_load(runtime: PrefetchRuntime, ckpt_dir, name: str):
    """One disk -> host -> device shard load, timed on the shared
    prefetch runtime (the same pool the Loading Agents use, so
    ``t_load`` measures the path serving actually takes)."""
    def _load():
        with _tele.get_tracer().span("profile_load", shard=name):
            w = jax.tree.map(jnp.asarray, load_shard(ckpt_dir, name))
            jax.tree.map(lambda a: a.block_until_ready(), w)
        return w
    return runtime.timed_load(_load)


def profile_model(ckpt_dir, cfg: ModelConfig, *, batch: int = 1,
                  seq: int = 128, repeats: int = 3,
                  expert_sample: int = 4) -> Dict:
    ckpt_dir = Path(ckpt_dir)
    manifest = load_manifest(ckpt_dir)
    fns = build_module_fns(cfg)
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, seq)),
                         jnp.int32)
    expert_split = bool(manifest.get("expert_split"))
    runtime = PrefetchRuntime(workers=2, name="profiler-load")
    es = None
    if expert_split:
        from repro.core.expert_stream import ExpertStreamEngine
        es = ExpertStreamEngine(ckpt_dir, manifest, cfg, fns, workers=2,
                                runtime=runtime)
    try:
        with _tele.get_tracer().span("profile_model", model=cfg.name):
            return _profile_model(ckpt_dir, cfg, manifest, fns, tokens,
                                  runtime, es, repeats=repeats,
                                  expert_sample=expert_sample, batch=batch,
                                  seq=seq)
    finally:
        if es is not None:
            es.close()
        runtime.close()


def _profile_model(ckpt_dir, cfg: ModelConfig, manifest, fns, tokens,
                   runtime: PrefetchRuntime, es, *, repeats: int,
                   expert_sample: int, batch: int, seq: int) -> Dict:
    expert_split = bool(manifest.get("expert_split"))
    profile = {"model": cfg.name, "batch": batch, "seq": seq,
               "quant": manifest.get("quant"),
               "ckpt_dtype": manifest.get("dtype", cfg.dtype),
               "expert_split": expert_split, "shards": []}
    x = None
    expert_rows = []
    expert_t_loads = []
    for shard in manifest["shards"]:
        name, kind = shard["name"], shard["kind"]
        if kind == "expert":
            # byte figures for every expert; t_load measured on a sample
            # per layer (the shards are identically-shaped files)
            row = {"name": name, "kind": kind, "bytes": shard["bytes"],
                   "index": shard["index"], "expert": shard["expert"],
                   "dtype": shard.get("dtype")}
            if shard["expert"] < expert_sample:
                t_loads = []
                for _ in range(repeats):
                    w, dt = _timed_device_load(runtime, ckpt_dir, name)
                    t_loads.append(dt)
                row["t_load"] = float(np.median(t_loads))
                expert_t_loads.append(row["t_load"])
            expert_rows.append(row)
            profile["shards"].append(row)
            continue
        # ---- load time (disk -> device), cold-ish: re-read every repeat
        t_loads = []
        for _ in range(repeats):
            w, dt = _timed_device_load(runtime, ckpt_dir, name)
            t_loads.append(dt)
        # ---- compute time (expert-split MoE layers run the streamed
        # router -> fetch -> combine path; the warmup call loads the
        # activated experts, so the timed repeats hit the cache and
        # measure compute, with demand-load cost modelled separately)
        if kind == "embed":
            fn = lambda w_, x_: fns["embed"](w_, tokens)
            x_in = tokens
        elif kind == "layer" and es is not None:
            fn = lambda w_, x_, nm=name: es.layer(nm, w_, x_)
            x_in = x
        elif kind == "layer":
            fn = lambda w_, x_: fns["layer"](w_, x_)
            x_in = x
        else:
            fn = lambda w_, x_: fns["head"](w_, x_)
            x_in = x
        out = fn(w, x_in)
        out.block_until_ready()          # warmup/compile
        t_comps = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            out = fn(w, x_in)
            out.block_until_ready()
            t_comps.append(time.perf_counter() - t0)
        row = {
            "name": name, "kind": kind, "bytes": shard["bytes"],
            "dtype": shard.get("dtype", manifest.get("dtype", cfg.dtype)),
            "t_load": float(np.median(t_loads)),
            "t_comp": float(np.median(t_comps)),
        }
        if kind == "layer":
            # one-token decode time for the generation-aware planner:
            # single-token step against a seq-length KV cache
            if es is not None:
                _, cache = es.layer_cache(name, w, x, seq + 1)
                step = lambda nm=name, w_=w, c=cache: es.layer_decode(
                    nm, w_, x[:, -1:], c, seq)
            else:
                _, cache = fns["layer_cache"](w, x, seq + 1)
                step = lambda w_=w, c=cache: fns["layer_decode"](
                    w_, x[:, -1:], c, seq)
            step()[0].block_until_ready()                      # compile
            t_decs = []
            for _ in range(repeats):
                t0 = time.perf_counter()
                y, _ = step()
                y.block_until_ready()
                t_decs.append(time.perf_counter() - t0)
            row["t_decode"] = float(np.median(t_decs))
            if es is not None:
                # keep residency at ONE layer's expert union: the next
                # layer fetches its own experts, and an uncapped cache
                # would otherwise accumulate the model's whole expert
                # pool (the dominant bytes of an MoE) during profiling
                es.clear()
        if kind == "embed":
            x = out
        elif kind == "layer":
            x = out
        profile["shards"].append(row)

    layers = [s for s in profile["shards"] if s["kind"] == "layer"]
    profile["layer_t_load"] = float(np.median([s["t_load"] for s in layers]))
    profile["layer_t_comp"] = float(np.median([s["t_comp"] for s in layers]))
    profile["layer_t_decode"] = float(np.median([s["t_decode"]
                                                 for s in layers]))
    profile["layer_bytes"] = int(np.median([s["bytes"] for s in layers]))
    profile["other_bytes"] = int(sum(s["bytes"] for s in profile["shards"]
                                     if s["kind"] not in ("layer",
                                                          "expert")))
    profile["num_layers"] = len(layers)
    if expert_split:
        med_load = float(np.median(expert_t_loads)) if expert_t_loads \
            else 0.0
        for row in expert_rows:
            row.setdefault("t_load", med_load)
        profile["expert_bytes"] = int(np.median([r["bytes"]
                                                 for r in expert_rows]))
        profile["expert_t_load"] = med_load
        profile["n_experts"] = cfg.n_experts
        profile["top_k"] = cfg.top_k
    return profile


def save_profile(profile: Dict, path):
    Path(path).write_text(json.dumps(profile, indent=1))


def load_profile(path) -> Dict:
    return json.loads(Path(path).read_text())
