"""Layer Profiler (Hermes §IV-1).

Measures, per shard of a partitioned checkpoint: load time (real disk ->
host -> device), compute time (jitted forward after warmup), one-token
decode time against a KV cache (feeds the generation-aware planner) and
byte size.  The profile feeds the Pipeline Planner.

Quantized checkpoints profile exactly like full-precision ones — the
shards ARE smaller on disk and the module fns DO pay the in-jit dequant
— so ``t_load``/``t_comp``/``t_decode`` are honest per-dtype
measurements, not scaled estimates.  The profile carries the manifest's
``quant``/``dtype`` tags so the Pipeline Planner can search shard dtype
jointly with the schedule (pass one profile per quantized variant as
``{dtype: profile}``).
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.partition import load_manifest, load_shard
from repro.core.modules import build_module_fns
from repro.models.config import ModelConfig


def profile_model(ckpt_dir, cfg: ModelConfig, *, batch: int = 1,
                  seq: int = 128, repeats: int = 3) -> Dict:
    ckpt_dir = Path(ckpt_dir)
    manifest = load_manifest(ckpt_dir)
    fns = build_module_fns(cfg)
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, seq)),
                         jnp.int32)

    profile = {"model": cfg.name, "batch": batch, "seq": seq,
               "quant": manifest.get("quant"),
               "ckpt_dtype": manifest.get("dtype", cfg.dtype), "shards": []}
    x = None
    for shard in manifest["shards"]:
        name, kind = shard["name"], shard["kind"]
        # ---- load time (disk -> device), cold-ish: re-read every repeat
        t_loads = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            w = jax.tree.map(jnp.asarray, load_shard(ckpt_dir, name))
            jax.tree.map(lambda a: a.block_until_ready(), w)
            t_loads.append(time.perf_counter() - t0)
        # ---- compute time
        if kind == "embed":
            fn = lambda w_, x_: fns["embed"](w_, tokens)
            x_in = tokens
        elif kind == "layer":
            fn = lambda w_, x_: fns["layer"](w_, x_)
            x_in = x
        else:
            fn = lambda w_, x_: fns["head"](w_, x_)
            x_in = x
        out = fn(w, x_in)
        out.block_until_ready()          # warmup/compile
        t_comps = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            out = fn(w, x_in)
            out.block_until_ready()
            t_comps.append(time.perf_counter() - t0)
        row = {
            "name": name, "kind": kind, "bytes": shard["bytes"],
            "dtype": shard.get("dtype", manifest.get("dtype", cfg.dtype)),
            "t_load": float(np.median(t_loads)),
            "t_comp": float(np.median(t_comps)),
        }
        if kind == "layer":
            # one-token decode time for the generation-aware planner:
            # single-token step against a seq-length KV cache
            _, cache = fns["layer_cache"](w, x, seq + 1)
            step = fns["layer_decode"]
            step(w, x[:, -1:], cache, seq)[0].block_until_ready()  # compile
            t_decs = []
            for _ in range(repeats):
                t0 = time.perf_counter()
                y, _ = step(w, x[:, -1:], cache, seq)
                y.block_until_ready()
                t_decs.append(time.perf_counter() - t0)
            row["t_decode"] = float(np.median(t_decs))
        if kind == "embed":
            x = out
        elif kind == "layer":
            x = out
        profile["shards"].append(row)

    layers = [s for s in profile["shards"] if s["kind"] == "layer"]
    profile["layer_t_load"] = float(np.median([s["t_load"] for s in layers]))
    profile["layer_t_comp"] = float(np.median([s["t_comp"] for s in layers]))
    profile["layer_t_decode"] = float(np.median([s["t_decode"]
                                                 for s in layers]))
    profile["layer_bytes"] = int(np.median([s["bytes"] for s in layers]))
    profile["other_bytes"] = int(sum(s["bytes"] for s in profile["shards"]
                                     if s["kind"] != "layer"))
    profile["num_layers"] = len(layers)
    return profile


def save_profile(profile: Dict, path):
    Path(path).write_text(json.dumps(profile, indent=1))


def load_profile(path) -> Dict:
    return json.loads(Path(path).read_text())
