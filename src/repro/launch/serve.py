"""Serving launcher: PIPELOAD-backed batched inference.

    PYTHONPATH=src python -m repro.launch.serve --arch gpt2-base \
        --budget-mb 600 --requests 4 --new-tokens 8

Builds (or reuses) a layer-partitioned checkpoint, profiles it, lets the
Pipeline Planner pick the schedule for the memory budget, and serves
batched requests through the Execution Engine.  KV-cache incremental
decode is the default serving mode — the generation-aware planner picks
``(num_agents, pin_window)`` jointly with cache bytes charged against the
budget; ``--no-kv-cache`` falls back to the paper's per-token re-prefill
engine (§V-B2).
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import numpy as np

from repro.checkpoint import partition_and_save
from repro.configs import get_config
from repro.core import Hermes
from repro.models.api import build_model

CKPT_ROOT = Path("/tmp/repro_ckpts")


def ensure_checkpoint(cfg, seed: int = 0) -> Path:
    path = CKPT_ROOT / cfg.name.replace("/", "_")
    if not (path / "manifest.json").exists():
        api = build_model(cfg)
        params = api.init(jax.random.PRNGKey(seed))
        partition_and_save(params, cfg, path)
    return path


def run(arch: str, *, budget_mb: float | None = None, requests: int = 2,
        prompt_len: int = 16, new_tokens: int = 8, reduced: bool = True,
        num_agents: int | None = None, pin_window: int | None = None,
        kv_cache: bool = True):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced().with_(num_layers=8)
    ckpt = ensure_checkpoint(cfg)
    hermes = Hermes(ckpt, cfg)
    budget = int(budget_mb * 2**20) if budget_mb else None

    if kv_cache:
        g = hermes.plan_generate([budget], batch=requests,
                                 prompt_len=prompt_len,
                                 new_tokens=new_tokens)[0]
        if not g.feasible:
            raise SystemExit(
                f"error: no feasible KV-decode schedule for "
                f"budget={budget_mb}MB (best candidate predicts peak "
                f"{g.predicted_peak_bytes/2**20:.1f}MB, of which "
                f"{g.cache_bytes/2**20:.1f}MB KV cache); raise the budget, "
                f"shrink requests/prompt/new-tokens, or pass --no-kv-cache")
        agents = num_agents or g.num_agents
        pin = g.pin_window if pin_window is None else pin_window
        print(f"planner(gen): budget={budget_mb}MB -> {agents} agents, "
              f"pin={pin}, predicted {g.predicted_per_token_s*1e3:.0f}"
              f"ms/token, peak {g.predicted_peak_bytes/2**20:.0f}MB "
              f"(cache {g.cache_bytes/2**20:.1f}MB)")
    else:
        plan = hermes.plan([budget])[0]
        agents, pin = num_agents or plan.num_agents, pin_window or 0
        print(f"planner: budget={budget_mb}MB -> {agents} agents, "
              f"predicted latency {plan.predicted_latency_s*1e3:.0f}ms, "
              f"peak {plan.predicted_peak_bytes/2**20:.0f}MB")

    eng = hermes.engine(mode="pipeload", budget_bytes=budget,
                        num_agents=agents, pin_window=pin)
    eng.warmup(requests, prompt_len, decode=kv_cache,
               total_len=prompt_len + new_tokens)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (requests, prompt_len))
    t0 = time.time()
    out, stats = eng.run_generate(toks, new_tokens, kv_cache=kv_cache)
    dt = time.time() - t0
    print(f"served {requests} reqs x {new_tokens} tokens in {dt:.2f}s "
          f"({requests*new_tokens/dt:.1f} tok/s, "
          f"{stats.per_token_s*1e3:.0f}ms/token), "
          f"peak {stats.peak_bytes/2**20:.0f}MB, {stats.loads} shard loads")
    return out, stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2_base")
    ap.add_argument("--budget-mb", type=float, default=None)
    ap.add_argument("--requests", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--num-agents", type=int, default=None)
    ap.add_argument("--pin-window", type=int, default=None)
    ap.add_argument("--no-kv-cache", action="store_true",
                    help="paper's per-token re-prefill engine (§V-B2)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    run(args.arch, budget_mb=args.budget_mb, requests=args.requests,
        prompt_len=args.prompt_len, new_tokens=args.new_tokens,
        reduced=not args.full, num_agents=args.num_agents,
        pin_window=args.pin_window, kv_cache=not args.no_kv_cache)


if __name__ == "__main__":
    main()
