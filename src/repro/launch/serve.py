"""Serving launcher: continuous-batching PIPELOAD inference.

    PYTHONPATH=src python -m repro.launch.serve --arch gpt2-base \
        --budget-mb 600 --requests 8 --max-inflight 4 --new-tokens 8

Builds (or reuses) a layer-partitioned checkpoint, profiles it, lets the
generation-aware Pipeline Planner pick the ``(num_agents, pin_window,
inflight)`` triple for the memory budget, and serves the requests through
the continuous-batching scheduler (core/scheduler.py): each PIPELOAD
round streams every layer ONCE and applies it to all in-flight requests,
so aggregate tokens/s scales with concurrency while peak memory stays
within the budget.

``--arrival-rate R`` replays a Poisson arrival process (R requests per
round on average, deterministic under ``--seed`` — the seed is recorded
in ``ServeStats.seed`` so any serve-level run can be replayed exactly)
instead of an everyone-at-once burst; ``--no-kv-cache`` falls back to
the paper's sequential per-token re-prefill engine (§V-B2) for
comparison.

``--shared-prefix N`` makes every request's first N prompt tokens
identical (the shared-system-prompt trace), and ``--page-size P`` adds
the PAGED KV reservation (core/kv_pages.py) to the planner's search:
requests map fixed-size cache pages through block tables, the radix
prefix tree maps the shared prompt's pages once across the fleet, and
admission charges pages actually mapped instead of
``inflight x max_total_len`` — more concurrent users under the same
budget.  ``--no-prefix-cache`` disables the sharing (pages stay
per-request) for A/B runs.

``--quant int8|int4`` serves per-channel-quantized shards (~4x/8x fewer
bytes streamed and resident per layer — deeper pin windows and more
in-flight requests under the same budget); ``--quant auto`` profiles
every dtype and lets the planner pick shard precision jointly with
``(num_agents, pin_window, inflight)``.

``--draft-arch`` turns on SPECULATIVE serving (needs ``--page-size``): a
small draft model — pinned whole under the budget, like the pin window —
proposes ``--spec-depth`` tokens per request per round, the target
scores each request's whole window in ONE stacked verify round over the
paged KV block tables, and the accepted prefix plus the target's bonus
token commit together, so a round advances each request by up to
``depth + 1`` tokens for one weight stream.  The draft must share the
target's vocabulary; greedy outputs are token-identical to
non-speculative serving.  ``--spec-depth 0`` (the default with a draft)
lets the planner search depth jointly with the rest of the schedule.

The SERVING TIER (multi-tenant SLO mode): ``--tenants N`` replays the
seeded heavy-tailed multi-tenant trace from ``repro.data.traces`` —
Zipf tenant mix, lognormal prompt lengths, priority classes 0..2 whose
high-priority arrivals may PREEMPT the lowest-priority/youngest
in-flight request — and ``--trace FILE`` replays a saved JSON trace
verbatim (``repro.data.traces.save_trace``) for exact cross-machine
reproduction.  ``--chunk-prefill C`` (needs ``--page-size``) splits
prompts longer than C into page-aligned chunks joined into decode
rounds, so a long prompt no longer stalls every in-flight decode for a
full weight stream; outputs stay token-identical.  ``--slo-ttft-ms`` /
``--slo-tpot-ms`` feed the planner's SLO gate (capacity-first search
stops at the largest in-flight count predicted to MEET the targets)
and arm the scheduler's rounds-based SLO accounting — the summary
reports p50/p99 TTFT/TPOT and goodput-under-SLO; ``--slo-shed``
additionally rejects requests at admission once their best-case TTFT
is already blown.

MoE architectures (e.g. ``--arch qwen3_moe_30b_a3b``) are partitioned
expert-split and served through the expert-streaming subsystem
(core/expert_stream.py): attention+router shards stream eagerly, the
round's activated experts are demand-loaded after the router runs, and
the planner sizes the ExpertCache jointly with the rest of the
schedule.  The summary line reports the expert hit rate and per-round
unique-expert count.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.analysis.report import (drift_report, format_drift,
                                   format_peak_breakdown,
                                   peak_breakdown_report)
from repro.checkpoint import partition_and_save
from repro.configs import get, names
from repro.core import SLO, BatchScheduler, Hermes
from repro.core import telemetry as tele
from repro.data.traces import (load_trace, make_trace, submit_trace,
                               trace_max_len)
from repro.models.api import build_model

CKPT_ROOT = Path("/tmp/repro_ckpts")
QUANT_CHOICES = ("fp32", "int8", "int4", "auto")


def ensure_checkpoint(cfg, seed: int = 0) -> Path:
    path = CKPT_ROOT / cfg.name.replace("/", "_")
    if not (path / "manifest.json").exists():
        api = build_model(cfg)
        params = api.init(jax.random.PRNGKey(seed))
        partition_and_save(params, cfg, path)
    return path


def poisson_arrivals(n: int, rate: float | None,
                     rng: np.random.Generator) -> list[int]:
    """Arrival round per request: a Poisson process at ``rate`` requests
    per ROUND (rounds are the scheduler's clock, so the trace replays
    identically on any machine).  ``rate=None``/0 = all arrive at once."""
    if not rate:
        return [0] * n
    gaps = rng.exponential(1.0 / rate, size=n)
    return np.floor(np.cumsum(gaps)).astype(int).tolist()


def export_telemetry(trace_out: str | None, metrics_out: str | None):
    """Write the run's Chrome trace / metrics-registry snapshot, if the
    caller asked for them."""
    if trace_out:
        tele.export_chrome_trace(trace_out)
        print(f"trace: wrote {trace_out} (load it in ui.perfetto.dev "
              "or chrome://tracing)")
    if metrics_out:
        Path(metrics_out).write_text(
            json.dumps(tele.metrics().snapshot(), indent=1))
        print(f"metrics: wrote {metrics_out}")


def run(arch: str, *, budget_mb: float | None = None, requests: int = 4,
        prompt_len: int = 16, new_tokens: int = 8, reduced: bool = True,
        num_agents: int | None = None, pin_window: int | None = None,
        kv_cache: bool = True, max_inflight: int = 4,
        arrival_rate: float | None = None, seed: int = 0,
        quant: str = "fp32", page_size: int = 0,
        prefix_cache: bool = True, shared_prefix: int = 0,
        draft_arch: str | None = None, spec_depth: int = 0,
        autotune: bool = False, trace: str | None = None,
        tenants: int = 0, chunk_prefill: int = 0,
        slo_ttft_ms: float | None = None, slo_tpot_ms: float | None = None,
        slo_shed: bool = False, trace_out: str | None = None,
        metrics_out: str | None = None):
    assert quant in QUANT_CHOICES, quant
    # fresh telemetry per run: zero the registry IN PLACE (cached
    # instruments stay wired) and install a recording tracer only when a
    # timeline export was requested — tracing off costs nothing
    tele.metrics().reset()
    if trace_out:
        tele.enable()
    cfg = get(arch)
    if reduced:
        cfg = cfg.reduced().with_(num_layers=8)
    if chunk_prefill and not page_size:
        raise SystemExit("error: --chunk-prefill needs --page-size "
                         "(chunk rounds write through the paged KV "
                         "kernel)")
    if chunk_prefill and draft_arch:
        raise SystemExit("error: --chunk-prefill is incompatible with "
                         "--draft-arch (speculative rounds own the "
                         "verify window)")
    if (trace or tenants) and not kv_cache:
        raise SystemExit("error: --trace/--tenants need the KV-cache "
                         "scheduler; drop --no-kv-cache")
    ckpt = ensure_checkpoint(cfg)
    hermes = Hermes(ckpt, cfg)
    draft = None
    if draft_arch:
        if not page_size:
            raise SystemExit("error: --draft-arch needs --page-size "
                             "(the verify window rides the paged KV "
                             "block tables)")
        if not kv_cache:
            raise SystemExit("error: --draft-arch needs the KV-cache "
                             "scheduler; drop --no-kv-cache")
        dcfg = get(draft_arch)
        if reduced:
            dcfg = dcfg.reduced()       # 2 layers: a genuinely small draft
        dcfg = dcfg.with_(name=dcfg.name + "-draft")
        if dcfg.vocab_size != cfg.vocab_size:
            raise SystemExit(
                f"error: draft vocab ({dcfg.vocab_size}) must match the "
                f"target's ({cfg.vocab_size}) — proposals are target "
                f"token ids")
        from repro.core.engine import DraftModel
        draft = DraftModel(ensure_checkpoint(dcfg), dcfg)
    # fixed dtype = a one-entry search; "auto" lets the planner pick the
    # shard precision jointly with the schedule
    quants = ("fp32", "int8", "int4") if quant == "auto" else (quant,)
    budget = int(budget_mb * 2**20) if budget_mb else None
    rng = np.random.default_rng(seed)
    shared_prefix = max(0, min(shared_prefix, prompt_len))
    prompts = rng.integers(0, cfg.vocab_size, (requests, prompt_len))
    if shared_prefix:
        # shared-system-prompt trace: every request opens with the same
        # tokens (what the prefix tree maps once across the fleet)
        prompts[:, :shared_prefix] = prompts[0, :shared_prefix]
    serve_trace = None
    if trace:
        serve_trace = load_trace(trace)
    elif tenants:
        # seeded heavy-tailed multi-tenant mix: prompt_len/new_tokens
        # bound the distributions so the planned reservation still fits
        serve_trace = make_trace(
            requests, tenants=tenants, seed=seed, vocab=cfg.vocab_size,
            arrival_rate=arrival_rate or 1.0,
            prompt_mean=max(prompt_len // 2, 4), max_prompt=prompt_len,
            new_mean=max(new_tokens // 2, 1), max_new=new_tokens,
            prefix_len=shared_prefix, share_prefix=0.6)
    total_len = prompt_len + new_tokens
    if serve_trace:
        total_len = max(total_len, trace_max_len(serve_trace))

    if not kv_cache:
        # paper's engine (§V-B2): sequential re-prefill, one weight
        # stream per request per token — the baseline the scheduler beats
        plan = hermes.plan([budget], quants=quants)[0]
        hermes = hermes.quantized(plan.dtype)
        agents, pin = num_agents or plan.num_agents, pin_window or 0
        print(f"planner: budget={budget_mb}MB -> {agents} agents, "
              f"dtype={plan.dtype}, "
              f"predicted latency {plan.predicted_latency_s*1e3:.0f}ms, "
              f"peak {plan.predicted_peak_bytes/2**20:.0f}MB")
        with hermes.engine(mode="pipeload", budget_bytes=budget,
                           num_agents=agents, pin_window=pin) as eng:
            eng.warmup(requests, prompt_len)
            t0 = time.time()
            out, stats = eng.run_generate(prompts, new_tokens,
                                          kv_cache=False)
            dt = time.time() - t0
        print(f"served {requests} reqs x {new_tokens} tokens in {dt:.2f}s "
              f"({requests*new_tokens/dt:.1f} tok/s), "
              f"peak {stats.peak_bytes/2**20:.0f}MB, "
              f"{stats.loads} shard loads "
              f"({stats.streamed_bytes/2**20:.0f}MB streamed)")
        if stats.retries or stats.faults_absorbed:
            print(f"  prefetch faults: {stats.retries} retries, "
                  f"{stats.faults_absorbed} loads recovered")
        print(format_peak_breakdown(peak_breakdown_report(stats)))
        export_telemetry(trace_out, metrics_out)
        return out, stats

    spec_kw = {}
    if draft is not None:
        depths = (spec_depth,) if spec_depth else (1, 2, 4)
        total = prompt_len + new_tokens
        spec_kw = dict(
            spec_depths=tuple(d for d in depths if d and d > 0),
            spec_draft=dict(bytes=draft.total_bytes,
                            cache_bytes=draft.cache_bytes(
                                1, total + max(depths)),
                            acceptance=0.8))
    g = hermes.plan_generate([budget], prompt_len=prompt_len,
                             new_tokens=new_tokens,
                             max_inflight=max_inflight,
                             quants=quants,
                             page_sizes=(page_size,) if page_size else (),
                             # with sharing disabled every page is
                             # private — don't let the plan assume hits
                             shared_prefix_len=(shared_prefix
                                                if prefix_cache else 0),
                             slo_ttft_s=(slo_ttft_ms / 1e3
                                         if slo_ttft_ms else None),
                             slo_tpot_s=(slo_tpot_ms / 1e3
                                         if slo_tpot_ms else None),
                             chunk_prefill=chunk_prefill,
                             **spec_kw)[0]
    if not g.feasible:
        raise SystemExit(
            f"error: no feasible serving schedule for budget="
            f"{budget_mb}MB (best candidate predicts peak "
            f"{g.predicted_peak_bytes/2**20:.1f}MB, of which "
            f"{g.cache_bytes/2**20:.1f}MB KV cache at inflight="
            f"{g.inflight}); raise the budget, shrink "
            f"prompt/new-tokens, or pass --no-kv-cache")
    hermes = hermes.quantized(g.dtype)
    agents = num_agents or g.num_agents
    pin = g.pin_window if pin_window is None else pin_window
    # an explicit --spec-depth pins the verify depth; 0 defers to the
    # planner's joint pick (which may conclude speculation doesn't pay)
    depth = (spec_depth or g.spec_depth) if draft is not None else 0
    print(f"planner(serve): budget={budget_mb}MB -> {agents} agents, "
          f"pin={pin}, inflight={g.inflight}, dtype={g.dtype}, predicted "
          f"{g.predicted_throughput_tps:.1f} tok/s aggregate, peak "
          f"{g.predicted_peak_bytes/2**20:.0f}MB "
          f"(cache {g.cache_bytes/2**20:.1f}MB"
          + (f", page size {g.page_size}" if g.page_size else "")
          + (f", spec depth {depth}" if depth else "")
          + (f", chunk {chunk_prefill}" if chunk_prefill else "")
          + (f", expert cache {g.expert_cache_bytes/2**20:.1f}MB"
             if g.expert_cache_bytes else "") + ")")
    if (slo_ttft_ms or slo_tpot_ms):
        print(f"planner(slo): predicted ttft {g.predicted_ttft_s*1e3:.0f}ms"
              f" / tpot {g.predicted_tpot_s*1e3:.1f}ms -> "
              f"{'MEETS' if g.slo_ok else 'MISSES'} the target"
              + ("" if g.slo_ok else " (serving degraded: no feasible "
                 "schedule attains it)"))

    if autotune:
        # per-device kernel tiles for the planner's winning (dtype, page
        # size), seeded from this checkpoint's profile and cached to
        # disk — repeat serves skip the timing sweep
        sel = hermes.autotune(page_size=g.page_size or None,
                              quant=(g.dtype if g.dtype != "fp32"
                                     else None))
        mm = sel["matmul"]
        print(f"autotune({sel['arch']}): matmul tiles "
              f"{mm['block_m']}x{mm['block_n']}x{mm['block_k']}"
              + (f", paged impl {sel['paged_decode']['impl']}"
                 if "paged_decode" in sel else ""))
    eng = hermes.engine(mode="pipeload", budget_bytes=budget,
                        num_agents=agents, pin_window=pin,
                        expert_cache_bytes=g.expert_cache_bytes or None,
                        page_size=g.page_size or None)
    slo = None
    if slo_ttft_ms or slo_tpot_ms:
        # seconds targets -> the scheduler's deterministic rounds clock,
        # via the planned round latency (same conversion as the facade)
        rl = g.predicted_per_token_s
        if rl and rl > 0 and np.isfinite(rl):
            slo = SLO(ttft_rounds=(max(int(slo_ttft_ms / 1e3 / rl), 1)
                                   if slo_ttft_ms else None),
                      tpot_rounds=((slo_tpot_ms / 1e3 / rl)
                                   if slo_tpot_ms else None),
                      shed=slo_shed)
    sched = BatchScheduler(eng, max_inflight=g.inflight,
                           max_total_len=total_len,
                           prefix_cache=prefix_cache, seed=seed,
                           draft=(draft if depth else None),
                           spec_depth=depth,
                           chunk_prefill=(chunk_prefill
                                          if g.page_size else 0),
                           slo=slo)
    try:
        sched.warmup(prompt_lens=[prompt_len])
        if serve_trace is not None:
            submit_trace(sched, serve_trace)
        else:
            arrivals = poisson_arrivals(requests, arrival_rate, rng)
            for i in range(requests):
                sched.submit(prompts[i], new_tokens,
                             arrival_round=arrivals[i])
        t0 = time.time()
        outs, stats = sched.run()
        dt = time.time() - t0
    except BaseException:
        sched.close()
        raise
    print(f"served {stats.requests} reqs x {new_tokens} tokens in "
          f"{stats.rounds} rounds / {dt:.2f}s "
          f"({stats.tokens_per_s:.1f} tok/s aggregate), peak "
          f"{stats.peak_bytes/2**20:.0f}MB "
          f"(cache {stats.cache_bytes_peak/2**20:.1f}MB), "
          f"{stats.loads} shard loads "
          f"({stats.streamed_bytes/2**20:.0f}MB streamed), "
          f"max inflight seen {stats.max_inflight_seen}, "
          f"seed {stats.seed}")
    # end-of-run summary: ONE metrics-snapshot table (stats stays the
    # source of truth for the numbers) instead of per-subsystem prints
    rows: dict[str, object] = {
        "streamed_mb": f"{stats.streamed_bytes/2**20:.0f}",
        "ledger_peak_mb": (f"{stats.peak_bytes/2**20:.0f}"
                           + (f" / budget {budget_mb:.0f}"
                              if budget_mb else "")),
        "cache_peak_mb": f"{stats.cache_bytes_peak/2**20:.1f}",
        "shard_loads": stats.loads,
    }
    if stats.retries or stats.faults_absorbed:
        rows["prefetch_retries"] = stats.retries
        rows["faults_absorbed"] = stats.faults_absorbed
    if stats.page_size:
        rows["page_size"] = stats.page_size
        rows["page_allocs"] = (f"{stats.pages_allocated} "
                               f"({stats.page_reuses} from the free "
                               f"list, pool peak {stats.pool_pages_peak})")
        rows["prefix_hit_pages"] = stats.prefix_hit_pages
        rows["cow_copies"] = stats.cow_copies
        rows["preemptions"] = stats.preemptions
    if stats.chunk_size:
        rows["chunk_prefill"] = (f"{stats.chunk_size}-token chunks, "
                                 f"{stats.chunk_jobs} jobs joined into "
                                 "decode rounds")
    if serve_trace is not None or slo is not None:
        rows["ttft_p50_p99_rounds"] = (f"{stats.ttft_p50_rounds:.1f} / "
                                       f"{stats.ttft_p99_rounds:.1f}")
        rows["tpot_p50_p99_rounds"] = (f"{stats.tpot_p50_rounds:.2f} / "
                                       f"{stats.tpot_p99_rounds:.2f}")
        rows["slo_attained"] = f"{stats.slo_attained:.0%}"
        rows["goodput_tokens"] = (f"{stats.goodput_tokens} "
                                  f"({stats.goodput_tokens_per_s:.1f} "
                                  "tok/s)")
        rows["shed"] = stats.slo_rejections
        rows["tenants"] = stats.tenants
    if stats.spec_depth:
        rows["spec_depth"] = stats.spec_depth
        rows["spec_accepted"] = (f"{stats.accepted_tokens}/"
                                 f"{stats.draft_tokens} "
                                 f"({stats.acceptance_rate:.0%}) over "
                                 f"{stats.spec_rounds} verify rounds")
    if eng.expert is not None:
        rows["expert_hit_rate"] = (f"{stats.expert_hit_rate:.0%} "
                                   f"({stats.expert_hits} hits / "
                                   f"{stats.expert_misses} loads, "
                                   f"{stats.expert_evictions} evicted)")
        rows["experts_per_round"] = f"{stats.unique_experts_per_round:.1f}"
        rows["expert_cache_mb"] = f"{stats.expert_cache_bytes/2**20:.1f}"
    print(tele.summary_table(rows, title="serve summary"))
    print(format_peak_breakdown(peak_breakdown_report(stats)))
    print(format_drift(drift_report(g, stats)))
    for rid, req in sorted(sched.done.items()):
        tag = (f" [{req.tenant} p{req.priority}]"
               if serve_trace is not None else "")
        state = ("SHED" if req.rejected else
                 f"admitted r{req.admitted_round} finished "
                 f"r{req.finished_round}")
        print(f"  req{rid}{tag}: arrived r{req.born_round} {state}")
    sched.close()
    export_telemetry(trace_out, metrics_out)
    return outs, stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2_base", choices=names(),
                    type=lambda a: a.replace("-", "_").replace(".", "_"),
                    help="architecture id from the config registry "
                    "(dashes/dots tolerated)")
    ap.add_argument("--budget-mb", type=float, default=None)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--num-agents", type=int, default=None)
    ap.add_argument("--pin-window", type=int, default=None)
    ap.add_argument("--max-inflight", type=int, default=4,
                    help="concurrency cap; the planner may pick less "
                    "under a tight budget")
    ap.add_argument("--arrival-rate", type=float, default=None,
                    help="Poisson arrivals, requests per round "
                    "(default: all at once)")
    ap.add_argument("--seed", type=int, default=0,
                    help="RNG seed for the prompt/arrival trace; "
                    "recorded in ServeStats.seed for exact replay")
    ap.add_argument("--no-kv-cache", action="store_true",
                    help="paper's per-token re-prefill engine (§V-B2)")
    ap.add_argument("--quant", default="fp32", choices=QUANT_CHOICES,
                    help="shard precision; 'auto' = planner searches "
                    "dtype jointly with the schedule")
    ap.add_argument("--page-size", type=int, default=0,
                    help="paged KV: cache page size in tokens added to "
                    "the planner's search (0 = dense per-request "
                    "reservation)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="paged KV: disable radix-tree prompt-prefix "
                    "page sharing")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="first N prompt tokens identical across "
                    "requests (shared-system-prompt trace)")
    ap.add_argument("--draft-arch", default=None,
                    type=lambda a: a.replace("-", "_").replace(".", "_"),
                    help="speculative serving: architecture id of the "
                    "pinned draft model (needs --page-size; must share "
                    "the target's vocabulary)")
    ap.add_argument("--spec-depth", type=int, default=0,
                    help="draft tokens proposed per verify round; 0 = "
                    "let the planner pick the depth jointly")
    ap.add_argument("--autotune", action="store_true",
                    help="per-device kernel tile/impl autotune for the "
                    "planner's winning (dtype, page size), cached to "
                    "disk (kernels/autotune.py)")
    ap.add_argument("--trace", default=None,
                    help="replay a saved multi-tenant JSON trace "
                    "(repro.data.traces.save_trace) verbatim")
    ap.add_argument("--tenants", type=int, default=0,
                    help="generate a seeded heavy-tailed multi-tenant "
                    "trace with N tenants (Zipf mix, priority classes, "
                    "per-tenant prefix namespaces)")
    ap.add_argument("--chunk-prefill", type=int, default=0,
                    help="split prompts longer than C tokens into "
                    "page-aligned chunks joined into decode rounds "
                    "(needs --page-size; token-identical to monolithic "
                    "prefill)")
    ap.add_argument("--slo-ttft-ms", type=float, default=None,
                    help="time-to-first-token target fed to the "
                    "planner's SLO gate and the scheduler's rounds-based "
                    "accounting")
    ap.add_argument("--slo-tpot-ms", type=float, default=None,
                    help="time-per-output-token target (same SLO "
                    "machinery as --slo-ttft-ms)")
    ap.add_argument("--slo-shed", action="store_true",
                    help="reject requests at admission once their "
                    "best-case TTFT already busts the --slo-ttft-ms "
                    "target")
    ap.add_argument("--trace-out", default=None, metavar="FILE",
                    help="enable the span tracer and write the run as "
                    "Chrome trace-event JSON (open in ui.perfetto.dev: "
                    "one track per loader thread, ledger-bytes counter "
                    "track, scheduler policy instants)")
    ap.add_argument("--metrics-out", default=None, metavar="FILE",
                    help="write the end-of-run metrics-registry "
                    "snapshot (counters/gauges/histograms) as JSON")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    run(args.arch, budget_mb=args.budget_mb, requests=args.requests,
        prompt_len=args.prompt_len, new_tokens=args.new_tokens,
        reduced=not args.full, num_agents=args.num_agents,
        pin_window=args.pin_window, kv_cache=not args.no_kv_cache,
        max_inflight=args.max_inflight, arrival_rate=args.arrival_rate,
        seed=args.seed, quant=args.quant, page_size=args.page_size,
        prefix_cache=not args.no_prefix_cache,
        shared_prefix=args.shared_prefix,
        draft_arch=args.draft_arch, spec_depth=args.spec_depth,
        autotune=args.autotune, trace=args.trace, tenants=args.tenants,
        chunk_prefill=args.chunk_prefill, slo_ttft_ms=args.slo_ttft_ms,
        slo_tpot_ms=args.slo_tpot_ms, slo_shed=args.slo_shed,
        trace_out=args.trace_out, metrics_out=args.metrics_out)


if __name__ == "__main__":
    main()
