"""Abstract input specs + sharding specs for every (arch x shape x mesh).

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, zero allocation) for every model input of a given step kind.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.api import ModelAPI, build_model, param_pspecs
from repro.models.config import (ENCDEC, MAMBA_HYBRID, MOE, VLM, XLSTM,
                                 ModelConfig)
from repro.sharding import ShardingCtx
from repro.launch.mesh import batch_axes_of


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def make_ctx(mesh, shape: InputShape) -> ShardingCtx:
    baxes = batch_axes_of(mesh)
    nb = 1
    for a in baxes:
        nb *= mesh.shape[a]
    return ShardingCtx(mesh=mesh, batch_axes=baxes, model_axis="model",
                       shard_batch=shape.global_batch % nb == 0
                       and shape.global_batch >= nb)


# ---------------------------------------------------------------------------
# Batch input specs (ShapeDtypeStructs) per step kind
# ---------------------------------------------------------------------------
def batch_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    out: Dict[str, Any] = {}
    if cfg.family == VLM:
        s_text = s - cfg.num_patches
        assert s_text > 0
        out["patches"] = sds((b, cfg.num_patches, cfg.d_model), jnp.float32)
        out["tokens"] = sds((b, s_text), jnp.int32)
        if shape.kind == "train":
            out["labels"] = sds((b, s_text), jnp.int32)
        return out
    if cfg.family == ENCDEC:
        out["frames"] = sds((b, cfg.enc_seq_len, cfg.d_model), jnp.float32)
    out["tokens"] = sds((b, s), jnp.int32)
    if shape.kind == "train":
        out["labels"] = sds((b, s), jnp.int32)
    return out


def batch_pspecs(cfg: ModelConfig, shape: InputShape,
                 ctx: ShardingCtx) -> Dict[str, P]:
    bs = ctx.batch_spec
    names = batch_specs(cfg, shape)
    return {k: P(bs) for k in names}


# ---------------------------------------------------------------------------
# KV/state cache specs + pspecs
# ---------------------------------------------------------------------------
def cache_len(cfg: ModelConfig, shape: InputShape) -> int:
    s = shape.seq_len
    if cfg.sliding_window is not None:
        return min(s, cfg.sliding_window)
    return s


def cache_specs(api: ModelAPI, cfg: ModelConfig,
                shape: InputShape) -> Any:
    b = shape.global_batch
    s = cache_len(cfg, shape)
    return jax.eval_shape(lambda: api.empty_cache(b, s))


def cache_pspecs(cfg: ModelConfig, shape: InputShape,
                 ctx: ShardingCtx) -> Any:
    """PartitionSpec tree matching the family's cache structure."""
    bs, ax = ctx.batch_spec, ctx.model_axis
    # Only shard the cache sequence dim when every shard gets >= 1 slot.
    s = cache_len(cfg, shape)
    seq_ax = ax if s % ctx.model_size == 0 else None

    if cfg.family == XLSTM:
        m = {"c": P(None, None, bs, None, None, None),
             "n": P(None, None, bs, None, None)}
        s_ = {k: P(None, bs, None) for k in ("c", "n", "m", "h")}
        return {"mlstm": m, "slstm": s_}
    if cfg.family == MAMBA_HYBRID:
        return {
            "mamba": {"ssm": P(None, bs, None, None, None),
                      "conv": P(None, bs, None, None)},
            "attn": {"k": P(None, bs, seq_ax, None, None),
                     "v": P(None, bs, seq_ax, None, None)},
        }
    if cfg.family == ENCDEC:
        enc_seq_ax = ax if cfg.enc_seq_len % ctx.model_size == 0 else None
        kv = lambda a: {"k": P(None, bs, a, None, None),
                        "v": P(None, bs, a, None, None)}
        return {"self": kv(seq_ax), "cross": kv(enc_seq_ax)}
    if cfg.attention == "mla":
        return {"c": P(None, bs, seq_ax, None),
                "kr": P(None, bs, seq_ax, None)}
    return {"k": P(None, bs, seq_ax, None, None),
            "v": P(None, bs, seq_ax, None, None)}


def decode_ctx(cfg: ModelConfig, shape: InputShape,
               mesh) -> ShardingCtx:
    """Ctx for the decode path: flash-decode shard_map needs the cache seq
    dim sharded on the model axis; disable when it does not divide."""
    ctx = make_ctx(mesh, shape)
    return ctx


# ---------------------------------------------------------------------------
# FSDP-style storage sharding for parameters / optimizer state
# ---------------------------------------------------------------------------
def fsdp_pspecs(params_shape, mesh, base_specs) -> Any:
    """Extend ``base_specs`` by sharding the largest unsharded dim of every
    large leaf over the data axis (ZeRO-3 storage; XLA all-gathers
    just-in-time inside the layer scan)."""
    data_axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    if "data" not in data_axes:
        return base_specs
    dsize = 1
    for a in data_axes:
        dsize *= mesh.shape[a]
    fsdp_axis = tuple(data_axes) if len(data_axes) > 1 else data_axes[0]

    def f(path, leaf, spec: P):
        names = {e.key for e in path
                 if isinstance(e, jax.tree_util.DictKey)}
        if names & {"embed", "lm_head"}:
            return spec  # vocab-sharded already; fsdp'ing them only makes
                         # the token-gather resharding pathological
        if leaf.size * jnp.dtype(leaf.dtype).itemsize < 1 << 20:
            return spec  # small leaves stay as-is
        entries = list(spec) + [None] * (leaf.ndim - len(spec))
        # choose the largest dim not already sharded and divisible
        order = sorted(range(leaf.ndim), key=lambda i: -leaf.shape[i])
        for i in order:
            if entries[i] is None and leaf.shape[i] % dsize == 0:
                entries[i] = fsdp_axis
                return P(*entries)
        # fall back to 'data' only when the combined axis doesn't divide
        if len(data_axes) > 1:
            dd = mesh.shape["data"]
            for i in order:
                if entries[i] is None and leaf.shape[i] % dd == 0:
                    entries[i] = "data"
                    return P(*entries)
        return spec

    return jax.tree_util.tree_map_with_path(
        f, params_shape, base_specs)


def named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
