import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  REPRO_DRYRUN_DEVICES overrides for fast dev runs.
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
combination on the production mesh, with zero real allocation
(ShapeDtypeStruct inputs), and record memory / cost / roofline data.

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.hlo import analyze_hlo
from repro.analysis.roofline import (model_flops, params_count,
                                     roofline_terms)
from repro.configs import get_config, list_archs, long_variant
from repro.launch.mesh import (HBM_PER_CHIP, compat_make_mesh,
                               compat_set_mesh, make_production_mesh)
from repro.launch.specs import (INPUT_SHAPES, batch_pspecs, batch_specs,
                                cache_pspecs, cache_specs, make_ctx, named)
from repro.launch.stepfns import (make_prefill_step, make_serve_step,
                                  make_train_step)
from repro.models.api import build_model, param_pspecs
from repro.launch.specs import fsdp_pspecs
from repro.optim import adamw_init

RESULTS_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _with_layer_specs(ctx, cfg, pspecs):
    """Derive per-layer param specs (stacked dim dropped) for remat-friendly
    weight regathering; dense-family models only (layers subtree)."""
    import dataclasses as _dc
    layers = pspecs.get("layers") if isinstance(pspecs, dict) else None
    if layers is None:
        return ctx
    drop = jax.tree.map(
        lambda s: P(*tuple(s)[1:]) if isinstance(s, P) and len(tuple(s))
        else P(),
        layers, is_leaf=lambda x: isinstance(x, P))
    return _dc.replace(ctx, layer_param_specs=drop)

SERVE_FSDP_THRESHOLD = 6 * 1024 ** 3  # bytes/chip of model-sharded params

# Gradient-accumulation factors for the train_4k shape (global batch 256 is
# preserved; microbatches shrink activation memory to fit 16 GiB/chip —
# standard production practice, applied per architecture).
# bf16 optimizer moments for configs whose f32 moments alone bust the
# 16 GiB budget (documented tradeoff; everything else keeps f32).
BF16_MOMENT_ARCHS = {"qwen3-moe-235b-a22b"}

TRAIN_GRAD_ACCUM = {
    "zamba2-1.2b": 4,
    "xlstm-1.3b": 2,
    "qwen3-moe-235b-a22b": 8,
    "yi-34b": 2,
    "qwen2.5-32b": 2,
    "minicpm3-4b": 2,
    "qwen3-moe-30b-a3b": 2,
}


def _mesh_for(tag: str):
    n = len(jax.devices())
    if n >= 512:
        return make_production_mesh(multi_pod=(tag == "multipod"))
    # scaled-down dev meshes keep both axes >1
    if tag == "multipod":
        return compat_make_mesh((2, max(n // 8, 1), 4),
                                ("pod", "data", "model"))
    return compat_make_mesh((max(n // 4, 1), 4), ("data", "model"))


def dryrun_one(arch: str, shape_name: str, mesh_tag: str,
               verbose: bool = True) -> dict:
    shape = INPUT_SHAPES[shape_name]
    cfg = get_config(arch)
    if shape_name == "long_500k":
        cfg = long_variant(cfg)
        if cfg is None:
            return {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
                    "status": "skipped",
                    "reason": "full-attention enc-dec; see DESIGN.md"}
    api = build_model(cfg)
    mesh = _mesh_for(mesh_tag)
    n_chips = mesh.size
    ctx = make_ctx(mesh, shape)

    t0 = time.time()
    params_shape = jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0)))
    counts = params_count(cfg, params_shape)
    base_specs = param_pspecs(params_shape, mesh)

    param_bytes = sum(l.size * jnp.dtype(l.dtype).itemsize
                      for l in jax.tree.leaves(params_shape))
    msize = mesh.shape["model"]
    per_chip_model_sharded = param_bytes / msize

    bspecs = batch_specs(cfg, shape)
    b_pspecs = batch_pspecs(cfg, shape, ctx)

    with compat_set_mesh(mesh):
        if shape.kind == "train":
            pspecs = fsdp_pspecs(params_shape, mesh, base_specs)
            ctx = _with_layer_specs(ctx, cfg, pspecs)
            moment_dt = (jnp.bfloat16 if cfg.name in BF16_MOMENT_ARCHS
                         else jnp.float32)
            opt_shape = jax.eval_shape(
                lambda p: adamw_init(p, moment_dtype=moment_dt),
                params_shape)
            opt_pspecs = {"mu": pspecs, "nu": pspecs,
                          "step": P()}
            accum = TRAIN_GRAD_ACCUM.get(cfg.name, 1)
            step = make_train_step(api, ctx, grad_accum=accum)
            in_sh = (named(mesh, pspecs), named(mesh, opt_pspecs),
                     named(mesh, b_pspecs))
            out_sh = (named(mesh, pspecs), named(mesh, opt_pspecs), None)
            args = (params_shape, opt_shape, bspecs)
            jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=(0, 1))
        elif shape.kind == "prefill":
            pspecs = (fsdp_pspecs(params_shape, mesh, base_specs)
                      if per_chip_model_sharded > SERVE_FSDP_THRESHOLD
                      else base_specs)
            c_pspecs = cache_pspecs(cfg, shape, ctx)
            step = make_prefill_step(api, ctx)
            in_sh = (named(mesh, pspecs), named(mesh, b_pspecs))
            out_sh = (None, named(mesh, c_pspecs))
            args = (params_shape, bspecs)
            jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
        else:  # decode
            pspecs = (fsdp_pspecs(params_shape, mesh, base_specs)
                      if per_chip_model_sharded > SERVE_FSDP_THRESHOLD
                      else base_specs)
            c_shape = cache_specs(api, cfg, shape)
            c_pspecs = cache_pspecs(cfg, shape, ctx)
            step = make_serve_step(api, ctx)
            tok = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            in_sh = (named(mesh, pspecs),
                     NamedSharding(mesh, P(ctx.batch_spec, None)),
                     named(mesh, c_pspecs), NamedSharding(mesh, P()))
            out_sh = (None, named(mesh, c_pspecs))
            args = (params_shape, tok, c_shape, pos)
            jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=(2,))

        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    try:
        cost = compiled.cost_analysis()
        cost_flops = float(cost.get("flops", -1.0))
        cost_bytes = float(cost.get("bytes accessed", -1.0))
    except Exception:   # pragma: no cover
        cost_flops = cost_bytes = -1.0
    hlo = analyze_hlo(compiled.as_text(), n_chips)
    terms = roofline_terms(hlo, n_chips=n_chips)
    mflops = model_flops(cfg, counts, shape.kind, shape.global_batch,
                         shape.seq_len)
    mflops_per_chip = mflops / n_chips
    useful_ratio = (mflops_per_chip / hlo["dot_flops"]
                    if hlo["dot_flops"] else 0.0)

    per_chip_bytes = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                      + mem.output_size_in_bytes - mem.alias_size_in_bytes)
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_tag,
        "status": "ok",
        "n_chips": n_chips,
        "mesh_shape": dict(mesh.shape),
        "family": cfg.family,
        "param_count": counts["total"],
        "param_bytes": param_bytes,
        "fsdp": bool(pspecs is not base_specs),
        "grad_accum": (TRAIN_GRAD_ACCUM.get(cfg.name, 1)
                       if shape.kind == "train" else None),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "per_chip_bytes": per_chip_bytes,
            "fits_hbm": bool(per_chip_bytes <= HBM_PER_CHIP),
        },
        "cost_analysis": {"flops_per_device_uncorrected": cost_flops,
                          "bytes_accessed_uncorrected": cost_bytes},
        "hlo": {
            "dot_flops_per_chip": hlo["dot_flops"],
            "hbm_bytes_per_chip": hlo["hbm_bytes"],
            "collective_wire_bytes_per_chip": hlo["collective_wire_bytes"],
            "collective_count": hlo["collective_count"],
            "collectives": hlo["collectives"],
        },
        "roofline": terms,
        "model_flops_global": mflops,
        "useful_flops_ratio": useful_ratio,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
    }
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {mesh_tag}: "
              f"compile={t_compile:.1f}s "
              f"mem/chip={per_chip_bytes/2**30:.2f}GiB "
              f"fits={result['memory']['fits_hbm']} "
              f"dominant={terms['dominant']} "
              f"(c={terms['compute_s']*1e3:.2f}ms m={terms['memory_s']*1e3:.2f}ms "
              f"coll={terms['collective_s']*1e3:.2f}ms) "
              f"useful={useful_ratio:.2f}")
    return result


def save_result(res: dict, out_dir: Path = RESULTS_DIR):
    out_dir.mkdir(parents=True, exist_ok=True)
    name = f"{res['arch']}__{res['shape']}__{res['mesh']}.json"
    (out_dir / name).write_text(json.dumps(res, indent=1, default=float))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--mesh", default="pod",
                    choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default=str(RESULTS_DIR))
    args = ap.parse_args()

    out_dir = Path(args.out)
    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mesh_tag in meshes:
                fname = out_dir / f"{arch}__{shape}__{mesh_tag}.json"
                if args.skip_existing and fname.exists():
                    print(f"[dryrun] skip existing {fname.name}")
                    continue
                try:
                    res = dryrun_one(arch, shape, mesh_tag)
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    res = {"arch": arch, "shape": shape, "mesh": mesh_tag,
                           "status": "error", "error": str(e)[-2000:]}
                    failures.append((arch, shape, mesh_tag, str(e)[:200]))
                save_result(res, out_dir)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nall dry-runs OK")


if __name__ == "__main__":
    main()
