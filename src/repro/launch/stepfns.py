"""Step functions (train / prefill / serve) used by launchers and dry-run."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.api import ModelAPI
from repro.optim import adamw_init, adamw_update, cosine_lr
from repro.sharding import ShardingCtx


def make_train_step(api: ModelAPI, ctx: Optional[ShardingCtx],
                    grad_accum: int = 1):
    """Train step with optional gradient accumulation: the global batch is
    split into ``grad_accum`` microbatches scanned sequentially (f32 grad
    accumulator), shrinking peak activation memory by ~grad_accum while
    keeping the update semantics of the full batch."""

    def value_and_grads(params, batch):
        def lf(p, b):
            return api.loss(p, b, ctx)

        if grad_accum <= 1:
            return jax.value_and_grad(lf, has_aux=True)(params, batch)

        def split(x):
            b = x.shape[0]
            assert b % grad_accum == 0, (b, grad_accum)
            return x.reshape(grad_accum, b // grad_accum, *x.shape[1:])

        micro = jax.tree.map(split, batch)

        def body(acc, mb):
            (loss, metrics), grads = jax.value_and_grad(
                lf, has_aux=True)(params, mb)
            acc_g, acc_l = acc
            acc_g = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / grad_accum,
                acc_g, grads)
            return (acc_g, acc_l + loss / grad_accum), metrics

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss), metrics_stack = jax.lax.scan(
            body, (zeros, jnp.zeros((), jnp.float32)), micro)
        metrics = jax.tree.map(lambda m: m[-1], metrics_stack)
        return (loss, metrics), grads

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = value_and_grads(params, batch)
        lr = cosine_lr(opt_state["step"] + 1)   # step counts from 1
        params2, opt2 = adamw_update(grads, opt_state, params, lr=lr)
        metrics = dict(metrics)
        metrics["loss"] = loss
        metrics["lr"] = lr
        return params2, opt2, metrics

    return train_step


def make_prefill_step(api: ModelAPI, ctx: Optional[ShardingCtx]):
    def prefill_step(params, batch):
        return api.prefill(params, batch, ctx)

    return prefill_step


def make_serve_step(api: ModelAPI, ctx: Optional[ShardingCtx]):
    def serve_step(params, tokens, cache, pos):
        return api.decode(params, tokens, cache, pos, ctx)

    return serve_step
