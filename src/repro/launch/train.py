"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch yi-9b --steps 50 \
        --batch 8 --seq 256 [--reduced] [--model-axis 1]

On the production pod the same step function is what dryrun.py lowers; on
this host it runs the reduced configs end-to-end (examples/quickstart.py
drives a ~100M-param run).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.data.synthetic import batch_iterator
from repro.launch.mesh import batch_axes_of, make_host_mesh
from repro.launch.stepfns import make_train_step
from repro.models.api import build_model, param_pspecs
from repro.launch.specs import named
from repro.optim import adamw_init
from repro.sharding import ShardingCtx


def run(arch: str, *, steps: int = 20, batch: int = 8, seq: int = 256,
        reduced: bool = True, model_axis: int = 1, log_every: int = 10,
        seed: int = 0):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    api = build_model(cfg)

    ctx = None
    if model_axis > 1 and len(jax.devices()) >= model_axis:
        mesh = make_host_mesh(model=model_axis)
        ctx = ShardingCtx(mesh=mesh, batch_axes=batch_axes_of(mesh),
                          model_axis="model",
                          shard_batch=batch % mesh.shape["data"] == 0)

    params = api.init(jax.random.PRNGKey(seed))
    opt_state = adamw_init(params)
    if ctx is not None:
        shardings = named(ctx.mesh, param_pspecs(params, ctx.mesh))
        params = jax.device_put(params, shardings)

    step = jax.jit(make_train_step(api, ctx), donate_argnums=(0, 1))
    it = batch_iterator(cfg, batch, seq, seed=seed)

    losses = []
    t0 = time.time()
    for i in range(steps):
        b = next(it)
        params, opt_state, metrics = step(params, opt_state, b)
        losses.append(float(metrics["loss"]))
        if i % log_every == 0 or i == steps - 1:
            print(f"step {i:4d} loss={losses[-1]:.4f} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)", flush=True)
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--full", action="store_true",
                    help="full-size config (pod target; host will OOM)")
    ap.add_argument("--model-axis", type=int, default=1)
    args = ap.parse_args()
    losses = run(args.arch, steps=args.steps, batch=args.batch,
                 seq=args.seq, reduced=not args.full,
                 model_axis=args.model_axis)
    print(f"first loss {losses[0]:.4f} -> last loss {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
