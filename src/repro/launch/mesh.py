"""Production mesh builders.

A function (not a module-level constant) so importing this module never
touches jax device state.  Production target: TPU v5e, 256 chips per pod,
(data=16, model=16); multi-pod doubles up with a leading "pod" axis.
"""
from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)


def batch_axes_of(mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


# Hardware constants for the roofline model (TPU v5e).
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link
HBM_PER_CHIP = 16 * 1024 ** 3     # 16 GiB
