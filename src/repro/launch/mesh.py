"""Production mesh builders.

A function (not a module-level constant) so importing this module never
touches jax device state.  Production target: TPU v5e, 256 chips per pod,
(data=16, model=16); multi-pod doubles up with a leading "pod" axis.
"""
from __future__ import annotations

from typing import Tuple

import jax

try:  # jax >= 0.5 wants explicit axis types; Auto matches older behaviour
    from jax.sharding import AxisType

    def _axis_types_kw(n: int) -> dict:
        return {"axis_types": (AxisType.Auto,) * n}
except ImportError:  # pragma: no cover - jax < 0.5: Auto is the only mode
    def _axis_types_kw(n: int) -> dict:
        return {}


def compat_make_mesh(shape, axes):
    """jax.make_mesh across the AxisType API break (jax 0.4 vs >= 0.5)."""
    return jax.make_mesh(shape, axes, **_axis_types_kw(len(axes)))


def compat_set_mesh(mesh):
    """Context manager entering ``mesh``: jax.sharding.set_mesh on >= 0.5,
    the Mesh object's own context manager on 0.4."""
    set_mesh = getattr(jax.sharding, "set_mesh", None)
    return set_mesh(mesh) if set_mesh is not None else mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    model = min(model, n)
    return compat_make_mesh((n // model, model), ("data", "model"))


def batch_axes_of(mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


# Hardware constants for the roofline model (TPU v5e).
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link
HBM_PER_CHIP = 16 * 1024 ** 3     # 16 GiB
