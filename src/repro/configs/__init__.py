"""Config registry: 10 assigned architectures + the 4 Hermes paper models.

``get(name)`` validates and returns the full-size ModelConfig (clear
ValueError listing the choices for typos); ``names()`` enumerates the
registry — ``--arch <id>`` in the launchers uses it for argparse
``choices``.  ``get_config(name)`` is the unchecked deep-import
resolver.  Long-context (500k) decode
uses ``long_variant(cfg)``: sub-quadratic archs pass through unchanged,
full-attention dense archs switch to their sliding-window variant (see
DESIGN.md §Shape coverage).
"""
from __future__ import annotations

import importlib
from typing import Dict, List, Optional

from repro.models.config import ModelConfig

_ASSIGNED = [
    "minicpm3_4b",
    "qwen3_moe_235b_a22b",
    "xlstm_1_3b",
    "qwen2_5_32b",
    "yi_34b",
    "zamba2_1_2b",
    "seamless_m4t_medium",
    "qwen2_vl_2b",
    "yi_9b",
    "qwen3_moe_30b_a3b",
]
_PAPER = ["bert_large", "gpt2_base", "vit_large", "gpt_j"]


def _norm(name: str) -> str:
    return name.replace("-", "_").replace(".", "_")


def names() -> List[str]:
    """Every registered architecture id (assigned + paper models) — the
    valid ``--arch`` choices."""
    return list(_ASSIGNED) + list(_PAPER)


def get(name: str) -> ModelConfig:
    """Resolve an architecture id (dashes/dots tolerated) to its
    ModelConfig, with a readable error for typos instead of an opaque
    deep-import failure."""
    key = _norm(name)
    if key not in names():
        raise ValueError(
            f"unknown architecture '{name}'; choices: {', '.join(names())}")
    return get_config(key)


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_norm(name)}")
    return mod.CONFIG


def list_archs() -> List[str]:
    return list(_ASSIGNED)


def list_paper_models() -> List[str]:
    return list(_PAPER)


def long_variant(cfg: ModelConfig) -> Optional[ModelConfig]:
    """Config used for the long_500k decode shape, or None if skipped."""
    mod = importlib.import_module(f"repro.configs.{_norm(cfg.name)}")
    return getattr(mod, "LONG_CONFIG", cfg)
