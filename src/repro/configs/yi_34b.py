"""Yi-34B — llama-architecture dense GQA.  [arXiv:2403.04652]
60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
"""
from repro.models.config import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="yi-34b",
    family=DENSE,
    num_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    head_dim=128,
    rope_theta=5_000_000.0,
)

LONG_CONFIG = CONFIG.with_(sliding_window=8192)
