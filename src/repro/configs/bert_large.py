"""BERT-Large (Hermes paper workload, Table I).
24 encoder layers, d=1024, 16H, d_ff=4096, vocab 30522, FP32, non-causal,
classic (non-gated) MLP so per-layer bytes match the paper's ~55 MB/layer.
"""
from repro.models.config import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="bert-large",
    family=DENSE,
    num_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=30522,
    head_dim=64,
    causal=False,
    gated_mlp=False,
    dtype="float32",
)
LONG_CONFIG = None
