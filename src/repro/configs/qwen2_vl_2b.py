"""Qwen2-VL-2B — VLM decoder backbone with M-RoPE; vision frontend stubbed.
[arXiv:2409.12191]  28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.
``input_specs`` supplies precomputed patch embeddings (1024 patches).
"""
from repro.models.config import VLM, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family=VLM,
    num_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    head_dim=128,
    num_patches=1024,
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
)

LONG_CONFIG = CONFIG.with_(sliding_window=8192)
