"""Qwen3-MoE-235B-A22B — 94L MoE, 128 experts top-8.
[hf:Qwen/Qwen3-30B-A3B family]  d_model=4096 64H (GQA kv=4) expert d_ff=1536
vocab=151936.
"""
from repro.models.config import MOE, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family=MOE,
    num_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=151936,
    head_dim=128,
    n_experts=128,
    top_k=8,
    expert_d_ff=1536,
    rope_theta=1_000_000.0,
)

# long_500k: full attention is quadratic -> sliding-window variant (8192),
# per DESIGN.md shape-coverage table.
LONG_CONFIG = CONFIG.with_(sliding_window=8192)
