"""GPT-2-Base (Hermes paper workload, Table I: 355M, 24 decoder layers).
d=1024, 16H, d_ff=4096, vocab 50257, FP32, ~51 MB/layer.
"""
from repro.models.config import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="gpt2-base",
    family=DENSE,
    num_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=50257,
    head_dim=64,
    gated_mlp=False,
    dtype="float32",
)
LONG_CONFIG = None
