"""Qwen3-MoE-30B-A3B — 48L MoE, 128 experts top-8.  [hf:Qwen/Qwen3-30B-A3B]
d_model=2048 32H (GQA kv=4) expert d_ff=768 vocab=151936.
"""
from repro.models.config import MOE, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family=MOE,
    num_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=151936,
    head_dim=128,
    n_experts=128,
    top_k=8,
    expert_d_ff=768,
    rope_theta=1_000_000.0,
)

LONG_CONFIG = CONFIG.with_(sliding_window=8192)
